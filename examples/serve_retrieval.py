"""CluSD serving demo on the unified RetrievalEngine: builds the index,
trains the selector, serves batched queries through power-of-two request
buckets, and exercises the on-disk backend (LRU block cache + async
Stage-I prefetch), reporting latency percentiles, I/O ops, and hit rate.

  PYTHONPATH=src python examples/serve_retrieval.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.launch import serve as serve_mod
    sys.argv = ["serve", "--docs", "12000", "--clusters", "192",
                "--queries", "128", "--epochs", "30", "--ondisk",
                "--cache-blocks", "256"]
    return serve_mod.main()


if __name__ == "__main__":
    raise SystemExit(main())
