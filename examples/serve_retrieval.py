"""CluSD serving demo: builds the index, trains the selector, serves batched
queries with latency percentiles, and exercises the on-disk block-I/O path.

  PYTHONPATH=src python examples/serve_retrieval.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.launch import serve as serve_mod
    sys.argv = ["serve", "--docs", "12000", "--clusters", "192",
                "--queries", "128", "--epochs", "30", "--ondisk"]
    return serve_mod.main()


if __name__ == "__main__":
    raise SystemExit(main())
