"""Quickstart: CluSD end to end in ~1 minute on CPU.

Builds a synthetic corpus with correlated sparse/dense relevance, clusters
the embeddings, trains the Stage-II LSTM selector the way the paper does
(positives = clusters holding top-10 full-dense results), then answers
queries with selective fusion and compares against full retrieval.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import sparse as sparse_lib
from repro.core import train_lstm as tl
from repro.data import mrr_at, recall_at, synth_corpus, synth_queries


def main():
    cfg = get_config("clusd-msmarco", "smoke")
    print(f"corpus: {cfg.n_docs} docs, dim={cfg.dim}, N={cfg.n_clusters} "
          f"clusters (cap {cfg.cluster_cap})")
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)

    # --- train the Stage-II LSTM (paper §2.3) ---
    train_q = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, train_q.q_dense,
                                      train_q.q_terms, train_q.q_weights)
    index.lstm_params, hist = tl.train_selector(
        cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels),
        epochs=30, batch_size=32, lr=0.01)
    print(f"LSTM: BCE {hist[0]:.4f} -> {hist[-1]:.4f}")

    # --- retrieve through the unified engine pipeline ---
    # (cl.retrieve is a thin wrapper over the same call; the explicit store
    #  shows the backend protocol — swap in DiskStore/PQStore unchanged)
    from repro import engine as eng
    qs = synth_queries(9, corpus, 64)
    store = eng.InMemoryStore(index.embeddings, index.cluster_docs)
    ids, scores, diag = eng.retrieve(cfg, index, store, qs.q_dense,
                                     qs.q_terms, qs.q_weights)
    dense_ids, _ = cl.full_dense_topk(index.embeddings, qs.q_dense, 64)
    sparse_ids, _ = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, qs.q_terms, qs.q_weights, cfg.k_sparse)

    print(f"\n{'retriever':24s} {'MRR@10':>8s} {'R@64':>7s} {'%corpus':>8s}")
    print(f"{'dense only':24s} {mrr_at(dense_ids, qs.rel_doc):8.4f} "
          f"{recall_at(dense_ids, qs.rel_doc, 64):7.4f} {'100.0':>8s}")
    print(f"{'sparse only':24s} {mrr_at(sparse_ids, qs.rel_doc):8.4f} "
          f"{recall_at(sparse_ids, qs.rel_doc, 64):7.4f} {'0.0':>8s}")
    pct = 100 * float(diag['frac_docs_scanned'].mean())
    print(f"{'S + CluSD':24s} {mrr_at(np.asarray(ids), qs.rel_doc):8.4f} "
          f"{recall_at(np.asarray(ids), qs.rel_doc, 64):7.4f} {pct:8.2f}")
    print(f"\navg clusters selected: {float(diag['n_selected'].mean()):.1f} "
          f"of {cfg.n_clusters}")


if __name__ == "__main__":
    main()
