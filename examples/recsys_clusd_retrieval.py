"""CluSD as a first-class recsys feature: score one user against 100k
candidate items with the paper's cluster-selection pipeline (wide branch as
the sparse guide) vs brute force, end to end on real arrays.

  PYTHONPATH=src python examples/recsys_clusd_retrieval.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import kmeans as km
from repro.core.lstm import lstm_init
from repro.core.retrieval import (CandidateIndexSpec, brute_force_retrieval,
                                  clusd_candidate_retrieval)
from repro.data.recsys_stream import RecsysStream
from repro.models import recsys as rs


def main():
    # dlrm: the guide is a low-dim prefix dot of the item vectors — the
    # correlated cheap scorer the paper's sparse retrieval plays (wide-branch
    # guides only correlate after training; see core/retrieval.py)
    cfg = get_config("dlrm-mlperf", "smoke")
    rng = np.random.default_rng(0)
    params = rs.init_params(cfg, jax.random.key(1))
    stream = RecsysStream(cfg, seed=3)
    batch = {k: jnp.asarray(v[:1]) for k, v in stream.batch(4).items()
             if k != "label"}

    # candidate items + cluster-blocked index
    n_cand, n_clusters, cap = 100_000, 256, 512
    cand_sparse_raw = np.stack(
        [rng.integers(0, cfg.table_sizes[i], n_cand) for i in range(2)], 1)
    item_vecs = np.asarray(rs.candidate_tower(
        cfg, params, jnp.asarray(cand_sparse_raw)))
    cents, assign = km.kmeans(jax.random.key(2), jnp.asarray(item_vecs),
                              n_clusters, iters=8)
    table, _ = km.build_cluster_table(assign, n_clusters, cap,
                                      item_vecs, cents)
    blocks = np.zeros((n_clusters, cap, item_vecs.shape[1]), np.float32)
    cand_blocked = np.zeros((n_clusters * cap, 2), np.int32)
    t = np.asarray(table)
    valid = t >= 0
    blocks[valid] = item_vecs[t[valid]]
    cand_blocked[(np.nonzero(valid)[0] * cap + np.nonzero(valid)[1])] = \
        cand_sparse_raw[t[valid]]
    nb_ids, nb_sims = km.neighbor_graph(cents, 64)

    # untrained demo selector: keep all 32 stage-1 candidates (selection
    # quality with a TRAINED LSTM is exercised in tests/benchmarks); alpha
    # low because the untrained guide is only rank-correlated, not calibrated
    spec = CandidateIndexSpec(n_candidates=n_cand, n_clusters=n_clusters,
                              cap=cap, k_guide=1024, max_selected=32,
                              alpha=0.2, k_final=100)
    lstm = lstm_init(jax.random.key(3), 1 + spec.u_bins + 2 * spec.v_bins, 32)

    bf = jax.jit(lambda p, b, ib: brute_force_retrieval(cfg, p, b, ib, k=100))
    slot_valid = jnp.asarray(valid.reshape(-1))
    cs = jax.jit(lambda p, b, csp, ib, c, l, ni, ns:
                 clusd_candidate_retrieval(cfg, spec, p, b, csp, ib, c, l,
                                           ni, ns, slot_valid=slot_valid))
    ids_b, _ = bf(params, batch, jnp.asarray(blocks))
    t0 = time.perf_counter()
    ids_b, _ = bf(params, batch, jnp.asarray(blocks))
    jax.block_until_ready(ids_b)
    t_b = time.perf_counter() - t0
    ids_c, _, diag = cs(params, batch, jnp.asarray(cand_blocked),
                        jnp.asarray(blocks), cents, lstm, nb_ids, nb_sims)
    t0 = time.perf_counter()
    ids_c, _, diag = cs(params, batch, jnp.asarray(cand_blocked),
                        jnp.asarray(blocks), cents, lstm, nb_ids, nb_sims)
    jax.block_until_ready(ids_c)
    t_c = time.perf_counter() - t0

    overlap = len(set(np.asarray(ids_b).ravel()[:100].tolist())
                  & set(np.asarray(ids_c).ravel()[:100].tolist())) / 100
    print(f"brute force: {t_b*1e3:.1f} ms; CluSD-guided: {t_c*1e3:.1f} ms "
          f"(untrained selector, {int(diag['n_selected'])} clusters = "
          f"{int(diag['n_selected']) * cap} of {n_cand} items scored)")
    print(f"top-100 overlap vs brute force: {overlap:.2f}")


if __name__ == "__main__":
    main()
