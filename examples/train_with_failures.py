"""Fault-tolerant LM training demo: trains a reduced qwen2-1.5b for 60 steps
with failures injected at steps 22 and 41; the restartable driver restores
from the latest async checkpoint and finishes the run.

  PYTHONPATH=src python examples/train_with_failures.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from repro.launch import train as train_mod
    ckpt = tempfile.mkdtemp(prefix="repro_ft_")
    sys.argv = ["train", "--arch", "qwen2-1.5b", "--variant", "smoke",
                "--steps", "60", "--batch", "4", "--seq", "64",
                "--ckpt-dir", ckpt, "--ckpt-every", "10",
                "--fail-at", "22", "41"]
    return train_mod.main()


if __name__ == "__main__":
    raise SystemExit(main())
