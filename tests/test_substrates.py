"""Substrate tests: optimizer, schedules, checkpointing (incl. elastic
restore), gradient compression, data pipeline, neighbor sampler, k-means,
quantization, on-disk store."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro.checkpoint import CheckpointManager, latest_step
from repro.optim import adamw_init, adamw_update, make_schedule
from repro.optim.compression import compress_roundtrip, ef_init


def test_adamw_reduces_quadratic():
    w = {"a": jnp.asarray([5.0, -3.0]), "b": jnp.asarray([[2.0]])}
    opt = adamw_init(w)
    loss = lambda p: jnp.sum(p["a"] ** 2) + jnp.sum(p["b"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(g, opt, w, lr=0.05)
    assert float(loss(w)) < 1e-2


def test_schedules():
    s = make_schedule("cosine", 1.0, warmup_steps=10, total_steps=100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(s(100)) == pytest.approx(0.1, abs=1e-3)
    lin = make_schedule("linear", 1.0, 10, 100)
    assert float(lin(55)) == pytest.approx(0.55, abs=0.01)


def test_checkpoint_roundtrip_and_gc():
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=True)
        for step in [10, 20, 30]:
            mgr.save(step, jax.tree.map(lambda x: x + step, tree),
                     extra={"step": step})
        mgr.wait()
        assert latest_step(d) == 30
        step, restored, extra = mgr.restore_latest(tree)
        assert step == 30 and extra["step"] == 30
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]) + 30)
        # keep=2 garbage collection
        assert not os.path.exists(os.path.join(d, "step_10"))


def test_restartable_training_recovers():
    from repro.runtime.fault import FailureInjector, restartable_train
    w0 = {"w": jnp.asarray([4.0])}

    def step_fn(state, batch):
        g = 2 * state["w"]
        return {"w": state["w"] - 0.05 * g}, {"w": float(state["w"][0])}

    def batches_fn(start):
        def gen():
            while True:
                yield {}
        return gen()

    with tempfile.TemporaryDirectory() as d:
        state, history, restarts = restartable_train(
            init_state=w0, step_fn=step_fn, batches_fn=batches_fn,
            total_steps=40, ckpt_dir=d, ckpt_every=10,
            failure_injector=FailureInjector([17, 33]))
        assert restarts == 2
        assert float(state["w"][0]) < 0.1
        steps = [h["step"] for h in history]
        assert steps[-1] == 39  # completed despite two failures


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_compression_error_feedback_contracts(seed):
    """Repeated compression of a CONSTANT gradient: accumulated output
    converges to the true sum (error feedback re-injects residuals)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(64) * rng.random(), jnp.float32)
    e = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for t in range(30):
        deq, e = compress_roundtrip(g, e)
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc) / 30, np.asarray(g),
                               atol=2e-2 * float(jnp.max(jnp.abs(g)) + 1e-6))


def test_neighbor_sampler_valid_edges():
    from repro.data.sampler import CSRGraph, sample_fanout, padded_batch
    rng = np.random.default_rng(0)
    N, E = 500, 4000
    src = rng.integers(0, N, E)
    dst = rng.integers(0, N, E)
    g = CSRGraph.from_edges(src, dst, N)
    edge_set = set(zip(src.tolist(), dst.tolist()))
    seeds = rng.choice(N, 32, replace=False)
    nodes, esrc, edst = sample_fanout(g, seeds, (5, 3),
                                      np.random.default_rng(1))
    assert len(esrc) <= 32 * 5 + 32 * 5 * 3
    for s, t in zip(esrc, edst):
        # sampled message edge (neighbor -> center) reverses a graph edge
        assert (int(nodes[t]), int(nodes[s])) in edge_set
    feats = rng.standard_normal((N, 8)).astype(np.float32)
    # same sampling seed -> identical subgraph in the padded batch
    batch = padded_batch(g, feats, seeds, (5, 3), np.random.default_rng(1),
                         max_nodes=1024, max_edges=1024,
                         targets=rng.standard_normal(N).astype(np.float32))
    assert batch["node_feat"].shape == (1024, 8)
    assert batch["edge_mask"].sum() == len(esrc)


def test_kmeans_and_balanced_table():
    from repro.core import kmeans as km
    rng = np.random.default_rng(1)
    X = jnp.asarray(np.concatenate([
        rng.standard_normal((100, 8)) + 4,
        rng.standard_normal((100, 8)) - 4]), jnp.float32)
    c, a = km.kmeans(jax.random.key(0), X, 2, iters=10)
    a = np.asarray(a)
    # the two blobs must separate
    assert len(set(a[:100])) == 1 and len(set(a[100:])) == 1
    assert a[0] != a[150]
    table, doc_cluster = km.build_cluster_table(a, 2, cap=128, X=X,
                                                centroids=c)
    t = np.asarray(table)
    assert ((t >= 0).sum(axis=1) == 100).all()
    # every doc appears exactly once
    docs = t[t >= 0]
    assert sorted(docs.tolist()) == list(range(200))


def test_pq_quantization_quality():
    from repro.core import quant as qt
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.standard_normal((1024, 32)), jnp.float32)
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True)
    pq = qt.train_pq(jax.random.key(1), X, nsub=8, iters=8)
    rec = qt.reconstruct(pq, jnp.arange(1024))
    err = float(jnp.mean(jnp.sum((rec - X) ** 2, -1)))
    assert err < 0.5  # << ||x||^2 = 1
    # ADC score approximates exact dot
    q = X[:4]
    lut = qt.adc_tables(pq, q)
    approx = qt.adc_score(pq, lut, jnp.tile(jnp.arange(100)[None], (4, 1)))
    exact = q @ X[:100].T
    corr = np.corrcoef(np.asarray(approx).ravel(),
                       np.asarray(exact).ravel())[0, 1]
    assert corr > 0.9


def test_disk_store_block_semantics():
    from repro.core import disk as dk
    rng = np.random.default_rng(3)
    emb = rng.standard_normal((256, 16)).astype(np.float32)
    cd = np.arange(256, dtype=np.int32).reshape(32, 8)
    with tempfile.TemporaryDirectory() as d:
        store = dk.DiskClusterStore(os.path.join(d, "b.bin"), emb, cd)
        stats = dk.IOStats()
        out = store.fetch_clusters([3, 7], stats)
        assert stats.n_ops == 2
        assert stats.bytes == 2 * store.block_bytes
        np.testing.assert_array_equal(np.asarray(out[0]), emb[cd[3]])
        assert stats.model_ms() > 0


def test_recsys_stream_learnable():
    from repro.configs import get_config
    from repro.data.recsys_stream import RecsysStream
    cfg = get_config("deepfm", "smoke")
    s = RecsysStream(cfg, seed=0)
    b = s.batch(512)
    assert b["sparse"].shape == (512, len(cfg.table_sizes))
    assert 0.05 < b["label"].mean() < 0.95
    for i, rows in enumerate(cfg.table_sizes):
        assert b["sparse"][:, i].max() < rows
