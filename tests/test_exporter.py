"""MetricsExporter endpoint tests against a jax-free dummy target:
route payloads (/metrics Prometheus text, /metrics.json snapshot, /slo,
/healthz), target.stats() sync before export, concurrent scrape
consistency during live metric mutation, and /healthz flipping 503 on
an SLO page or a shard losing every replica — then recovering. The
router-backed equivalents live in tests/test_router.py; this file keeps
the HTTP surface testable without building an index."""

import json
import threading
import urllib.error
import urllib.request

from repro.obs import (
    MetricsExporter, MetricsRegistry, SLOMonitor, SLOObjective)


class DummyTarget:
    """Duck-typed serving target: registry + optional stats()/
    missing_shards(), mirroring RetrievalEngine / ShardRouter."""

    def __init__(self):
        self.metrics = MetricsRegistry()
        self.stats_calls = 0
        self.lost = []

    def stats(self):
        self.stats_calls += 1
        self.metrics.gauge("dummy.synced").set(self.stats_calls)
        return {}

    def missing_shards(self):
        return list(self.lost)


def _get(port, path):
    url = f"http://127.0.0.1:{port}{path}"
    try:
        with urllib.request.urlopen(url, timeout=5) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_routes_and_stats_sync():
    t = DummyTarget()
    t.metrics.counter("reqs.total").inc(7)
    t.metrics.histogram("lat.ms").observe(3.0)
    with MetricsExporter(t, port=0) as exp:
        assert exp.port > 0                     # ephemeral port resolved
        code, text = _get(exp.port, "/metrics")
        assert code == 200
        assert "reqs_total 7" in text           # dots -> underscores
        assert t.stats_calls == 1               # stats() synced pre-export

        code, body = _get(exp.port, "/metrics.json")
        snap = json.loads(body)
        assert code == 200
        assert snap["counters"]["reqs.total"] == 7
        assert snap["gauges"]["dummy.synced"] == 2

        code, body = _get(exp.port, "/slo")
        assert code == 200
        assert json.loads(body) == {"state": "disabled"}

        code, body = _get(exp.port, "/healthz")
        assert code == 200 and json.loads(body)["ok"] is True

        code, body = _get(exp.port, "/nope")
        assert code == 404


def test_concurrent_scrapes_during_mutation():
    t = DummyTarget()
    c = t.metrics.counter("reqs.total")
    stop = threading.Event()

    def mutate():
        while not stop.is_set():
            c.inc()

    results = []

    def scrape(port):
        for _ in range(20):
            code, body = _get(port, "/metrics.json")
            results.append((code, json.loads(body)["counters"]
                            .get("reqs.total", 0)))

    with MetricsExporter(t, port=0) as exp:
        w = threading.Thread(target=mutate)
        w.start()
        scrapers = [threading.Thread(target=scrape, args=(exp.port,))
                    for _ in range(4)]
        for s in scrapers:
            s.start()
        for s in scrapers:
            s.join()
        stop.set()
        w.join()
    assert all(code == 200 for code, _ in results)
    vals = [v for _, v in results]
    assert all(isinstance(v, (int, float)) and v >= 0 for v in vals)
    # scrapes observed a consistent, monotone-ish counter (never negative,
    # final value at least the max any scrape saw)
    assert c.value >= max(vals)


def test_healthz_slo_page_and_shard_loss():
    t = DummyTarget()
    clock = [0.0]
    obj = SLOObjective(name="g", kind="gauge", metric="v", threshold=1.0,
                       fast_window_s=10.0, slow_window_s=30.0,
                       warn_burn=1.0, page_burn=1.0)
    slo = SLOMonitor(t.metrics, [obj], clock=lambda: clock[0])
    with MetricsExporter(t, port=0, slo=slo) as exp:
        code, body = _get(exp.port, "/healthz")
        assert code == 200

        t.metrics.gauge("v").set(5.0)           # burn 5.0 -> PAGE
        clock[0] = 1.0
        code, body = _get(exp.port, "/healthz")
        assert code == 503
        assert "slo_page" in json.loads(body)["reasons"]

        # recovery: the bad sample rolls out of both windows
        t.metrics.gauge("v").set(0.0)
        clock[0] = 100.0
        code, body = _get(exp.port, "/healthz")
        assert code == 200

        # replica loss is a health reason independent of the SLO
        t.lost = [2]
        code, body = _get(exp.port, "/healthz")
        reasons = json.loads(body)["reasons"]
        assert code == 503 and "shards_without_replicas:[2]" in reasons
        t.lost = []
        code, _ = _get(exp.port, "/healthz")
        assert code == 200

        # /slo reflects the monitor
        code, body = _get(exp.port, "/slo")
        assert code == 200 and json.loads(body)["state"] == "OK"


def test_scrape_error_is_500_not_crash():
    class Broken:
        @property
        def metrics(self):
            raise RuntimeError("boom")

    with MetricsExporter(Broken(), port=0) as exp:
        code, body = _get(exp.port, "/metrics")
        assert code == 500 and "boom" in body
        # server survives a failing scrape
        code, _ = _get(exp.port, "/healthz")
        assert code == 200
