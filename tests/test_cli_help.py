"""Every repro.launch CLI must answer `--help` with exit code 0, and the
build/serve help text must be the single source of truth for the flags it
documents (the PR-3 flags drifted out of the old epilogs once — this
pins them)."""

import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

CLIS = ["repro.launch.build_index", "repro.launch.serve",
        "repro.launch.update_index", "repro.launch.train",
        "repro.launch.train_selector", "repro.launch.dryrun"]


def _help_output(module):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", module, "--help"],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT, timeout=300)
    assert proc.returncode == 0, \
        f"{module} --help exited {proc.returncode}:\n{proc.stderr[-2000:]}"
    return proc.stdout


@pytest.mark.parametrize("module", CLIS)
def test_cli_help_exits_zero(module):
    out = _help_output(module)
    assert "usage:" in out


def test_build_index_help_documents_current_flags():
    out = _help_output("repro.launch.build_index")
    for flag in ("--format-version", "--memmap", "--chunk-docs", "--pq-nsub",
                 "--shards", "--kmeans-iters"):
        assert flag in out, f"build_index --help no longer documents {flag}"


def test_serve_help_documents_current_flags():
    out = _help_output("repro.launch.serve")
    for flag in ("--index-dir", "--verify", "--check-parity",
                 "--parity-mrr-tol", "--cache-blocks", "--no-prefetch",
                 "--trace-out", "--trace-sample-rate", "--metrics-out",
                 "--fusion", "--expand-depth", "--hosts", "--replication",
                 "--host-timeout-ms", "--kill-host",
                 "--metrics-port", "--slo-config", "--explain-out",
                 "--explain-sample-rate", "--serve-seconds"):
        assert flag in out, f"serve --help no longer documents {flag}"


def test_soak_help_documents_current_flags():
    out = _help_output("benchmarks.soak")
    for flag in ("--index-dir", "--duration", "--generations", "--queries",
                 "--upserts", "--deletes", "--p99-gate-ms", "--drift-gate",
                 "--out", "--seed"):
        assert flag in out, f"soak --help no longer documents {flag}"
    assert "SLOMonitor" in out          # epilog = module docstring


def test_explain_report_help_documents_current_flags():
    out = _help_output("benchmarks.explain_report")
    for flag in ("--index-dir", "--queries", "--batch", "--query-seed",
                 "--out"):
        assert flag in out, \
            f"explain_report --help no longer documents {flag}"
    # the three-way gap decomposition is the contract
    for word in ("candidate_miss", "selector_miss", "budget_cutoff"):
        assert word in out


def test_update_index_help_documents_current_flags():
    out = _help_output("repro.launch.update_index")
    for flag in ("--upserts", "--deletes", "--compact", "--check-parity",
                 "--serve-queries", "--recluster-overflow",
                 "--trace-out", "--metrics-out"):
        assert flag in out, f"update_index --help no longer documents {flag}"


def test_train_selector_help_documents_current_flags():
    out = _help_output("repro.launch.train_selector")
    for flag in ("--index-dir", "--train-queries", "--holdout-queries",
                 "--chunk-clusters", "--label-cache", "--pos-weight",
                 "--no-bucket", "--use-kernel", "--ckpt-every", "--resume",
                 "--thetas", "--budgets", "--target-recall",
                 "--target-budget", "--expand-depths", "--fusion",
                 "--publish", "--serve-check",
                 "--trace-out", "--metrics-out"):
        assert flag in out, \
            f"train_selector --help no longer documents {flag}"
    # the epilog is the module docstring: the four pipeline stages must be
    # documented in help verbatim
    for word in ("LABELS", "TRAIN", "CALIBRATE", "PUBLISH"):
        assert word in out


def test_train_help_is_docstring_backed():
    out = _help_output("repro.launch.train")
    for flag in ("--arch", "--variant", "--steps", "--ckpt-every",
                 "--fail-at"):
        assert flag in out, f"train --help no longer documents {flag}"
    # epilog = module docstring (the restartable-loop description)
    assert "fault-tolerant" in out or "restartable" in out
