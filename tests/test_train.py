"""Selector-training subsystem tests (src/repro/train/):

  * streaming index-backed label generation is bit-identical to the
    in-RAM `make_labels` path on the same corpus/geometry — for v1 float
    shards (vs the raw embeddings) and v2 PQ shards (vs the decoded
    matrix the index actually stores) — at ANY chunk budget (property
    test), with every streamed read bounded (CappedFetch wrapper) and no
    embedding matrix materialized
  * label cache round trip + key sensitivity to generation/config/queries
  * checkpoint-resume determinism: train N steps == train k, resume,
    train N-k — bitwise-equal parameters
  * config-driven BCE positive weight (cfg.pos_weight, derived when None)
  * power-of-two sequence bucketing: exact per-epoch coverage, weighted
    padding, and truncation-exactness of the causal selectors
  * calibration sweep semantics + operating-point choice
  * publish-as-generation: manifest/selector metadata, full-verify
    integrity, live-engine reload_selector parity vs a fresh engine
  * Pallas-LSTM-cell training step: kernel-forward gradients match the
    reference scan
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro import index as index_lib
from repro import train as train_lib
from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import train_lstm as tl
from repro.data import synth_corpus, synth_queries


def _tiny_cfg():
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=512, dim=16, n_clusters=32, vocab=256, max_postings=128,
        k_sparse=64, bins=(5, 15, 30, 64), n_candidates=8, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=32, train_queries=24, epochs=2)


class CappedFetchStore:
    """ClusterStore wrapper that fails the test if any single fetch asks
    for more than `max_blocks` cluster blocks — the bounded-read contract
    of streaming label generation, enforced."""

    is_host = True

    def __init__(self, store, max_blocks):
        self._store = store
        self.max_blocks = int(max_blocks)
        self.peak = 0

    @property
    def cluster_docs(self):
        return self._store.cluster_docs

    @property
    def block_bytes(self):
        return self._store.block_bytes

    def fetch_blocks(self, cluster_ids):
        n = len(np.asarray(cluster_ids).reshape(-1))
        self.peak = max(self.peak, n)
        assert n <= self.max_blocks, \
            f"fetched {n} blocks in one read (cap {self.max_blocks})"
        return self._store.fetch_blocks(cluster_ids)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Tiny corpus serialized as BOTH on-disk formats + a label query set."""
    cfg = _tiny_cfg()
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    root = tmp_path_factory.mktemp("train_idx")
    out_v1 = str(root / "v1")
    out_v2 = str(root / "v2")
    emb = np.asarray(corpus.embeddings)
    index_lib.write_index(out_v1, cfg, index, emb, n_shards=3)
    index_lib.write_index(out_v2, cfg, index, emb, n_shards=3,
                          format_version=2, pq_nsub=4)
    qs = synth_queries(3, corpus, 24)
    return cfg, corpus, index, out_v1, out_v2, qs


def _open(out):
    reader = index_lib.IndexReader.open(out)
    cfg, lindex = reader.load_index()
    store = reader.open_store(cluster_docs=lindex.cluster_docs)
    return reader, cfg, lindex, store


def _decoded_matrix(store, n_docs, dim):
    """The (D, dim) float matrix a store's shards decode to."""
    dec = np.zeros((n_docs, dim), np.float32)
    vecs, docs, valid = store.fetch_blocks(np.arange(store.n_clusters))
    dec[np.asarray(docs)[np.asarray(valid)]] = \
        np.asarray(vecs)[np.asarray(valid)]
    return dec


# ---------------------------------------------------------------------------
# streaming label parity
# ---------------------------------------------------------------------------

def test_streaming_labels_bitwise_match_inram_v1(built):
    cfg, corpus, index, out_v1, _, qs = built
    reader, lcfg, lindex, store = _open(out_v1)
    assert lindex.embeddings is None     # never materialized
    cand, feats, labels = tl.make_labels(cfg, index, qs.q_dense, qs.q_terms,
                                         qs.q_weights)
    ls = train_lib.make_labels_streaming(
        lcfg, lindex, store, qs.q_dense, qs.q_terms, qs.q_weights,
        label_cfg=train_lib.LabelConfig(chunk_clusters=5))
    np.testing.assert_array_equal(np.asarray(cand), ls.cand)
    np.testing.assert_array_equal(np.asarray(feats), ls.feats)
    np.testing.assert_array_equal(np.asarray(labels), ls.labels)
    ref_ids, _ = cl.full_dense_topk(corpus.embeddings, qs.q_dense, 10)
    np.testing.assert_array_equal(np.asarray(ref_ids), ls.dense_ids)


def test_streaming_labels_bitwise_match_inram_v2(built):
    """v2 supervision is exact w.r.t. what the PQ index stores: streaming
    off the code shards == in-RAM make_labels on the decoded matrix."""
    cfg, _, _, _, out_v2, qs = built
    reader, lcfg, lindex, store = _open(out_v2)
    dec = _decoded_matrix(store, cfg.n_docs, cfg.dim)
    lindex.embeddings = jnp.asarray(dec)
    cand, feats, labels = tl.make_labels(lcfg, lindex, qs.q_dense,
                                         qs.q_terms, qs.q_weights)
    lindex.embeddings = None
    ls = train_lib.make_labels_streaming(
        lcfg, lindex, store, qs.q_dense, qs.q_terms, qs.q_weights,
        label_cfg=train_lib.LabelConfig(chunk_clusters=7))
    np.testing.assert_array_equal(np.asarray(cand), ls.cand)
    np.testing.assert_array_equal(np.asarray(feats), ls.feats)
    np.testing.assert_array_equal(np.asarray(labels), ls.labels)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 32))
def test_streaming_topk_exact_and_bounded_any_chunk(built, chunk):
    """Property: at ANY chunk budget the streamed top-k equals the full
    matmul top-k bitwise, and no single read exceeds the budget."""
    cfg, corpus, _, out_v1, _, qs = built
    _, _, _, store = _open(out_v1)
    capped = CappedFetchStore(store, chunk)
    ids, scores = train_lib.streaming_full_dense_topk(
        capped, qs.q_dense, 10, chunk_clusters=chunk)
    ref_ids, ref_scores = cl.full_dense_topk(corpus.embeddings,
                                             qs.q_dense, 10)
    np.testing.assert_array_equal(np.asarray(ref_ids), ids)
    np.testing.assert_array_equal(np.asarray(ref_scores), scores)
    assert 0 < capped.peak <= chunk


def test_label_cache_roundtrip_and_key_sensitivity(built, tmp_path):
    cfg, _, _, out_v1, _, qs = built
    reader, lcfg, lindex, store = _open(out_v1)
    label_cfg = train_lib.LabelConfig(chunk_clusters=5)
    fp = train_lib.query_fingerprint(qs.q_dense, qs.q_terms, qs.q_weights)
    key = train_lib.label_cache_key(reader.manifest, lcfg, label_cfg, fp)
    cache = train_lib.LabelCache(str(tmp_path / "labels"))
    assert cache.load(key) is None
    calls = []
    ls, hit = cache.get_or_build(key, lambda: (calls.append(1) or
        train_lib.make_labels_streaming(lcfg, lindex, store, qs.q_dense,
                                        qs.q_terms, qs.q_weights,
                                        label_cfg=label_cfg)))
    assert not hit and calls == [1]
    ls2, hit2 = cache.get_or_build(key, lambda: calls.append(2))
    assert hit2 and calls == [1]          # second call never rebuilds
    for attr in ("cand", "feats", "labels", "dense_ids"):
        np.testing.assert_array_equal(getattr(ls, attr), getattr(ls2, attr))
    # any input the labels depend on changes the key ...
    import json as json_lib
    assert key != train_lib.label_cache_key(
        reader.manifest, lcfg, train_lib.LabelConfig(chunk_clusters=5,
                                                     top_dense=20), fp)
    mutated = json_lib.loads(json_lib.dumps(reader.manifest))
    shard = next(r for r in mutated["files"] if r.startswith("blocks"))
    mutated["files"][shard]["sha256"] = "0" * 64     # corpus bytes moved
    assert key != train_lib.label_cache_key(mutated, lcfg, label_cfg, fp)
    assert key != train_lib.label_cache_key(
        reader.manifest, lcfg, label_cfg,
        train_lib.query_fingerprint(qs.q_dense[:8], qs.q_terms[:8],
                                    qs.q_weights[:8]))
    # ... but a selector-only publish (new generation, lstm files, theta)
    # reuses the cache: labels never depended on the selector
    published = json_lib.loads(json_lib.dumps(reader.manifest))
    published["generation"] = 3
    published["config"]["theta"] = 0.42
    published["files"]["lstm.g3/step_0/manifest.json"] = \
        {"bytes": 1, "sha256": "a" * 64}
    assert key == train_lib.label_cache_key(published, lcfg, label_cfg, fp)


# ---------------------------------------------------------------------------
# trainer: pos_weight, bucketing, checkpoint resume
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def label_set(built):
    cfg, _, _, out_v1, _, qs = built
    _, lcfg, lindex, store = _open(out_v1)
    return train_lib.make_labels_streaming(
        lcfg, lindex, store, qs.q_dense, qs.q_terms, qs.q_weights,
        label_cfg=train_lib.LabelConfig(chunk_clusters=8))


def test_pos_weight_config_driven(built, label_set):
    cfg = built[0]
    labels = label_set.labels
    # default: the historical constant rides along in the config
    assert cfg.pos_weight == 4.0
    assert train_lib.resolve_pos_weight(cfg, labels) == 4.0
    # explicit override wins
    assert train_lib.resolve_pos_weight(cfg, labels, 7.5) == 7.5
    # None derives from the observed positive rate
    derived = train_lib.resolve_pos_weight(
        dataclasses.replace(cfg, pos_weight=None), labels)
    p = float(np.asarray(labels).mean())
    assert derived == pytest.approx((1 - p) / p)
    trainer = train_lib.SelectorTrainer(
        dataclasses.replace(cfg, pos_weight=None),
        train_lib.SelectorTrainConfig(epochs=1, batch_size=8,
                                      use_kernel=False))
    trainer.fit(jax.random.key(0), label_set.feats, label_set.labels)
    assert trainer.pos_weight == pytest.approx(derived)
    # an all-negative label set cannot explode the weight
    assert train_lib.derive_pos_weight(np.zeros((4, 8))) == 100.0


def test_bucketing_coverage_and_truncation_exactness(built, label_set):
    cfg = built[0]
    feats, labels = label_set.feats, label_set.labels
    buckets = train_lib.bucket_lengths(cfg, feats, labels, min_len=2)
    n = feats.shape[1]
    eff = train_lib.effective_lengths(cfg, feats, labels, min_len=2)
    assert np.all(buckets >= eff) and np.all(buckets <= n)
    assert np.all((buckets & (buckets - 1)) == 0)        # powers of two
    # every query exactly once per epoch; padded rows carry weight 0
    seen = []
    for batch in train_lib.bucketed_batches(feats, labels, buckets,
                                            batch_size=5, seed=1, epoch=0):
        assert batch.feats.shape == (5, batch.length, feats.shape[-1])
        real = int(batch.weights.sum())
        seen.extend([None] * real)
        assert np.all(batch.weights[real:] == 0)
    assert len(seen) == feats.shape[0]
    assert train_lib.n_batches_per_epoch(buckets, 5) >= 1
    # deterministic in (seed, epoch)
    a = [b.feats for b in train_lib.bucketed_batches(
        feats, labels, buckets, batch_size=5, seed=1, epoch=3)]
    b = [b.feats for b in train_lib.bucketed_batches(
        feats, labels, buckets, batch_size=5, seed=1, epoch=3)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # causal selectors: prefix probabilities are bitwise the full run's
    params = train_lib.SelectorTrainer(cfg).init_params(
        jax.random.key(5), feats.shape[-1])
    full = np.asarray(train_lib.selector_apply(params, jnp.asarray(feats)))
    for L in sorted(set(int(x) for x in buckets)):
        trunc = np.asarray(train_lib.selector_apply(
            params, jnp.asarray(feats[:, :L])))
        np.testing.assert_array_equal(full[:, :L], trunc)


def test_checkpoint_resume_determinism(built, label_set, tmp_path):
    """train N steps == train k, resume, train N-k (bitwise params)."""
    cfg = built[0]
    feats, labels = label_set.feats, label_set.labels
    kw = dict(epochs=3, batch_size=5, seed=7, use_kernel=False)
    full = train_lib.SelectorTrainer(
        cfg, train_lib.SelectorTrainConfig(**kw))
    p_full, h_full = full.fit(jax.random.key(1), feats, labels)
    per_epoch = train_lib.n_batches_per_epoch(
        train_lib.bucket_lengths(cfg, feats, labels), 5)
    k = per_epoch + max(1, per_epoch // 2)        # stop mid-epoch 2
    part = train_lib.SelectorTrainer(cfg, train_lib.SelectorTrainConfig(
        ckpt_dir=str(tmp_path / "ck"), max_steps=k, **kw))
    part.fit(jax.random.key(1), feats, labels)
    resumed = train_lib.SelectorTrainer(cfg, train_lib.SelectorTrainConfig(
        ckpt_dir=str(tmp_path / "ck"), **kw))
    p_res, _ = resumed.fit(jax.random.key(1), feats, labels, resume=True)
    for key in p_full:
        np.testing.assert_array_equal(np.asarray(p_full[key]),
                                      np.asarray(p_res[key]), err_msg=key)


def test_kernel_forward_grads_match_reference(built, label_set):
    """The fused Pallas LSTM cell trains with exact gradients: custom-VJP
    kernel path vs the jnp reference scan."""
    cfg = built[0]
    feats = jnp.asarray(label_set.feats[:6])
    labels = jnp.asarray(label_set.labels[:6])
    params = train_lib.SelectorTrainer(cfg).init_params(
        jax.random.key(3), feats.shape[-1])

    def loss(p, use_kernel):
        probs = train_lib.selector_apply(p, feats, use_kernel=use_kernel)
        probs = jnp.clip(probs, 1e-6, 1 - 1e-6)
        return -jnp.mean(4.0 * labels * jnp.log(probs)
                         + (1 - labels) * jnp.log(1 - probs))

    g_ref = jax.grad(lambda p: loss(p, False))(params)
    g_ker = jax.grad(lambda p: loss(p, True))(params)
    for key in g_ref:
        np.testing.assert_allclose(np.asarray(g_ker[key]),
                                   np.asarray(g_ref[key]),
                                   rtol=1e-5, atol=1e-6, err_msg=key)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibration_table_and_operating_point(built, label_set):
    cfg = built[0]
    _, _, lindex, store = _open(built[3])
    params, _ = train_lib.train_selector(cfg, jax.random.key(2),
                                         label_set.feats, label_set.labels,
                                         epochs=3)
    probs = train_lib.selector_probs(params, label_set.feats)
    table = train_lib.calibration_table(
        label_set, probs, np.asarray(lindex.doc_cluster),
        thetas=[0.02, 0.2, 0.5], budgets=[2, 4, 8],
        block_bytes=store.block_bytes)
    assert len(table) == 9
    by = {(r["theta"], r["budget"]): r for r in table}
    for theta in (0.02, 0.2, 0.5):
        # more budget never hurts recall at fixed theta
        assert by[(theta, 2)]["recall"] <= by[(theta, 4)]["recall"] \
            <= by[(theta, 8)]["recall"]
    for budget in (2, 4, 8):
        # higher theta never selects more clusters at fixed budget
        assert by[(0.02, budget)]["avg_selected"] >= \
            by[(0.5, budget)]["avg_selected"]
    for r in table:
        # avg_selected is rounded for the table; the byte estimate is
        # computed from the unrounded value
        assert abs(r["est_read_bytes"]
                   - r["avg_selected"] * store.block_bytes) \
            <= 0.01 * store.block_bytes
    best = max(t["recall"] for t in table)
    op = train_lib.choose_operating_point(table, target_recall=best)
    assert op["target_met"] and op["recall"] >= best
    cheap = train_lib.choose_operating_point(table, target_budget=4)
    assert cheap["budget"] <= 4 and cheap["target_met"]
    # an unmeetable budget must be FLAGGED, not silently satisfied by the
    # cheapest row
    over = train_lib.choose_operating_point(table, target_budget=1)
    assert not over["target_met"] and over["budget"] == 2
    unreachable = train_lib.choose_operating_point(table, target_recall=1.1)
    assert not unreachable["target_met"] and unreachable["recall"] == best
    with pytest.raises(ValueError):
        train_lib.choose_operating_point(table)
    # selection semantics mirror stage2_select exactly
    sel_ids, sel_mask = train_lib.select_at(label_set.cand, probs, 0.2, 4)
    s2 = cl.stage2_select(dataclasses.replace(cfg, max_selected=4), lindex,
                          jnp.asarray(label_set.cand),
                          jnp.asarray(label_set.feats), theta=0.2,
                          selector_params=params)
    np.testing.assert_array_equal(np.asarray(s2["sel_mask"]), sel_mask)
    np.testing.assert_array_equal(
        np.where(np.asarray(s2["sel_mask"]), np.asarray(s2["sel_ids"]), -1),
        np.where(sel_mask, sel_ids, -1))


# ---------------------------------------------------------------------------
# publish + live hot reload
# ---------------------------------------------------------------------------

def test_publish_generation_and_hot_reload_parity(built, label_set,
                                                  tmp_path):
    cfg, corpus, index, out_v1, _, qs = built
    work = str(tmp_path / "pubidx")
    import shutil
    shutil.copytree(out_v1, work)
    reader = index_lib.IndexReader.open(work, verify="full")
    assert reader.generation == 0 and reader.selector_meta() is None
    params, _ = train_lib.train_selector(cfg, jax.random.key(2),
                                         label_set.feats, label_set.labels,
                                         epochs=3)
    engine = reader.engine(max_batch=8)
    engine.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])

    report = train_lib.publish_selector(
        work, params, theta=0.11, budget=4,
        calibration=[{"theta": 0.11, "budget": 4, "recall": 0.5,
                      "avg_selected": 3.0, "est_read_bytes": 0}],
        label_config={"top_dense": 10}, train_meta={"epochs": 3})
    assert report["generation"] == 1

    gen = engine.reload_selector()
    assert gen == 1
    assert engine.cfg.theta == 0.11 and engine.cfg.max_selected == 4
    got, _ = engine.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                             qs.q_weights[:8])
    engine.close()
    assert engine.stats()["selector_reloads"] == 1

    fresh = index_lib.IndexReader.open(work, verify="full")  # checksums OK
    assert fresh.generation == 1
    meta = fresh.selector_meta()
    assert meta["theta"] == 0.11 and meta["budget"] == 4
    assert fresh.config().theta == 0.11
    assert fresh.config().max_selected == 4
    for key in params:
        np.testing.assert_array_equal(np.asarray(fresh.lstm_params()[key]),
                                      np.asarray(params[key]), err_msg=key)
    with fresh.engine(max_batch=8) as fe:
        want, _ = fe.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                              qs.q_weights[:8])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the old generation's manifest stays readable (archived)
    old = index_lib.load_manifest(work, generation=0)
    assert index_lib.manifest_generation(old) == 0


def test_publish_rejects_non_lstm_and_bad_params(built, tmp_path):
    cfg, _, _, out_v1, _, _ = built
    import shutil
    work = str(tmp_path / "pub2")
    shutil.copytree(out_v1, work)
    with pytest.raises(ValueError):
        train_lib.publish_selector(work, {"w1": np.zeros((3, 3))},
                                   selector="mlp")
    with pytest.raises(ValueError):
        train_lib.publish_selector(work, {"wx": np.zeros((3, 12))})


# ---------------------------------------------------------------------------
# hybrid candidate generation: relabel + expansion sweep + publish
# ---------------------------------------------------------------------------

def test_relabel_for_config_matches_streamed_labels(built, label_set):
    """relabel_for_config at the SAME depth reproduces the streamed label
    set exactly (same stage-1, same dense ids => same supervision); at a
    deeper depth the candidate prefix and its labels are preserved."""
    _, _, _, out_v1, _, qs = built
    _, lcfg, lindex, _ = _open(out_v1)
    same = train_lib.relabel_for_config(
        lcfg, lindex, qs.q_dense, qs.q_terms, qs.q_weights,
        label_set.dense_ids)
    np.testing.assert_array_equal(same.cand, label_set.cand)
    np.testing.assert_array_equal(same.feats, label_set.feats)
    np.testing.assert_array_equal(same.labels, label_set.labels)
    n = lcfg.n_candidates
    deep_cfg = dataclasses.replace(lcfg, expand_depth=2)
    assert deep_cfg.n_candidates_total > n
    deep = train_lib.relabel_for_config(
        deep_cfg, lindex, qs.q_dense, qs.q_terms, qs.q_weights,
        label_set.dense_ids)
    assert deep.cand.shape[1] == deep_cfg.n_candidates_total
    np.testing.assert_array_equal(deep.cand[:, :n], label_set.cand)
    np.testing.assert_array_equal(deep.labels[:, :n], label_set.labels)
    # expansion can only add positives, never lose them
    assert (deep.labels.sum(axis=1) >= label_set.labels.sum(axis=1)).all()


def test_expansion_sweep_depth0_equals_calibration_table(built, label_set):
    cfg, _, _, out_v1, _, qs = built
    _, lcfg, lindex, store = _open(out_v1)
    params, _ = train_lib.train_selector(cfg, jax.random.key(2),
                                         label_set.feats, label_set.labels,
                                         epochs=3)
    thetas, budgets = [0.02, 0.2], [2, 4]
    sweep = train_lib.expansion_sweep(
        lcfg, lindex, params, qs.q_dense, qs.q_terms, qs.q_weights,
        label_set.dense_ids, depths=[0, 2], thetas=thetas, budgets=budgets,
        block_bytes=store.block_bytes)
    assert [d["depth"] for d in sweep] == [0, 2]
    # depth-0 rows == the plain calibration table (modulo the depth tags)
    probs = train_lib.selector_probs(params, label_set.feats)
    table = train_lib.calibration_table(
        label_set, probs, np.asarray(lindex.doc_cluster), thetas=thetas,
        budgets=budgets, block_bytes=store.block_bytes)
    d0 = [{k: v for k, v in r.items() if k not in ("depth", "n_candidates")}
          for r in sweep[0]["rows"]]
    assert d0 == table
    # a wider stage-1 can only raise the recall ceiling
    assert sweep[1]["stage1_ceiling"] >= sweep[0]["stage1_ceiling"]
    assert sweep[1]["n_candidates"] == lcfg.n_candidates * 3
    for per_depth in sweep:
        for r in per_depth["rows"]:
            assert r["depth"] == per_depth["depth"]
            assert r["n_candidates"] == per_depth["n_candidates"]
            # expansion changes WHICH clusters compete, not the read cost
            assert r["est_read_bytes"] <= r["budget"] * store.block_bytes


def test_publish_hybrid_fields_roundtrip_and_stage1_reload(built, label_set,
                                                           tmp_path):
    """expand_depth/fusion published into the manifest reach a reader's
    config, and a live engine's reload_selector() recompiles stage 1 so
    hot-swapped serving matches a fresh engine on the new generation."""
    cfg, _, _, out_v1, _, qs = built
    import shutil
    work = str(tmp_path / "pubhyb")
    shutil.copytree(out_v1, work)
    deep_cfg = dataclasses.replace(cfg, expand_depth=1)
    reader = index_lib.IndexReader.open(work)
    _, lindex = reader.load_index()
    ls = train_lib.relabel_for_config(
        deep_cfg, lindex, qs.q_dense, qs.q_terms, qs.q_weights,
        label_set.dense_ids)
    params, _ = train_lib.train_selector(deep_cfg, jax.random.key(2),
                                         ls.feats, ls.labels, epochs=3)
    engine = reader.engine(max_batch=8)
    engine.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])

    with pytest.raises(ValueError):
        train_lib.publish_selector(work, params, fusion="borda")
    report = train_lib.publish_selector(
        work, params, theta=0.1, budget=4, expand_depth=1, fusion="rrf")
    assert engine.reload_selector() == report["generation"]
    assert engine.cfg.expand_depth == 1 and engine.cfg.fusion == "rrf"
    got, _ = engine.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                             qs.q_weights[:8])
    engine.close()

    fresh = index_lib.IndexReader.open(work, verify="full")
    fcfg = fresh.config()
    assert fcfg.expand_depth == 1 and fcfg.fusion == "rrf"
    meta = fresh.selector_meta()
    assert meta["expand_depth"] == 1 and meta["fusion"] == "rrf"
    with fresh.engine(max_batch=8) as fe:
        assert fe.stats()["fusion"] == "rrf"
        assert fe.stats()["expand_depth"] == 1
        want, _ = fe.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                              qs.q_weights[:8])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
