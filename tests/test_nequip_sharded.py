"""Owner-sharded NequIP message passing (§Perf) must match the pjit
reference forward, and the edge partitioner must preserve every edge."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_shard_edges_by_owner_preserves_edges():
    from repro.models.nequip_sharded import shard_edges_by_owner
    rng = np.random.default_rng(0)
    N, E, S = 100, 400, 8
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    es, ed, em = shard_edges_by_owner(src, dst, np.ones(E), N, S)
    kept = [(int(s), int(d)) for sh in range(S)
            for s, d, m in zip(es[sh], ed[sh], em[sh]) if m > 0]
    assert sorted(kept) == sorted(zip(src.tolist(), dst.tolist()))
    # ownership: every kept edge's dst lands in its shard's node range
    n_loc = -(-N // S)
    for sh in range(S):
        d = ed[sh][em[sh] > 0]
        assert ((d // n_loc) == sh).all()


@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType / make_mesh "
           "axis_types= (needs jax >= 0.6)")
def test_owner_sharded_forward_matches_pjit():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import nequip as nq
        from repro.models import nequip_sharded as nqs
        cfg = get_config("nequip", "smoke")
        rng = np.random.default_rng(0)
        N, E = 64, 300
        pos = jnp.asarray(rng.standard_normal((N, 3)) * 2, jnp.float32)
        src = rng.integers(0, N, E).astype(np.int32)
        dst = rng.integers(0, N, E).astype(np.int32)
        params = nq.init_params(cfg, jax.random.key(0))
        batch = {"positions": pos,
                 "species": jnp.asarray(rng.integers(0, 8, N), jnp.int32),
                 "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
                 "edge_mask": jnp.ones(E),
                 "graph_id": jnp.zeros(N, jnp.int32),
                 "energy_target": jnp.zeros(1)}
        e_ref = nq.forward(cfg, params, batch)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        es, ed, em = nqs.shard_edges_by_owner(src, dst, np.ones(E), N, 8)
        bs = {k: v for k, v in batch.items()
              if not k.startswith("edge_")}
        bs.update({"edge_src_sharded": jnp.asarray(es),
                   "edge_dst_sharded": jnp.asarray(ed),
                   "edge_mask_sharded": jnp.asarray(em)})
        e_sh = jax.jit(lambda p, b: nqs.forward_sharded(cfg, p, b, mesh))(
            params, bs)
        np.testing.assert_allclose(np.asarray(e_sh), np.asarray(e_ref),
                                   rtol=2e-4, atol=2e-5)
        print("OK owner-sharded == pjit")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK owner-sharded" in r.stdout
