"""Engine-layer tests: ClusterStore backend parity (in-memory / disk / PQ
with an identity quantizer return identical fused top-k), LRU block-cache
accounting, request bucketing, the stage-2 selection-budget bugfix, and
RetrievalEngine end-to-end (dedup'd I/O, cache hits, prefetch shutdown)."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import quant as quant_lib
from repro.engine import (
    BlockCache, DiskStore, InMemoryStore, PQStore, RetrievalEngine,
    bucket_size, pipeline)


@pytest.fixture(scope="module")
def tiny():
    """256-doc corpus (small enough for an exact identity PQ)."""
    cfg = dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=256, dim=32, n_clusters=16, vocab=256, max_postings=256,
        k_sparse=64, bins=(5, 15, 30, 64), n_candidates=8, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=32)
    from repro.data import synth_corpus, synth_queries
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    qs = synth_queries(7, corpus, 12)
    return cfg, corpus, index, qs


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------

def _stores(index, tmpdir):
    yield "inmemory", InMemoryStore(index.embeddings, index.cluster_docs)
    yield "disk", DiskStore.create(os.path.join(tmpdir, "blocks.bin"),
                                   index.embeddings, index.cluster_docs)
    yield "pq-identity", PQStore(quant_lib.identity_pq(index.embeddings, 8),
                                 index.cluster_docs)


def test_backend_parity_fused_topk(tiny):
    cfg, _, index, qs = tiny
    results = {}
    with tempfile.TemporaryDirectory() as d:
        for name, store in _stores(index, d):
            ids, scores, _ = pipeline.retrieve(cfg, index, store, qs.q_dense,
                                               qs.q_terms, qs.q_weights)
            results[name] = (np.asarray(ids), np.asarray(scores))
    ref_ids, ref_scores = results["inmemory"]
    for name in ("disk", "pq-identity"):
        ids, scores = results[name]
        np.testing.assert_array_equal(ids, ref_ids, err_msg=name)
        np.testing.assert_allclose(scores, ref_scores, rtol=1e-5, atol=1e-5,
                                   err_msg=name)


def test_backend_parity_fetch_blocks(tiny):
    _, _, index, _ = tiny
    cids = np.asarray([0, 3, 7, 3])
    with tempfile.TemporaryDirectory() as d:
        fetched = {name: store.fetch_blocks(jnp.asarray(cids)
                                            if not store.is_host else cids)
                   for name, store in _stores(index, d)}
    vecs_ref, docs_ref, valid_ref = map(np.asarray, fetched["inmemory"])
    for name in ("disk", "pq-identity"):
        vecs, docs, valid = map(np.asarray, fetched[name])
        np.testing.assert_array_equal(docs, docs_ref, err_msg=name)
        np.testing.assert_array_equal(valid, valid_ref, err_msg=name)
        np.testing.assert_allclose(vecs, vecs_ref, rtol=1e-5, atol=1e-5,
                                   err_msg=name)


def test_legacy_wrappers_match_pipeline(tiny):
    """core.clusd.retrieve / core.disk.ondisk_clusd_retrieve are thin
    wrappers — same ids as calling the pipeline directly."""
    from repro.core import disk as dk
    cfg, corpus, index, qs = tiny
    ids_mem, _, _ = cl.retrieve(cfg, index, qs.q_dense, qs.q_terms,
                                qs.q_weights)
    with tempfile.TemporaryDirectory() as d:
        blocks = dk.DiskClusterStore(os.path.join(d, "b.bin"),
                                     corpus.embeddings, index.cluster_docs)
        ids_dk, _, stats = dk.ondisk_clusd_retrieve(
            cfg, index, blocks, qs.q_dense, qs.q_terms, qs.q_weights)
    np.testing.assert_array_equal(np.asarray(ids_dk), np.asarray(ids_mem))
    # n_ops counts coalesced runs of adjacent blocks, bytes counts blocks
    n_blocks = stats.bytes // blocks.block_bytes
    assert 0 < stats.n_ops <= n_blocks
    assert stats.bytes == n_blocks * blocks.block_bytes


# ---------------------------------------------------------------------------
# backend parity matrix: all five stores on one fixture index
# ---------------------------------------------------------------------------

_MATRIX_SHAPES = {
    "base": dict(n_docs=256, n_clusters=16),
    # n_docs not divisible by cluster_cap, odd cluster count
    "ragged": dict(n_docs=237, n_clusters=7),
    "single-cluster": dict(n_docs=64, n_clusters=1),
    "empty-stage1": dict(n_docs=256, n_clusters=16),
}


@pytest.mark.parametrize("case", sorted(_MATRIX_SHAPES))
def test_backend_parity_matrix(case, tmp_path):
    """InMemoryStore / DiskStore / ShardedDiskStore agree exactly;
    PQStore / ShardedPQStore agree with each other and stay within a
    bounded MRR@10 delta of the exact backends — across odd geometries
    and an all-padding (empty Stage-I sparse input) query batch."""
    from repro import index as index_lib
    from repro.data import mrr_at, synth_corpus, synth_queries

    shape = _MATRIX_SHAPES[case]
    N = shape["n_clusters"]
    cfg = dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=shape["n_docs"], dim=32, n_clusters=N, vocab=128,
        max_postings=64, k_sparse=32, bins=(5, 15, 32),
        n_candidates=min(8, N), max_selected=min(4, N),
        n_neighbors=min(8, max(1, N - 1)), u_bins=4, k_final=16)
    corpus = synth_corpus(11, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    qs = synth_queries(13, corpus, 16)
    q_terms, q_weights = qs.q_terms, qs.q_weights
    if case == "empty-stage1":
        q_terms = jnp.full_like(qs.q_terms, -1)
        q_weights = jnp.zeros_like(qs.q_weights)

    emb = np.asarray(corpus.embeddings)
    pq = quant_lib.train_pq(jax.random.key(1), corpus.embeddings, nsub=8)
    v1 = str(tmp_path / "v1")
    v2 = str(tmp_path / "v2")
    index_lib.write_index(v1, cfg, index, emb, n_shards=min(3, N))
    index_lib.write_index(v2, cfg, index, emb, n_shards=min(3, N),
                          format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    stores = {
        "inmemory": InMemoryStore(index.embeddings, index.cluster_docs),
        "disk": DiskStore.create(str(tmp_path / "blocks.bin"),
                                 index.embeddings, index.cluster_docs),
        "sharded-disk": index_lib.IndexReader.open(v1, verify="full")
        .open_store(cluster_docs=index.cluster_docs),
        "pq": PQStore(pq, index.cluster_docs),
        "sharded-pq": index_lib.IndexReader.open(v2, verify="full")
        .open_store(cluster_docs=index.cluster_docs),
    }
    results = {}
    for name, store in stores.items():
        ids, scores, _ = pipeline.retrieve(cfg, index, store, qs.q_dense,
                                           q_terms, q_weights)
        results[name] = (np.asarray(ids), np.asarray(scores))

    ref_ids, ref_scores = results["inmemory"]
    for name in ("disk", "sharded-disk"):       # exact backends: identical
        np.testing.assert_array_equal(results[name][0], ref_ids,
                                      err_msg=f"{case}:{name}")
        np.testing.assert_allclose(results[name][1], ref_scores,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{case}:{name}")
    ref_mrr = mrr_at(ref_ids, qs.rel_doc)
    for name in ("pq", "sharded-pq"):           # PQ backends: bounded delta
        got_mrr = mrr_at(results[name][0], qs.rel_doc)
        assert abs(got_mrr - ref_mrr) <= 0.02, (case, name, ref_mrr, got_mrr)
    # the two PQ encodings score the same quantized vectors
    np.testing.assert_allclose(results["sharded-pq"][1], results["pq"][1],
                               rtol=1e-4, atol=1e-4, err_msg=case)


@pytest.mark.parametrize("method", ("interp", "rrf"))
def test_fusion_mode_backend_parity(method, tmp_path):
    """Hybrid serving (fusion method x neighbor-graph expansion) holds the
    same backend-parity contract as the default pipeline: the three exact
    stores bitwise-identical, the two PQ encodings mutually exact — and
    explicit fusion="interp" + expand_depth=0 IS the default config, so
    current serving is reproduced bitwise by construction."""
    from repro import index as index_lib
    from repro.data import synth_corpus, synth_queries

    base = dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=256, dim=32, n_clusters=16, vocab=128, max_postings=64,
        k_sparse=32, bins=(5, 15, 32), n_candidates=4, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=16)
    # the explicit defaults ARE the default config (depth-0 back-compat)
    assert dataclasses.replace(base, fusion="interp", expand_depth=0) == base
    assert base.n_candidates_total == base.n_candidates
    cfg = dataclasses.replace(base, fusion=method, expand_depth=2)
    assert cfg.n_candidates_total == 12
    corpus = synth_corpus(11, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    qs = synth_queries(13, corpus, 12)
    emb = np.asarray(corpus.embeddings)
    pq = quant_lib.train_pq(jax.random.key(1), corpus.embeddings, nsub=8)
    v1 = str(tmp_path / "v1")
    v2 = str(tmp_path / "v2")
    index_lib.write_index(v1, cfg, index, emb, n_shards=3)
    index_lib.write_index(v2, cfg, index, emb, n_shards=3,
                          format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    stores = {
        "inmemory": InMemoryStore(index.embeddings, index.cluster_docs),
        "disk": DiskStore.create(str(tmp_path / "blocks.bin"),
                                 index.embeddings, index.cluster_docs),
        "sharded-disk": index_lib.IndexReader.open(v1, verify="full")
        .open_store(cluster_docs=index.cluster_docs),
        "pq": PQStore(pq, index.cluster_docs),
        "sharded-pq": index_lib.IndexReader.open(v2, verify="full")
        .open_store(cluster_docs=index.cluster_docs),
    }
    results = {}
    for name, store in stores.items():
        ids, scores, _ = pipeline.retrieve(cfg, index, store, qs.q_dense,
                                           qs.q_terms, qs.q_weights)
        results[name] = (np.asarray(ids), np.asarray(scores))
    ref_ids, ref_scores = results["inmemory"]
    for name in ("disk", "sharded-disk"):
        np.testing.assert_array_equal(results[name][0], ref_ids,
                                      err_msg=f"{method}:{name}")
        np.testing.assert_allclose(results[name][1], ref_scores,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{method}:{name}")
    np.testing.assert_allclose(results["sharded-pq"][1], results["pq"][1],
                               rtol=1e-4, atol=1e-4, err_msg=method)
    # depth 0 under the same fusion method only reorders by fused score;
    # it must run (static-shape path) and return valid ids
    ids0, _, _ = pipeline.retrieve(dataclasses.replace(cfg, expand_depth=0),
                                   index, stores["inmemory"], qs.q_dense,
                                   qs.q_terms, qs.q_weights)
    assert ((0 <= np.asarray(ids0)) & (np.asarray(ids0) < cfg.n_docs)).all()


def test_host_scoring_kernel_path_matches(tiny):
    """score_selected_host(use_kernel=True) routes the unique-block dots
    through the cluster_score Pallas kernel — same fused results."""
    cfg, corpus, index, qs = tiny
    with tempfile.TemporaryDirectory() as d:
        store = DiskStore.create(os.path.join(d, "b.bin"),
                                 index.embeddings, index.cluster_docs)
        ids_ref, _, _ = pipeline.retrieve(cfg, index, store, qs.q_dense,
                                          qs.q_terms, qs.q_weights)
        ids_k, _, _ = pipeline.retrieve(cfg, index, store, qs.q_dense,
                                        qs.q_terms, qs.q_weights,
                                        use_kernel=True)
    np.testing.assert_array_equal(np.asarray(ids_k), np.asarray(ids_ref))


# ---------------------------------------------------------------------------
# LRU block cache
# ---------------------------------------------------------------------------

def test_block_cache_hit_miss_accounting():
    c = BlockCache(capacity=4)
    assert c.get(1) is None
    c.put(1, np.ones(3))
    assert np.all(c.get(1) == 1.0)
    assert (c.hits, c.misses) == (1, 1)
    c.get(2)
    assert (c.hits, c.misses) == (1, 2)
    assert c.hit_rate() == pytest.approx(1 / 3)
    st = c.stats()
    assert st["size"] == 1 and st["capacity"] == 4 and st["evictions"] == 0


def test_block_cache_eviction_order():
    c = BlockCache(capacity=2)
    c.put(1, "a")
    c.put(2, "b")
    c.get(1)            # 1 becomes most-recent
    c.put(3, "c")       # evicts 2 (LRU), not 1
    assert 2 not in c and 1 in c and 3 in c
    assert c.evictions == 1
    assert c.keys() == [1, 3]
    c.put(4, "d")       # evicts 1
    assert c.keys() == [3, 4]
    assert c.evictions == 2


def test_block_cache_get_or_fetch_many_single_flight():
    c = BlockCache(capacity=8)
    calls = []

    def fetch(cids):
        calls.append(list(cids))
        return np.stack([np.full(2, cid, np.float32) for cid in cids])

    out = c.get_or_fetch_many([1, 2, 1], fetch)
    assert set(out) == {1, 2} and calls == [[1, 2]]
    # second call: all hits, no new fetch
    out2 = c.get_or_fetch_many([1, 2], fetch)
    assert len(calls) == 1 and np.all(out2[2] == 2.0)
    assert c.hits == 2 and c.misses == 2
    # record=False (prefetch path) doesn't touch hit/miss accounting
    c.get_or_fetch_many([3], fetch, record=False)
    assert (c.hits, c.misses) == (2, 2) and 3 in c and len(calls) == 2


def test_block_cache_rejects_bad_capacity():
    with pytest.raises(ValueError):
        BlockCache(0)
    with pytest.raises(ValueError):
        BlockCache()                              # no bound at all
    with pytest.raises(ValueError):
        BlockCache(4, capacity_bytes=1024)        # ambiguous double bound
    with pytest.raises(ValueError):
        BlockCache(capacity_bytes=0)


def test_block_cache_byte_budget_accounting():
    """capacity_bytes bounds the ACTUAL stored bytes: replacing a block
    re-charges it, eviction refunds it, and stats reports the live total."""
    blk = lambda n: np.zeros(n, np.uint8)         # nbytes == n
    c = BlockCache(capacity_bytes=100)
    c.put(1, blk(40))
    c.put(2, blk(40))
    assert c.cached_bytes == 80 and c.evictions == 0
    c.put(1, blk(10))                             # replace: 40 -> 10
    assert c.cached_bytes == 50 and len(c) == 2
    c.put(3, blk(60))                             # 110 > 100: evict LRU (2)
    assert 2 not in c and c.cached_bytes == 70 and c.evictions == 1
    st = c.stats()
    assert st["cached_bytes"] == 70
    assert st["capacity_bytes"] == 100 and st["capacity"] is None


def test_block_cache_byte_budget_density():
    """The point of code-caching: a byte budget sized for F float blocks
    holds ~4*dim/nsub times more (smaller) code blocks."""
    cap, dim, nsub = 8, 32, 8
    budget = 4 * cap * dim * 4                    # 4 float32 blocks
    floats = BlockCache(capacity_bytes=budget)
    for i in range(10):
        floats.put(i, np.zeros((cap, dim), np.float32))
    assert len(floats) == 4
    codes = BlockCache(capacity_bytes=budget)
    for i in range(100):
        codes.put(i, np.zeros((cap, nsub), np.uint8))
    assert len(codes) == 4 * (4 * dim // nsub)    # 16x more clusters
    assert codes.cached_bytes <= budget


# ---------------------------------------------------------------------------
# bucketing + stage-2 budget fix
# ---------------------------------------------------------------------------

def test_bucket_size_power_of_two():
    assert [bucket_size(n, 64) for n in (1, 2, 3, 5, 8, 9, 33)] == \
        [1, 2, 4, 8, 8, 16, 64]
    assert bucket_size(100, 32) == 32
    with pytest.raises(ValueError):
        bucket_size(0, 32)


def test_stage2_budget_keeps_picked_negative_scores(tiny):
    """Regression for the `-1.0` sentinel bug: selectors emitting scores
    outside [0, 1] (or theta <= 0) must not corrupt the selection mask."""
    from repro.core.lstm import SELECTORS
    cfg, _, index, _ = tiny
    raw = jnp.asarray([[0.9, -0.4, -0.6, 0.2, -2.0, 0.1, -0.3, -5.0]])
    SELECTORS["_raw_test"] = (None, lambda params, feats: params)
    try:
        cand = jnp.arange(8, dtype=jnp.int32)[None, :]
        feats = jnp.zeros((1, 8, 4))
        out = cl.stage2_select(cfg, index, cand, feats,
                               selector="_raw_test", theta=-0.5,
                               selector_params=raw)
    finally:
        del SELECTORS["_raw_test"]
    # picked = score >= -0.5 -> {0.9, -0.4, 0.2, 0.1, -0.3}; budget 4 keeps
    # the top 4 by score, ALL valid (old code masked out every negative one)
    sel = np.asarray(out["sel_ids"])[0][np.asarray(out["sel_mask"])[0]]
    assert set(sel.tolist()) == {0, 3, 5, 6}
    assert int(np.asarray(out["sel_mask"]).sum()) == 4


# ---------------------------------------------------------------------------
# RetrievalEngine end-to-end
# ---------------------------------------------------------------------------

def test_engine_device_bucketing_matches_direct(tiny):
    cfg, _, index, qs = tiny
    ref, _, _ = cl.retrieve(cfg, index, qs.q_dense, qs.q_terms, qs.q_weights)
    eng = RetrievalEngine(cfg, index, max_batch=8)
    out = []
    for lo, hi in ((0, 5), (5, 8), (8, 12)):      # ragged: buckets 8, 4
        ids, _ = eng.retrieve(qs.q_dense[lo:hi], qs.q_terms[lo:hi],
                              qs.q_weights[lo:hi])
        out.append(np.asarray(ids))
    np.testing.assert_array_equal(np.concatenate(out), np.asarray(ref))
    assert eng.stats()["compiled_buckets"] == [4, 8]
    assert eng.serve_stats.n_queries == 12


def test_engine_host_dedups_and_caches(tiny):
    cfg, corpus, index, qs = tiny
    from repro.core import disk as dk
    ref, _, diag = cl.retrieve(cfg, index, qs.q_dense, qs.q_terms,
                               qs.q_weights)
    naive_ops = int(np.asarray(diag["sel_mask"]).sum())
    with tempfile.TemporaryDirectory() as d:
        blocks = dk.DiskClusterStore(os.path.join(d, "b.bin"),
                                     corpus.embeddings, index.cluster_docs)
        with RetrievalEngine(cfg, index,
                             store=DiskStore(blocks, index.cluster_docs),
                             max_batch=16, cache_capacity=32) as eng:
            ids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
            ops_first = eng.store.stats.n_ops
            # second identical pass: blocks already cached (incl. prefetch)
            ids2, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        st = eng.stats()    # after close(): prefetch drained, counters final
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(ref))
    # dedup across the batch: strictly fewer reads than one per (q, cluster)
    assert 0 < ops_first < naive_ops
    assert st["cache"]["hits"] > 0
    # the second pass was served without growing serving-path reads beyond
    # the unique-cluster set (prefetch may add candidate blocks, n <= N)
    assert st["io"]["n_ops"] <= index.n_clusters + ops_first


# ---------------------------------------------------------------------------
# ADC serving: code-backed stores through the fused engine tail
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def v2_reader(tiny, tmp_path_factory):
    """A format-v2 (PQ code shard) index over the tiny corpus, with an OPQ
    rotation so the LUT folding is exercised."""
    from repro import index as index_lib
    cfg, corpus, index, _ = tiny
    pq = quant_lib.train_pq(jax.random.key(1), corpus.embeddings, nsub=8,
                            rotate=True)
    out = str(tmp_path_factory.mktemp("adc") / "v2")
    index_lib.write_index(out, cfg, index, np.asarray(corpus.embeddings),
                          n_shards=3,
                          format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    return index_lib.IndexReader.open(out, verify="full")


def test_engine_adc_matches_decode_path(tiny, v2_reader):
    """Backend parity for the code path: the ADC engine (raw codes ->
    LUT scoring, zero host decode) returns the SAME fused top-k as the
    decode-then-score engine over the same v2 index — scores included."""
    _, _, _, qs = tiny
    res = {}
    for use_adc in (True, False):
        with v2_reader.engine(max_batch=16, cache_capacity=32,
                              use_adc=use_adc) as eng:
            ids, scores = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
            st = eng.stats()
        res[use_adc] = (np.asarray(ids), np.asarray(scores), st)
    ids_adc, sc_adc, st_adc = res[True]
    ids_dec, sc_dec, st_dec = res[False]
    np.testing.assert_array_equal(ids_adc, ids_dec)
    np.testing.assert_allclose(sc_adc, sc_dec, rtol=1e-5, atol=1e-5)
    # the ADC path never decoded a float block on the host
    assert st_adc["use_adc"] and st_adc["decode_ms"] == 0.0
    assert "adc_ms" in st_adc and "lut_build_ms" in st_adc
    assert not st_dec["use_adc"] and st_dec["decode_ms"] > 0.0
    # both paths read CODE bytes off disk (same shards)
    assert st_adc["io"]["bytes"] > 0
    # the cache holds code blocks under its byte budget
    assert 0 < st_adc["cache"]["cached_bytes"] \
        <= st_adc["cache"]["capacity_bytes"]


def test_engine_adc_auto_detection_and_validation(tiny, v2_reader):
    """use_adc=None auto-enables exactly for code-backed host stores;
    use_adc=True on a float store is a loud error."""
    cfg, corpus, index, _ = tiny
    with v2_reader.engine(max_batch=16) as eng:
        assert eng.use_adc                        # auto: v2 store is coded
    from repro.core import disk as dk
    with tempfile.TemporaryDirectory() as d:
        blocks = dk.DiskClusterStore(os.path.join(d, "b.bin"),
                                     corpus.embeddings, index.cluster_docs)
        store = DiskStore(blocks, index.cluster_docs)
        with RetrievalEngine(cfg, index, store=store, max_batch=16) as eng:
            assert not eng.use_adc
        with pytest.raises(ValueError):
            RetrievalEngine(cfg, index, store=store, use_adc=True)


def test_engine_adc_empty_selection(tiny, v2_reader):
    """All-padding sparse input (nothing selected) serves cleanly through
    the fused ADC tail with zero block I/O for scoring."""
    _, _, _, qs = tiny
    qt = np.full_like(np.asarray(qs.q_terms), -1)
    qw = np.zeros_like(np.asarray(qs.q_weights))
    with v2_reader.engine(max_batch=16, prefetch=False) as eng:
        ids, scores = eng.retrieve(qs.q_dense, qt, qw)
    assert np.asarray(ids).shape == (len(np.asarray(qs.q_dense)), eng.k)
    assert not np.isnan(np.asarray(scores)).any()
