"""Incremental index update tests (repro.index.update):

  * property (hypothesis, stub-compatible): ANY sequence of upsert/delete
    deltas applied on disk, followed by compaction, equals `write_index`
    of the same deltas applied in memory — byte-level for v1 block shards
    and arrays, code-level for v2 PQ shards (+ identical CSR postings)
  * a delta stamped for format v2 is rejected cleanly against a v1 index
    (and vice versa)
  * deletes rewrite ZERO shard bytes (tombstones) yet deleted docs vanish
    from dense fetch, sparse postings, and served top-k
  * atomic generations: commits bump the generation, archive the old
    manifest (still loadable + fully verifiable), refresh() adopts newer
    generations exactly once
  * RetrievalEngine.reload_index(): one engine serves across a commit with
    no failed requests, an invalidated block cache, and the new corpus
  * overflowing upserts trigger local shard re-clustering, preserving the
    compaction invariant
"""

import dataclasses
import os
import shutil

import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from test_index_properties import _random_index

from repro import index as index_lib
from repro.core import quant as quant_lib
from repro.index import format as fmt

jnp = pytest.importorskip("jax.numpy")


def _random_delta(rng, doc_cluster, n_slots, dim, vocab, dmax=3):
    """Feasible random delta against the current state: up to `dmax` each
    of deletes, replacements, and appends (appends bounded by free
    capacity)."""
    doc_cluster = np.asarray(doc_cluster)
    D = len(doc_cluster)
    live = np.flatnonzero(doc_cluster >= 0)
    n_del = int(rng.integers(0, min(dmax, len(live)) + 1))
    dele = rng.choice(live, n_del, replace=False) if n_del else \
        np.zeros(0, np.int64)
    rest = np.setdiff1d(live, dele)
    n_rep = int(rng.integers(0, min(dmax, len(rest)) + 1))
    reps = rng.choice(rest, n_rep, replace=False) if n_rep else \
        np.zeros(0, np.int64)
    free = n_slots - (len(live) - n_del - n_rep)
    n_app = int(rng.integers(0, max(0, min(dmax, free - n_rep)) + 1))
    ids = np.concatenate([reps, np.arange(D, D + n_app)]).astype(np.int64)
    U, T = len(ids), 4
    terms = rng.integers(0, vocab, (U, T)).astype(np.int32)
    terms[rng.random((U, T)) < 0.25] = -1
    weights = rng.lognormal(0.0, 0.5, (U, T)).astype(np.float32)
    return index_lib.IndexDelta(
        upsert_ids=ids,
        upsert_embeddings=rng.standard_normal((U, dim)).astype(np.float32),
        upsert_terms=terms, upsert_weights=weights, delete_ids=dele)


def _assert_same_artifacts(dir_a, man_a, dir_b, man_b):
    """Byte-compare every array and every block shard of two indexes."""
    assert set(man_a["arrays"]) == set(man_b["arrays"])
    for name, rel in man_a["arrays"].items():
        with open(os.path.join(dir_a, rel), "rb") as f:
            a = f.read()
        with open(os.path.join(dir_b, man_b["arrays"][name]), "rb") as f:
            b = f.read()
        assert a == b, f"array {name} differs"
    assert len(man_a["block_shards"]) == len(man_b["block_shards"])
    for s1, s2 in zip(man_a["block_shards"], man_b["block_shards"]):
        with open(os.path.join(dir_a, s1["file"]), "rb") as f:
            a = f.read()
        with open(os.path.join(dir_b, s2["file"]), "rb") as f:
            b = f.read()
        assert a == b, f"shard {s1['file']} differs"


def _run_delta_sequence(tmp_root, seed, format_version, n_deltas=2):
    """Shared property body: random index -> write -> delta sequence on
    disk -> compact; vs the same deltas applied in memory -> write."""
    cfg, index, emb = _random_index(seed)
    cfg = dataclasses.replace(
        cfg, max_postings=int(np.asarray(
            index.sparse_index.postings_docs).shape[1]))
    n_shards = 1 + seed % 3
    pq = None
    if format_version == index_lib.FORMAT_VERSION_PQ:
        nsub = 4 if emb.shape[1] % 4 == 0 else 8
        pq = quant_lib.train_pq(jax.random.key(seed), jnp.asarray(emb), nsub,
                                iters=2)
        index.quantizer = pq
    out = str(tmp_root / "live")
    index_lib.write_index(out, cfg, index, emb, n_shards=n_shards,
                          format_version=format_version, pq=pq)

    rng = np.random.default_rng(seed + 1)
    ref_index, ref_emb, ref_cfg = index, emb, cfg
    for _ in range(n_deltas):
        n_slots = int(np.asarray(ref_index.cluster_docs).size)
        delta = _random_delta(rng, np.asarray(ref_index.doc_cluster),
                              n_slots, emb.shape[1], cfg.vocab)
        report = index_lib.write_index_delta(out, delta)
        assert report["bytes_rewritten"] <= report["shard_bytes_total"]
        if delta.n_upserts == 0:         # delete-only: zero-rewrite
            assert report["bytes_rewritten"] == 0
        ref_index, ref_emb, _ = index_lib.apply_delta_to_index(
            ref_cfg, ref_index, ref_emb, delta, n_shards=n_shards)
        ref_cfg = dataclasses.replace(ref_cfg, n_docs=ref_index.n_docs)

    man_live = index_lib.compact_index(out)
    ref_out = str(tmp_root / "ref")
    man_ref = index_lib.write_index(
        ref_out, ref_cfg, ref_index, ref_emb, n_shards=n_shards,
        format_version=format_version, pq=ref_index.quantizer)
    _assert_same_artifacts(out, man_live, ref_out, man_ref)
    # the compacted index is fully valid + verifiable
    index_lib.IndexReader.open(out, verify="full")


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 10_000))
def test_delta_sequence_then_compaction_equals_rebuild_v1(tmp_path_factory,
                                                          seed):
    _run_delta_sequence(tmp_path_factory.mktemp("upd_v1"), seed,
                        index_lib.FORMAT_VERSION)


@settings(max_examples=5, deadline=None)
@given(st.integers(1, 10_000))
def test_delta_sequence_then_compaction_equals_rebuild_v2(tmp_path_factory,
                                                          seed):
    _run_delta_sequence(tmp_path_factory.mktemp("upd_v2"), seed,
                        index_lib.FORMAT_VERSION_PQ)


# ---------------------------------------------------------------------------
# fixed scenarios on a real (k-means-built) index
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def live_index(tmp_path_factory):
    """A real tiny index on disk + its corpus, rebuilt per module."""
    from test_index import _tiny_cfg
    from repro.core import clusd as cl
    from repro.data import synth_corpus

    cfg = _tiny_cfg()
    corpus = synth_corpus(11, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings, np.float32)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    src = str(tmp_path_factory.mktemp("upd_live") / "index")
    index_lib.write_index(src, cfg, index, emb, n_shards=4)
    return cfg, corpus, index, emb, src


def _fresh_copy(src, tmp_path, name="idx"):
    dst = str(tmp_path / name)
    shutil.copytree(src, dst)
    return dst


def _delta_from_corpus(cfg, corpus, *, upsert_ids, delete_ids, seed=3):
    rng = np.random.default_rng(seed)
    U = len(upsert_ids)
    emb = rng.standard_normal((U, cfg.dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    terms = rng.integers(0, cfg.vocab, (U, 8)).astype(np.int32)
    weights = rng.lognormal(0.0, 0.5, (U, 8)).astype(np.float32)
    return index_lib.IndexDelta(
        upsert_ids=np.asarray(upsert_ids, np.int64),
        upsert_embeddings=emb, upsert_terms=terms, upsert_weights=weights,
        delete_ids=np.asarray(delete_ids, np.int64))


def test_wrong_format_delta_rejected(live_index, tmp_path):
    """Satellite acceptance: a v2 delta against a v1 index fails up front
    with IndexFormatError (and a v1 delta against a v2 index likewise)."""
    cfg, corpus, index, emb, src = live_index
    out = _fresh_copy(src, tmp_path)
    delta = _delta_from_corpus(cfg, corpus, upsert_ids=[0], delete_ids=[])
    delta.format_version = index_lib.FORMAT_VERSION_PQ
    with pytest.raises(index_lib.IndexFormatError, match="format"):
        index_lib.write_index_delta(out, delta)
    # nothing was committed: still generation 0, fully verifiable
    reader = index_lib.IndexReader.open(out, verify="full")
    assert reader.generation == 0

    pq = quant_lib.train_pq(jax.random.key(1), jnp.asarray(emb), nsub=8,
                            iters=2)
    out_v2 = str(tmp_path / "v2")
    index_lib.write_index(out_v2, cfg, index, emb, n_shards=2,
                          format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    delta.format_version = index_lib.FORMAT_VERSION
    with pytest.raises(index_lib.IndexFormatError, match="format"):
        index_lib.write_index_delta(out_v2, delta)


def test_delete_only_delta_is_zero_rewrite_and_masks(live_index, tmp_path):
    cfg, corpus, index, emb, src = live_index
    out = _fresh_copy(src, tmp_path)
    dele = np.asarray([5, 17, 200, 201, 202], np.int64)
    victim_clusters = np.asarray(index.doc_cluster)[dele]
    delta = _delta_from_corpus(cfg, corpus, upsert_ids=[], delete_ids=dele)
    report = index_lib.write_index_delta(out, delta)
    assert report["bytes_rewritten"] == 0
    assert report["shards_rewritten"] == []

    reader = index_lib.IndexReader.open(out, verify="full")
    tomb = reader.tombstones()
    assert tomb is not None and tomb.sum() == len(dele)
    # the store masks tombstoned slots at fetch time: same bytes on disk,
    # docs reported -1/invalid
    store = reader.open_store()
    _, docs, valid = store.fetch_blocks(np.unique(victim_clusters))
    assert not np.isin(docs, dele).any()
    # deleted docs are gone from the loaded index's doc table and postings
    _, lindex = reader.load_index()
    assert not np.isin(np.asarray(lindex.cluster_docs), dele).any()
    assert not np.isin(np.asarray(lindex.sparse_index.postings_docs),
                       dele).any()
    assert np.all(np.asarray(lindex.doc_cluster)[dele] == -1)


def test_generation_archive_and_refresh(live_index, tmp_path):
    cfg, corpus, index, emb, src = live_index
    out = _fresh_copy(src, tmp_path)
    reader = index_lib.IndexReader.open(out)
    assert reader.generation == 0
    for i in range(2):
        delta = _delta_from_corpus(
            cfg, corpus, upsert_ids=[cfg.n_docs + i], delete_ids=[],
            seed=20 + i)
        index_lib.write_index_delta(out, delta)
    # stale reader sees gen 0 until refresh; refresh adopts exactly once
    assert reader.generation == 0
    assert reader.refresh() is True
    assert reader.generation == 2
    assert reader.refresh() is False
    # every older generation stays loadable AND fully verifiable
    for g in (0, 1):
        man = index_lib.load_manifest(out, generation=g)
        assert index_lib.manifest_generation(man) == g
        fmt.verify_files(out, man, level="full")
    with pytest.raises(index_lib.IndexFormatError, match="generation"):
        index_lib.load_manifest(out, generation=7)
    # compaction drops the history but keeps the lineage stamp
    man = index_lib.compact_index(out)
    assert man["generation"] == 3 and man["parent_generation"] == 2
    index_lib.IndexReader.open(out, verify="full")


def test_engine_hot_reload_serves_across_commit(live_index, tmp_path):
    from repro.data import synth_queries
    cfg, corpus, index, emb, src = live_index
    out = _fresh_copy(src, tmp_path)
    reader = index_lib.IndexReader.open(out)
    qs = synth_queries(7, corpus, 8)
    dele = np.asarray([40, 41, 42], np.int64)
    with reader.engine(max_batch=8, cache_capacity=64) as eng:
        pre_ids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        assert eng.stats()["generation"] == 0
        assert eng.stats()["cache"]["size"] > 0
        delta = _delta_from_corpus(
            cfg, corpus,
            upsert_ids=np.arange(cfg.n_docs, cfg.n_docs + 4),
            delete_ids=dele)
        index_lib.write_index_delta(out, delta)
        # old generation keeps serving until the explicit swap
        mid_ids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        np.testing.assert_array_equal(np.asarray(mid_ids),
                                      np.asarray(pre_ids))
        gen = eng.reload_index()
        assert gen == 1
        st = eng.stats()
        assert st["generation"] == 1 and st["reloads"] == 1
        assert st["cache"]["size"] == 0 and st["cache"]["clears"] >= 1
        post_ids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        assert not np.isin(np.asarray(post_ids), dele).any()
        assert eng.index.n_docs == cfg.n_docs + 4
    # engines not built from a reader refuse to reload
    from repro.engine import RetrievalEngine, InMemoryStore
    mem_eng = RetrievalEngine(cfg, index,
                              store=InMemoryStore(corpus.embeddings,
                                                  index.cluster_docs))
    with pytest.raises(ValueError, match="reader"):
        mem_eng.reload_index()


def test_overflow_triggers_local_recluster_and_keeps_parity(tmp_path):
    """Pack clusters to capacity, then upsert into them: placements
    overflow to next-nearest clusters, the shard re-clusters locally, and
    the compaction invariant still holds byte-for-byte."""
    from test_index_properties import _random_index
    cfg, index, emb = _random_index(17)
    cfg = dataclasses.replace(
        cfg, max_postings=int(np.asarray(
            index.sparse_index.postings_docs).shape[1]))
    cd = np.asarray(index.cluster_docs)
    n_clusters, cap = cd.shape
    out = str(tmp_path / "live")
    index_lib.write_index(out, cfg, index, emb, n_shards=2)

    rng = np.random.default_rng(0)
    live = np.flatnonzero(np.asarray(index.doc_cluster) >= 0)
    n_free = n_clusters * cap - len(live)
    dele = rng.choice(live, min(4, len(live) - 1), replace=False)
    n_app = min(4, n_free + len(dele))
    D = len(np.asarray(index.doc_cluster))
    delta = index_lib.IndexDelta(
        upsert_ids=np.arange(D, D + n_app),
        upsert_embeddings=rng.standard_normal(
            (n_app, emb.shape[1])).astype(np.float32),
        upsert_terms=rng.integers(0, cfg.vocab, (n_app, 4)).astype(np.int32),
        upsert_weights=rng.lognormal(0, 0.5, (n_app, 4)).astype(np.float32),
        delete_ids=dele)
    kw = dict(recluster_overflow=0.0, recluster_min_overflow=0,
              lloyd_iters=2)
    report = index_lib.write_index_delta(out, delta, **kw)
    assert report["reclustered_shards"], "recluster did not trigger"

    ref_index, ref_emb, ref_report = index_lib.apply_delta_to_index(
        cfg, index, emb, delta, n_shards=2, **kw)
    assert ref_report["reclustered_shards"] == report["reclustered_shards"]
    man_live = index_lib.compact_index(out)
    ref_out = str(tmp_path / "ref")
    man_ref = index_lib.write_index(
        ref_out, dataclasses.replace(cfg, n_docs=ref_index.n_docs),
        ref_index, ref_emb, n_shards=2)
    _assert_same_artifacts(out, man_live, ref_out, man_ref)
