"""Observability tests: metrics-registry semantics + thread safety,
stage-span tracing (nesting, deterministic sampling, export schema
round-trip through benchmarks/check_trace.py), the bounded ServeStats
rewrite, engine span/stat integration on a tiny disk-backed engine, and
a loose bound on the tracing-disabled hot-path cost."""

import dataclasses
import json
import os
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.obs import (
    NOOP_SPAN, NOOP_TRACE, MetricsRegistry, Tracer, write_metrics,
    write_trace)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("a.b")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    assert reg.counter("a.b") is c          # get-or-create returns the same
    g = reg.gauge("g")
    g.set(7)
    assert g.value == 7
    reg.reset()
    assert c.value == 0


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_counter_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    h = reg.histogram("lat", ring=64)
    n, per = 8, 10_000

    def work():
        for _ in range(per):
            c.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n * per
    snap = h.snapshot()
    assert snap["count"] == n * per
    assert snap["sum"] == pytest.approx(n * per)


def test_histogram_ring_bounded_and_percentiles_exact():
    reg = MetricsRegistry()
    h = reg.histogram("ms", ring=100)
    vals = np.arange(1000, dtype=np.float64)
    for v in vals:
        h.observe(float(v))
    # ring keeps only the most recent 100; lifetime count keeps all
    kept = np.asarray(h.values())
    assert len(kept) == 100
    np.testing.assert_array_equal(kept, vals[-100:])
    assert h.snapshot()["count"] == 1000
    # percentile matches np.percentile (linear interpolation) on the ring
    assert h.percentile(50) == pytest.approx(np.percentile(kept, 50))
    assert h.percentile(99) == pytest.approx(np.percentile(kept, 99))


def test_snapshot_and_prometheus_exposition(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serve.queries").inc(5)
    reg.gauge("cache.hit_rate").set(0.75)
    reg.histogram("serve.batch_ms", buckets=(1.0, 10.0, float("inf")))
    reg.histogram("serve.batch_ms").observe(0.5)
    reg.histogram("serve.batch_ms").observe(5.0)
    snap = reg.snapshot()
    assert snap["counters"]["serve.queries"] == 5
    assert snap["gauges"]["cache.hit_rate"] == 0.75
    assert snap["histograms"]["serve.batch_ms"]["count"] == 2
    text = reg.to_prometheus()
    assert "serve_queries 5" in text
    assert "cache_hit_rate 0.75" in text
    # cumulative buckets: le="10.0" counts both observations
    assert 'serve_batch_ms_bucket{le="1.0"} 1' in text
    assert 'serve_batch_ms_bucket{le="10.0"} 2' in text
    assert 'serve_batch_ms_bucket{le="+Inf"} 2' in text
    # write_metrics picks the format by suffix
    pj, pp = str(tmp_path / "m.json"), str(tmp_path / "m.prom")
    write_metrics(reg, pj)
    write_metrics(reg, pp)
    assert json.load(open(pj))["counters"]["serve.queries"] == 5
    assert "serve_queries 5" in open(pp).read()


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------

def _one_trace(tracer):
    tr = tracer.trace("batch", size=4)
    with tr.span("stage1"):
        time.sleep(0.001)
    with tr.span("cache_fetch", n_blocks=3) as sp:
        with tr.span("disk_fetch"):
            time.sleep(0.001)
        sp.annotate(bytes=4096)
    tr.finish(compiled=False)
    return tr


def test_span_nesting_and_annotations():
    tracer = Tracer(sample_rate=1.0)
    tr = _one_trace(tracer)
    names = [sp.name for sp in tr.spans]
    assert names == ["batch", "stage1", "cache_fetch", "disk_fetch"]
    assert [sp.depth for sp in tr.spans] == [0, 1, 1, 2]
    assert [sp.parent for sp in tr.spans] == [-1, 0, 0, 2]
    fetch = tr.spans[2]
    assert fetch.annot == {"n_blocks": 3, "bytes": 4096}
    assert tr.spans[0].annot == {"size": 4, "compiled": False}
    # children lie inside the root's window
    assert all((sp.t0_ms + sp.dur_ms) <= tr.dur_ms + 0.1 for sp in tr.spans)
    totals = tracer.span_totals("batch")
    assert set(totals) == {"stage1", "cache_fetch", "disk_fetch"}
    assert totals["stage1"]["count"] == 1


def test_export_schema_roundtrip(tmp_path):
    from benchmarks import check_trace
    tracer = Tracer(sample_rate=1.0)
    for _ in range(3):
        _one_trace(tracer)
    jp = str(tmp_path / "t.jsonl")
    cp = str(tmp_path / "t.json")
    write_trace(tracer, jp)
    write_trace(tracer, cp)
    # JSONL: every line round-trips and passes the CI schema checker
    lines = [json.loads(ln) for ln in open(jp)]
    assert len(lines) == 3 * 4
    assert {ln["span"] for ln in lines} == \
        {"batch", "stage1", "cache_fetch", "disk_fetch"}
    bad, n_traces, names = check_trace.check_jsonl(jp)
    assert bad == [] and n_traces == 3
    # Chrome export: valid JSON, complete events, passes the checker
    doc = json.load(open(cp))
    assert all(ev["ph"] == "X" for ev in doc["traceEvents"])
    bad_c, _, names_c = check_trace.check_chrome(cp)
    assert bad_c == [] and "disk_fetch" in names_c
    # the checker's CLI contract: exit 0 on valid, 1 on a missing span
    assert check_trace.main([jp, "--require-spans", "stage1"]) == 0
    assert check_trace.main([jp, "--require-spans", "nonexistent"]) == 1


def test_sampling_deterministic_and_bounded():
    tracer = Tracer(sample_rate=0.25, capacity=2)
    kinds = []
    for _ in range(8):
        tr = tracer.trace("batch")
        kinds.append(tr is NOOP_TRACE)
        tr.finish()
    # accumulator sampling: exactly every 4th request is recorded
    assert kinds == [True, True, True, False] * 2
    assert tracer.started == 2 and tracer.skipped == 6
    # retention is bounded by capacity
    tracer2 = Tracer(sample_rate=1.0, capacity=2)
    for _ in range(5):
        tracer2.trace("t").finish()
    assert len(tracer2.traces) == 2 and tracer2.dropped == 3


def test_disabled_path_is_noop_and_cheap():
    tracer = Tracer(sample_rate=0.0)
    tr = tracer.trace("batch")
    assert tr is NOOP_TRACE
    assert tr.span("anything") is NOOP_SPAN
    with tr.span("x") as sp:
        sp.annotate(bytes=1)
    tr.finish()
    assert tracer.traces == []
    # loose micro-bound: the disabled hot path (trace + 3 spans) must stay
    # well under anything that could perturb a millisecond-scale batch
    n = 2000
    t0 = time.perf_counter()
    for _ in range(n):
        t = tracer.trace("batch")
        with t.span("a"):
            pass
        with t.span("b"):
            pass
        with t.span("c"):
            pass
        t.finish()
    per_call_us = (time.perf_counter() - t0) / n * 1e6
    assert per_call_us < 50, f"disabled tracing costs {per_call_us:.1f}us"


# ---------------------------------------------------------------------------
# bounded ServeStats
# ---------------------------------------------------------------------------

def test_serve_stats_bounded_window():
    from repro.engine.server import ServeStats
    st = ServeStats(MetricsRegistry(), window=16)
    st.record(4, 4, True, 50.0)                 # compile batch: excluded
    for i in range(100):
        st.record(4, 4, False, float(i))
    assert st.n_batches == 101 and st.n_queries == 404
    assert st.n_compile_batches == 1
    # memory is bounded: the recent-batch ring holds `window` records
    assert len(st.batches) == 16
    assert len(st.batch_ms) == 16
    # percentiles computed over the steady ring, same fields as PR 6
    pct = st.latency_percentiles()
    assert set(pct) == {"p50_ms", "p99_ms", "mean_ms"}
    ring = np.asarray([float(i) for i in range(100)][-16:])
    assert pct["p50_ms"] == pytest.approx(
        round(float(np.percentile(ring, 50)), 3))
    st.reset()
    assert st.n_batches == 0 and st.latency_percentiles() == {}


# ---------------------------------------------------------------------------
# engine integration: spans + stats keys + reset semantics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine_parts():
    from repro.configs import get_config
    from repro.core import clusd as cl
    from repro.data import synth_corpus, synth_queries
    cfg = dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=256, dim=32, n_clusters=16, vocab=256, max_postings=256,
        k_sparse=64, bins=(5, 15, 30, 64), n_candidates=8, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=32)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    qs = synth_queries(7, corpus, 8)
    return cfg, corpus, index, qs


def test_engine_spans_and_stats_contract(tiny_engine_parts):
    from repro.engine import DiskStore, RetrievalEngine
    cfg, corpus, index, qs = tiny_engine_parts
    tracer = Tracer(sample_rate=1.0)
    with tempfile.TemporaryDirectory() as d:
        store = DiskStore.create(os.path.join(d, "blocks.bin"),
                                 index.embeddings, index.cluster_docs)
        with RetrievalEngine(cfg, index, store=store, max_batch=8,
                             cache_capacity=8, tracer=tracer) as eng:
            for _ in range(3):
                eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
            st = eng.stats()
            # PR-6 stats() keys stay intact (byte-compatible contract)
            for key in ("n_queries", "n_batches", "p50_ms", "p99_ms",
                        "mean_ms", "qps_steady", "compiled_buckets", "io",
                        "cache", "use_adc", "reloads", "selector_reloads",
                        "prefetch_enqueued", "prefetch_errors",
                        "n_compile_batches"):
                assert key in st, f"stats() lost key {key!r}"
            assert st["n_queries"] == 24 and st["n_compile_batches"] >= 1
            # every serve stage appears as a span (lut_build is ADC-only);
            # compile batches are flagged on the root, not dropped
            totals = tracer.span_totals("batch")
            for span in ("pad", "stage1", "stage2_select", "fuse",
                         "cache_fetch", "disk_fetch", "fused_score_topk"):
                assert span in totals, f"serve never emitted span {span!r}"
            flags = [tr.spans[0].annot.get("compiled")
                     for tr in tracer.traces if tr.name == "batch"]
            assert flags[0] is True and flags[-1] is False
            # registry mirrors the serve counters
            snap = eng.metrics.snapshot()
            assert snap["counters"]["serve.queries"] == 24
            # reset_stats: counters to zero, serving keeps working
            eng.reset_stats()
            st2 = eng.stats()
            assert st2["n_queries"] == 0 and st2["io"]["n_ops"] == 0
            assert st2["cache"]["hits"] == 0
            eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
            assert eng.stats()["n_queries"] == 8


def test_engine_span_coverage_of_batch_wall(tiny_engine_parts):
    """Depth-1 stage spans must explain >=90% of the measured batch time
    (the pq-sharded acceptance bound, exercised here on the disk path)."""
    from repro.engine import DiskStore, RetrievalEngine
    cfg, corpus, index, qs = tiny_engine_parts
    tracer = Tracer(sample_rate=1.0)
    with tempfile.TemporaryDirectory() as d:
        store = DiskStore.create(os.path.join(d, "blocks.bin"),
                                 index.embeddings, index.cluster_docs)
        with RetrievalEngine(cfg, index, store=store, max_batch=8,
                             cache_capacity=8, prefetch=False,
                             tracer=tracer) as eng:
            for _ in range(6):
                eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
    batch_wall = covered = 0.0
    for t in tracer.traces:
        if t.spans[0].annot.get("compiled"):
            continue                # compile batches measure XLA, not serving
        batch_wall += float(t.spans[0].annot["batch_ms"])
        covered += sum(sp.dur_ms for sp in t.spans
                       if sp.depth == 1 and sp.name != "pad")
    assert batch_wall > 0
    assert covered / batch_wall >= 0.9, \
        f"spans cover {covered / batch_wall:.0%} of batch wall time"
