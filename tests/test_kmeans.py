"""Coverage for core/kmeans.py: capacity-balanced cluster-table overflow
reassignment (nearest-with-space and round-robin paths), empty-cluster
reseeding in Lloyd's, determinism under a fixed seed, and the streaming
sharded k-means used by the offline index builder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kmeans as km


def _check_partition(table, doc_cluster, n_docs, cap):
    """Every doc placed exactly once, no cluster over cap, table/doc_cluster
    consistent."""
    table = np.asarray(table)
    dc = np.asarray(doc_cluster)
    members = table[table >= 0]
    assert sorted(members.tolist()) == list(range(n_docs)), \
        "docs must appear exactly once"
    assert ((table >= 0).sum(axis=1) <= cap).all()
    for c in range(table.shape[0]):
        for d in table[c][table[c] >= 0]:
            assert dc[d] == c


def test_build_cluster_table_no_overflow():
    assign = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    table, dc = km.build_cluster_table(assign, 3, cap=4)
    _check_partition(table, dc, 6, 4)
    np.testing.assert_array_equal(np.asarray(dc), np.asarray(assign))


def test_build_cluster_table_overflow_nearest_with_space():
    """Overflow docs go to their next-nearest centroid that has room."""
    rng = np.random.default_rng(0)
    X = rng.standard_normal((12, 4)).astype(np.float32)
    centroids = np.stack([X[:8].mean(0), X[8:].mean(0),
                          10.0 + rng.standard_normal(4).astype(np.float32)])
    assign = jnp.asarray([0] * 10 + [1] * 2, jnp.int32)   # cluster 0 over cap
    table, dc = km.build_cluster_table(assign, 3, cap=6, X=X,
                                       centroids=centroids)
    _check_partition(table, dc, 12, 6)
    dc = np.asarray(dc)
    # first 6 stayed in 0; the 4 overflow docs were re-homed
    assert (dc[:6] == 0).all()
    moved = dc[6:10]
    assert (moved != 0).all()
    # the far-away centroid 2 only receives docs when 1 has no room; with
    # cap 6 cluster 1 had 4 free slots for 4 overflow docs
    assert (moved == 1).all()


def test_build_cluster_table_overflow_round_robin_without_geometry():
    assign = jnp.asarray([0] * 7 + [1], jnp.int32)
    table, dc = km.build_cluster_table(assign, 4, cap=3)
    _check_partition(table, dc, 8, 3)


def test_build_cluster_table_deterministic():
    rng = np.random.default_rng(1)
    X = rng.standard_normal((64, 8)).astype(np.float32)
    cent, assign = km.kmeans(jax.random.key(3), jnp.asarray(X), 8, iters=4)
    t1, d1 = km.build_cluster_table(assign, 8, cap=16, X=X, centroids=cent)
    t2, d2 = km.build_cluster_table(assign, 8, cap=16, X=X, centroids=cent)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    _check_partition(t1, d1, 64, 16)


def test_build_cluster_table_total_capacity_exceeded():
    assign = jnp.zeros((10,), jnp.int32)
    X = np.random.default_rng(2).standard_normal((10, 4)).astype(np.float32)
    C = np.zeros((2, 4), np.float32)
    with pytest.raises(RuntimeError, match="capacity"):
        km.build_cluster_table(assign, 2, cap=4, X=X, centroids=C)


def test_kmeans_reseeds_empty_clusters():
    """More clusters than distinct points: empties get reseeded from data,
    centroids stay finite, assignments stay in range, runs are
    deterministic under a fixed key."""
    base = np.random.default_rng(4).standard_normal((4, 8)).astype(np.float32)
    X = jnp.asarray(np.repeat(base, 8, axis=0))     # 32 docs, 4 distinct
    c1, a1 = km.kmeans(jax.random.key(11), X, 16, iters=6)
    c2, a2 = km.kmeans(jax.random.key(11), X, 16, iters=6)
    assert np.isfinite(np.asarray(c1)).all()
    a1 = np.asarray(a1)
    assert ((a1 >= 0) & (a1 < 16)).all()
    np.testing.assert_array_equal(a1, np.asarray(a2))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))


def test_kmeans_shards_matches_partition_quality():
    """Streaming sharded Lloyd's produces a valid, deterministic clustering
    whose objective is in the same ballpark as single-shot kmeans."""
    rng = np.random.default_rng(5)
    X = rng.standard_normal((256, 8)).astype(np.float32)
    shards = [X[:100], X[100:180], X[180:]]
    c1, a1 = km.kmeans_shards(jax.random.key(6), shards, 8, iters=6)
    c2, a2 = km.kmeans_shards(jax.random.key(6), shards, 8, iters=6)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))
    a1 = np.asarray(a1)
    assert a1.shape == (256,) and ((a1 >= 0) & (a1 < 8)).all()

    def objective(C, a):
        C = np.asarray(C)
        return float(((X - C[np.asarray(a)]) ** 2).sum())

    cf, af = km.kmeans(jax.random.key(6), jnp.asarray(X), 8, iters=6)
    assert objective(c1, a1) <= 2.0 * objective(cf, af)
