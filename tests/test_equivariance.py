"""NequIP equivariance property tests: energies invariant under SO(3)
rotations + translations; l=1 features rotate as vectors."""

import jax
import jax.numpy as jnp
import numpy as np
from scipy.spatial.transform import Rotation

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro.configs import get_config
from repro.models import nequip as nq


def _random_batch(seed, N=40, E=150, G=2):
    rng = np.random.default_rng(seed)
    return {
        "positions": jnp.asarray(rng.standard_normal((N, 3)) * 2, jnp.float32),
        "species": jnp.asarray(rng.integers(0, 8, N), jnp.int32),
        "edge_src": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "edge_mask": jnp.ones(E, jnp.float32),
        "graph_id": jnp.asarray(rng.integers(0, G, N), jnp.int32),
        "energy_target": jnp.zeros(G, jnp.float32),
    }


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_energy_rotation_translation_invariant(seed):
    cfg = get_config("nequip", "smoke")
    params = nq.init_params(cfg, jax.random.key(0))
    batch = _random_batch(seed % 3)
    R = jnp.asarray(Rotation.random(random_state=seed).as_matrix(),
                    jnp.float32)
    t = jnp.asarray(np.random.default_rng(seed).standard_normal(3),
                    jnp.float32)
    e0 = nq.forward(cfg, params, batch)
    batch_rt = dict(batch, positions=batch["positions"] @ R.T + t)
    e1 = nq.forward(cfg, params, batch_rt)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=2e-4, atol=2e-5)


def test_l2_basis_roundtrip():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((7, 3, 3)), jnp.float32)
    M = nq.symtr(A)
    M2 = nq.from5(nq.to5(M))
    np.testing.assert_allclose(np.asarray(M), np.asarray(M2), atol=1e-5)
    # symtr output is symmetric and traceless
    np.testing.assert_allclose(np.asarray(M), np.asarray(
        jnp.swapaxes(M, -1, -2)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.trace(M, axis1=-2, axis2=-1)),
                               np.zeros(7), atol=1e-5)


def test_tensor_product_paths_equivariant():
    """Every TP path output transforms covariantly under rotation."""
    rng = np.random.default_rng(4)
    E, C = 16, 4
    R = jnp.asarray(Rotation.random(random_state=1).as_matrix(), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((E, C)), jnp.float32)
    h1 = jnp.asarray(rng.standard_normal((E, C, 3)), jnp.float32)
    h2 = nq.symtr(jnp.asarray(rng.standard_normal((E, C, 3, 3)), jnp.float32))
    y1 = jnp.asarray(rng.standard_normal((E, 3)), jnp.float32)
    y1 = y1 / jnp.linalg.norm(y1, axis=-1, keepdims=True)
    y2 = nq.symtr(jnp.einsum("ei,ej->eij", y1, y1))
    w = jnp.asarray(rng.standard_normal((E, nq.N_PATHS, C)), jnp.float32)

    m0, m1, m2 = nq.tensor_product(h0, h1, h2, jnp.ones(E), y1, y2, w)
    # rotated inputs
    h1r = jnp.einsum("ij,ecj->eci", R, h1)
    h2r = jnp.einsum("ij,ecjk,lk->ecil", R, h2, R)
    y1r = jnp.einsum("ij,ej->ei", R, y1)
    y2r = jnp.einsum("ij,ejk,lk->eil", R, y2, R)
    r0, r1, r2 = nq.tensor_product(h0, h1r, h2r, jnp.ones(E), y1r, y2r, w)
    np.testing.assert_allclose(np.asarray(r0), np.asarray(m0), atol=2e-4)
    np.testing.assert_allclose(np.asarray(r1),
                               np.asarray(jnp.einsum("ij,ecj->eci", R, m1)),
                               atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(r2),
        np.asarray(jnp.einsum("ij,ecjk,lk->ecil", R, m2, R)), atol=2e-4)
