"""System-level invariants: registry completeness, dry-run cell coverage,
artifact schema, and the roofline parser's trip-count math."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, cells, get_config
from repro.analysis.hlo import collective_bytes, hlo_cost

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _cost_analysis_returns_dict():
    """Old jax returns cost_analysis() as a one-element list of dicts;
    the trip-count test indexes it as a dict (jax >= 0.5 API)."""
    comp = jax.jit(lambda x: x + 1.0).lower(
        jax.ShapeDtypeStruct((1,), jnp.float32)).compile()
    return isinstance(comp.cost_analysis(), dict)


def test_cell_enumeration_is_40():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    skipped = [c for c in all_cells if c[2]]
    # long_500k skipped exactly for the 4 pure-full-attention LMs
    assert sorted(c[0] for c in skipped) == sorted(
        ["arctic-480b", "qwen2-1.5b", "deepseek-67b", "qwen2.5-32b"])


def test_dryrun_artifacts_complete_and_green():
    if not os.path.isdir(ART):
        import pytest
        pytest.skip("dry-run artifacts not generated in this checkout")
    ok = skip = fail = 0
    for f in os.listdir(ART):
        if not f.endswith(".json") or "_opt" in f or "paper_" in f:
            continue
        d = json.load(open(os.path.join(ART, f)))
        s = d.get("status")
        ok += s == "ok"
        skip += s == "skip"
        fail += s == "fail"
    assert fail == 0
    assert ok == 72 and skip == 8  # 36 runnable cells x 2 meshes


@pytest.mark.skipif(
    not _cost_analysis_returns_dict(),
    reason="installed jax returns compiled cost_analysis() as a list "
           "(dict form needs jax >= 0.5)")
def test_hlo_parser_counts_loop_trips():
    L, d = 6, 64

    def scanned(ws, x):
        def body(c, w):
            return c @ w, ()
        out, _ = jax.lax.scan(body, x, ws)
        return out

    comp = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((L, d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32)).compile()
    got = hlo_cost(comp.as_text())["flops"]
    want = L * 2 * d * d * d
    assert abs(got - want) / want < 0.05, (got, want)
    # XLA's own analysis counts the body once — that's why we parse
    assert comp.cost_analysis()["flops"] < want / 2


def test_model_flops_sane():
    from repro.analysis.roofline import model_flops
    from repro.configs.shapes import LM_SHAPES
    cfg = get_config("deepseek-67b")
    mf = model_flops(cfg, LM_SHAPES["train_4k"])
    # 6 * 67e9 * 1.05e6 tokens ~ 4.2e17, plus attention
    assert 3e17 < mf < 1e18
