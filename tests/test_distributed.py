"""Distributed CluSD tests.

Two tiers, skipped independently:

  * pure-host invariants of the blocked layout + shard ownership
    (build_blocked_index, shard_ranges/owner_of,
    shard_postings_by_owner) — run everywhere, no mesh needed; these
    pin the non-divisible-N ownership fix (the old
    `cluster // (N // n_shards)` rule assigned tail clusters to a
    nonexistent shard and silently dropped their postings)
  * multi-device mesh tests (8 virtual CPU devices via subprocess so the
    main pytest process keeps its single-device view) — skip on jax
    builds without jax.sharding.AxisType
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# mesh tests build via jax.make_mesh(..., axis_types=AxisType.Auto); the
# pure-host layout/ownership tests below run on any jax
needs_mesh = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType / make_mesh "
           "axis_types= (needs jax >= 0.6)")


def _run(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@needs_mesh
def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_config
        from repro.models import transformer as tf
        from repro.models.sharding import rules_ctx, named_sharding
        from repro.optim import adamw_init

        cfg = dataclasses.replace(get_config("qwen2-1.5b", "smoke"),
                                  dtype="float32", param_dtype="float32",
                                  n_heads=4, n_kv_heads=2)
        params = tf.init_params(cfg, jax.random.key(0))
        opt = adamw_init(params)
        B, S = 8, 32
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (B, S)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        step = tf.make_train_step(cfg)
        # single device
        p1, _, m1 = jax.jit(step)(params, opt, batch)
        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with rules_ctx({}, mesh=mesh):
            psh = tf.param_shardings(cfg, mesh)
            osh = {"mu": psh, "nu": psh,
                   "count": NamedSharding(mesh, P())}
            bsh = {k: named_sharding(mesh, "batch", None) for k in batch}
            p2, _, m2 = jax.jit(step, in_shardings=(psh, osh, bsh))(
                params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (
            float(m1["loss"]), float(m2["loss"]))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-4, worst
        print("OK sharded == single", float(m1["loss"]))
    """)
    assert "OK sharded" in out


@needs_mesh
def test_distributed_clusd_serve_matches_host():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data import synth_corpus, synth_queries, mrr_at
        from repro.core import clusd as cl, distributed as dist
        from repro.core import train_lstm as tl

        cfg = get_config("clusd-msmarco", "smoke")
        corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
        index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                               corpus.doc_terms, corpus.doc_weights)
        tq = synth_queries(1, corpus, 128)
        _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                          tq.q_weights)
        index.lstm_params, _ = tl.train_selector(
            cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels),
            epochs=10)
        bidx = dist.build_blocked_index(cfg, index)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        pd, pw = dist.shard_postings_by_owner(bidx, 4)
        N, cap = bidx.blocks.shape[:2]
        serve = dist.make_serve_step(cfg, mesh,
            (N, cap, cfg.dim, cfg.vocab, pd.shape[2],
             bidx.neighbor_ids.shape[1]), feats.shape[-1])
        test_q = synth_queries(7, corpus, 16)
        ids, scores = jax.jit(serve)(
            jnp.asarray(bidx.blocks), jnp.asarray(pd), jnp.asarray(pw),
            jnp.asarray(bidx.centroids), jnp.asarray(bidx.neighbor_ids),
            jnp.asarray(bidx.neighbor_sims), index.lstm_params,
            test_q.q_dense, test_q.q_terms, test_q.q_weights)
        new_to_old = np.full(N * cap, -1, np.int64)
        o2n = bidx.old_to_new
        new_to_old[o2n[o2n >= 0]] = np.nonzero(o2n >= 0)[0]
        ids_orig = new_to_old[np.asarray(ids)]
        ids1, _, _ = cl.retrieve(cfg, index, test_q.q_dense, test_q.q_terms,
                                 test_q.q_weights)
        overlap = np.mean([len(set(ids_orig[b, :10])
                               & set(np.asarray(ids1)[b, :10])) / 10
                           for b in range(16)])
        assert overlap > 0.9, overlap
        print("OK dist overlap", overlap)
    """)
    assert "OK dist overlap" in out


@needs_mesh
def test_compressed_psum_shardmap():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum, ef_init
        mesh = jax.make_mesh((8,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,))
        g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(
            (8, 64)), jnp.float32)}
        e = {"w": jnp.zeros((8, 64), jnp.float32)}

        def f(g, e):
            return compressed_psum(g, e, "data", 8)

        out, new_e = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))(
            {"w": g["w"]}, {"w": e["w"]})
        # each shard's dequantized sum approximates the true mean*8
        true = jnp.sum(g["w"], axis=0, keepdims=True)
        got = out["w"][0:1]
        err = float(jnp.max(jnp.abs(got - true)))
        scale = float(jnp.max(jnp.abs(true))) + 1e-6
        assert err / scale < 0.15, err / scale
        print("OK compressed psum", err / scale)
    """)
    assert "OK compressed psum" in out


@needs_mesh
def test_elastic_checkpoint_restore_new_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        mesh8 = jax.make_mesh((8,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,))
        mesh4 = jax.make_mesh((4, 2), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data", None)))
        tree = {"w": w}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 5, tree)
            new_sh = {"w": NamedSharding(mesh4, P("model", "data"))}
            restored, _ = restore_checkpoint(d, 5, tree, new_sh)
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(w))
            assert restored["w"].sharding == new_sh["w"]
        print("OK elastic restore")
    """)
    assert "OK elastic restore" in out


# ---------------------------------------------------------------------------
# pure-host layout + ownership invariants (no mesh; run on any jax)
# ---------------------------------------------------------------------------

def _tiny_blocked():
    from repro.configs import get_config
    from repro.core import clusd as cl, distributed as dist
    from repro.data import synth_corpus

    cfg = dataclasses.replace(get_config("clusd-msmarco", "smoke"),
                              n_docs=300, dim=16, n_clusters=9, vocab=128,
                              max_postings=64, k_sparse=32,
                              bins=(3, 6, 9), n_candidates=6,
                              max_selected=3, n_neighbors=4, u_bins=3,
                              k_final=16)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    return cfg, corpus, index, dist.build_blocked_index(cfg, index)


def test_blocked_index_roundtrip_invariants():
    """doc id = c*cap + s renumbering is a bijection on live docs, blocks
    carry the right embeddings, and postings renumber consistently."""
    _, corpus, index, bidx = _tiny_blocked()
    cd = np.asarray(index.cluster_docs)
    N, cap = cd.shape
    assert bidx.blocks.shape[:2] == (N, cap)
    # bijection: every live doc appears exactly once, at the slot its
    # cluster_docs entry names
    o2n = bidx.old_to_new
    live = o2n >= 0
    assert live.sum() == (cd >= 0).sum()
    assert len(np.unique(o2n[live])) == int(live.sum())
    c_idx, s_idx = np.nonzero(cd >= 0)
    np.testing.assert_array_equal(
        o2n[cd[c_idx, s_idx]], c_idx * cap + s_idx)
    # blocked id -> cluster is pure arithmetic
    np.testing.assert_array_equal((o2n[live] // cap),
                                  np.asarray(index.doc_cluster)[live])
    # block contents match the embeddings they renumber
    emb = np.asarray(corpus.embeddings)
    np.testing.assert_array_equal(bidx.blocks[c_idx, s_idx],
                                  emb[cd[c_idx, s_idx]])
    np.testing.assert_array_equal(bidx.valid, cd >= 0)
    # postings renumbered with pads preserved
    pd_old = np.asarray(index.sparse_index.postings_docs)
    assert bidx.postings_docs.shape == pd_old.shape
    np.testing.assert_array_equal(bidx.postings_docs < 0, pd_old < 0)
    real = pd_old >= 0
    np.testing.assert_array_equal(bidx.postings_docs[real],
                                  o2n[pd_old[real]])


def test_shard_ranges_balanced_total():
    from repro.core import distributed as dist
    for n_clusters in (1, 7, 8, 9, 64, 65):
        for n_shards in (1, 2, 3, 4, 8):
            if n_clusters < n_shards:
                with pytest.raises(ValueError):
                    dist.shard_ranges(n_clusters, n_shards)
                continue
            ranges = dist.shard_ranges(n_clusters, n_shards)
            assert ranges[0][0] == 0 and ranges[-1][1] == n_clusters
            sizes = [hi - lo for lo, hi in ranges]
            assert all(a == b for (_, a), (b, _)
                       in zip(ranges[:-1], ranges[1:]))   # no gaps
            assert max(sizes) - min(sizes) <= 1           # balanced
            # ownership total + consistent with the ranges
            owner = dist.owner_of(np.arange(n_clusters), ranges)
            for s, (lo, hi) in enumerate(ranges):
                np.testing.assert_array_equal(owner[lo:hi], s)
    with pytest.raises(ValueError):
        dist.owner_of([7], dist.shard_ranges(7, 2))       # id == n_clusters


def test_shard_postings_by_owner_covers_non_divisible():
    """Every posting lands on exactly one shard — the shard owning its
    doc's cluster — including when n_clusters % n_shards != 0 (the old
    owner rule silently dropped the tail clusters' postings)."""
    from repro.core import distributed as dist
    _, _, _, bidx = _tiny_blocked()
    N, cap = bidx.blocks.shape[:2]
    assert N == 9
    for n_shards in (2, 3, 4):             # 9 % 2, 9 % 4 != 0
        docs, ws = dist.shard_postings_by_owner(bidx, n_shards)
        V = bidx.postings_docs.shape[0]
        assert docs.shape[:2] == (V, n_shards)
        ranges = dist.shard_ranges(N, n_shards)
        total = 0
        for t in range(V):
            orig = bidx.postings_docs[t]
            orig_real = np.sort(orig[orig >= 0])
            got = docs[t][docs[t] >= 0]
            # nothing dropped, nothing duplicated
            np.testing.assert_array_equal(np.sort(got), orig_real)
            total += len(got)
            # every posting sits on the shard owning its cluster
            for s in range(n_shards):
                mine = docs[t, s][docs[t, s] >= 0]
                if len(mine):
                    np.testing.assert_array_equal(
                        dist.owner_of(mine // cap, ranges), s)
                # weights travel with their docs
                k = len(mine)
                assert (ws[t, s, k:] == 0).all()
        assert total == int((bidx.postings_docs >= 0).sum())
