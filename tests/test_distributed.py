"""Multi-device tests (8 virtual CPU devices via subprocess so the main
pytest process keeps its single-device view)."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# every test builds a mesh via jax.make_mesh(..., axis_types=AxisType.Auto)
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType / make_mesh "
           "axis_types= (needs jax >= 0.6)")


def _run(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_config
        from repro.models import transformer as tf
        from repro.models.sharding import rules_ctx, named_sharding
        from repro.optim import adamw_init

        cfg = dataclasses.replace(get_config("qwen2-1.5b", "smoke"),
                                  dtype="float32", param_dtype="float32",
                                  n_heads=4, n_kv_heads=2)
        params = tf.init_params(cfg, jax.random.key(0))
        opt = adamw_init(params)
        B, S = 8, 32
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (B, S)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        step = tf.make_train_step(cfg)
        # single device
        p1, _, m1 = jax.jit(step)(params, opt, batch)
        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with rules_ctx({}, mesh=mesh):
            psh = tf.param_shardings(cfg, mesh)
            osh = {"mu": psh, "nu": psh,
                   "count": NamedSharding(mesh, P())}
            bsh = {k: named_sharding(mesh, "batch", None) for k in batch}
            p2, _, m2 = jax.jit(step, in_shardings=(psh, osh, bsh))(
                params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (
            float(m1["loss"]), float(m2["loss"]))
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-4, worst
        print("OK sharded == single", float(m1["loss"]))
    """)
    assert "OK sharded" in out


def test_distributed_clusd_serve_matches_host():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.data import synth_corpus, synth_queries, mrr_at
        from repro.core import clusd as cl, distributed as dist
        from repro.core import train_lstm as tl

        cfg = get_config("clusd-msmarco", "smoke")
        corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
        index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                               corpus.doc_terms, corpus.doc_weights)
        tq = synth_queries(1, corpus, 128)
        _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                          tq.q_weights)
        index.lstm_params, _ = tl.train_selector(
            cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels),
            epochs=10)
        bidx = dist.build_blocked_index(cfg, index)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        pd, pw = dist.shard_postings_by_owner(bidx, 4)
        N, cap = bidx.blocks.shape[:2]
        serve = dist.make_serve_step(cfg, mesh,
            (N, cap, cfg.dim, cfg.vocab, pd.shape[2],
             bidx.neighbor_ids.shape[1]), feats.shape[-1])
        test_q = synth_queries(7, corpus, 16)
        ids, scores = jax.jit(serve)(
            jnp.asarray(bidx.blocks), jnp.asarray(pd), jnp.asarray(pw),
            jnp.asarray(bidx.centroids), jnp.asarray(bidx.neighbor_ids),
            jnp.asarray(bidx.neighbor_sims), index.lstm_params,
            test_q.q_dense, test_q.q_terms, test_q.q_weights)
        new_to_old = np.full(N * cap, -1, np.int64)
        o2n = bidx.old_to_new
        new_to_old[o2n[o2n >= 0]] = np.nonzero(o2n >= 0)[0]
        ids_orig = new_to_old[np.asarray(ids)]
        ids1, _, _ = cl.retrieve(cfg, index, test_q.q_dense, test_q.q_terms,
                                 test_q.q_weights)
        overlap = np.mean([len(set(ids_orig[b, :10])
                               & set(np.asarray(ids1)[b, :10])) / 10
                           for b in range(16)])
        assert overlap > 0.9, overlap
        print("OK dist overlap", overlap)
    """)
    assert "OK dist overlap" in out


def test_compressed_psum_shardmap():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import compressed_psum, ef_init
        mesh = jax.make_mesh((8,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,))
        g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(
            (8, 64)), jnp.float32)}
        e = {"w": jnp.zeros((8, 64), jnp.float32)}

        def f(g, e):
            return compressed_psum(g, e, "data", 8)

        out, new_e = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))(
            {"w": g["w"]}, {"w": e["w"]})
        # each shard's dequantized sum approximates the true mean*8
        true = jnp.sum(g["w"], axis=0, keepdims=True)
        got = out["w"][0:1]
        err = float(jnp.max(jnp.abs(got - true)))
        scale = float(jnp.max(jnp.abs(true))) + 1e-6
        assert err / scale < 0.15, err / scale
        print("OK compressed psum", err / scale)
    """)
    assert "OK compressed psum" in out


def test_elastic_checkpoint_restore_new_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        mesh8 = jax.make_mesh((8,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,))
        mesh4 = jax.make_mesh((4, 2), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data", None)))
        tree = {"w": w}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 5, tree)
            new_sh = {"w": NamedSharding(mesh4, P("model", "data"))}
            restored, _ = restore_checkpoint(d, 5, tree, new_sh)
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(w))
            assert restored["w"].sharding == new_sh["w"]
        print("OK elastic restore")
    """)
    assert "OK elastic restore" in out
