"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward + one train step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.optim import adamw_init

LM = ["arctic-480b", "mixtral-8x7b", "qwen2-1.5b", "deepseek-67b",
      "qwen2.5-32b"]
RECSYS = ["wide-deep", "din", "deepfm", "dlrm-mlperf"]


def _recsys_batch(cfg, B, rng):
    batch = {"sparse": jnp.stack(
        [jnp.asarray(rng.integers(0, r, B), jnp.int32)
         for r in cfg.table_sizes], 1),
        "label": jnp.asarray(rng.integers(0, 2, B), jnp.int32)}
    if cfg.kind == "dlrm":
        batch["dense"] = jnp.asarray(rng.standard_normal((B, cfg.n_dense)),
                                     jnp.float32)
    if cfg.kind == "din":
        batch["hist_item"] = jnp.asarray(
            rng.integers(0, cfg.table_sizes[0], (B, cfg.seq_len)), jnp.int32)
        batch["hist_cate"] = jnp.asarray(
            rng.integers(0, cfg.table_sizes[1], (B, cfg.seq_len)), jnp.int32)
        batch["hist_mask"] = jnp.ones((B, cfg.seq_len), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", LM)
def test_lm_smoke(arch, rng):
    from repro.models import transformer as tf
    cfg = get_config(arch, "smoke")
    params = tf.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    h, _, aux = jax.jit(lambda p, t: tf.forward(cfg, p, t))(params, tokens)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    step = jax.jit(tf.make_train_step(cfg))
    p2, o2, m = step(params, adamw_init(params), {"tokens": tokens,
                                                  "labels": tokens})
    assert np.isfinite(float(m["loss"]))
    # decode path
    pf = jax.jit(tf.make_prefill_step(cfg))
    logits, cache = pf(params, tokens)
    assert logits.shape == (B, cfg.vocab_size)
    dec = jax.jit(tf.make_decode_step(cfg))
    cache_z = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype),
                           tf.abstract_cache(cfg, B, 64))
    lg, _ = dec(params, cache_z, tokens[:, :1], jnp.int32(3))
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_lm_microbatch_equivalence(rng):
    """Gradient accumulation must match the monolithic step."""
    import dataclasses
    from repro.models import transformer as tf
    cfg = dataclasses.replace(get_config("qwen2-1.5b", "smoke"),
                              dtype="float32", param_dtype="float32")
    params = tf.init_params(cfg, jax.random.key(0))
    B, S = 4, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    p1, _, m1 = jax.jit(tf.make_train_step(cfg))(
        params, adamw_init(params), batch)
    cfg2 = dataclasses.replace(cfg, microbatch=2)
    p2, _, m2 = jax.jit(tf.make_train_step(cfg2))(
        params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3  # adam normalizes the tiny g-diff


def test_nequip_smoke(rng):
    from repro.data.graphs import synth_molecules
    from repro.models import nequip as nq
    cfg = get_config("nequip", "smoke")
    params = nq.init_params(cfg, jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, synth_molecules(0, 4, 10, 24,
                                                      cfg.n_species))
    e = jax.jit(lambda p, b: nq.forward(cfg, p, b))(params, batch)
    assert e.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(e)))
    step = jax.jit(nq.make_train_step(cfg))
    _, _, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_smoke(arch, rng):
    from repro.models import recsys as rs
    cfg = get_config(arch, "smoke")
    params = rs.init_params(cfg, jax.random.key(1))
    batch = _recsys_batch(cfg, 16, rng)
    step = jax.jit(rs.make_train_step(cfg))
    _, _, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
    scores = jax.jit(rs.make_serve_step(cfg))(params, batch)
    assert scores.shape == (16,)
    assert bool(jnp.all((scores >= 0) & (scores <= 1)))


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_retrieval(arch, rng):
    from repro.models import recsys as rs
    cfg = get_config(arch, "smoke")
    params = rs.init_params(cfg, jax.random.key(1))
    batch = _recsys_batch(cfg, 2, rng)
    cand = jnp.stack([jnp.asarray(rng.integers(0, cfg.table_sizes[i], 300),
                                  jnp.int32) for i in range(2)], 1)
    scores, idx = jax.jit(rs.make_retrieval_step(cfg, k=10))(params, batch,
                                                             cand)
    assert scores.shape == (2, 10) and idx.shape == (2, 10)
    assert bool(jnp.all(scores[:, :-1] >= scores[:, 1:]))  # sorted


def test_all_assigned_archs_have_configs():
    assert len(ASSIGNED_ARCHS) == 10
    for arch in ASSIGNED_ARCHS:
        full = get_config(arch, "full")
        smoke = get_config(arch, "smoke")
        assert full.family == smoke.family


def test_param_counts_match_scale():
    cfg = get_config("arctic-480b")
    assert 4.4e11 < cfg.param_count() < 5.2e11       # ~480B
    assert cfg.active_param_count() < 3.5e10          # ~17B + dense active
    mx = get_config("mixtral-8x7b")
    assert 4.4e10 < mx.param_count() < 4.9e10         # ~46.7B
    ds = get_config("deepseek-67b")
    assert 6.2e10 < ds.param_count() < 7.2e10
    qw = get_config("qwen2-1.5b")
    assert 1.2e9 < qw.param_count() < 2.1e9
