"""shard_map MoE dispatch (§Perf optimized paths) must match the pjit
baseline numerically in the no-capacity-drop regime, for both the
expert-parallel and the few-experts tensor-parallel variants."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# every test builds a mesh via jax.make_mesh(..., axis_types=AxisType.Auto)
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="installed jax lacks jax.sharding.AxisType / make_mesh "
           "axis_types= (needs jax >= 0.6)")


def _run(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_shardmap_moe_matches_baseline():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import moe as moe_lib
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        rng = np.random.default_rng(0)
        T, d, f, E, K = 64, 32, 16, 8, 2
        x = jnp.asarray(rng.standard_normal((T, d)) * 0.5, jnp.float32)
        rw = jnp.asarray(rng.standard_normal((d, E)) * 0.3, jnp.float32)
        wg = jnp.asarray(rng.standard_normal((E, d, f)) * 0.2, jnp.float32)
        wu = jnp.asarray(rng.standard_normal((E, d, f)) * 0.2, jnp.float32)
        wd = jnp.asarray(rng.standard_normal((E, f, d)) * 0.2, jnp.float32)
        ref, _ = jax.jit(lambda *a: moe_lib.moe_ffn(
            *a, top_k=K, ep=False))(x, rw, wg, wu, wd)
        for fn in (moe_lib.moe_ffn_tp_shardmap, moe_lib.moe_ffn_ep_shardmap):
            got, _ = jax.jit(lambda *a: fn(*a, top_k=K, mesh=mesh))(
                x, rw, wg, wu, wd)
            err = float(jnp.max(jnp.abs(got - ref)))
            assert err < 1e-4, (fn.__name__, err)
        print("OK moe dispatch equivalence")
    """)
    assert "OK moe" in out


def test_shardmap_moe_transformer_grad_flows():
    """Full train step with the shard_map dispatch: finite loss + grads."""
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import transformer as tf
        from repro.models.sharding import rules_ctx, named_sharding
        from repro.optim import adamw_init
        from jax.sharding import PartitionSpec as P, NamedSharding

        cfg = dataclasses.replace(get_config("mixtral-8x7b", "smoke"),
                                  moe_impl="tp_shard_map")
        mesh = jax.make_mesh((2, 4), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        params = tf.init_params(cfg, jax.random.key(0))
        batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (8, 32)), jnp.int32)}
        batch["labels"] = batch["tokens"]
        with rules_ctx({}, mesh=mesh):
            psh = tf.param_shardings(cfg, mesh)
            osh = {"mu": psh, "nu": psh, "count": NamedSharding(mesh, P())}
            bsh = {k: named_sharding(mesh, "batch", None) for k in batch}
            step = jax.jit(tf.make_train_step(cfg),
                           in_shardings=(psh, osh, bsh))
            p, o, m = step(params, adamw_init(params), batch)
        assert np.isfinite(float(m["loss"])), float(m["loss"])
        print("OK shard_map train step loss", float(m["loss"]))
    """)
    assert "OK shard_map train step" in out
