"""Property-based invariants for the on-disk index formats (hypothesis,
falling back to the deterministic tests/_hypothesis_stub.py sweep):

  * arbitrary-geometry write -> read round trips: v1 block shards are
    byte-identical to reference packing, v2 code shards are code-identical,
    and CSR postings re-pad losslessly — including odd shapes (n_docs not
    divisible by cap, single cluster, singleton shards)
  * full-verify checksums catch ANY single flipped bit in ANY artifact
  * run-coalesced fetch_clusters returns exactly the same arrays as naive
    per-cluster reads, with one I/O op per run
"""

import dataclasses
import os
import shutil

import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro import index as index_lib
from repro.configs import get_config
from repro.core import quant as quant_lib
from repro.core.clusd import CluSDIndex
from repro.core.disk import pack_blocks
from repro.core.sparse import SparseIndex

jnp = pytest.importorskip("jax.numpy")


def _random_index(seed):
    """Arbitrary-geometry CluSDIndex built directly (no k-means): a random
    valid partition of D docs into N clusters of size <= cap, random
    embeddings, and left-aligned impact-ordered postings."""
    rng = np.random.default_rng(seed)
    n_clusters = 1 if seed % 5 == 0 else int(rng.integers(2, 24))
    cap = int(rng.integers(3, 17))
    # odd shapes on purpose: D rarely divides cap * n_clusters
    n_docs = int(rng.integers(1, n_clusters * cap + 1))
    dim = int(rng.choice([8, 16, 24]))
    emb = rng.standard_normal((n_docs, dim)).astype(np.float32)

    perm = rng.permutation(n_docs)
    cd = np.full((n_clusters, cap), -1, np.int32)
    dc = np.zeros(n_docs, np.int32)
    sizes = np.zeros(n_clusters, np.int64)
    for d in perm:                       # random feasible placement
        c = rng.integers(0, n_clusters)
        while sizes[c] >= cap:
            c = (c + 1) % n_clusters
        cd[c, sizes[c]] = d
        dc[d] = c
        sizes[c] += 1

    vocab = int(rng.integers(4, 40))
    P = int(rng.integers(1, 9))
    pd = np.full((vocab, P), -1, np.int32)
    pw = np.zeros((vocab, P), np.float32)
    for t in range(vocab):               # left-aligned, like SparseIndex.build
        n = int(rng.integers(0, P + 1))
        pd[t, :n] = rng.integers(0, n_docs, n)
        pw[t, :n] = np.sort(rng.random(n).astype(np.float32))[::-1]
    sp = SparseIndex(jnp.asarray(pd), jnp.asarray(pw), n_docs)

    m = max(1, min(4, n_clusters - 1)) if n_clusters > 1 else 1
    nb = rng.integers(0, n_clusters, (n_clusters, m)).astype(np.int32)
    index = CluSDIndex(
        centroids=jnp.asarray(rng.standard_normal(
            (n_clusters, dim)).astype(np.float32)),
        cluster_docs=jnp.asarray(cd), doc_cluster=jnp.asarray(dc),
        neighbor_ids=jnp.asarray(nb),
        neighbor_sims=jnp.asarray(rng.random(nb.shape).astype(np.float32)),
        embeddings=None, sparse_index=sp,
        bin_ids=jnp.asarray(np.arange(8, dtype=np.int32)))
    cfg = dataclasses.replace(get_config("clusd-msmarco", "smoke"),
                              n_docs=n_docs, dim=dim, n_clusters=n_clusters,
                              vocab=vocab)
    return cfg, index, emb


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000))
def test_v1_roundtrip_blocks_byte_identical(tmp_path_factory, seed):
    cfg, index, emb = _random_index(seed)
    cd = np.asarray(index.cluster_docs)
    n_shards = 1 + seed % 4
    out = str(tmp_path_factory.mktemp("prop_v1") / "index")
    manifest = index_lib.write_index(out, cfg, index, emb,
                                     n_shards=n_shards,
                                     chunk_docs=max(cd.shape[1], 16))
    assert manifest["format_version"] == 1
    reader = index_lib.IndexReader.open(out, verify="full")
    for s in manifest["block_shards"]:
        lo, hi = s["cluster_lo"], s["cluster_hi"]
        expected = pack_blocks(emb, cd[lo:hi], np.float32).tobytes()
        with open(os.path.join(out, s["file"]), "rb") as f:
            assert f.read() == expected, s["file"]
    # and the store returns those exact blocks
    store = reader.open_store()
    vecs, _, _ = store.fetch_blocks(np.arange(cd.shape[0]))
    np.testing.assert_array_equal(np.asarray(vecs),
                                  pack_blocks(emb, cd, np.float32))
    shutil.rmtree(out, ignore_errors=True)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_v2_roundtrip_codes_identical(tmp_path_factory, seed):
    cfg, index, emb = _random_index(seed)
    cd = np.asarray(index.cluster_docs)
    nsub = 4 if emb.shape[1] % 4 == 0 else 8
    pq = quant_lib.train_pq(jax.random.key(seed), jnp.asarray(emb), nsub,
                            iters=2)
    codes = np.asarray(pq.codes).astype(np.uint8)
    out = str(tmp_path_factory.mktemp("prop_v2") / "index")
    manifest = index_lib.write_index(
        out, cfg, index, emb, n_shards=1 + seed % 3,
        format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    reader = index_lib.IndexReader.open(out, verify="full")
    for s in manifest["block_shards"]:
        lo, hi = s["cluster_lo"], s["cluster_hi"]
        block = np.zeros((hi - lo, cd.shape[1], nsub), np.uint8)
        mask = cd[lo:hi] >= 0
        block[mask] = codes[cd[lo:hi][mask]]
        with open(os.path.join(out, s["file"]), "rb") as f:
            assert f.read() == block.tobytes(), s["file"]
    # per-doc codes survive the shard round trip exactly
    _, lindex = reader.load_index()
    np.testing.assert_array_equal(np.asarray(reader.quantizer().codes),
                                  np.asarray(pq.codes))
    # CSR postings re-pad losslessly: same valid (doc, weight) multiset
    pd = np.asarray(index.sparse_index.postings_docs)
    pw = np.asarray(index.sparse_index.postings_weights)
    pd2 = np.asarray(lindex.sparse_index.postings_docs)
    pw2 = np.asarray(lindex.sparse_index.postings_weights)
    np.testing.assert_array_equal(pd2[pd2 >= 0], pd[pd >= 0])
    np.testing.assert_array_equal(pw2[pd2 >= 0], pw[pd >= 0])
    shutil.rmtree(out, ignore_errors=True)


# ---------------------------------------------------------------------------
# checksums + coalescing over one fixed index, many probes
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prop_index(tmp_path_factory):
    cfg, index, emb = _random_index(17)          # 17 % 5 != 0: multi-cluster
    base = tmp_path_factory.mktemp("prop_fix")
    out1 = str(base / "v1")
    index_lib.write_index(out1, cfg, index, emb, n_shards=3)
    pq = quant_lib.train_pq(jax.random.key(0), jnp.asarray(emb),
                            4 if emb.shape[1] % 4 == 0 else 8, iters=2)
    out2 = str(base / "v2")
    index_lib.write_index(out2, cfg, index, emb, n_shards=3,
                          format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    return out1, out2


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1 << 30))
def test_full_verify_catches_any_single_bit_flip(prop_index, tmp_path_factory,
                                                 seed):
    """Flip one random bit of one random artifact in a copy of the index:
    verify="full" must reject; verify="none" must not mask the corruption
    check (it is an explicit opt-out)."""
    rng = np.random.default_rng(seed)
    src = prop_index[seed % 2]
    dst = str(tmp_path_factory.mktemp("flip") / "index")
    shutil.copytree(src, dst)
    manifest = index_lib.load_manifest(dst)
    files = sorted(manifest["files"])
    rel = files[int(rng.integers(0, len(files)))]
    path = os.path.join(dst, rel)
    size = os.path.getsize(path)
    off = int(rng.integers(0, size))
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1)[0]
        f.seek(off)
        f.write(bytes([byte ^ (1 << int(rng.integers(0, 8)))]))
    with pytest.raises(index_lib.IndexChecksumError, match="sha256|size"):
        index_lib.IndexReader.open(dst, verify="full")
    shutil.rmtree(dst, ignore_errors=True)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1 << 30))
def test_coalesced_fetch_matches_naive_reads(prop_index, seed):
    """fetch_clusters over any sorted-unique id set == concatenated
    one-cluster fetches, for both store kinds, with ops == run count."""
    rng = np.random.default_rng(seed)
    for out in prop_index:
        reader = index_lib.IndexReader.open(out)
        store = reader.open_store()
        N = store.n_clusters
        n_pick = int(rng.integers(1, N + 1))
        ids = np.sort(rng.choice(N, n_pick, replace=False))
        batched = np.asarray(store.fetch_clusters(ids))
        naive = np.concatenate([np.asarray(store.fetch_clusters([i]))
                                for i in ids])
        np.testing.assert_array_equal(batched, naive)
        # ops for the batched read == number of (shard, adjacency) runs
        fresh = reader.open_store()
        fresh.fetch_clusters(ids)
        sid = np.searchsorted(fresh._hi, ids, side="right")
        runs = 1 + int(((np.diff(ids) != 1) | (np.diff(sid) != 0)).sum())
        assert fresh.stats.n_ops == runs
        assert fresh.stats.bytes == n_pick * fresh.block_bytes
