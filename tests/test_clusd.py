"""CluSD system tests: stage-1 invariants (hypothesis property tests),
LSTM training improves selection, end-to-end quality, fusion exactness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro.configs import get_config
from repro.core import bins as bins_lib
from repro.core import clusd as cl
from repro.core import fusion as fusion_lib
from repro.core import sparse as sparse_lib
from repro.core import stage1 as stage1_lib
from repro.core import train_lstm as tl
from repro.data import mrr_at, recall_at, synth_corpus, synth_queries


@pytest.fixture(scope="module")
def small_index():
    cfg = get_config("clusd-msmarco", "smoke")
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    return cfg, corpus, index


# ---------------------------------------------------------------------------
# stage 1 properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_multikey_sort_is_lexicographic(seed):
    rng = np.random.default_rng(seed)
    N, v, n = 40, 4, 10
    P = jnp.asarray(rng.integers(0, 4, (1, N, v)), jnp.float32)
    sim = jnp.asarray(rng.random((1, N)), jnp.float32)
    got = np.asarray(stage1_lib.sort_by_overlap(P, sim, n))[0]
    keys = [tuple(-np.asarray(P[0, c])) + (-float(sim[0, c]),)
            for c in range(N)]
    want = sorted(range(N), key=lambda c: keys[c])[:n]
    assert list(got) == list(want)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_overlap_counts_match_bruteforce(seed):
    rng = np.random.default_rng(seed)
    D, N, k, v = 200, 16, 50, 4
    bins = (5, 15, 30, 50)
    doc_cluster = jnp.asarray(rng.integers(0, N, D), jnp.int32)
    top = jnp.asarray(rng.choice(D, (2, k), replace=False), jnp.int32)
    scores = jnp.asarray(rng.random((2, k)), jnp.float32)
    bin_ids = bins_lib.rank_bin_ids(bins, k)
    P, Q = bins_lib.overlap_features(top, scores, doc_cluster, N, bin_ids, v)
    P, Q = np.asarray(P), np.asarray(Q)
    dc = np.asarray(doc_cluster)
    bi = np.asarray(bin_ids)
    for b in range(2):
        for c in range(N):
            for j in range(v):
                members = [i for i in range(k)
                           if dc[top[b, i]] == c and bi[i] == j]
                assert P[b, c, j] == len(members)
                if members:
                    np.testing.assert_allclose(
                        Q[b, c, j],
                        np.mean([scores[b, i] for i in members]), rtol=1e-5)


def test_sparse_retrieval_exact_when_untruncated():
    """With max_postings >= D the inverted-index score equals brute force."""
    rng = np.random.default_rng(3)
    D, V, T = 300, 64, 8
    dt = rng.integers(0, V, (D, T)).astype(np.int32)
    dw = rng.random((D, T)).astype(np.float32)
    idx = sparse_lib.SparseIndex.build(dt, dw, V, max_postings=D)
    qt = jnp.asarray(rng.integers(0, V, (4, 5)), jnp.int32)
    qw = jnp.asarray(rng.random((4, 5)), jnp.float32)
    _, _, scores = sparse_lib.sparse_retrieve(idx, qt, qw, 10)
    # brute force: dense doc-term matrix
    M = np.zeros((D, V), np.float32)
    for d in range(D):
        for t, w in zip(dt[d], dw[d]):
            M[d, t] += w
    Q = np.zeros((4, V), np.float32)
    for b in range(4):
        for t, w in zip(np.asarray(qt[b]), np.asarray(qw[b])):
            Q[b, t] += w
    np.testing.assert_allclose(np.asarray(scores), Q @ M.T, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_fusion_merge_equals_scatter(seed):
    rng = np.random.default_rng(seed)
    D, Ks, Kd, k = 500, 40, 60, 20
    sid = jnp.asarray(rng.choice(D, (2, Ks), replace=False), jnp.int32)
    ss = jnp.asarray(rng.random((2, Ks)), jnp.float32)
    did = jnp.asarray(rng.choice(D, (2, Kd), replace=False), jnp.int32)
    ds = jnp.asarray(rng.random((2, Kd)), jnp.float32)
    dm = jnp.asarray(rng.random((2, Kd)) > 0.2)
    a = 0.5
    i1, s1 = fusion_lib.fuse_topk(sid, ss, did, jnp.where(dm, ds, 0.0), dm,
                                  D, a, k)
    i2, s2 = fusion_lib.fuse_topk_merge(sid, ss, did, jnp.where(dm, ds, 0.0),
                                        dm, a, k, sentinel=D + 7)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-6)


def test_fused_equals_full_when_all_selected(small_index):
    """If every cluster is selected, CluSD's dense side equals brute force."""
    cfg, corpus, index = small_index
    q = synth_queries(5, corpus, 8)
    big = dataclasses.replace(cfg, theta=0.0,
                              max_selected=cfg.n_candidates)
    sel_ids = jnp.tile(jnp.arange(cfg.n_clusters, dtype=jnp.int32)[None],
                       (8, 1))
    sel_mask = jnp.ones_like(sel_ids, bool)
    did, dscore, dmask = cl.score_selected(index, q.q_dense, sel_ids, sel_mask)
    full = np.asarray(q.q_dense @ index.embeddings.T)
    ds = np.asarray(jnp.where(dmask, dscore, -np.inf))
    ids = np.asarray(did)
    for b in range(8):
        valid = np.isfinite(ds[b])
        np.testing.assert_allclose(ds[b][valid], full[b][ids[b][valid]],
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# LSTM training + end-to-end
# ---------------------------------------------------------------------------

def test_lstm_training_improves_selection(small_index):
    cfg, corpus, index = small_index
    tq = synth_queries(1, corpus, 128)
    cand, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                         tq.q_weights)
    params, hist = tl.train_selector(cfg, jax.random.key(2),
                                     np.asarray(feats), np.asarray(labels),
                                     epochs=30, batch_size=32, lr=0.01)
    assert hist[-1] < hist[0] * 0.9
    from repro.core.lstm import lstm_apply
    probs = lstm_apply(params, feats)
    # theta=0.02 is the paper's permissive serving threshold (selects ~2/3 of
    # candidates); separation is tested at the 0.5 operating point.
    q = tl.selection_quality(probs, labels, 0.5)
    assert float(q["precision"]) > float(labels.mean()) * 1.2
    assert float(q["recall"]) > 0.2


def test_end_to_end_beats_single_retrievers(small_index):
    cfg, corpus, index = small_index
    tq = synth_queries(1, corpus, 128)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(
        cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels),
        epochs=30, batch_size=32, lr=0.01)
    test_q = synth_queries(11, corpus, 64)
    ids, _, diag = cl.retrieve(cfg, index, test_q.q_dense, test_q.q_terms,
                               test_q.q_weights)
    clusd_mrr = mrr_at(np.asarray(ids), test_q.rel_doc)
    dense_ids, _ = cl.full_dense_topk(index.embeddings, test_q.q_dense, 64)
    dense_mrr = mrr_at(np.asarray(dense_ids), test_q.rel_doc)
    sid, _ = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, test_q.q_terms, test_q.q_weights, cfg.k_sparse)
    sparse_mrr = mrr_at(np.asarray(sid), test_q.rel_doc)
    assert clusd_mrr > max(dense_mrr, sparse_mrr) * 0.95
    # partial retrieval: only a fraction of the corpus scanned
    assert float(diag["frac_docs_scanned"].mean()) < 0.5
    index.lstm_params = None
