"""CluSD system tests: stage-1 invariants (hypothesis property tests),
LSTM training improves selection, end-to-end quality, fusion exactness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro.configs import get_config
from repro.core import bins as bins_lib
from repro.core import clusd as cl
from repro.core import fusion as fusion_lib
from repro.core import sparse as sparse_lib
from repro.core import stage1 as stage1_lib
from repro.core import train_lstm as tl
from repro.data import mrr_at, recall_at, synth_corpus, synth_queries


@pytest.fixture(scope="module")
def small_index():
    cfg = get_config("clusd-msmarco", "smoke")
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    return cfg, corpus, index


# ---------------------------------------------------------------------------
# stage 1 properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_multikey_sort_is_lexicographic(seed):
    rng = np.random.default_rng(seed)
    N, v, n = 40, 4, 10
    P = jnp.asarray(rng.integers(0, 4, (1, N, v)), jnp.float32)
    sim = jnp.asarray(rng.random((1, N)), jnp.float32)
    got = np.asarray(stage1_lib.sort_by_overlap(P, sim, n))[0]
    keys = [tuple(-np.asarray(P[0, c])) + (-float(sim[0, c]),)
            for c in range(N)]
    want = sorted(range(N), key=lambda c: keys[c])[:n]
    assert list(got) == list(want)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_overlap_counts_match_bruteforce(seed):
    rng = np.random.default_rng(seed)
    D, N, k, v = 200, 16, 50, 4
    bins = (5, 15, 30, 50)
    doc_cluster = jnp.asarray(rng.integers(0, N, D), jnp.int32)
    top = jnp.asarray(rng.choice(D, (2, k), replace=False), jnp.int32)
    scores = jnp.asarray(rng.random((2, k)), jnp.float32)
    bin_ids = bins_lib.rank_bin_ids(bins, k)
    P, Q = bins_lib.overlap_features(top, scores, doc_cluster, N, bin_ids, v)
    P, Q = np.asarray(P), np.asarray(Q)
    dc = np.asarray(doc_cluster)
    bi = np.asarray(bin_ids)
    for b in range(2):
        for c in range(N):
            for j in range(v):
                members = [i for i in range(k)
                           if dc[top[b, i]] == c and bi[i] == j]
                assert P[b, c, j] == len(members)
                if members:
                    np.testing.assert_allclose(
                        Q[b, c, j],
                        np.mean([scores[b, i] for i in members]), rtol=1e-5)


def test_sparse_retrieval_exact_when_untruncated():
    """With max_postings >= D the inverted-index score equals brute force."""
    rng = np.random.default_rng(3)
    D, V, T = 300, 64, 8
    dt = rng.integers(0, V, (D, T)).astype(np.int32)
    dw = rng.random((D, T)).astype(np.float32)
    idx = sparse_lib.SparseIndex.build(dt, dw, V, max_postings=D)
    qt = jnp.asarray(rng.integers(0, V, (4, 5)), jnp.int32)
    qw = jnp.asarray(rng.random((4, 5)), jnp.float32)
    _, _, scores = sparse_lib.sparse_retrieve(idx, qt, qw, 10)
    # brute force: dense doc-term matrix
    M = np.zeros((D, V), np.float32)
    for d in range(D):
        for t, w in zip(dt[d], dw[d]):
            M[d, t] += w
    Q = np.zeros((4, V), np.float32)
    for b in range(4):
        for t, w in zip(np.asarray(qt[b]), np.asarray(qw[b])):
            Q[b, t] += w
    np.testing.assert_allclose(np.asarray(scores), Q @ M.T, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# fusion
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_fusion_merge_equals_scatter(seed):
    rng = np.random.default_rng(seed)
    D, Ks, Kd, k = 500, 40, 60, 20
    sid = jnp.asarray(rng.choice(D, (2, Ks), replace=False), jnp.int32)
    ss = jnp.asarray(rng.random((2, Ks)), jnp.float32)
    did = jnp.asarray(rng.choice(D, (2, Kd), replace=False), jnp.int32)
    ds = jnp.asarray(rng.random((2, Kd)), jnp.float32)
    dm = jnp.asarray(rng.random((2, Kd)) > 0.2)
    a = 0.5
    i1, s1 = fusion_lib.fuse_topk(sid, ss, did, jnp.where(dm, ds, 0.0), dm,
                                  D, a, k)
    i2, s2 = fusion_lib.fuse_topk_merge(sid, ss, did, jnp.where(dm, ds, 0.0),
                                        dm, a, k, sentinel=D + 7)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_fusion_merge_equals_scatter_any_multiplicity(seed):
    """Merge-path == scatter-oracle with ids drawn WITH replacement (a doc
    may repeat within a side and across sides at any multiplicity) and a
    ragged valid prefix on the sparse side — for both fusion methods."""
    rng = np.random.default_rng(seed)
    D, Ks, Kd, k = 120, 30, 40, 15
    sid = jnp.asarray(rng.integers(0, D, (3, Ks)), jnp.int32)
    ss = jnp.asarray(np.sort(rng.random((3, Ks)))[:, ::-1].copy(),
                     jnp.float32)
    sm = jnp.arange(Ks)[None, :] < jnp.asarray(
        rng.integers(0, Ks + 1, (3, 1)))             # ragged prefix
    did = jnp.asarray(rng.integers(0, D, (3, Kd)), jnp.int32)
    ds = jnp.asarray(rng.random((3, Kd)), jnp.float32)
    dm = jnp.asarray(rng.random((3, Kd)) > 0.2)
    a = 0.43                                         # != 0.5: no cross-side
    for method in fusion_lib.FUSION_METHODS:         # rank ties under rrf
        i1, s1 = fusion_lib.fuse_topk(
            sid, ss, did, ds, dm, D, a, k, sparse_mask=sm, method=method)
        i2, s2 = fusion_lib.fuse_topk_merge(
            sid, ss, did, ds, dm, a, k, sentinel=D + 7, sparse_mask=sm,
            method=method)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-5, atol=1e-6, err_msg=method)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2),
                                      err_msg=method)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_fusion_ignores_sparse_padding(seed):
    """Regression for the padding bug: entries behind the sparse valid
    mask must not shift normalization, ranks, or the fused top-k — two
    different junk tails under the same mask fuse bitwise identically."""
    rng = np.random.default_rng(seed)
    D, Ks, Kd, k = 200, 24, 24, 10
    n_valid = int(rng.integers(1, Ks))
    sid_v = rng.choice(D, n_valid, replace=False).astype(np.int32)
    ss_v = np.sort(rng.random(n_valid).astype(np.float32))[::-1].copy()
    sm = jnp.asarray((np.arange(Ks) < n_valid)[None, :])
    did = jnp.asarray(rng.choice(D, (1, Kd), replace=False), jnp.int32)
    ds = jnp.asarray(rng.random((1, Kd)), jnp.float32)
    dm = jnp.ones((1, Kd), bool)

    def pad(junk_ids, junk_scores):
        sid = np.concatenate([sid_v, junk_ids]).astype(np.int32)
        ss = np.concatenate([ss_v, junk_scores]).astype(np.float32)
        return jnp.asarray(sid[None, :]), jnp.asarray(ss[None, :])

    pads = [pad(rng.integers(0, D, Ks - n_valid),
                rng.random(Ks - n_valid) * 10 - 5),
            pad(np.zeros(Ks - n_valid, np.int64),
                np.full(Ks - n_valid, 99.0))]
    for method in fusion_lib.FUSION_METHODS:
        outs = []
        for sid, ss in pads:
            i1, s1 = fusion_lib.fuse_topk(sid, ss, did, ds, dm, D, 0.5, k,
                                          sparse_mask=sm, method=method)
            i2, s2 = fusion_lib.fuse_topk_merge(sid, ss, did, ds, dm, 0.5,
                                                k, sentinel=D + 7,
                                                sparse_mask=sm,
                                                method=method)
            outs.append((np.asarray(i1), np.asarray(s1),
                         np.asarray(i2), np.asarray(s2)))
        for got, want in zip(outs[0], outs[1]):
            np.testing.assert_array_equal(got, want, err_msg=method)


def test_rrf_matches_rank_oracle():
    """Weighted-RRF fused scores equal the textbook sum over both lists:
    weight / (rrf_k + 1-based rank among valid entries)."""
    D, k, a, K = 50, 6, 0.4, 60.0
    sid = np.array([[3, 5, 7, 9]], np.int32)
    ss = np.array([[9.0, 5.0, 1.0, 0.5]], np.float32)
    sm = np.array([[True, True, True, False]])     # 9 is padding
    did = np.array([[5, 2, 11]], np.int32)
    ds = np.array([[8.0, 6.0, 4.0]], np.float32)
    dm = np.array([[True, True, False]])           # 11 is a dead slot
    acc = {}
    for ids, scores, mask, w in ((sid, ss, sm, a), (did, ds, dm, 1 - a)):
        order = np.argsort(-scores[0][mask[0]], kind="stable")
        for rank, j in enumerate(order, start=1):
            doc = int(ids[0][mask[0]][j])
            acc[doc] = acc.get(doc, 0.0) + w / (K + rank)
    want = sorted(acc.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
    ids, scores = fusion_lib.fuse_topk(
        jnp.asarray(sid), jnp.asarray(ss), jnp.asarray(did),
        jnp.asarray(ds), jnp.asarray(dm), D, a, k,
        sparse_mask=jnp.asarray(sm), method="rrf", rrf_k=K)
    got = list(zip(np.asarray(ids)[0][:len(want)].tolist(),
                   np.asarray(scores)[0][:len(want)].tolist()))
    for (gi, gs), (wi, ws) in zip(got, want):
        assert gi == wi, (got, want)
        np.testing.assert_allclose(gs, ws, rtol=1e-6)


def test_fusion_rejects_unknown_method():
    z = jnp.zeros((1, 4))
    zi = jnp.zeros((1, 4), jnp.int32)
    m = jnp.ones((1, 4), bool)
    with pytest.raises(ValueError):
        fusion_lib.fuse_topk(zi, z, zi, z, m, 8, 0.5, 2, method="borda")


# ---------------------------------------------------------------------------
# stage-1 neighbor-graph expansion
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_expand_candidates_invariants(seed):
    rng = np.random.default_rng(seed)
    N, n, m, B, depth = 24, 5, 6, 3, 2
    S = rng.random((N, N)).astype(np.float32)
    np.fill_diagonal(S, -1.0)                      # graph excludes self
    nid = np.argsort(-S, axis=1)[:, :m].astype(np.int32)
    nsim = np.take_along_axis(S, nid, axis=1).astype(np.float32)
    qc = rng.random((B, N)).astype(np.float32)
    cand = np.stack([rng.choice(N, n, replace=False)
                     for _ in range(B)]).astype(np.int32)
    n_out = min(n * (1 + depth), N)
    out = np.asarray(stage1_lib.expand_candidates(
        jnp.asarray(cand), jnp.asarray(nid), jnp.asarray(nsim),
        jnp.asarray(qc), depth, n_out))
    assert out.shape == (B, n_out) and out.dtype == np.int32
    for b in range(B):
        assert list(out[b, :n]) == list(cand[b])   # seed prefix untouched
        assert len(set(out[b].tolist())) == n_out  # all-distinct
        assert ((0 <= out[b]) & (out[b] < N)).all()
        reach = ({int(c) for s in cand[b] for c in nid[s, :depth]}
                 - set(cand[b].tolist()))
        take = min(len(reach), n_out - n)
        # graph-reached clusters fill the extension before any IVF fill
        assert set(out[b, n:n + take].tolist()) <= reach
        if len(reach) <= n_out - n:
            assert reach <= set(out[b, n:].tolist())
    # depth 0 (or no headroom) is the identity — the current pipeline
    out0 = stage1_lib.expand_candidates(
        jnp.asarray(cand), jnp.asarray(nid), jnp.asarray(nsim),
        jnp.asarray(qc), 0, n_out)
    np.testing.assert_array_equal(np.asarray(out0), cand)
    same = stage1_lib.expand_candidates(
        jnp.asarray(cand), jnp.asarray(nid), jnp.asarray(nsim),
        jnp.asarray(qc), depth, n)
    np.testing.assert_array_equal(np.asarray(same), cand)
    with pytest.raises(ValueError):
        stage1_lib.expand_candidates(
            jnp.asarray(cand), jnp.asarray(nid), jnp.asarray(nsim),
            jnp.asarray(qc), depth, N + 1)


def test_fused_equals_full_when_all_selected(small_index):
    """If every cluster is selected, CluSD's dense side equals brute force."""
    cfg, corpus, index = small_index
    q = synth_queries(5, corpus, 8)
    big = dataclasses.replace(cfg, theta=0.0,
                              max_selected=cfg.n_candidates)
    sel_ids = jnp.tile(jnp.arange(cfg.n_clusters, dtype=jnp.int32)[None],
                       (8, 1))
    sel_mask = jnp.ones_like(sel_ids, bool)
    did, dscore, dmask = cl.score_selected(index, q.q_dense, sel_ids, sel_mask)
    full = np.asarray(q.q_dense @ index.embeddings.T)
    ds = np.asarray(jnp.where(dmask, dscore, -np.inf))
    ids = np.asarray(did)
    for b in range(8):
        valid = np.isfinite(ds[b])
        np.testing.assert_allclose(ds[b][valid], full[b][ids[b][valid]],
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# LSTM training + end-to-end
# ---------------------------------------------------------------------------

def test_lstm_training_improves_selection(small_index):
    cfg, corpus, index = small_index
    tq = synth_queries(1, corpus, 128)
    cand, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                         tq.q_weights)
    params, hist = tl.train_selector(cfg, jax.random.key(2),
                                     np.asarray(feats), np.asarray(labels),
                                     epochs=30, batch_size=32, lr=0.01)
    assert hist[-1] < hist[0] * 0.9
    from repro.core.lstm import lstm_apply
    probs = lstm_apply(params, feats)
    # theta=0.02 is the paper's permissive serving threshold (selects ~2/3 of
    # candidates); separation is tested at the 0.5 operating point.
    q = tl.selection_quality(probs, labels, 0.5)
    assert float(q["precision"]) > float(labels.mean()) * 1.2
    assert float(q["recall"]) > 0.2


def test_end_to_end_beats_single_retrievers(small_index):
    cfg, corpus, index = small_index
    tq = synth_queries(1, corpus, 128)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(
        cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels),
        epochs=30, batch_size=32, lr=0.01)
    test_q = synth_queries(11, corpus, 64)
    ids, _, diag = cl.retrieve(cfg, index, test_q.q_dense, test_q.q_terms,
                               test_q.q_weights)
    clusd_mrr = mrr_at(np.asarray(ids), test_q.rel_doc)
    dense_ids, _ = cl.full_dense_topk(index.embeddings, test_q.q_dense, 64)
    dense_mrr = mrr_at(np.asarray(dense_ids), test_q.rel_doc)
    sid, _ = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, test_q.q_terms, test_q.q_weights, cfg.k_sparse)
    sparse_mrr = mrr_at(np.asarray(sid), test_q.rel_doc)
    assert clusd_mrr > max(dense_mrr, sparse_mrr) * 0.95
    # partial retrieval: only a fraction of the corpus scanned
    assert float(diag["frac_docs_scanned"].mean()) < 0.5
    index.lstm_params = None
