"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.adc import (
    adc_score_blocks, adc_score_blocks_ref, adc_tables, adc_tables_ref)
from repro.kernels.bin_overlap import bin_overlap, bin_overlap_ref
from repro.kernels.cluster_score import cluster_score, cluster_score_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.lstm import lstm_sequence, lstm_sequence_ref
from repro.kernels.topk import topk, topk_ref


@pytest.mark.parametrize("B,dim,N,cap,S", [
    (1, 32, 8, 8, 2), (4, 64, 32, 16, 5), (3, 128, 64, 32, 8),
    (2, 256, 16, 128, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cluster_score(B, dim, N, cap, S, dtype, rng):
    q = jnp.asarray(rng.standard_normal((B, dim)), dtype)
    blocks = jnp.asarray(rng.standard_normal((N, cap, dim)), dtype)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    out = cluster_score(q, blocks, sel)
    ref = cluster_score_ref(q, blocks, sel)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("B,n,F,H", [
    (1, 4, 8, 8), (12, 32, 21, 32), (5, 16, 13, 16), (9, 64, 21, 32),
])
def test_lstm(B, n, F, H, rng):
    x = jnp.asarray(rng.standard_normal((B, n, F)), jnp.float32)
    wx = jnp.asarray(rng.standard_normal((F, 4 * H)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.standard_normal((H, 4 * H)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(4 * H) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(lstm_sequence(x, wx, wh, b)),
        np.asarray(lstm_sequence_ref(x, wx, wh, b)), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("V,d,B,hot", [
    (100, 32, 8, 1), (500, 64, 12, 4), (64, 128, 3, 9),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(V, d, B, hot, dtype, rng):
    table = jnp.asarray(rng.standard_normal((V, d)), dtype)
    idx = jnp.asarray(rng.integers(0, V, (B, hot)), jnp.int32)
    out = embedding_bag(table, idx)
    ref = embedding_bag_ref(table, idx)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 4)


@pytest.mark.parametrize("B,N,v,k", [(2, 16, 4, 32), (6, 64, 7, 200),
                                     (1, 128, 6, 64)])
def test_bin_overlap(B, N, v, k, rng):
    c = jnp.asarray(rng.integers(0, N, (B, k)), jnp.int32)
    bi = jnp.asarray(rng.integers(0, v, (B, k)), jnp.int32)
    s = jnp.asarray(rng.random((B, k)), jnp.float32)
    P1, Q1 = bin_overlap(c, bi, s, n_clusters=N, v=v)
    P2, Q2 = bin_overlap_ref(c, bi, s, n_clusters=N, v=v)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(Q1), np.asarray(Q2),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,D,k,block", [(2, 1000, 16, 256),
                                         (4, 5000, 100, 2048),
                                         (1, 300, 300, 128)])
def test_topk(B, D, k, block, rng):
    x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    from repro.kernels.topk.kernel import topk_pallas
    v1, i1 = topk_pallas(x, k, block_d=block, interpret=True)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    # values gathered at reported indices must equal reported values
    got = np.take_along_axis(np.asarray(x), np.asarray(i1), axis=1)
    np.testing.assert_allclose(got, np.asarray(v1), rtol=1e-6)


# ---------------------------------------------------------------------------
# non-aligned shapes + ties: ref-vs-ops parity off the happy path
# (interpret mode imposes no TPU tiling constraints, so these geometries
# exercise the kernel logic itself — index maps, tail tiles, merge order)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,dim,N,cap,S", [
    (2, 13, 5, 7, 3),        # nothing a power of two or lane-aligned
    (1, 24, 3, 5, 6),        # S > N: repeated cluster selections per row
    (3, 8, 2, 1, 2),         # cap = 1 blocks
    (1, 48, 1, 9, 4),        # single cluster, every slot the same block
])
def test_cluster_score_nonaligned(B, dim, N, cap, S, rng):
    q = jnp.asarray(rng.standard_normal((B, dim)), jnp.float32)
    blocks = jnp.asarray(rng.standard_normal((N, cap, dim)), jnp.float32)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    out = cluster_score(q, blocks, sel)
    ref = cluster_score_ref(q, blocks, sel)
    assert out.shape == (B, S, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,D,k,block", [
    (2, 37, 11, 8),          # tail tile of 5
    (1, 5, 5, 3),            # k == D, block > tail
    (2, 100, 32, 7),         # k >> block: merge keeps more than one tile
])
def test_topk_nonaligned_shapes(B, D, k, block, rng):
    from repro.kernels.topk.kernel import topk_pallas
    # distinct values: parity must be exact, indices included
    x = rng.standard_normal((B, D)).astype(np.float32)
    x += np.arange(D, dtype=np.float32)[None, :] * 1e-3
    x = jnp.asarray(x)
    v1, i1 = topk_pallas(x, k, block_d=block, interpret=True)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# ADC (PQ asymmetric-distance) kernels: LUT build + code-block scoring.
# use_kernel=True pins the Pallas bodies (interpret mode on CPU); the
# parity target is both the jnp oracle AND decode-then-dot, per the
# accumulation-order contract in kernels/adc/ref.py.
# ---------------------------------------------------------------------------

def _decode_np(codebooks, codes, rotation=None):
    books = np.asarray(codebooks, np.float32)
    vecs = books[np.arange(books.shape[0]), np.asarray(codes, np.int64)]
    flat = vecs.reshape(codes.shape[:-1] + (-1,))
    if rotation is not None:
        flat = flat @ np.asarray(rotation, np.float32).T
    return flat


@pytest.mark.parametrize("B,nsub,dsub,K", [
    (2, 8, 6, 256),          # standard K, nothing lane-aligned
    (1, 3, 5, 17),           # tiny odd K
    (4, 12, 4, 256),         # the serving geometry's nsub
    (3, 1, 7, 9),            # single subspace
])
@pytest.mark.parametrize("rotate", [False, True])
def test_adc_tables_matrix(B, nsub, dsub, K, rotate, rng):
    dim = nsub * dsub
    q = jnp.asarray(rng.standard_normal((B, dim)), jnp.float32)
    books = jnp.asarray(rng.standard_normal((nsub, K, dsub)), jnp.float32)
    rot = jnp.asarray(np.linalg.qr(rng.standard_normal((dim, dim)))[0],
                      jnp.float32) if rotate else None
    out = adc_tables(q, books, rot, use_kernel=True)
    ref = adc_tables_ref(q, books, rot)
    assert out.shape == (B, nsub, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,nsub,dsub,N,cap,S", [
    (2, 3, 5, 4, 7, 3),      # nothing a power of two or lane-aligned
    (1, 5, 2, 1, 9, 4),      # single cluster, every slot the same block
    (3, 4, 4, 2, 1, 2),      # cap = 1 blocks
    (2, 12, 4, 6, 13, 9),    # serving nsub, odd cap, S > N (repeats)
])
def test_adc_score_blocks_matrix(B, nsub, dsub, N, cap, S, rng):
    """Kernel == oracle == dot(q, decode(codes)) on ragged geometries."""
    K = 256
    dim = nsub * dsub
    q = jnp.asarray(rng.standard_normal((B, dim)), jnp.float32)
    books = jnp.asarray(rng.standard_normal((nsub, K, dsub)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, K, (N, cap, nsub)), jnp.uint8)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    lut = adc_tables(q, books, use_kernel=True)
    out = adc_score_blocks(lut, codes, sel, use_kernel=True)
    ref = adc_score_blocks_ref(adc_tables_ref(q, books), codes, sel)
    assert out.shape == (B, S, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # the documented contract: ADC == dot against the decoded vectors
    dec = _decode_np(books, np.asarray(codes))          # (N, cap, dim)
    dot = np.einsum("bd,bscd->bsc", np.asarray(q),
                    dec[np.asarray(sel)])
    np.testing.assert_allclose(np.asarray(out), dot, rtol=1e-4, atol=1e-4)


def test_adc_rotation_folding(rng):
    """OPQ rotation folds into the query: scoring rotation-free codes with
    a rotated-q LUT equals dot(q, decode-with-unrotation(codes))."""
    B, nsub, dsub, N, cap, S, K = 2, 4, 3, 5, 6, 3, 32
    dim = nsub * dsub
    q = rng.standard_normal((B, dim)).astype(np.float32)
    books = rng.standard_normal((nsub, K, dsub)).astype(np.float32)
    rot = np.linalg.qr(rng.standard_normal((dim, dim)))[0].astype(np.float32)
    codes = rng.integers(0, K, (N, cap, nsub)).astype(np.uint8)
    sel = rng.integers(0, N, (B, S)).astype(np.int32)
    lut = adc_tables(jnp.asarray(q), jnp.asarray(books), jnp.asarray(rot),
                     use_kernel=True)
    out = adc_score_blocks(lut, jnp.asarray(codes), jnp.asarray(sel),
                           use_kernel=True)
    dec = _decode_np(books, codes, rot)
    dot = np.einsum("bd,bscd->bsc", q, dec[sel])
    np.testing.assert_allclose(np.asarray(out), dot, rtol=1e-4, atol=1e-4)


def test_adc_empty_selection_and_empty_fetch(rng):
    """S == 0 (nothing selected) and N == 0 (empty fetch) both return the
    contract-shaped zeros without invoking a zero-size kernel grid."""
    lut = jnp.asarray(rng.standard_normal((2, 4, 16)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 16, (3, 5, 4)), jnp.uint8)
    out = adc_score_blocks(lut, codes, jnp.zeros((2, 0), jnp.int32),
                           use_kernel=True)
    assert out.shape == (2, 0, 5) and not np.asarray(out).size
    out = adc_score_blocks(lut, jnp.zeros((0, 5, 4), jnp.uint8),
                           jnp.zeros((2, 3), jnp.int32), use_kernel=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((2, 3, 5)))


def test_adc_tombstone_slot_independence(rng):
    """Garbage codes in tombstone-masked slots must not perturb any live
    slot's score — each slot accumulates only its own LUT rows (the engine
    drops masked slots via the validity mask AFTER scoring)."""
    B, nsub, N, cap, S, K = 2, 4, 3, 6, 4, 32
    lut = jnp.asarray(rng.standard_normal((B, nsub, K)), jnp.float32)
    codes = rng.integers(0, K, (N, cap, nsub)).astype(np.uint8)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    base = np.asarray(adc_score_blocks(lut, jnp.asarray(codes), sel,
                                       use_kernel=True))
    tomb = codes.copy()
    tomb[:, 2, :] = 255                      # "deleted" slot: garbage codes
    got = np.asarray(adc_score_blocks(lut, jnp.asarray(tomb), sel,
                                      use_kernel=True))
    live = np.ones(cap, bool)
    live[2] = False
    np.testing.assert_array_equal(got[:, :, live], base[:, :, live])


def test_adc_tie_determinism_vs_lax_topk(rng):
    """Kernel scores are BITWISE equal to the oracle's (same ascending-
    subspace accumulation of identical f32 terms), so a downstream
    lax.top_k resolves ties identically on either path — even with many
    exactly-equal scores (integer-valued LUT, repeated codes)."""
    B, nsub, N, cap, S, K = 2, 4, 4, 8, 3, 16
    lut = jnp.asarray(rng.integers(-3, 4, (B, nsub, K)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 4, (N, cap, nsub)), jnp.uint8)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    out = adc_score_blocks(lut, codes, sel, use_kernel=True)
    ref = adc_score_blocks_ref(lut, codes, sel)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    k = 5
    _, i1 = jax.lax.top_k(out.reshape(B, S * cap), k)
    _, i2 = jax.lax.top_k(ref.reshape(B, S * cap), k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("block", [4, 7, 16, 64])
def test_topk_ties_deterministic(block, rng):
    """Duplicated values everywhere, including runs that span tile
    boundaries: the blocked merge must reproduce lax.top_k's deterministic
    lowest-index-first tie-break exactly (values AND indices)."""
    from repro.kernels.topk.kernel import topk_pallas
    B, D, k = 3, 50, 17
    x = jnp.asarray(rng.integers(0, 4, (B, D)), jnp.float32)   # heavy ties
    v1, i1 = topk_pallas(x, k, block_d=block, interpret=True)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # ties within one kernel call are stable across block sizes too
    v3, i3 = topk_pallas(x, k, block_d=max(2, block // 2), interpret=True)
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(i1))
