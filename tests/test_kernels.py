"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bin_overlap import bin_overlap, bin_overlap_ref
from repro.kernels.cluster_score import cluster_score, cluster_score_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.lstm import lstm_sequence, lstm_sequence_ref
from repro.kernels.topk import topk, topk_ref


@pytest.mark.parametrize("B,dim,N,cap,S", [
    (1, 32, 8, 8, 2), (4, 64, 32, 16, 5), (3, 128, 64, 32, 8),
    (2, 256, 16, 128, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cluster_score(B, dim, N, cap, S, dtype, rng):
    q = jnp.asarray(rng.standard_normal((B, dim)), dtype)
    blocks = jnp.asarray(rng.standard_normal((N, cap, dim)), dtype)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    out = cluster_score(q, blocks, sel)
    ref = cluster_score_ref(q, blocks, sel)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("B,n,F,H", [
    (1, 4, 8, 8), (12, 32, 21, 32), (5, 16, 13, 16), (9, 64, 21, 32),
])
def test_lstm(B, n, F, H, rng):
    x = jnp.asarray(rng.standard_normal((B, n, F)), jnp.float32)
    wx = jnp.asarray(rng.standard_normal((F, 4 * H)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.standard_normal((H, 4 * H)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(4 * H) * 0.1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(lstm_sequence(x, wx, wh, b)),
        np.asarray(lstm_sequence_ref(x, wx, wh, b)), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("V,d,B,hot", [
    (100, 32, 8, 1), (500, 64, 12, 4), (64, 128, 3, 9),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag(V, d, B, hot, dtype, rng):
    table = jnp.asarray(rng.standard_normal((V, d)), dtype)
    idx = jnp.asarray(rng.integers(0, V, (B, hot)), jnp.int32)
    out = embedding_bag(table, idx)
    ref = embedding_bag_ref(table, idx)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 4)


@pytest.mark.parametrize("B,N,v,k", [(2, 16, 4, 32), (6, 64, 7, 200),
                                     (1, 128, 6, 64)])
def test_bin_overlap(B, N, v, k, rng):
    c = jnp.asarray(rng.integers(0, N, (B, k)), jnp.int32)
    bi = jnp.asarray(rng.integers(0, v, (B, k)), jnp.int32)
    s = jnp.asarray(rng.random((B, k)), jnp.float32)
    P1, Q1 = bin_overlap(c, bi, s, n_clusters=N, v=v)
    P2, Q2 = bin_overlap_ref(c, bi, s, n_clusters=N, v=v)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(Q1), np.asarray(Q2),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,D,k,block", [(2, 1000, 16, 256),
                                         (4, 5000, 100, 2048),
                                         (1, 300, 300, 128)])
def test_topk(B, D, k, block, rng):
    x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    from repro.kernels.topk.kernel import topk_pallas
    v1, i1 = topk_pallas(x, k, block_d=block, interpret=True)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    # values gathered at reported indices must equal reported values
    got = np.take_along_axis(np.asarray(x), np.asarray(i1), axis=1)
    np.testing.assert_allclose(got, np.asarray(v1), rtol=1e-6)


# ---------------------------------------------------------------------------
# non-aligned shapes + ties: ref-vs-ops parity off the happy path
# (interpret mode imposes no TPU tiling constraints, so these geometries
# exercise the kernel logic itself — index maps, tail tiles, merge order)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,dim,N,cap,S", [
    (2, 13, 5, 7, 3),        # nothing a power of two or lane-aligned
    (1, 24, 3, 5, 6),        # S > N: repeated cluster selections per row
    (3, 8, 2, 1, 2),         # cap = 1 blocks
    (1, 48, 1, 9, 4),        # single cluster, every slot the same block
])
def test_cluster_score_nonaligned(B, dim, N, cap, S, rng):
    q = jnp.asarray(rng.standard_normal((B, dim)), jnp.float32)
    blocks = jnp.asarray(rng.standard_normal((N, cap, dim)), jnp.float32)
    sel = jnp.asarray(rng.integers(0, N, (B, S)), jnp.int32)
    out = cluster_score(q, blocks, sel)
    ref = cluster_score_ref(q, blocks, sel)
    assert out.shape == (B, S, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,D,k,block", [
    (2, 37, 11, 8),          # tail tile of 5
    (1, 5, 5, 3),            # k == D, block > tail
    (2, 100, 32, 7),         # k >> block: merge keeps more than one tile
])
def test_topk_nonaligned_shapes(B, D, k, block, rng):
    from repro.kernels.topk.kernel import topk_pallas
    # distinct values: parity must be exact, indices included
    x = rng.standard_normal((B, D)).astype(np.float32)
    x += np.arange(D, dtype=np.float32)[None, :] * 1e-3
    x = jnp.asarray(x)
    v1, i1 = topk_pallas(x, k, block_d=block, interpret=True)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("block", [4, 7, 16, 64])
def test_topk_ties_deterministic(block, rng):
    """Duplicated values everywhere, including runs that span tile
    boundaries: the blocked merge must reproduce lax.top_k's deterministic
    lowest-index-first tie-break exactly (values AND indices)."""
    from repro.kernels.topk.kernel import topk_pallas
    B, D, k = 3, 50, 17
    x = jnp.asarray(rng.integers(0, 4, (B, D)), jnp.float32)   # heavy ties
    v1, i1 = topk_pallas(x, k, block_d=block, interpret=True)
    v2, i2 = topk_ref(x, k)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # ties within one kernel call are stable across block sizes too
    v3, i3 = topk_pallas(x, k, block_d=max(2, block // 2), interpret=True)
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(i1))
