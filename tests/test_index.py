"""Persistent index subsystem tests: build -> write -> reopen round trip,
manifest/checksum rejection of corruption, mmap loading without embedding
materialization, ShardedDiskStore routing + run coalescing, the
DiskClusterStore pack/open split, and the offline sharded build pipeline."""

import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import index as index_lib
from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.core import train_lstm as tl
from repro.data import synth_corpus, synth_queries
from repro.engine import InMemoryStore, RetrievalEngine, pipeline


def _tiny_cfg():
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=512, dim=16, n_clusters=32, vocab=256, max_postings=128,
        k_sparse=64, bins=(5, 15, 30, 64), n_candidates=8, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=32, train_queries=24, epochs=2)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """In-memory index (trained selector) + its serialized on-disk form."""
    cfg = _tiny_cfg()
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    tq = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(cfg, jax.random.key(2),
                                             np.asarray(feats),
                                             np.asarray(labels))
    out = str(tmp_path_factory.mktemp("idx") / "index")
    manifest = index_lib.write_index(out, cfg, index,
                                     np.asarray(corpus.embeddings),
                                     n_shards=3)
    qs = synth_queries(7, corpus, 10)
    return cfg, corpus, index, out, manifest, qs


# ---------------------------------------------------------------------------
# round trip
# ---------------------------------------------------------------------------

def test_roundtrip_arrays_config_and_lstm(built):
    cfg, _, index, out, manifest, _ = built
    reader = index_lib.IndexReader.open(out, verify="full")
    lcfg, lindex = reader.load_index()
    assert lcfg == cfg
    assert lindex.embeddings is None
    for name, ref in (("centroids", index.centroids),
                      ("cluster_docs", index.cluster_docs),
                      ("doc_cluster", index.doc_cluster),
                      ("neighbor_ids", index.neighbor_ids),
                      ("bin_ids", index.bin_ids)):
        np.testing.assert_array_equal(np.asarray(getattr(lindex, name)),
                                      np.asarray(ref), err_msg=name)
    np.testing.assert_allclose(
        np.asarray(lindex.sparse_index.postings_weights),
        np.asarray(index.sparse_index.postings_weights))
    for k, v in index.lstm_params.items():
        np.testing.assert_array_equal(np.asarray(lindex.lstm_params[k]),
                                      np.asarray(v), err_msg=k)
    # manifest accounting covers every artifact
    assert manifest["total_bytes"] == sum(
        e["bytes"] for e in manifest["files"].values())
    assert len(manifest["block_shards"]) == 3


def test_mmap_loading_no_copy(built):
    _, _, _, out, _, _ = built
    reader = index_lib.IndexReader.open(out)
    arr = reader.array("centroids")
    assert isinstance(arr, np.memmap)
    store = reader.open_store()
    assert all(isinstance(mm, np.memmap) for mm in store._mms)
    assert store.n_shards == 3


def test_built_index_serving_parity(built):
    """Acceptance: built index -> IndexReader -> ShardedDiskStore returns the
    same fused top-k as the in-memory pipeline, direct and via the engine."""
    cfg, corpus, _, out, _, qs = built
    reader = index_lib.IndexReader.open(out, verify="full")
    lcfg, lindex = reader.load_index()
    mem = InMemoryStore(corpus.embeddings, lindex.cluster_docs)
    ref_ids, ref_scores, _ = pipeline.retrieve(
        lcfg, lindex, mem, qs.q_dense, qs.q_terms, qs.q_weights)

    store = reader.open_store(cluster_docs=lindex.cluster_docs)
    ids, scores, _ = pipeline.retrieve(lcfg, lindex, store, qs.q_dense,
                                       qs.q_terms, qs.q_weights)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref_scores),
                               rtol=1e-5, atol=1e-5)
    assert store.stats.n_ops > 0
    assert store.stats.bytes % store.block_bytes == 0
    # coalescing: ops count runs, never more than blocks read
    assert store.stats.n_ops <= store.stats.bytes // store.block_bytes

    with reader.engine(cfg=lcfg, index=lindex, max_batch=8) as eng:
        eids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
    np.testing.assert_array_equal(np.asarray(eids), np.asarray(ref_ids))
    assert eng.stats()["io"]["n_ops"] > 0


# ---------------------------------------------------------------------------
# format validation
# ---------------------------------------------------------------------------

def _copy_index(out, tmp_path, name):
    dst = str(tmp_path / name)
    shutil.copytree(out, dst)
    return dst


def test_wrong_format_version_rejected(built, tmp_path):
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "badver")
    mpath = os.path.join(bad, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format_version"] = index_lib.FORMAT_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_lib.IndexFormatError, match="version"):
        index_lib.IndexReader.open(bad)


def test_stripped_checksum_map_fails_closed(built, tmp_path):
    """verify != "none" must refuse a manifest without checksums rather
    than silently verifying nothing."""
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "nofiles")
    mpath = os.path.join(bad, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["files"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_lib.IndexFormatError, match="checksum"):
        index_lib.IndexReader.open(bad, verify="full")
    index_lib.IndexReader.open(bad, verify="none")      # explicit opt-out


def test_overwrite_in_place_keeps_index_readable(built, tmp_path):
    cfg, corpus, index, _, _, _ = built
    out = str(tmp_path / "index")
    for _ in range(2):      # second write exercises the move-aside commit
        index_lib.write_index(out, cfg, index,
                              np.asarray(corpus.embeddings), n_shards=2)
        index_lib.IndexReader.open(out, verify="full")
    assert not os.path.exists(out + ".old")
    assert not os.path.exists(out + ".tmp")


def test_corrupted_shard_rejected(built, tmp_path):
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "corrupt")
    shard = os.path.join(bad, "blocks", "shard_00001.bin")
    with open(shard, "r+b") as f:
        f.seek(128)
        f.write(b"\xff" * 64)
    # size-level check passes (same byte count) ...
    index_lib.IndexReader.open(bad, verify="size")
    # ... full checksum catches the flip
    with pytest.raises(index_lib.IndexChecksumError, match="shard_00001"):
        index_lib.IndexReader.open(bad, verify="full")


def test_truncated_shard_rejected_at_size_level(built, tmp_path):
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "trunc")
    shard = os.path.join(bad, "blocks", "shard_00000.bin")
    with open(shard, "r+b") as f:
        f.truncate(os.path.getsize(shard) - 8)
    with pytest.raises(index_lib.IndexChecksumError, match="truncated"):
        index_lib.IndexReader.open(bad, verify="size")
    missing = _copy_index(out, tmp_path, "missing")
    os.remove(os.path.join(missing, "centroids.npy"))
    with pytest.raises(index_lib.IndexChecksumError, match="missing"):
        index_lib.IndexReader.open(missing, verify="size")


# ---------------------------------------------------------------------------
# sharded store routing + coalescing
# ---------------------------------------------------------------------------

def test_sharded_store_routes_and_coalesces(built):
    cfg, corpus, index, out, manifest, _ = built
    reader = index_lib.IndexReader.open(out)
    store = reader.open_store()
    mem = InMemoryStore(corpus.embeddings, index.cluster_docs)
    lo1 = manifest["block_shards"][1]["cluster_lo"]
    # adjacent run inside shard 0, a run crossing into shard 1, a singleton
    cids = np.asarray([2, 3, 4, lo1 - 1, lo1, 31])
    vecs, docs, valid = store.fetch_blocks(cids)
    vref, dref, varef = map(np.asarray, mem.fetch_blocks(jnp.asarray(cids)))
    np.testing.assert_allclose(np.asarray(vecs), vref, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(docs, dref)
    np.testing.assert_array_equal(valid, varef)
    # runs: [2,3,4], [lo1-1], [lo1], [31] -> 4 ops for 6 blocks
    assert store.stats.n_ops == 4
    assert store.stats.bytes == 6 * store.block_bytes


def test_disk_cluster_store_pack_open_split(built, tmp_path):
    _, corpus, index, _, _, _ = built
    path = str(tmp_path / "blocks.bin")
    packed = dk.DiskClusterStore.pack(path, corpus.embeddings,
                                      index.cluster_docs)
    stamp = (os.path.getmtime(path), os.path.getsize(path))
    reopened = dk.DiskClusterStore.open(path, packed.n_clusters, packed.cap,
                                        packed.dim)
    stats = dk.IOStats()
    got = np.asarray(reopened.fetch_clusters([5, 6, 7, 20], stats))
    np.testing.assert_array_equal(got,
                                  np.asarray(packed.fetch_clusters([5, 6, 7, 20])))
    # reopening + reading never rewrites the block file
    assert (os.path.getmtime(path), os.path.getsize(path)) == stamp
    # [5,6,7] coalesce into one read; [20] is a second
    assert stats.n_ops == 2 and stats.bytes == 4 * reopened.block_bytes
    with pytest.raises(ValueError, match="expected"):
        dk.DiskClusterStore.open(path, packed.n_clusters + 1, packed.cap,
                                 packed.dim)
    with pytest.raises(ValueError, match="n_clusters"):
        dk.DiskClusterStore(path)


# ---------------------------------------------------------------------------
# offline sharded build
# ---------------------------------------------------------------------------

def test_offline_sharded_build_deterministic_and_valid():
    cfg = _tiny_cfg()
    corpus = synth_corpus(3, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings)

    def build():
        return index_lib.build_index_offline(
            cfg, jax.random.key(5), emb, corpus.doc_terms,
            corpus.doc_weights, shard_docs=200, kmeans_iters=5)

    a, b = build(), build()
    assert a.embeddings is None
    np.testing.assert_array_equal(np.asarray(a.cluster_docs),
                                  np.asarray(b.cluster_docs))
    np.testing.assert_allclose(np.asarray(a.centroids),
                               np.asarray(b.centroids))
    # valid partition: every doc exactly once, consistent doc_cluster
    cd = np.asarray(a.cluster_docs)
    members = cd[cd >= 0]
    assert sorted(members.tolist()) == list(range(cfg.n_docs))
    dc = np.asarray(a.doc_cluster)
    for c in range(cfg.n_clusters):
        for d in cd[c][cd[c] >= 0]:
            assert dc[d] == c
