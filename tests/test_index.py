"""Persistent index subsystem tests: build -> write -> reopen round trip
(v1 float blocks and v2 PQ code shards), manifest/checksum rejection of
corruption, v1-reader rejection of v2, mmap loading without embedding
materialization, sharded-store routing + run coalescing, the
DiskClusterStore pack/open split, and the offline sharded build pipeline —
including corpus>RAM streaming builds with read sizes capped by a test
wrapper."""

import dataclasses
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import index as index_lib
from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.core import quant as quant_lib
from repro.core import sparse as sparse_lib
from repro.core import train_lstm as tl
from repro.data import mrr_at, synth_corpus, synth_queries
from repro.engine import InMemoryStore, RetrievalEngine, pipeline


class CappedReads:
    """Row-indexable embedding source that fails the test if any single
    read pulls more than `max_rows` rows or the full matrix is
    materialized — the streaming-build contract, enforced."""

    def __init__(self, arr, max_rows):
        self._arr = np.asarray(arr)
        self.max_rows = int(max_rows)
        self.peak = 0
        self.shape = self._arr.shape
        self.dtype = self._arr.dtype

    def __len__(self):
        return self.shape[0]

    def __array__(self, dtype=None, copy=None):
        raise AssertionError("full embedding matrix materialized")

    def __getitem__(self, key):
        out = self._arr[key]
        rows = int(out.shape[0]) if out.ndim == 2 else 1
        self.peak = max(self.peak, rows)
        if rows > self.max_rows:
            raise AssertionError(
                f"read {rows} embedding rows in one access "
                f"(cap {self.max_rows})")
        return out


def _tiny_cfg():
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=512, dim=16, n_clusters=32, vocab=256, max_postings=128,
        k_sparse=64, bins=(5, 15, 30, 64), n_candidates=8, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=32, train_queries=24, epochs=2)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """In-memory index (trained selector) + its serialized on-disk form."""
    cfg = _tiny_cfg()
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    tq = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                      tq.q_weights)
    index.lstm_params, _ = tl.train_selector(cfg, jax.random.key(2),
                                             np.asarray(feats),
                                             np.asarray(labels))
    out = str(tmp_path_factory.mktemp("idx") / "index")
    manifest = index_lib.write_index(out, cfg, index,
                                     np.asarray(corpus.embeddings),
                                     n_shards=3)
    qs = synth_queries(7, corpus, 10)
    return cfg, corpus, index, out, manifest, qs


# ---------------------------------------------------------------------------
# round trip
# ---------------------------------------------------------------------------

def test_roundtrip_arrays_config_and_lstm(built):
    cfg, _, index, out, manifest, _ = built
    reader = index_lib.IndexReader.open(out, verify="full")
    lcfg, lindex = reader.load_index()
    assert lcfg == cfg
    assert lindex.embeddings is None
    for name, ref in (("centroids", index.centroids),
                      ("cluster_docs", index.cluster_docs),
                      ("doc_cluster", index.doc_cluster),
                      ("neighbor_ids", index.neighbor_ids),
                      ("bin_ids", index.bin_ids)):
        np.testing.assert_array_equal(np.asarray(getattr(lindex, name)),
                                      np.asarray(ref), err_msg=name)
    np.testing.assert_allclose(
        np.asarray(lindex.sparse_index.postings_weights),
        np.asarray(index.sparse_index.postings_weights))
    for k, v in index.lstm_params.items():
        np.testing.assert_array_equal(np.asarray(lindex.lstm_params[k]),
                                      np.asarray(v), err_msg=k)
    # manifest accounting covers every artifact
    assert manifest["total_bytes"] == sum(
        e["bytes"] for e in manifest["files"].values())
    assert len(manifest["block_shards"]) == 3


def test_mmap_loading_no_copy(built):
    _, _, _, out, _, _ = built
    reader = index_lib.IndexReader.open(out)
    arr = reader.array("centroids")
    assert isinstance(arr, np.memmap)
    store = reader.open_store()
    assert all(isinstance(mm, np.memmap) for mm in store._mms)
    assert store.n_shards == 3


def test_built_index_serving_parity(built):
    """Acceptance: built index -> IndexReader -> ShardedDiskStore returns the
    same fused top-k as the in-memory pipeline, direct and via the engine."""
    cfg, corpus, _, out, _, qs = built
    reader = index_lib.IndexReader.open(out, verify="full")
    lcfg, lindex = reader.load_index()
    mem = InMemoryStore(corpus.embeddings, lindex.cluster_docs)
    ref_ids, ref_scores, _ = pipeline.retrieve(
        lcfg, lindex, mem, qs.q_dense, qs.q_terms, qs.q_weights)

    store = reader.open_store(cluster_docs=lindex.cluster_docs)
    ids, scores, _ = pipeline.retrieve(lcfg, lindex, store, qs.q_dense,
                                       qs.q_terms, qs.q_weights)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref_scores),
                               rtol=1e-5, atol=1e-5)
    assert store.stats.n_ops > 0
    assert store.stats.bytes % store.block_bytes == 0
    # coalescing: ops count runs, never more than blocks read
    assert store.stats.n_ops <= store.stats.bytes // store.block_bytes

    with reader.engine(cfg=lcfg, index=lindex, max_batch=8) as eng:
        eids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
    np.testing.assert_array_equal(np.asarray(eids), np.asarray(ref_ids))
    assert eng.stats()["io"]["n_ops"] > 0


# ---------------------------------------------------------------------------
# format validation
# ---------------------------------------------------------------------------

def _copy_index(out, tmp_path, name):
    dst = str(tmp_path / name)
    shutil.copytree(out, dst)
    return dst


def test_wrong_format_version_rejected(built, tmp_path):
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "badver")
    mpath = os.path.join(bad, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["format_version"] = max(index_lib.SUPPORTED_VERSIONS) + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_lib.IndexFormatError, match="version"):
        index_lib.IndexReader.open(bad)


def test_stripped_checksum_map_fails_closed(built, tmp_path):
    """verify != "none" must refuse a manifest without checksums rather
    than silently verifying nothing."""
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "nofiles")
    mpath = os.path.join(bad, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["files"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(index_lib.IndexFormatError, match="checksum"):
        index_lib.IndexReader.open(bad, verify="full")
    index_lib.IndexReader.open(bad, verify="none")      # explicit opt-out


def test_overwrite_in_place_keeps_index_readable(built, tmp_path):
    cfg, corpus, index, _, _, _ = built
    out = str(tmp_path / "index")
    for _ in range(2):      # second write exercises the move-aside commit
        index_lib.write_index(out, cfg, index,
                              np.asarray(corpus.embeddings), n_shards=2)
        index_lib.IndexReader.open(out, verify="full")
    assert not os.path.exists(out + ".old")
    assert not os.path.exists(out + ".tmp")


def test_corrupted_shard_rejected(built, tmp_path):
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "corrupt")
    shard = os.path.join(bad, "blocks", "shard_00001.bin")
    with open(shard, "r+b") as f:
        f.seek(128)
        f.write(b"\xff" * 64)
    # size-level check passes (same byte count) ...
    index_lib.IndexReader.open(bad, verify="size")
    # ... full checksum catches the flip
    with pytest.raises(index_lib.IndexChecksumError, match="shard_00001"):
        index_lib.IndexReader.open(bad, verify="full")


def test_truncated_shard_rejected_at_size_level(built, tmp_path):
    _, _, _, out, _, _ = built
    bad = _copy_index(out, tmp_path, "trunc")
    shard = os.path.join(bad, "blocks", "shard_00000.bin")
    with open(shard, "r+b") as f:
        f.truncate(os.path.getsize(shard) - 8)
    with pytest.raises(index_lib.IndexChecksumError, match="truncated"):
        index_lib.IndexReader.open(bad, verify="size")
    missing = _copy_index(out, tmp_path, "missing")
    os.remove(os.path.join(missing, "centroids.npy"))
    with pytest.raises(index_lib.IndexChecksumError, match="missing"):
        index_lib.IndexReader.open(missing, verify="size")


# ---------------------------------------------------------------------------
# sharded store routing + coalescing
# ---------------------------------------------------------------------------

def test_sharded_store_routes_and_coalesces(built):
    cfg, corpus, index, out, manifest, _ = built
    reader = index_lib.IndexReader.open(out)
    store = reader.open_store()
    mem = InMemoryStore(corpus.embeddings, index.cluster_docs)
    lo1 = manifest["block_shards"][1]["cluster_lo"]
    # adjacent run inside shard 0, a run crossing into shard 1, a singleton
    cids = np.asarray([2, 3, 4, lo1 - 1, lo1, 31])
    vecs, docs, valid = store.fetch_blocks(cids)
    vref, dref, varef = map(np.asarray, mem.fetch_blocks(jnp.asarray(cids)))
    np.testing.assert_allclose(np.asarray(vecs), vref, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(docs, dref)
    np.testing.assert_array_equal(valid, varef)
    # runs: [2,3,4], [lo1-1], [lo1], [31] -> 4 ops for 6 blocks
    assert store.stats.n_ops == 4
    assert store.stats.bytes == 6 * store.block_bytes


def test_disk_cluster_store_pack_open_split(built, tmp_path):
    _, corpus, index, _, _, _ = built
    path = str(tmp_path / "blocks.bin")
    packed = dk.DiskClusterStore.pack(path, corpus.embeddings,
                                      index.cluster_docs)
    stamp = (os.path.getmtime(path), os.path.getsize(path))
    reopened = dk.DiskClusterStore.open(path, packed.n_clusters, packed.cap,
                                        packed.dim)
    stats = dk.IOStats()
    got = np.asarray(reopened.fetch_clusters([5, 6, 7, 20], stats))
    np.testing.assert_array_equal(got,
                                  np.asarray(packed.fetch_clusters([5, 6, 7, 20])))
    # reopening + reading never rewrites the block file
    assert (os.path.getmtime(path), os.path.getsize(path)) == stamp
    # [5,6,7] coalesce into one read; [20] is a second
    assert stats.n_ops == 2 and stats.bytes == 4 * reopened.block_bytes
    with pytest.raises(ValueError, match="expected"):
        dk.DiskClusterStore.open(path, packed.n_clusters + 1, packed.cap,
                                 packed.dim)
    with pytest.raises(ValueError, match="n_clusters"):
        dk.DiskClusterStore(path)


# ---------------------------------------------------------------------------
# format v2: PQ code shards
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def built_v2(built, tmp_path_factory):
    """The same index serialized as a v2 PQ index (explicit trained PQ)."""
    cfg, corpus, index, _, _, qs = built
    pq = quant_lib.train_pq(jax.random.key(3), corpus.embeddings, nsub=8)
    out = str(tmp_path_factory.mktemp("idx_v2") / "index")
    manifest = index_lib.write_index(
        out, cfg, index, np.asarray(corpus.embeddings), n_shards=3,
        format_version=index_lib.FORMAT_VERSION_PQ, pq=pq)
    return cfg, corpus, index, out, manifest, qs, pq


def test_v2_roundtrip_codes_and_postings(built_v2):
    cfg, corpus, index, out, manifest, qs, pq = built_v2
    assert manifest["format_version"] == index_lib.FORMAT_VERSION_PQ
    assert manifest["pq"] is not None
    assert "codes" not in manifest["pq"]["arrays"]   # codes live in shards
    reader = index_lib.IndexReader.open(out, verify="full")
    assert reader.is_pq
    lcfg, lindex = reader.load_index()
    assert lcfg == cfg and lindex.embeddings is None
    # cold open stays cheap: the v2 per-doc code view is NOT rebuilt by
    # default (serving decodes straight from the shards) ...
    assert lindex.quantizer is None
    # ... but rebuilding it on demand recovers exactly the written codes
    np.testing.assert_array_equal(np.asarray(reader.quantizer().codes),
                                  np.asarray(pq.codes))
    # CSR re-pad is lossless: identical sparse retrieval
    ref_ids, ref_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, qs.q_terms, qs.q_weights, cfg.k_sparse)
    got_ids, got_scores = sparse_lib.sparse_retrieve_topk(
        lindex.sparse_index, qs.q_terms, qs.q_weights, cfg.k_sparse)
    np.testing.assert_array_equal(np.asarray(got_ids), np.asarray(ref_ids))
    np.testing.assert_allclose(np.asarray(got_scores),
                               np.asarray(ref_scores), rtol=1e-6, atol=1e-6)


def test_v2_store_decodes_to_pq_reconstruction(built_v2):
    """ShardedPQStore.fetch_blocks == codebook reconstruction of the same
    docs (= exact ADC), and I/O bytes count CODE bytes, not float bytes."""
    _, _, index, out, _, _, pq = built_v2
    reader = index_lib.IndexReader.open(out)
    store = reader.open_store()
    assert isinstance(store, index_lib.ShardedPQStore)
    cids = np.asarray([0, 1, 2, 17, 31])
    vecs, docs, valid = store.fetch_blocks(cids)
    flat_docs = np.where(docs >= 0, docs, 0).reshape(-1)
    ref = np.asarray(quant_lib.reconstruct(pq, jnp.asarray(flat_docs)))
    ref = ref.reshape(vecs.shape)
    np.testing.assert_allclose(np.asarray(vecs)[valid], ref[valid],
                               rtol=1e-5, atol=1e-5)
    # [0,1,2] coalesce; [17]; [31] -> 3 ops, and bytes are uint8 codes
    assert store.stats.n_ops == 3
    assert store.stats.bytes == 5 * store.cap * store.nsub


def test_v2_serving_quality_within_tolerance(built_v2):
    """Acceptance: v2 PQ serving through the engine stays within 0.02
    MRR@10 of the float32 in-memory backend on the same queries."""
    cfg, corpus, _, out, _, qs, _ = built_v2
    reader = index_lib.IndexReader.open(out, verify="full")
    lcfg, lindex = reader.load_index()
    mem = InMemoryStore(corpus.embeddings, lindex.cluster_docs)
    ref_ids, _, _ = pipeline.retrieve(lcfg, lindex, mem, qs.q_dense,
                                      qs.q_terms, qs.q_weights)
    with reader.engine(cfg=lcfg, index=lindex, max_batch=8) as eng:
        ids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
    ref_mrr = mrr_at(np.asarray(ref_ids), qs.rel_doc)
    got_mrr = mrr_at(np.asarray(ids), qs.rel_doc)
    assert abs(ref_mrr - got_mrr) <= 0.02, (ref_mrr, got_mrr)
    assert eng.stats()["io"]["n_ops"] > 0


def test_v2_index_smaller_than_v1(built, built_v2):
    _, _, _, _, m1, _ = built
    _, _, _, _, m2, _, _ = built_v2
    assert m2["total_bytes"] < m1["total_bytes"] / 2, \
        (m2["total_bytes"], m1["total_bytes"])


def test_v1_reader_rejects_v2(built_v2):
    """Compat rule: a PR-2-era reader (speaks only format 1) must refuse a
    v2 index up front with a clear error, not misread code shards."""
    _, _, _, out, _, _, _ = built_v2
    with pytest.raises(index_lib.IndexFormatError, match="version"):
        index_lib.load_manifest(out, supported=(index_lib.FORMAT_VERSION,))
    with pytest.raises(index_lib.IndexFormatError, match="version"):
        index_lib.IndexReader.open(out,
                                   supported=(index_lib.FORMAT_VERSION,))


# ---------------------------------------------------------------------------
# corpus > RAM: streaming builds with bounded reads
# ---------------------------------------------------------------------------

def test_streaming_build_bounded_reads(tmp_path):
    """build_index_offline + write_index (v1 and v2) over a capped-read
    source: no single access exceeds the chunk, nothing materializes the
    matrix, and the result matches the unrestricted build exactly."""
    cfg = _tiny_cfg()
    corpus = synth_corpus(5, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings)
    chunk = 96              # > cluster_cap, << n_docs
    assert chunk < cfg.n_docs and chunk >= cfg.cluster_cap
    capped = CappedReads(emb, chunk)
    index = index_lib.build_index_offline(
        cfg, jax.random.key(1), capped, corpus.doc_terms,
        corpus.doc_weights, shard_docs=chunk, kmeans_iters=3)
    ref = index_lib.build_index_offline(
        cfg, jax.random.key(1), emb, corpus.doc_terms,
        corpus.doc_weights, shard_docs=chunk, kmeans_iters=3)
    np.testing.assert_array_equal(np.asarray(index.cluster_docs),
                                  np.asarray(ref.cluster_docs))
    np.testing.assert_allclose(np.asarray(index.centroids),
                               np.asarray(ref.centroids))
    assert 0 < capped.peak <= chunk

    for version, name in ((1, "v1"), (2, "v2")):
        out = str(tmp_path / f"idx_{name}")
        index_lib.write_index(out, cfg, index, capped, n_shards=3,
                              format_version=version, chunk_docs=chunk,
                              pq_nsub=8)
        index_lib.IndexReader.open(out, verify="full")
    assert capped.peak <= chunk


def test_build_and_serve_from_memmap(tmp_path):
    """End to end with an actual np.memmap source: offline build matches
    the in-memory build, and a v2 index written from the memmap serves."""
    cfg = _tiny_cfg()
    corpus = synth_corpus(6, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings, np.float32)
    raw = str(tmp_path / "emb.bin")
    emb.tofile(raw)
    mm = np.memmap(raw, dtype=np.float32, mode="r", shape=emb.shape)

    index = index_lib.build_index_offline(
        cfg, jax.random.key(2), mm, corpus.doc_terms, corpus.doc_weights,
        shard_docs=128, kmeans_iters=3)
    ref = index_lib.build_index_offline(
        cfg, jax.random.key(2), emb, corpus.doc_terms, corpus.doc_weights,
        shard_docs=128, kmeans_iters=3)
    np.testing.assert_array_equal(np.asarray(index.cluster_docs),
                                  np.asarray(ref.cluster_docs))

    out = str(tmp_path / "idx")
    index_lib.write_index(out, cfg, index, mm, n_shards=2,
                          format_version=index_lib.FORMAT_VERSION_PQ,
                          chunk_docs=128, pq_nsub=8)
    reader = index_lib.IndexReader.open(out, verify="full")
    qs = synth_queries(8, corpus, 4)
    with reader.engine(max_batch=4) as eng:
        ids, _ = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
    assert np.asarray(ids).shape[0] == 4


# ---------------------------------------------------------------------------
# offline sharded build
# ---------------------------------------------------------------------------

def test_offline_sharded_build_deterministic_and_valid():
    cfg = _tiny_cfg()
    corpus = synth_corpus(3, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings)

    def build():
        return index_lib.build_index_offline(
            cfg, jax.random.key(5), emb, corpus.doc_terms,
            corpus.doc_weights, shard_docs=200, kmeans_iters=5)

    a, b = build(), build()
    assert a.embeddings is None
    np.testing.assert_array_equal(np.asarray(a.cluster_docs),
                                  np.asarray(b.cluster_docs))
    np.testing.assert_allclose(np.asarray(a.centroids),
                               np.asarray(b.centroids))
    # valid partition: every doc exactly once, consistent doc_cluster
    cd = np.asarray(a.cluster_docs)
    members = cd[cd >= 0]
    assert sorted(members.tolist()) == list(range(cfg.n_docs))
    dc = np.asarray(a.doc_cluster)
    for c in range(cfg.n_clusters):
        for d in cd[c][cd[c] >= 0]:
            assert dc[d] == c
