"""Minimal stand-in for `hypothesis` so property tests still run (with a
deterministic sample sweep) where the real package isn't installed.

Only covers what this suite uses: `@settings(max_examples=..., deadline=...)`
stacked on `@given(st.integers(lo, hi))`. Prefer the real hypothesis
(requirements.txt) — this fallback trades shrinking/coverage for zero deps.
"""

import numpy as np

DEFAULT_EXAMPLES = 10


class _Integers:
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def samples(self, n):
        out = [self.lo, self.hi] if self.hi > self.lo else [self.lo]
        rng = np.random.default_rng(0xC1D5D)
        while len(out) < n:
            out.append(int(rng.integers(self.lo, self.hi + 1)))
        return out[:n]


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)


def given(strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_EXAMPLES)
            for value in strategy.samples(n):
                fn(*args, value, **kwargs)
        # no functools.wraps: pytest must see the zero-arg wrapper signature,
        # not the inner function's strategy-filled parameter
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = DEFAULT_EXAMPLES
        return wrapper
    return deco


def settings(max_examples=DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
