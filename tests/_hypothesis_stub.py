"""Minimal stand-in for `hypothesis` so property tests still run (with a
deterministic sample sweep) where the real package isn't installed.

Only covers what this suite uses: `@settings(max_examples=..., deadline=...)`
stacked on `@given(st.integers(lo, hi))`, with the drawn value filling the
test's LAST parameter (hypothesis's right-to-left convention) so pytest
fixtures in earlier parameters keep working. Prefer the real hypothesis
(requirements.txt) — this fallback trades shrinking/coverage for zero deps.
"""

import inspect

import numpy as np

DEFAULT_EXAMPLES = 10


class _Integers:
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def samples(self, n):
        out = [self.lo, self.hi] if self.hi > self.lo else [self.lo]
        rng = np.random.default_rng(0xC1D5D)
        while len(out) < n:
            out.append(int(rng.integers(self.lo, self.hi + 1)))
        return out[:n]


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)


def given(strategy):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        value_name = params[-1].name          # strategy fills the last param

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", DEFAULT_EXAMPLES)
            for value in strategy.samples(n):
                fn(*args, **dict(kwargs, **{value_name: value}))
        # pytest must see only the fixture parameters, not the
        # strategy-filled one (and not a bare *args/**kwargs signature)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = sig.replace(parameters=params[:-1])
        wrapper._max_examples = DEFAULT_EXAMPLES
        return wrapper
    return deco


def settings(max_examples=DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
