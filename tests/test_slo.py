"""SLO monitor + explain-logger tests: deterministic burn-rate state
machine via an injectable clock (OK -> PAGE -> recovery across window
rollover), error-rate delta baselines, zero-tolerance budgets, event-log
bounding, config round-trip, and the ExplainLogger's deterministic
sampling accumulator + bounded ring + JSONL file sink. Everything here
is jax-free: repro.obs stays stdlib-only."""

import json
import os
import tempfile

import pytest

from repro.obs import (
    ExplainLogger, MetricsRegistry, SLOMonitor, SLOObjective,
    default_objectives)


class FakeRegistry:
    """Minimal registry-shaped object: SLOMonitor only calls snapshot()."""

    def __init__(self):
        self.counters = {}
        self.gauges = {}
        self.histograms = {}

    def snapshot(self):
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: dict(v)
                               for k, v in self.histograms.items()}}


class Clock:
    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t


def _latency_obj(**kw):
    base = dict(name="p99", kind="latency", metric="serve.batch_ms",
                threshold=500.0, fast_window_s=10.0, slow_window_s=30.0,
                warn_burn=0.75, page_burn=1.0)
    base.update(kw)
    return SLOObjective(**base)


# ---------------------------------------------------------------------------
# objective validation
# ---------------------------------------------------------------------------

def test_objective_validation():
    with pytest.raises(ValueError, match="unknown objective kind"):
        SLOObjective(name="x", kind="nope", metric="m", threshold=1.0)
    with pytest.raises(ValueError, match="total"):
        SLOObjective(name="x", kind="error_rate", metric="m", threshold=0.1)
    with pytest.raises(ValueError, match="negative threshold"):
        SLOObjective(name="x", kind="gauge", metric="m", threshold=-1.0)
    with pytest.raises(ValueError, match="fast window"):
        _latency_obj(fast_window_s=60.0, slow_window_s=30.0)
    with pytest.raises(ValueError, match="unknown SLO objective keys"):
        SLOObjective.from_dict({"name": "x", "kind": "gauge", "metric": "m",
                                "threshold": 1.0, "bogus": 1})


def test_monitor_rejects_empty_and_duplicate_objectives():
    reg = FakeRegistry()
    with pytest.raises(ValueError, match="at least one"):
        SLOMonitor(reg, [])
    with pytest.raises(ValueError, match="duplicate"):
        SLOMonitor(reg, [_latency_obj(), _latency_obj()])


def test_default_objectives_shape():
    objs = default_objectives(p99_gate_ms=250.0)
    assert [o.name for o in objs] == ["p99_latency", "failed_requests",
                                      "recall_drift"]
    assert objs[0].threshold == 250.0
    assert objs[1].kind == "error_rate" and objs[1].total == "soak.requests"


# ---------------------------------------------------------------------------
# latency burn: spike -> PAGE -> window rollover -> recovery
# ---------------------------------------------------------------------------

def test_latency_page_and_window_rollover():
    reg, clock = FakeRegistry(), Clock()
    mon = SLOMonitor(reg, [_latency_obj()], clock=clock)

    reg.histograms["serve.batch_ms"] = {"p99": 100.0}
    assert mon.evaluate()["state"] == "OK"

    # burn 1.2 lands in BOTH windows at once -> PAGE
    clock.t = 1.0
    reg.histograms["serve.batch_ms"] = {"p99": 600.0}
    assert mon.evaluate()["state"] == "PAGE"
    assert mon.state == "PAGE"

    # t=12: the spike left the fast window (cutoff t=2) but still sits in
    # the slow one -> fast burn drops, PAGE clears (multi-window: recovery
    # confirmed by the fast window first)
    clock.t = 12.0
    reg.histograms["serve.batch_ms"] = {"p99": 100.0}
    assert mon.evaluate()["state"] == "OK"

    # t=40: spike out of the slow window too; still OK
    clock.t = 40.0
    assert mon.evaluate()["state"] == "OK"

    v = mon.verdict()
    assert v["final_state"] == "OK"
    assert v["worst_state"] == "PAGE"       # history is not forgotten
    assert v["pages"] == 1
    assert v["ok"] is False                  # a page anywhere fails the run
    transitions = [(e["from"], e["to"]) for e in mon.events]
    assert transitions == [("OK", "PAGE"), ("PAGE", "OK")]


def test_latency_warn_band():
    reg, clock = FakeRegistry(), Clock()
    mon = SLOMonitor(reg, [_latency_obj(warn_burn=0.75, page_burn=2.0)],
                     clock=clock)
    reg.histograms["serve.batch_ms"] = {"p99": 500.0}   # burn exactly 1.0
    assert mon.evaluate()["state"] == "WARN"
    v = mon.verdict()
    assert v["warns"] == 1 and v["pages"] == 0 and v["ok"] is True


# ---------------------------------------------------------------------------
# error rate: delta baselines + zero tolerance
# ---------------------------------------------------------------------------

def _err_obj(threshold):
    return SLOObjective(name="fail", kind="error_rate", metric="err",
                        total="tot", threshold=threshold,
                        fast_window_s=10.0, slow_window_s=30.0,
                        warn_burn=1.0, page_burn=1.0)


def test_error_rate_zero_tolerance_pages_then_recovers():
    reg, clock = FakeRegistry(), Clock()
    mon = SLOMonitor(reg, [_err_obj(0.0)], clock=clock)
    reg.counters = {"err": 0, "tot": 10}
    assert mon.evaluate()["state"] == "OK"

    clock.t = 1.0
    reg.counters = {"err": 2, "tot": 20}
    assert mon.evaluate()["state"] == "PAGE"    # any windowed error pages
    assert mon._last["fail"]["burn_fast"] == "inf"

    # t=50: the error increment predates both windows; the baseline sample
    # (newest older than the window) pins delta(err)=0 -> recovery. The
    # counters are CUMULATIVE and never reset.
    clock.t = 50.0
    reg.counters = {"err": 2, "tot": 100}
    assert mon.evaluate()["state"] == "OK"


def test_error_rate_fractional_threshold():
    reg, clock = FakeRegistry(), Clock()
    mon = SLOMonitor(reg, [_err_obj(0.5)], clock=clock)
    reg.counters = {"err": 0, "tot": 0}
    mon.evaluate()
    clock.t = 1.0
    reg.counters = {"err": 2, "tot": 20}    # windowed rate 0.1, burn 0.2
    assert mon.evaluate()["state"] == "OK"
    clock.t = 2.0
    reg.counters = {"err": 14, "tot": 40}   # windowed rate 0.35, burn 0.7
    assert mon.evaluate()["state"] == "OK"
    clock.t = 3.0
    reg.counters = {"err": 44, "tot": 60}   # windowed rate ~0.73, burn >1
    assert mon.evaluate()["state"] == "PAGE"


def test_gauge_objective_and_unregistered_metric_burns_zero():
    reg, clock = FakeRegistry(), Clock()
    obj = SLOObjective(name="drift", kind="gauge", metric="soak.drift",
                       threshold=0.05, fast_window_s=10.0,
                       slow_window_s=30.0, warn_burn=0.75, page_burn=1.0)
    mon = SLOMonitor(reg, [obj], clock=clock)
    assert mon.evaluate()["state"] == "OK"      # metric never registered
    reg.gauges["soak.drift"] = -0.06            # abs() -> burn 1.2
    clock.t = 1.0
    assert mon.evaluate()["state"] == "PAGE"


# ---------------------------------------------------------------------------
# bounding + config + endpoint payloads
# ---------------------------------------------------------------------------

def test_event_log_and_sample_bounding():
    reg, clock = FakeRegistry(), Clock()
    obj = SLOObjective(name="g", kind="gauge", metric="v", threshold=1.0,
                       fast_window_s=1.0, slow_window_s=1.0,
                       warn_burn=1.0, page_burn=1.0)
    mon = SLOMonitor(reg, [obj], clock=clock, event_capacity=4,
                     max_samples=8)
    for i in range(40):                         # flip every evaluation
        clock.t = float(i * 2)                  # old samples roll out
        reg.gauges["v"] = 5.0 if i % 2 else 0.0
        mon.evaluate()
    assert len(mon.events) == 4                 # bounded, newest kept
    assert len(mon._samples["g"]) == 8
    assert mon.verdict()["pages"] > 4           # counts survive trimming


def test_from_config_file_roundtrip(tmp_path):
    cfg = {"objectives": [
        {"name": "p99", "kind": "latency", "metric": "serve.batch_ms",
         "threshold": 123.0, "fast_window_s": 5.0, "slow_window_s": 9.0},
    ]}
    path = os.path.join(tmp_path, "slo.json")
    with open(path, "w") as f:
        json.dump(cfg, f)
    mon = SLOMonitor.from_config(FakeRegistry(), path, clock=Clock())
    assert mon.objectives[0].threshold == 123.0
    assert mon.objectives[0].slow_window_s == 9.0
    with pytest.raises(ValueError, match="unknown SLO objective keys"):
        SLOMonitor.from_config(FakeRegistry(),
                               {"objectives": [{"name": "x", "oops": 1}]})


def test_status_payload_shape():
    reg, clock = FakeRegistry(), Clock()
    mon = SLOMonitor(reg, [_latency_obj()], clock=clock)
    reg.histograms["serve.batch_ms"] = {"p99": 50.0}
    mon.evaluate()
    st = mon.status()
    assert st["state"] == "OK" and st["n_evaluations"] == 1
    assert st["objectives"]["p99"]["threshold"] == 500.0
    assert st["objectives"]["p99"]["kind"] == "latency"
    json.dumps(st)                              # endpoint-serializable


def test_works_against_real_registry():
    reg = MetricsRegistry()
    reg.counter("soak.requests").inc(100)
    reg.counter("soak.failed_requests").inc(0)
    h = reg.histogram("serve.batch_ms")
    for _ in range(20):
        h.observe(3.0)
    clock = Clock()
    mon = SLOMonitor(reg, default_objectives(p99_gate_ms=100.0),
                     clock=clock)
    assert mon.evaluate()["state"] == "OK"
    reg.counter("soak.failed_requests").inc()
    clock.t = 1.0
    assert mon.evaluate()["state"] == "PAGE"    # zero failure budget


# ---------------------------------------------------------------------------
# ExplainLogger
# ---------------------------------------------------------------------------

def test_explain_sampling_deterministic():
    ex = ExplainLogger(sample_rate=0.25)
    # accumulator starts at 1.0: the FIRST batch is always explained
    assert [ex.sample() for _ in range(8)] == \
        [True, False, False, True, False, False, False, True]
    assert ex.stats()["n_sampled"] == 3
    assert ExplainLogger(sample_rate=0.0).sample() is False
    assert all(ExplainLogger(sample_rate=1.0).sample() for _ in range(5))
    with pytest.raises(ValueError):
        ExplainLogger(sample_rate=1.5)


def test_explain_ring_bounded_and_file_sink():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "explain.jsonl")
        with ExplainLogger(path, capacity=3) as ex:
            for i in range(7):
                ex.emit({"qid": i})
            assert [r["qid"] for r in ex.recent()] == [4, 5, 6]
            assert ex.stats()["n_records"] == 7
            ex.flush()
            with open(path) as f:
                lines = [json.loads(x) for x in f]
        # the FILE keeps everything; only the in-memory ring is bounded
        assert [r["qid"] for r in lines] == list(range(7))
