"""Multi-host scatter-gather serving tier tests (engine/router.py).

  * partial top-k merge is bitwise-identical to single-host lax.top_k
    over the union — property-tested over arbitrary host partitions,
    duplicate ids at any multiplicity, and exact score ties
  * healthy-fleet routing is bitwise-identical to the single-host engine
    (v1 float shards and v2 ADC alike, divisible or not)
  * fault injection: kill one host mid-stream -> the replica serves and
    failed_requests stays 0; kill ALL replicas of a shard -> requests
    complete degraded with the missing shard flagged, exactly equal to
    serving without that shard; timeouts retry with exponential backoff
  * rolling generation hops: a delta commit + reload_index rolls the
    fleet host-by-host under concurrent queries with zero failures,
    every response served from exactly one generation
  * router traces carry scatter/gather/merge stage spans
  * shard-subset stores refuse clusters they don't own
"""

import dataclasses
import json
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # fall back to deterministic sweeps
    from _hypothesis_stub import given, settings
    from _hypothesis_stub import strategies as st

from repro import index as index_lib
from repro.configs import get_config
from repro.core import clusd as cl
from repro.data import synth_corpus, synth_queries
from repro.engine import (
    MERGE_SENTINEL, HostDown, ShardPlacement, ShardRouter,
    merge_partial_topk)
from repro.launch.update_index import synth_delta


def _tiny_cfg():
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=512, dim=16, n_clusters=32, vocab=256, max_postings=128,
        k_sparse=64, bins=(5, 15, 30, 64), n_candidates=8, max_selected=4,
        n_neighbors=8, u_bins=4, k_final=32, train_queries=24, epochs=2)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Tiny corpus serialized as BOTH formats (3 shards) + queries."""
    cfg = _tiny_cfg()
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    root = tmp_path_factory.mktemp("router_idx")
    out_v1, out_v2 = str(root / "v1"), str(root / "v2")
    emb = np.asarray(corpus.embeddings)
    index_lib.write_index(out_v1, cfg, index, emb, n_shards=3)
    index_lib.write_index(out_v2, cfg, index, emb, n_shards=3,
                          format_version=2, pq_nsub=4)
    qs = synth_queries(7, corpus, 24)
    return cfg, corpus, out_v1, out_v2, qs


def _engine_ids(out, qs, max_batch=8):
    reader = index_lib.IndexReader.open(out)
    with reader.engine(max_batch=max_batch, prefetch=False) as eng:
        ids, scores = eng.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
    return np.asarray(ids), np.asarray(scores)


def _router(out, n_hosts, replication=1, **kw):
    reader = index_lib.IndexReader.open(out)
    return ShardRouter.local(reader, n_hosts=n_hosts,
                             replication=replication, max_batch=8, **kw)


# ---------------------------------------------------------------------------
# merge: property test vs the single-host lax.top_k oracle
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**9))
def test_merge_matches_topk_oracle(seed):
    """Arbitrary host partitions with duplicate ids (any multiplicity),
    exact score ties, and ragged pads merge bitwise-identically to
    lax.top_k over the union (ties: score desc, then doc id asc)."""
    rng = np.random.default_rng(seed)
    B = int(rng.integers(1, 4))
    n_docs = int(rng.integers(5, 40))
    k = int(rng.integers(1, 20))
    n_hosts = int(rng.integers(1, 5))
    # few distinct score values -> plenty of exact ties
    score_pool = np.asarray([0.0, 0.25, 0.5, 1.0, 2.0], np.float32)
    parts = []
    for _ in range(n_hosts):
        width = int(rng.integers(1, 16))
        ids = rng.integers(0, n_docs, (B, width)).astype(np.int64)
        ss = score_pool[rng.integers(0, len(score_pool), (B, width))]
        pad = rng.random((B, width)) < 0.25
        ids = np.where(pad, MERGE_SENTINEL, ids)
        ss = np.where(pad, -np.inf, ss).astype(np.float32)
        parts.append((ids, ss))
    got_ids, got_ss = merge_partial_topk(parts, k)

    # oracle: scatter every occurrence into an id-indexed buffer (slot
    # id*M + occurrence) and lax.top_k it — top_k breaks value ties by
    # lowest index, i.e. (score desc, id asc); //M erases the occurrence
    all_ids = np.concatenate([p[0] for p in parts], axis=1)
    all_ss = np.concatenate([p[1] for p in parts], axis=1)
    M = all_ids.shape[1]                       # max possible multiplicity
    buf = np.full((B, n_docs * M), -np.inf, np.float32)
    for b in range(B):
        occ = {}
        for i, s in zip(all_ids[b], all_ss[b]):
            if i >= MERGE_SENTINEL or not np.isfinite(s):
                continue
            j = occ.get(int(i), 0)
            occ[int(i)] = j + 1
            buf[b, int(i) * M + j] = s
    vals, idx = jax.lax.top_k(jnp.asarray(buf), k)
    vals, idx = np.asarray(vals), np.asarray(idx)
    want_ids = np.where(np.isfinite(vals), idx // M, MERGE_SENTINEL)
    np.testing.assert_array_equal(got_ids, want_ids)
    np.testing.assert_array_equal(got_ss,
                                  np.where(np.isfinite(vals), vals, -np.inf))


def test_merge_underfull_and_duplicates():
    """Fewer real entries than k -> sentinel/-inf tail; duplicate ids keep
    their multiplicity (the fused tail scatter-adds duplicate slots, so
    the merge must not collapse them)."""
    ids = np.array([[3, 3, 7]], np.int64)
    ss = np.array([[1.0, 1.0, 2.0]], np.float32)
    got_ids, got_ss = merge_partial_topk([(ids, ss)], 6)
    np.testing.assert_array_equal(
        got_ids[0], [7, 3, 3, MERGE_SENTINEL, MERGE_SENTINEL,
                     MERGE_SENTINEL])
    np.testing.assert_array_equal(got_ss[0],
                                  [2.0, 1.0, 1.0, -np.inf, -np.inf, -np.inf])


# ---------------------------------------------------------------------------
# healthy-fleet parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt,n_hosts,replication", [
    ("v1", 3, 1), ("v1", 3, 2), ("v2", 3, 2),
    ("v2", 2, 1),        # 3 shards over 2 hosts: gappy subset ranges
])
def test_router_bitwise_matches_engine(built, fmt, n_hosts, replication):
    _, _, out_v1, out_v2, qs = built
    out = out_v1 if fmt == "v1" else out_v2
    ref_ids, ref_ss = _engine_ids(out, qs)
    with _router(out, n_hosts, replication) as router:
        ids, ss = router.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        st = router.stats()
    np.testing.assert_array_equal(np.asarray(ids), ref_ids)
    np.testing.assert_array_equal(np.asarray(ss), ref_ss)
    assert st["failed_requests"] == 0
    assert st["degraded_requests"] == 0 and not st["degraded"]
    assert all(h["served"] > 0 for h in st["per_host"])


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_kill_one_host_replica_serves(built):
    """R=2: killing a host mid-stream fails zero requests — its shards
    fail over to the surviving replica, results stay exact."""
    _, _, _, out_v2, qs = built
    ref_ids, _ = _engine_ids(out_v2, qs)
    with _router(out_v2, 3, replication=2) as router:
        ids_a, _ = router.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                                   qs.q_weights[:8])
        router.hosts[0].kill()
        ids_b, _ = router.retrieve(qs.q_dense[8:], qs.q_terms[8:],
                                   qs.q_weights[8:])
        st = router.stats()
    ids = np.concatenate([np.asarray(ids_a), np.asarray(ids_b)])
    np.testing.assert_array_equal(ids, ref_ids)
    assert st["failed_requests"] == 0
    assert st["failovers"] > 0          # shards routed off their primary
    assert not st["degraded"] and st["missing_shards"] == []
    assert st["per_host"][0]["alive"] is False


def test_kill_all_replicas_degrades_exactly(built):
    """R=1: killing a shard's only host leaves requests completing in
    degraded mode — missing shard flagged in stats(), results EXACTLY
    equal to a fleet that never had that shard."""
    _, _, _, out_v2, qs = built
    with _router(out_v2, 3, replication=1) as router:
        router.hosts[1].kill()
        ids, ss = router.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        st = router.stats()
        metas = list(router.last_batches)
    assert st["failed_requests"] == 0
    assert st["degraded"] and st["missing_shards"] == [1]
    assert st["degraded_requests"] == len(metas) > 0
    assert all(m["degraded"] and m["missing_shards"] == [1] for m in metas)

    # reference: placement where shard 1 has NO replica at all (serving
    # without that shard by construction)
    reader = index_lib.IndexReader.open(out_v2)
    pl = ShardPlacement(3, 2, replication=1,
                        replicas={0: [0], 1: [], 2: [1]})
    with ShardRouter.local(reader, n_hosts=2, placement=pl,
                           max_batch=8) as ref:
        ref_ids, ref_ss = ref.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        assert ref.stats()["degraded"]
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_ids))
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(ref_ss))


def test_timeout_retries_with_backoff(built):
    """A host that stalls past the timeout is retried with exponential
    backoff (injected sleep observes the waits) and the request still
    completes exactly, with zero failures."""
    _, _, out_v1, _, qs = built
    ref_ids, _ = _engine_ids(out_v1, qs)
    sleeps = []
    with _router(out_v1, 3, replication=1, host_timeout=0.1,
                 max_retries=4, backoff_ms=20.0,
                 sleep=lambda s: sleeps.append(s)) as router:
        # warm compile first so the stall hits a steady batch
        router.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])
        router.hosts[2].inject_delay(250.0, times=1)
        ids, _ = router.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                                 qs.q_weights[:8])
        st = router.stats()
    np.testing.assert_array_equal(np.asarray(ids), ref_ids[:8])
    assert st["failed_requests"] == 0
    assert st["retries"] >= 1
    assert len(sleeps) >= 1             # backoff actually waited
    assert all(b >= a for a, b in zip(sleeps, sleeps[1:]))  # exponential
    assert sleeps[0] == pytest.approx(0.02)


def test_all_hosts_dead_fails_request(built):
    _, _, out_v1, _, qs = built
    with _router(out_v1, 2, replication=2) as router:
        for h in router.hosts:
            h.kill()
        # a direct submit to a dead host raises HostDown ...
        from repro.engine.router import HostRequest
        req = HostRequest(generation=0, mode="dot",
                          q_or_lut=np.zeros((1, 16), np.float32),
                          sel_ids=np.zeros((1, 1), np.int64),
                          mine=np.zeros((1, 1), bool),
                          uniq=np.zeros((0,), np.int64))
        with pytest.raises(HostDown):
            router.hosts[0].submit(req).result()
        # ... but the ROUTER still completes the batch, fully degraded
        ids, _ = router.retrieve(qs.q_dense[:4], qs.q_terms[:4],
                                 qs.q_weights[:4])
        st = router.stats()
    # every shard missing: the batch completes fully degraded (sparse side
    # only — dense side empty), nothing raises
    assert st["degraded"] and st["missing_shards"] == [0, 1, 2]
    assert st["failed_requests"] == 0 and st["degraded_requests"] == 1
    assert np.asarray(ids).shape == (4, _tiny_cfg().k_final)


# ---------------------------------------------------------------------------
# rolling generation hops
# ---------------------------------------------------------------------------

def test_rolling_reload_under_concurrent_queries(built, tmp_path):
    """Commit a delta and roll the 3-host fleet to the new generation
    while a second thread keeps serving: zero failed requests, every
    batch served from exactly one generation, post-hop results bitwise
    equal to a fresh single-host engine on the updated index."""
    _, _, _, out_v2, qs = built
    out = str(tmp_path / "live")
    shutil.copytree(out_v2, out)
    with _router(out, 3, replication=2) as router:
        router.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])
        assert router.stats()["generation"] == 0

        errors = []
        stop = threading.Event()

        def serve_loop():
            while not stop.is_set():
                try:
                    router.retrieve(qs.q_dense[:4], qs.q_terms[:4],
                                    qs.q_weights[:4])
                except Exception as e:          # pragma: no cover
                    errors.append(e)
                    return

        t = threading.Thread(target=serve_loop)
        t.start()
        try:
            delta, _ = synth_delta(router.reader, 12, 8, seed=3)
            index_lib.write_index_delta(out, delta)
            gen = router.reload_index()
            time.sleep(0.05)                   # a few post-hop batches
        finally:
            stop.set()
            t.join()
        assert not errors
        assert gen == 1
        ids, ss = router.retrieve(qs.q_dense, qs.q_terms, qs.q_weights)
        st = router.stats()
        metas = list(router.last_batches)
    assert st["failed_requests"] == 0 and st["degraded_requests"] == 0
    assert st["reloads"] == 1 and st["generation"] == 1
    # every batch came from exactly one generation, and only gens {0, 1}
    # ever served (the router asserts single-generation per batch)
    assert {m["generation"] for m in metas} <= {0, 1}
    assert metas[-1]["generation"] == 1
    # hosts retired the old generation through their serve queues
    for h in router.hosts:
        assert h.generations() == [1]
    ref_ids, ref_ss = _engine_ids(out, qs)
    np.testing.assert_array_equal(np.asarray(ids), ref_ids)
    np.testing.assert_array_equal(np.asarray(ss), ref_ss)


def test_selector_reload_noop_without_new_generation(built):
    _, _, out_v1, _, qs = built
    with _router(out_v1, 2) as router:
        router.retrieve(qs.q_dense[:4], qs.q_terms[:4], qs.q_weights[:4])
        assert router.reload_selector() == 0
        assert router.reload_index() == 0      # no new commit: no-op
        assert router.stats()["reloads"] == 0


# ---------------------------------------------------------------------------
# observability + subset stores
# ---------------------------------------------------------------------------

def test_router_traces_carry_scatter_gather_merge_spans(built):
    _, _, _, out_v2, qs = built
    with _router(out_v2, 3, replication=2, trace_sample_rate=1.0) as router:
        router.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])
        totals = router.tracer.span_totals("batch")
    for span in ("stage1", "lut_build", "stage2_select", "scatter",
                 "gather", "merge", "fuse"):
        assert span in totals, f"missing router span {span!r}"


def test_host_spans_graft_under_scatter(built, tmp_path):
    """Cross-host trace propagation: host-side spans (compact/score/
    partial_topk, block fetch) land nested under the router's scatter
    span, annotated host=i, and both export formats pass the extended
    check_trace rules (per-host Chrome lanes included)."""
    from benchmarks import check_trace
    from repro.obs import write_trace
    _, _, _, out_v2, qs = built
    with _router(out_v2, 3, replication=2, trace_sample_rate=1.0) as router:
        router.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])
        totals = router.tracer.span_totals("batch")
        for span in ("host_serve", "score", "partial_topk"):
            assert span in totals, f"host-side span {span!r} never grafted"
        traces = [t for t in router.tracer.traces if t.name == "batch"]
        hosts_seen = set()
        for tr in traces:
            by_index = {i: sp for i, sp in enumerate(tr.spans)}
            for sp in tr.spans:
                if sp.name == "host_serve":
                    parent = by_index[sp.parent]
                    assert parent.name == "scatter"
                    assert isinstance(sp.annot.get("host"), int)
                    hosts_seen.add(sp.annot["host"])
                    # grafted span sits inside the scatter window
                    assert sp.t0_ms + 0.1 >= parent.t0_ms
                    assert sp.t0_ms + sp.dur_ms <= \
                        parent.t0_ms + parent.dur_ms + 0.1
                if sp.name in ("score", "partial_topk", "compact",
                               "block_fetch"):
                    assert by_index[sp.parent].name == "host_serve"
                    assert sp.annot.get("host") == \
                        by_index[sp.parent].annot.get("host")
        assert len(hosts_seen) == 3         # every host contributed spans
        jp, cp = str(tmp_path / "r.jsonl"), str(tmp_path / "r.json")
        write_trace(router.tracer, jp)
        write_trace(router.tracer, cp)
    bad, _, names = check_trace.check_jsonl(jp)
    assert bad == [] and "host_serve" in names
    bad_c, n_lanes, _ = check_trace.check_chrome(cp)
    assert bad_c == []
    # host-annotated spans ride their own per-host Chrome lanes
    doc = json.load(open(cp))
    host_tids = {ev["tid"] for ev in doc["traceEvents"]
                 if (ev.get("args") or {}).get("host") is not None}
    assert len(host_tids) >= 3
    assert all(isinstance(t, str) and ".host" in t for t in host_tids)


def test_router_metrics_export_includes_per_host(built):
    """Satellite: per-host cache/IO counters from stats()["per_host"] are
    mirrored into the registry as namespaced gauges, so a /metrics scrape
    (or --metrics-out) captures the whole fleet, not just the router."""
    _, _, _, out_v2, qs = built
    with _router(out_v2, 3, replication=1) as router:
        router.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])
        router.hosts[2].kill()
        st = router.stats()                 # stats() syncs the gauges
        snap = router.metrics.snapshot()
        prom = router.metrics.to_prometheus()
    g = snap["gauges"]
    assert g["router.generation"] == 0
    assert g["router.hosts_alive"] == 2
    assert g["router.missing_shards"] == len(st["missing_shards"]) > 0
    for i, h in enumerate(st["per_host"]):
        assert g[f"host{i}.alive"] == int(h["alive"])
        assert g[f"host{i}.served"] == h["served"]
        for k, v in (h.get("cache") or {}).items():
            if isinstance(v, (int, float)):
                assert g[f"host{i}.cache.{k}"] == v
        for k, v in (h.get("io") or {}).items():
            if isinstance(v, (int, float)):
                assert g[f"host{i}.io.{k}"] == v
    assert "host0_served" in prom           # dots -> underscores


def test_router_healthz_flips_on_replica_loss_and_recovers(built):
    """Live endpoint semantics under fault injection: /healthz serves 200
    on a healthy fleet, 503 (shards_without_replicas) once a shard loses
    every replica, and recovers to 200 after revive()."""
    import urllib.error
    import urllib.request
    from repro.obs import MetricsExporter

    def get(port, path):
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}{path}", timeout=5) as r:
                return r.status, r.read().decode()
        except urllib.error.HTTPError as e:
            return e.code, e.read().decode()

    _, _, _, out_v2, qs = built
    with _router(out_v2, 3, replication=1) as router:
        router.retrieve(qs.q_dense[:8], qs.q_terms[:8], qs.q_weights[:8])
        with MetricsExporter(router, port=0) as exp:
            code, body = get(exp.port, "/healthz")
            assert code == 200 and json.loads(body)["ok"] is True
            code, text = get(exp.port, "/metrics")
            assert code == 200 and "router_hosts_alive 3" in text

            router.hosts[1].kill()          # R=1: shard 1 loses its only
            code, body = get(exp.port, "/healthz")
            reasons = json.loads(body)["reasons"]
            assert code == 503
            assert any("shards_without_replicas" in r for r in reasons)
            # serving continues degraded while health reports it
            router.retrieve(qs.q_dense[:4], qs.q_terms[:4],
                            qs.q_weights[:4])
            code, text = get(exp.port, "/metrics")
            assert code == 200 and "router_hosts_alive 2" in text

            router.hosts[1].revive()
            code, body = get(exp.port, "/healthz")
            assert code == 200 and json.loads(body)["ok"] is True


def test_router_explain_records_host_contrib(built):
    """Router-side explain telemetry: every sampled batch yields per-query
    records carrying the per-host score attribution (host_contrib) and
    the degraded flag, on top of the shared engine record fields."""
    from repro.obs import ExplainLogger
    cfg, _, _, out_v2, qs = built
    ex = ExplainLogger(sample_rate=1.0)
    with _router(out_v2, 3, replication=1, explain=ex) as router:
        ids, _ = router.retrieve(qs.q_dense[:8], qs.q_terms[:8],
                                 qs.q_weights[:8])
        router.hosts[1].kill()
        router.retrieve(qs.q_dense[8:12], qs.q_terms[8:12],
                        qs.q_weights[8:12])
    recs = ex.recent()
    assert len(recs) == 12
    assert [r["qid"] for r in recs] == list(range(12))
    healthy, degraded = recs[:8], recs[8:]
    assert all(r["degraded"] is False for r in healthy)
    assert all(r["degraded"] is True for r in degraded)
    k = np.asarray(ids).shape[1]
    for r in healthy:
        assert set(r) >= {"cand", "probs", "selected", "provenance",
                          "theta", "budget", "fusion_contrib",
                          "host_contrib"}
        assert len(r["probs"]) == len(r["cand"]) == len(r["provenance"])
        assert set(r["provenance"]) <= {"seed", "expand"}
        # host attribution covers at most the final top-k, never negative
        total = sum(r["host_contrib"].values())
        assert 0 <= total <= k
    # the killed host contributes to no degraded record
    assert all(r["host_contrib"].get("1", 0) == 0 for r in degraded)


def test_subset_store_owns_only_its_shards(built):
    _, _, out_v1, _, _ = built
    reader = index_lib.IndexReader.open(out_v1)
    full = reader.open_store()
    sub = reader.open_store(shards=[1])
    assert sub.is_subset and not full.is_subset
    (lo, hi), = sub.owned_ranges
    vecs_s, docs_s, valid_s = sub.fetch_blocks(np.arange(lo, hi))
    vecs_f, docs_f, valid_f = full.fetch_blocks(np.arange(lo, hi))
    np.testing.assert_array_equal(np.asarray(vecs_s), np.asarray(vecs_f))
    np.testing.assert_array_equal(docs_s, docs_f)
    with pytest.raises(KeyError):
        sub.fetch_blocks(np.asarray([0 if lo > 0 else hi]))
    with pytest.raises(ValueError):
        reader.open_store(shards=[99])
