import os
import sys

# smoke tests and benches must see exactly ONE device; only the dry-run
# (launch/dryrun.py) sets the 512-device flag, and only in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
