from repro.runtime.fault import restartable_train, FailureInjector, StragglerMonitor
