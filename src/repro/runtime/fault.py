"""Fault tolerance for long-running training:

  - `restartable_train`: checkpoint/restart driver. Periodic async sharded
    checkpoints; on (simulated or real) failure the driver restores the
    latest complete checkpoint — onto a *different* mesh if the world size
    changed (elastic scaling via checkpoint.restore with new shardings).
  - `FailureInjector`: deterministic failure schedule for tests/examples
    (real deployments replace this with preemption signals / heartbeats).
  - `StragglerMonitor`: flags steps slower than k x rolling median; the
    driver's mitigation is to cut the step's microbatch (skip-and-log) —
    on real fleets this is where you'd trigger hot-spare swap.
"""

import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.tripped = set()

    def check(self, step):
        if step in self.fail_at and step not in self.tripped:
            self.tripped.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class StragglerMonitor:
    def __init__(self, factor=3.0, window=20):
        self.times = []
        self.factor = factor
        self.window = window
        self.flagged = []

    def observe(self, step, dt):
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        if len(hist) >= 5 and dt > self.factor * med:
            self.flagged.append((step, dt, med))
            return True
        return False


def restartable_train(*, init_state, step_fn, batches_fn, total_steps,
                      ckpt_dir, ckpt_every=50, failure_injector=None,
                      shardings=None, logger=None, max_restarts=10):
    """Run `step_fn(state, batch) -> (state, metrics)` to total_steps with
    checkpoint/restart. `batches_fn(start_step)` must return an iterator
    positioned at `start_step` (deterministic data order across restarts).

    Returns (state, history, restart_count).
    """
    mgr = CheckpointManager(ckpt_dir, keep=2)
    monitor = StragglerMonitor()
    history = []
    restarts = 0

    while True:
        # restore-or-init
        step0, restored, extra = mgr.restore_latest(init_state, shardings)
        state = restored if restored is not None else init_state
        start = (step0 + 1) if step0 is not None else 0
        try:
            it = batches_fn(start)
            for step in range(start, total_steps):
                if failure_injector is not None:
                    failure_injector.check(step)
                batch = next(it)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(state)[0])
                dt = time.perf_counter() - t0
                straggler = monitor.observe(step, dt)
                rec = {"step": step, "time_s": dt,
                       "straggler": straggler, **{
                           k: float(v) for k, v in metrics.items()}}
                history.append(rec)
                if logger:
                    logger.log(**rec)
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    mgr.save(step, state, extra={"step": step})
            mgr.wait()
            return state, history, restarts
        except SimulatedFailure as e:
            restarts += 1
            if logger:
                logger.log(event="restart", error=str(e), restarts=restarts)
            if restarts > max_restarts:
                raise
            mgr.wait()
            continue
