"""CluSD retrieval serving driver: builds the index over a synthetic corpus,
trains the Stage-II LSTM, then serves batched queries end-to-end (sparse ->
Stage I/II -> partial dense -> fusion), reporting latency percentiles and
quality vs the full-retrieval oracle.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --docs 20000 --queries 256 \
      [--ondisk] [--distributed]
"""

import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import baselines as bl
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.core import train_lstm as tl
from repro.data import mrr_at, recall_at, synth_corpus, synth_queries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--ondisk", action="store_true")
    args = ap.parse_args()

    import dataclasses
    cfg = dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=args.docs, dim=args.dim, n_clusters=args.clusters,
        vocab=2048, k_sparse=512, bins=(10, 25, 50, 100, 200, 512),
        n_candidates=32, max_selected=16, k_final=256,
        train_queries=512, epochs=args.epochs)

    print("building corpus + index ...", flush=True)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    train_q = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, train_q.q_dense,
                                      train_q.q_terms, train_q.q_weights)
    index.lstm_params, hist = tl.train_selector(
        cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels))
    print(f"LSTM trained: loss {hist[0]:.4f} -> {hist[-1]:.4f}", flush=True)

    test_q = synth_queries(9, corpus, args.queries)
    fn = jax.jit(lambda qd, qt, qw: cl.retrieve(cfg, index, qd, qt, qw)[:2])
    lat = []
    all_ids = []
    for i in range(0, args.queries, args.batch):
        qd = test_q.q_dense[i:i + args.batch]
        qt = test_q.q_terms[i:i + args.batch]
        qw = test_q.q_weights[i:i + args.batch]
        t0 = time.perf_counter()
        ids, scores = fn(qd, qt, qw)
        ids.block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3 / qd.shape[0])
        all_ids.append(np.asarray(ids))
    ids = np.concatenate(all_ids)
    lat = np.asarray(lat[1:])  # drop compile

    oracle_ids, _ = cl.full_dense_topk(index.embeddings, test_q.q_dense, 64)
    print(f"CluSD   MRR@10={mrr_at(ids, test_q.rel_doc):.4f} "
          f"R@{cfg.k_final}={recall_at(ids, test_q.rel_doc, cfg.k_final):.4f}")
    print(f"oracle-dense MRR@10={mrr_at(np.asarray(oracle_ids), test_q.rel_doc):.4f}")
    print(f"serve latency/query: mean={lat.mean():.2f}ms p99={np.percentile(lat, 99):.2f}ms")

    if args.ondisk:
        tmp = tempfile.mkdtemp()
        store = dk.DiskClusterStore(os.path.join(tmp, "blocks.bin"),
                                    corpus.embeddings, index.cluster_docs)
        ids_d, _, stats = dk.ondisk_clusd_retrieve(
            cfg, index, store, test_q.q_dense[:16], test_q.q_terms[:16],
            test_q.q_weights[:16])
        print(f"on-disk: {stats.n_ops} block reads, "
              f"{stats.bytes/2**20:.1f} MiB, model {stats.model_ms():.1f} ms, "
              f"MRR@10={mrr_at(np.asarray(ids_d), test_q.rel_doc[:16]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
