"""CluSD serving driver on the unified RetrievalEngine (repro.engine).

Builds the index over a synthetic corpus, trains the Stage-II LSTM, then
serves batched queries through `RetrievalEngine` — one select/score/fuse
pipeline (engine/pipeline.py) behind a pluggable ClusterStore backend:

  * default: in-memory backend; request batches are padded to power-of-two
    buckets so jit compiles once per bucket, not once per ragged tail.
  * --ondisk: DiskStore backend with a bounded LRU block cache and a
    background thread prefetching Stage-I candidate blocks while Stage-II
    LSTM selection runs; reports I/O ops/bytes and cache hit rate.

Reports latency percentiles and quality vs the full-retrieval oracle.

With --index-dir, the build step is skipped entirely: the engine serves a
persistent index built by `python -m repro.launch.build_index` — the
manifest is validated (at the --verify level: none/size/full), arrays are
mmapped, and cluster blocks are read from the per-shard files through a
`ShardedDiskStore` (v1 float blocks) or `ShardedPQStore` (v2 PQ code
shards built with `--format-version 2 [--memmap --chunk-docs N]`; codes
decode through the index codebooks at fetch time — exact-ADC numerics).
Indexes mutated by `repro.launch.update_index` serve their newest
generation; deleted docs are tombstone-masked at fetch.

--check-parity replays the queries through the in-memory pipeline and
exits non-zero on mismatch: exact top-k ids for v1 indexes; for v2 (PQ)
indexes — approximate by construction — parity is an MRR@10 delta bound,
tunable with --parity-mrr-tol (default 0.02).

--trace-out exports per-batch stage-span traces (stage1 -> stage2_select
-> cache/disk fetch -> fused_score_topk; `.jsonl` span lines or Chrome
trace JSON for Perfetto), sampled at --trace-sample-rate; --metrics-out
dumps the engine metrics registry (JSON or Prometheus text by suffix).
Catalog: docs/OBSERVABILITY.md.

Live observability (with --index-dir): --metrics-port P starts an HTTP
exporter over the serving engine/router BEFORE the first batch — GET
/metrics (Prometheus text), /metrics.json, /slo, /healthz (503 while the
SLO state is PAGE or any shard has lost every replica); P=0 binds an
ephemeral port (printed). --slo-config PATH loads declarative SLO
objectives (JSON {"objectives": [...]}; see docs/OBSERVABILITY.md) into
an SLOMonitor judging the run — without it --metrics-port uses the
default objective set. --explain-out PATH.jsonl emits sampled per-query
explain records (candidate provenance, selector probs vs theta/budget,
fusion contributions, per-host attribution on the router path) at
--explain-sample-rate; analyze with `python -m benchmarks.explain_report`.
--serve-seconds S keeps replaying the query set until the deadline so
the endpoints stay live under sustained traffic (the CI metrics-endpoint
smoke curls them mid-stream).

--hosts N (with --index-dir) serves through the multi-host scatter-gather
tier (engine/router.py) instead of a single engine: a ShardRouter runs
sparse retrieval + Stage I/II replicated and scatters the selected
clusters to N simulated hosts, each owning a balanced subset of the index
block shards behind its own store + cache; per-host partial top-k lists
merge under the exact (score desc, doc id asc) rule and fuse with the
sparse side — bitwise-identical results to the single-host engine under
interp fusion. --replication R places each shard on R hosts (replica
failover); --host-timeout-ms bounds each scatter leg; --kill-host I kills
host I after the first batch (fault injection: with R >= 2 serving must
continue with zero failed requests — the CI router-smoke job asserts
this plus parity vs the single-host engine). --check-parity on this path
replays the queries through a single-host engine and exits non-zero on
any id mismatch. Router traces add scatter/gather/merge spans.

--fusion overrides the final-list fusion method (interp = paper min-max
interpolation, rrf = weighted reciprocal-rank fusion); --expand-depth N
deepens Stage-I candidates through the cluster neighbor graph (LADR-style
hybrid candidate generation, N extra n_candidates blocks of clusters
considered per query at the same selection budget). Both default to the
served config (a calibrated publish may have set them); depth 0 + interp
is bitwise the classic pipeline.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --docs 20000 --queries 256 \
      [--ondisk] [--cache-blocks 512] [--no-prefetch] \
      [--fusion interp|rrf] [--expand-depth N]
  PYTHONPATH=src python -m repro.launch.serve --index-dir /tmp/idx \
      --queries 64 [--verify full] [--check-parity [--parity-mrr-tol T]] \
      [--trace-out trace.jsonl] [--metrics-out metrics.json]
  PYTHONPATH=src python -m repro.launch.serve --index-dir /tmp/idx \
      --hosts 3 --replication 2 [--host-timeout-ms 10000] [--kill-host 0] \
      --check-parity [--trace-out trace.jsonl]
"""

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import clusd as cl
from repro.core import disk as dk
from repro.core import train_lstm as tl
from repro.data import mrr_at, recall_at, synth_corpus, synth_queries
from repro.engine import DiskStore, RetrievalEngine


def _apply_hybrid_flags(cfg, args):
    """Overlay --fusion / --expand-depth on the served config (None =
    keep what the config/manifest says, e.g. a calibrated publish)."""
    changes = {}
    if args.fusion is not None:
        changes["fusion"] = args.fusion
    if args.expand_depth is not None:
        changes["expand_depth"] = args.expand_depth
    return dataclasses.replace(cfg, **changes) if changes else cfg


def _write_obs(args, engine):
    """Export --metrics-out / --trace-out from a served engine."""
    from repro.obs import write_metrics, write_trace
    if args.metrics_out:
        engine.stats()          # folds cache/io/decode counters into gauges
        write_metrics(engine.metrics, args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if args.trace_out:
        write_trace(engine.tracer, args.trace_out)
        print(f"trace -> {args.trace_out} "
              f"({engine.tracer.started} trace(s) at "
              f"sample rate {engine.tracer.sample_rate})")


def _make_explain(args):
    """--explain-out: a sampled per-query ExplainLogger for the engine/
    router ctor (None when the flag is absent — zero serving cost)."""
    if not getattr(args, "explain_out", None):
        return None
    from repro.obs import ExplainLogger
    return ExplainLogger(args.explain_out,
                         sample_rate=args.explain_sample_rate)


def _start_exporter(args, target):
    """--metrics-port / --slo-config: attach an SLOMonitor and start the
    live HTTP endpoint over the serving target. Returns (exporter, slo),
    either of which may be None."""
    from repro.obs import MetricsExporter, SLOMonitor, default_objectives
    slo = None
    if getattr(args, "slo_config", None):
        slo = SLOMonitor.from_config(target.metrics, args.slo_config)
    elif args.metrics_port is not None:
        slo = SLOMonitor(target.metrics, default_objectives())
    exp = None
    if args.metrics_port is not None:
        exp = MetricsExporter(target, port=args.metrics_port,
                              slo=slo).start()
        print(f"metrics endpoint: http://127.0.0.1:{exp.port}/metrics "
              f"(also /metrics.json /slo /healthz)", flush=True)
    return exp, slo


def _finish_obs(args, exporter, slo, explain):
    """Tear down the live observability attachments, reporting state."""
    if slo is not None:
        slo.evaluate()
        print(f"SLO state: {slo.state} "
              f"(pages={slo.verdict()['pages']}, "
              f"warns={slo.verdict()['warns']})")
    if exporter is not None:
        exporter.stop()
    if explain is not None:
        explain.close()
        st = explain.stats()
        print(f"explain -> {st['path']} ({st['n_records']} record(s), "
              f"{st['n_sampled']}/{st['n_sampled'] + st['n_skipped']} "
              f"batches sampled)")


def _sustain(args, serve_pass, slo=None):
    """--serve-seconds: keep replaying the query set until the deadline
    (keeps the metrics endpoints live under sustained traffic)."""
    if not args.serve_seconds:
        return
    deadline = time.monotonic() + args.serve_seconds
    passes = 0
    while time.monotonic() < deadline:
        serve_pass(deadline)
        passes += 1
        if slo is not None:
            slo.evaluate()
    print(f"sustained serving: {passes} extra pass(es) over "
          f"{args.serve_seconds:.0f}s window")


def serve_from_router(args, reader, cfg, index, test_q):
    """Serve through the multi-host scatter-gather tier (--hosts N)."""
    from repro import index as index_lib
    from repro.engine import ShardRouter

    trace_rate = args.trace_sample_rate if args.trace_out else None
    with ShardRouter.local(
            reader, n_hosts=args.hosts, replication=args.replication,
            cfg=cfg, index=index, max_batch=args.batch,
            cache_capacity=args.cache_blocks,
            host_timeout=args.host_timeout_ms / 1e3,
            trace_sample_rate=trace_rate,
            explain=_make_explain(args)) as router:
        # endpoints come up before the first (compiling) batch, so a
        # scraper polling /metrics gets 200 while serving warms up
        exporter, slo = _start_exporter(args, router)
        all_ids = []
        for bi, i in enumerate(range(0, args.queries, args.batch)):
            ids, _ = router.retrieve(test_q.q_dense[i:i + args.batch],
                                     test_q.q_terms[i:i + args.batch],
                                     test_q.q_weights[i:i + args.batch])
            all_ids.append(np.asarray(ids))
            if args.kill_host is not None and bi == 0:
                router.hosts[args.kill_host].kill()
                print(f"injected failure: host {args.kill_host} killed "
                      f"after batch 0 (replication {args.replication})",
                      flush=True)
        ids = np.concatenate(all_ids)

        def _replay(deadline):
            for i in range(0, args.queries, args.batch):
                router.retrieve(test_q.q_dense[i:i + args.batch],
                                test_q.q_terms[i:i + args.batch],
                                test_q.q_weights[i:i + args.batch])
                if time.monotonic() >= deadline:
                    return
        _sustain(args, _replay, slo)
        st = router.stats()
        print(f"router: {st['hosts']} hosts x replication "
              f"{st['replication']} over {st['n_shards']} shards, "
              f"generation {st['generation']}")
        print(f"served {args.queries} queries: "
              f"MRR@10={mrr_at(ids, test_q.rel_doc):.4f}, "
              f"failed={st['failed_requests']} "
              f"degraded={st['degraded_requests']} "
              f"failovers={st['failovers']} retries={st['retries']} "
              f"missing_shards={st['missing_shards']}")
        _write_obs(args, router)
        _finish_obs(args, exporter, slo, router.explain)

        ok = True
        if args.check_parity:
            # reference: a fresh single-host engine over the same index —
            # results must match exactly (same pipeline, v1 and v2 alike)
            ref_reader = index_lib.IndexReader.open(args.index_dir,
                                                    verify="none")
            refs = []
            with ref_reader.engine(max_batch=args.batch,
                                   prefetch=False) as eng:
                for i in range(0, args.queries, args.batch):
                    r, _ = eng.retrieve(test_q.q_dense[i:i + args.batch],
                                        test_q.q_terms[i:i + args.batch],
                                        test_q.q_weights[i:i + args.batch])
                    refs.append(np.asarray(r))
            ref_ids = np.concatenate(refs)
            if not np.array_equal(ids, ref_ids):
                bad = int((ids != ref_ids).any(axis=1).sum())
                print(f"PARITY FAIL: {bad}/{args.queries} queries differ "
                      f"from the single-host engine")
                ok = False
            else:
                print(f"parity OK: {args.hosts}-host scatter-gather matches "
                      f"the single-host engine exactly")
        if st["failed_requests"]:
            print(f"FAIL: {st['failed_requests']} failed request(s)")
            ok = False
    return 0 if ok else 1


def serve_from_index(args):
    """Serve a persistent index built by repro.launch.build_index."""
    from repro import index as index_lib
    from repro.engine import InMemoryStore, pipeline as pipe_lib

    t0 = time.perf_counter()
    reader = index_lib.IndexReader.open(args.index_dir, verify=args.verify)
    cfg, index = reader.load_index()
    cfg = _apply_hybrid_flags(cfg, args)
    open_ms = (time.perf_counter() - t0) * 1e3
    meta = reader.manifest.get("extra", {}).get("corpus")
    if meta is None or meta.get("kind") != "synthetic":
        raise SystemExit("index lacks synthetic-corpus metadata; cannot "
                         "regenerate queries for quality evaluation")
    corpus = synth_corpus(meta["seed"], meta["n_docs"], meta["dim"],
                          meta["vocab"])
    test_q = synth_queries(9, corpus, args.queries)

    if args.hosts:
        return serve_from_router(args, reader, cfg, index, test_q)

    trace_rate = args.trace_sample_rate if args.trace_out else None
    with reader.engine(cfg=cfg, index=index, max_batch=args.batch,
                       cache_capacity=args.cache_blocks,
                       prefetch=not args.no_prefetch,
                       trace_sample_rate=trace_rate,
                       explain=_make_explain(args)) as engine:
        exporter, slo = _start_exporter(args, engine)
        t1 = time.perf_counter()
        first_ids, _ = engine.retrieve(
            test_q.q_dense[:args.batch], test_q.q_terms[:args.batch],
            test_q.q_weights[:args.batch])
        first_ms = (time.perf_counter() - t1) * 1e3
        all_ids = [np.asarray(first_ids)]
        for i in range(args.batch, args.queries, args.batch):
            ids, _ = engine.retrieve(test_q.q_dense[i:i + args.batch],
                                     test_q.q_terms[i:i + args.batch],
                                     test_q.q_weights[i:i + args.batch])
            all_ids.append(np.asarray(ids))

        def _replay(deadline):
            for i in range(0, args.queries, args.batch):
                engine.retrieve(test_q.q_dense[i:i + args.batch],
                                test_q.q_terms[i:i + args.batch],
                                test_q.q_weights[i:i + args.batch])
                if time.monotonic() >= deadline:
                    return
        _sustain(args, _replay, slo)
        _finish_obs(args, exporter, slo, engine.explain)
    ids = np.concatenate(all_ids)
    st = engine.stats()
    io, cache = st.get("io", {}), st.get("cache", {})
    print(f"index: {reader.index_dir} "
          f"(format v{reader.format_version}, "
          f"{reader.manifest['total_bytes'] / 2**20:.1f} MiB, "
          f"{len(reader.manifest['block_shards'])} shard(s), verify={args.verify})")
    print(f"cold open {open_ms:.0f} ms, first batch {first_ms:.0f} ms "
          f"(incl. compile)")
    print(f"served {args.queries} queries: "
          f"MRR@10={mrr_at(ids, test_q.rel_doc):.4f}, "
          f"{io.get('n_ops', 0)} I/O ops, "
          f"{io.get('bytes', 0) / 2**20:.1f} MiB read, "
          f"cache hit rate {cache.get('hit_rate', 0.0):.2f}")
    _write_obs(args, engine)

    if args.check_parity:
        if reader.generation > 0:
            print("PARITY UNAVAILABLE: this index has been incrementally "
                  f"updated (generation {reader.generation}); the "
                  "synthetic-corpus recipe no longer reproduces its "
                  "documents, so the in-memory baseline would be stale. "
                  "Use repro.launch.update_index --check-parity (compares "
                  "against a compacted copy) instead.")
            return 1
        mem = InMemoryStore(corpus.embeddings, index.cluster_docs)
        ref_ids, _, _ = pipe_lib.retrieve(
            cfg, index, mem, test_q.q_dense[:args.queries],
            test_q.q_terms[:args.queries], test_q.q_weights[:args.queries])
        if reader.is_pq:
            # PQ serving is approximate by construction: parity is a
            # bounded MRR@10 delta vs the float32 in-memory backend
            ref_mrr = mrr_at(np.asarray(ref_ids),
                             test_q.rel_doc[:args.queries])
            got_mrr = mrr_at(ids, test_q.rel_doc[:args.queries])
            if abs(ref_mrr - got_mrr) > args.parity_mrr_tol:
                print(f"PARITY FAIL: PQ MRR@10 {got_mrr:.4f} vs in-memory "
                      f"{ref_mrr:.4f} (tol {args.parity_mrr_tol})")
                return 1
            print(f"parity OK: PQ MRR@10 {got_mrr:.4f} within "
                  f"{args.parity_mrr_tol} of in-memory {ref_mrr:.4f}")
        elif not np.array_equal(ids, np.asarray(ref_ids)):
            bad = int((ids != np.asarray(ref_ids)).any(axis=1).sum())
            print(f"PARITY FAIL: {bad}/{args.queries} queries differ from "
                  f"the in-memory pipeline")
            return 1
        else:
            print("parity OK: sharded on-disk serving matches the "
                  "in-memory pipeline exactly")
    return 0


def main():
    # __doc__ IS the epilog: the module docstring and --help can never
    # drift apart (CI smoke-tests --help for every repro.launch CLI)
    ap = argparse.ArgumentParser(
        description="Serve CluSD retrieval through the unified "
                    "RetrievalEngine (in-memory, on-disk, or a persistent "
                    "built index).",
        epilog=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--ondisk", action="store_true")
    ap.add_argument("--fusion", default=None, choices=("interp", "rrf"),
                    help="final-list fusion method override (default: the "
                         "served config's; interp = paper min-max "
                         "interpolation, rrf = weighted reciprocal-rank)")
    ap.add_argument("--expand-depth", type=int, default=None,
                    help="Stage-I neighbor-graph expansion depth override "
                         "(0 = off; widens candidates to n_candidates * "
                         "(1 + depth) at the same selection budget)")
    ap.add_argument("--cache-blocks", type=int, default=512)
    ap.add_argument("--no-prefetch", action="store_true")
    ap.add_argument("--hosts", type=int, default=0,
                    help="with --index-dir: serve through the multi-host "
                         "scatter-gather router over N simulated hosts "
                         "(0 = single-host engine)")
    ap.add_argument("--replication", type=int, default=1,
                    help="replicas per index shard across the host fleet "
                         "(R >= 2 survives any R-1 host failures)")
    ap.add_argument("--host-timeout-ms", type=float, default=10000.0,
                    help="per-host scatter-leg timeout before the router "
                         "retries / fails over to a replica")
    ap.add_argument("--kill-host", type=int, default=None, metavar="I",
                    help="fault injection: kill host I after the first "
                         "batch (with --replication >= 2 serving must "
                         "continue with zero failed requests)")
    ap.add_argument("--index-dir", default=None,
                    help="serve a built index (repro.launch.build_index) "
                         "instead of rebuilding in memory")
    ap.add_argument("--verify", default="size",
                    choices=("none", "size", "full"),
                    help="built-index integrity check level at open")
    ap.add_argument("--check-parity", action="store_true",
                    help="with --index-dir: compare against the in-memory "
                         "pipeline, exit non-zero on mismatch (exact ids "
                         "for v1; MRR@10 tolerance for PQ/v2 indexes)")
    ap.add_argument("--parity-mrr-tol", type=float, default=0.02,
                    help="allowed MRR@10 delta for PQ-index parity")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export per-batch stage-span traces after serving "
                         "(.jsonl = one span per line, anything else = "
                         "Chrome trace JSON; see docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-sample-rate", type=float, default=1.0,
                    help="fraction of batches traced when --trace-out is "
                         "set (deterministic: 0.25 = every 4th batch)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the engine metrics registry after serving "
                         "(.prom/.txt = Prometheus text, else JSON)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="P",
                    help="with --index-dir: serve live /metrics, "
                         "/metrics.json, /slo, and /healthz over HTTP on "
                         "port P while serving runs (0 = ephemeral port, "
                         "printed at startup)")
    ap.add_argument("--slo-config", default=None, metavar="PATH",
                    help="JSON SLO objectives ({\"objectives\": [...]}; "
                         "schema in docs/OBSERVABILITY.md) judging the run "
                         "via an SLOMonitor; default objectives are used "
                         "when --metrics-port is set without this")
    ap.add_argument("--explain-out", default=None, metavar="PATH",
                    help="with --index-dir: write sampled per-query "
                         "explain records (JSONL; schema in "
                         "docs/OBSERVABILITY.md) for "
                         "benchmarks.explain_report")
    ap.add_argument("--explain-sample-rate", type=float, default=1.0,
                    help="fraction of batches explained when --explain-out "
                         "is set (deterministic accumulator sampling)")
    ap.add_argument("--serve-seconds", type=float, default=0.0, metavar="S",
                    help="after the scored pass, keep replaying the query "
                         "set for S more seconds so the live endpoints "
                         "can be scraped under sustained traffic")
    args = ap.parse_args()

    if args.index_dir:
        return serve_from_index(args)

    cfg = dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=args.docs, dim=args.dim, n_clusters=args.clusters,
        vocab=2048, k_sparse=512, bins=(10, 25, 50, 100, 200, 512),
        n_candidates=32, max_selected=16, k_final=256,
        train_queries=512, epochs=args.epochs)
    cfg = _apply_hybrid_flags(cfg, args)

    print("building corpus + index ...", flush=True)
    corpus = synth_corpus(0, cfg.n_docs, cfg.dim, cfg.vocab)
    index = cl.build_index(cfg, jax.random.key(0), corpus.embeddings,
                           corpus.doc_terms, corpus.doc_weights)
    train_q = synth_queries(1, corpus, cfg.train_queries)
    _, feats, labels = tl.make_labels(cfg, index, train_q.q_dense,
                                      train_q.q_terms, train_q.q_weights)
    index.lstm_params, hist = tl.train_selector(
        cfg, jax.random.key(2), np.asarray(feats), np.asarray(labels))
    print(f"LSTM trained: loss {hist[0]:.4f} -> {hist[-1]:.4f}", flush=True)

    test_q = synth_queries(9, corpus, args.queries)
    engine = RetrievalEngine(
        cfg, index, max_batch=args.batch,
        trace_sample_rate=args.trace_sample_rate if args.trace_out else None)
    all_ids = []
    for i in range(0, args.queries, args.batch):
        ids, _ = engine.retrieve(test_q.q_dense[i:i + args.batch],
                                 test_q.q_terms[i:i + args.batch],
                                 test_q.q_weights[i:i + args.batch])
        all_ids.append(np.asarray(ids))
    ids = np.concatenate(all_ids)
    st = engine.stats()
    lat = np.asarray(engine.serve_stats.per_query_ms())

    oracle_ids, _ = cl.full_dense_topk(index.embeddings, test_q.q_dense, 64)
    print(f"CluSD   MRR@10={mrr_at(ids, test_q.rel_doc):.4f} "
          f"R@{cfg.k_final}={recall_at(ids, test_q.rel_doc, cfg.k_final):.4f}")
    print(f"oracle-dense MRR@10={mrr_at(np.asarray(oracle_ids), test_q.rel_doc):.4f}")
    if len(lat):
        print(f"serve latency/query: mean={lat.mean():.2f}ms "
              f"p99={np.percentile(lat, 99):.2f}ms "
              f"(buckets compiled: {st['compiled_buckets']})")
    _write_obs(args, engine)

    if args.ondisk:
        tmp = tempfile.mkdtemp()
        blocks = dk.DiskClusterStore.pack(os.path.join(tmp, "blocks.bin"),
                                          corpus.embeddings,
                                          index.cluster_docs)
        nq = min(64, args.queries)
        with RetrievalEngine(cfg, index,
                             store=DiskStore(blocks, index.cluster_docs),
                             max_batch=args.batch,
                             cache_capacity=args.cache_blocks,
                             prefetch=not args.no_prefetch) as deng:
            t0 = time.perf_counter()
            ids_d, _ = deng.retrieve(test_q.q_dense[:nq], test_q.q_terms[:nq],
                                     test_q.q_weights[:nq])
            wall = time.perf_counter() - t0
        # stats after close(): the prefetch worker has drained, so I/O and
        # cache numbers are final
        ds = deng.stats()
        io, cache = ds["io"], ds.get("cache", {})
        qps = ds["qps_steady"]
        qps_str = f"{qps:.1f} QPS steady" if qps else \
            f"{nq / wall:.1f} QPS incl. compile"
        print(f"on-disk engine: {io['n_ops']} block reads, "
              f"{io['bytes'] / 2**20:.1f} MiB, model {io['model_ms']:.1f} ms, "
              f"cache hit rate {cache.get('hit_rate', 0.0):.2f}, "
              f"{qps_str}, "
              f"MRR@10={mrr_at(np.asarray(ids_d), test_q.rel_doc[:nq]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
