"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
is pure data parallelism (gradient all-reduce crosses DCN/optical links).
"""

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())}. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE "
            "importing jax (launch/dryrun.py does this).")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (8 fake devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
