"""Incremental index update CLI: apply an upsert/delete delta to a built
index as a new atomic generation, optionally hot-reloading a serving
engine across the commit and parity-checking the result.

  # apply a localized synthetic delta (5% upserts / 2% deletes of a
  # 20k-doc index), serving 8 queries before AND after the commit through
  # one engine that hot-reloads between them, then parity-check against
  # a compacted (from-scratch serialized) copy:
  PYTHONPATH=src python -m repro.launch.update_index --index-dir /tmp/idx \
      --upserts 1000 --deletes 400 --serve-queries 8 --check-parity

  # fold tombstones + generations back into a clean layout:
  PYTHONPATH=src python -m repro.launch.update_index --index-dir /tmp/idx \
      --compact

The synthetic delta is **shard-localized**, the way a production updater
batches churn: upserted docs are placed near centroids of a small prefix
of target shards (replacements pull existing docs toward their own
centroid; appends spawn near centroids with free capacity), and every
candidate is pre-checked against the full centroid table so its nearest
cluster really falls inside the target shards. Deletes are free
(tombstones — zero shard bytes rewritten), so they are sampled anywhere.

Works on both on-disk formats: v1 float-block indexes re-pack only the
touched shards; v2 PQ indexes re-encode touched shards against the
EXISTING codebooks. A delta stamped for the wrong format version is
rejected up front (IndexFormatError).

--check-parity compacts a copy of the updated index (which by the
repro.index.update invariant equals a from-scratch serialization of the
same logical state) and verifies both serve identical top-k ids.
"""

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

from repro import index as index_lib
from repro.index import update as update_lib


def synth_delta(reader, n_upserts, n_deletes, *, seed=0, append_frac=0.3,
                target_shards=None, doc_terms=16, noise=0.15):
    """Build a shard-localized synthetic IndexDelta against a built index.

    Upsert vectors are drawn near centroids of the first `target_shards`
    shards (default: the smallest prefix with enough free capacity), with
    per-cluster placement capped by live headroom and each candidate's
    nearest centroid verified to stay inside the target range — so the
    delta exercises the "localized churn rewrites few shards" path the
    update subsystem is designed for. Returns (delta, info)."""
    rng = np.random.default_rng(seed)
    geom = reader.geometry
    D, dim, cap = geom["n_docs"], geom["dim"], geom["cap"]
    vocab = reader.config().vocab
    centroids = np.asarray(reader.array("centroids"), np.float32)
    masked = reader.masked_cluster_docs()
    fill = (masked >= 0).sum(axis=1)
    free = cap - fill
    ranges = [(s["cluster_lo"], s["cluster_hi"])
              for s in reader.manifest["block_shards"]]

    n_app = int(round(n_upserts * append_frac))
    n_rep = n_upserts - n_app
    if target_shards is None:
        # smallest shard prefix whose free capacity covers the appends (and
        # whose live docs cover the replacements) with 2x headroom
        target_shards = 1
        while target_shards < len(ranges):
            hi = ranges[target_shards - 1][1]
            if (free[:hi].sum() >= 2 * n_app
                    and fill[:hi].sum() >= 2 * n_rep):
                break
            target_shards += 1
    hi_cluster = ranges[target_shards - 1][1]

    def spawn_near(c):
        """Unit vector near centroid c, perturbed by a `noise` fraction of
        the centroid's norm (NOT per-dimension — at dim=48 a per-dim sigma
        would swamp the signal and scatter placements everywhere),
        resampled until its true nearest centroid stays in the target
        shard range. Returns None if it will not stay put."""
        scale = noise * max(float(np.linalg.norm(centroids[c])), 1e-9)
        for _ in range(8):
            g = rng.standard_normal(dim).astype(np.float32)
            v = centroids[c] + scale * g / max(float(np.linalg.norm(g)),
                                               1e-9)
            v /= max(float(np.linalg.norm(v)), 1e-9)
            d2 = ((centroids - v) ** 2).sum(axis=1)
            if int(np.argmin(d2)) < hi_cluster:
                return v
        return None

    # replacements: live docs of target clusters get an "edited" vector
    # near their own centroid (verified to stay inside the target shards)
    live_docs = masked[:hi_cluster]
    live_docs = live_docs[live_docs >= 0]
    if n_rep > len(live_docs):
        raise ValueError(f"not enough live docs in {target_shards} target "
                         f"shard(s) for {n_rep} replacements")
    rep_ids = rng.choice(live_docs, n_rep, replace=False).astype(np.int64)
    doc_cluster = np.asarray(reader.array("doc_cluster"))
    vecs, ids = [], []
    headroom = free.astype(np.int64).copy()
    for d in rep_ids:
        v = spawn_near(int(doc_cluster[d]))
        if v is not None:
            vecs.append(v)
            ids.append(int(d))
    n_rep_made = len(ids)
    # appends: spawn near target centroids with free capacity
    next_id = D
    order = np.argsort(-headroom[:hi_cluster], kind="stable")
    oi = 0
    made = 0
    attempts = 0
    while made < n_app and attempts < 16 * n_app:
        attempts += 1
        c = int(order[oi % len(order)])
        oi += 1
        if headroom[c] <= 0:
            continue
        v = spawn_near(c)
        if v is None:
            continue
        headroom[c] -= 1
        vecs.append(v)
        ids.append(next_id)
        next_id += 1
        made += 1

    terms = rng.integers(0, vocab, (len(ids), doc_terms)).astype(np.int32)
    weights = rng.lognormal(0.0, 0.5, (len(ids), doc_terms)).astype(
        np.float32)
    del_pool = np.setdiff1d(np.flatnonzero(doc_cluster >= 0),
                            np.asarray(ids, np.int64))
    delete_ids = rng.choice(del_pool, min(n_deletes, len(del_pool)),
                            replace=False).astype(np.int64)
    delta = index_lib.IndexDelta(
        upsert_ids=np.asarray(ids, np.int64),
        upsert_embeddings=np.asarray(vecs, np.float32),
        upsert_terms=terms, upsert_weights=weights, delete_ids=delete_ids)
    return delta, {"target_shards": target_shards,
                   "n_replacements": n_rep_made, "n_appends": made,
                   "n_deletes": int(len(delete_ids))}


def _synthetic_queries(reader, n_queries):
    """Regenerate evaluation queries from the index's synthetic-corpus
    recipe (the original generation-0 corpus is enough: queries are just
    vectors + terms)."""
    from repro.data import synth_corpus, synth_queries
    meta = reader.manifest.get("extra", {}).get("corpus")
    if meta is None or meta.get("kind") != "synthetic":
        raise SystemExit("index lacks synthetic-corpus metadata; cannot "
                         "generate queries (--serve-queries/--check-parity "
                         "need it)")
    corpus = synth_corpus(meta["seed"], meta["n_docs"], meta["dim"],
                          meta["vocab"])
    return synth_queries(9, corpus, n_queries)


def _serve(engine, qs, n, batch):
    out = []
    for lo in range(0, n, batch):
        ids, _ = engine.retrieve(qs.q_dense[lo:lo + batch],
                                 qs.q_terms[lo:lo + batch],
                                 qs.q_weights[lo:lo + batch])
        out.append(np.asarray(ids))
    return np.concatenate(out)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Apply an incremental upsert/delete delta to a built "
                    "index (new atomic generation), hot-reload a serving "
                    "engine across it, compact, and parity-check.",
        epilog=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--index-dir", required=True,
                    help="built index (repro.launch.build_index)")
    ap.add_argument("--upserts", type=int, default=0,
                    help="synthetic upserts to apply (replacements + "
                         "appends, shard-localized)")
    ap.add_argument("--deletes", type=int, default=0,
                    help="synthetic deletes (tombstoned: zero shard-byte "
                         "rewrites)")
    ap.add_argument("--append-frac", type=float, default=0.3,
                    help="fraction of upserts that append new doc ids "
                         "(rest replace existing docs in place)")
    ap.add_argument("--target-shards", type=int, default=None,
                    help="localize upserts to this many shards (default: "
                         "smallest prefix with enough capacity)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", default="size",
                    choices=("none", "size", "full"),
                    help="integrity check level when opening the index")
    ap.add_argument("--serve-queries", type=int, default=0,
                    help="serve N queries through ONE engine before and "
                         "after the delta commit, hot-swapping generations "
                         "with engine.reload_index() in between (no "
                         "restart, cache invalidated)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--check-parity", action="store_true",
                    help="compact a COPY of the updated index (equals a "
                         "from-scratch serialization of the same logical "
                         "state) and require identical served top-k ids")
    ap.add_argument("--compact", action="store_true",
                    help="after any delta: fold tombstones + generations "
                         "into a clean single-generation layout, in place")
    ap.add_argument("--recluster-overflow", type=float, default=0.5,
                    help="re-cluster a shard locally when this fraction of "
                         "its targeted upserts overflowed their nearest "
                         "cluster")
    ap.add_argument("--recluster-min-overflow", type=int, default=4,
                    help="...and at least this many overflowed")
    ap.add_argument("--lloyd-iters", type=int, default=4,
                    help="local Lloyd's iterations for shard re-clustering")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export per-phase spans of the delta commit / "
                         "compaction (and any serve batches) after the run "
                         "(.jsonl span lines or Chrome trace JSON)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the serving engine's metrics registry "
                         "(.prom/.txt = Prometheus text, else JSON)")
    args = ap.parse_args(argv)

    from repro.obs import MetricsRegistry, Tracer, write_metrics, write_trace
    tracer = Tracer(sample_rate=1.0) if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None

    reader = index_lib.IndexReader.open(args.index_dir, verify=args.verify)
    print(f"index: {reader.index_dir} (format v{reader.format_version}, "
          f"generation {reader.generation}, "
          f"{reader.geometry['n_docs']} docs, "
          f"{len(reader.manifest['block_shards'])} shard(s))")

    engine, qs, pre_ids = None, None, None
    if args.serve_queries > 0:
        qs = _synthetic_queries(reader, args.serve_queries)
        engine = reader.engine(max_batch=args.batch, metrics=metrics,
                               tracer=tracer)
        pre_ids = _serve(engine, qs, args.serve_queries, args.batch)
        print(f"served {args.serve_queries} queries on generation "
              f"{reader.generation}")

    report = None
    if args.upserts or args.deletes:
        delta, info = synth_delta(
            reader, args.upserts, args.deletes, seed=args.seed,
            append_frac=args.append_frac, target_shards=args.target_shards)
        report = update_lib.write_index_delta(
            args.index_dir, delta, verify="none",
            recluster_overflow=args.recluster_overflow,
            recluster_min_overflow=args.recluster_min_overflow,
            lloyd_iters=args.lloyd_iters, tracer=tracer)
        print(f"committed generation {report['generation']}: "
              f"{report['n_upserts']} upserts "
              f"({report['n_replaced']} replace, "
              f"{report['n_appended']} append; "
              f"{info['target_shards']} target shard(s)), "
              f"{report['n_deletes']} deletes -> "
              f"{len(report['shards_rewritten'])}/{report['n_shards']} "
              f"shards rewritten "
              f"({report['bytes_rewritten_frac']:.0%} of shard bytes), "
              f"reclustered {report['reclustered_shards']}, "
              f"{report['wall_s']:.2f}s")

    if engine is not None:
        gen = engine.reload_index()
        post_ids = _serve(engine, qs, args.serve_queries, args.batch)
        st = engine.stats()
        engine.close()
        assert post_ids.shape == pre_ids.shape
        print(f"hot-reloaded to generation {gen}: served "
              f"{args.serve_queries} more queries, 0 failed requests, "
              f"cache cleared {st['cache']['clears']}x "
              f"(reloads={st['reloads']})")

    rc = 0
    if args.check_parity:
        tmp = tempfile.mkdtemp()
        copy_dir = os.path.join(tmp, "compacted")
        shutil.copytree(args.index_dir, copy_dir)
        update_lib.compact_index(copy_dir)
        if qs is None:
            qs = _synthetic_queries(reader, args.batch)
        nq = int(np.asarray(qs.q_dense).shape[0])
        reader.refresh()
        with reader.engine(max_batch=args.batch) as live_eng:
            live_ids = _serve(live_eng, qs, nq, args.batch)
        with index_lib.IndexReader.open(copy_dir).engine(
                max_batch=args.batch) as comp_eng:
            comp_ids = _serve(comp_eng, qs, nq, args.batch)
        if np.array_equal(live_ids, comp_ids):
            print(f"parity OK: updated index == compacted (from-scratch "
                  f"serialized) index on {nq} queries")
        else:
            bad = int((live_ids != comp_ids).any(axis=1).sum())
            print(f"PARITY FAIL: {bad}/{nq} queries differ between the "
                  f"incrementally-updated index and its compaction")
            rc = 1
        shutil.rmtree(tmp, ignore_errors=True)

    if args.compact:
        t0 = time.perf_counter()
        manifest = update_lib.compact_index(args.index_dir, tracer=tracer)
        print(f"compacted -> generation {manifest['generation']} "
              f"({manifest['total_bytes'] / 2**20:.1f} MiB, "
              f"{time.perf_counter() - t0:.2f}s)")

    if metrics is not None:
        write_metrics(metrics, args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
    if tracer is not None:
        write_trace(tracer, args.trace_out)
        print(f"trace -> {args.trace_out} ({tracer.started} trace(s))")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
