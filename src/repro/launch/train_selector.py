"""Selector training CLI: stream labels off a built index, train the
Stage-II LSTM, calibrate theta/budget on held-out queries, and publish
the result as a new index generation that a live engine hot-reloads.

  PYTHONPATH=src python -m repro.launch.train_selector \
      --index-dir /tmp/idx --train-queries 512 --holdout-queries 128 \
      --epochs 40 --target-recall 0.9 --publish --serve-check 8

Pipeline (src/repro/train/):
  1. LABELS  — exact full-dense top-k streamed through the index's own
     ShardedDiskStore/ShardedPQStore, at most --chunk-clusters blocks per
     read, no materialized embedding matrix; spilled to a reusable label
     cache (--label-cache, default <index-dir>.labels) keyed by index
     generation + label config + query set.
  2. TRAIN   — candidate sequences bucketed to power-of-two lengths,
     jit-compiled steps (optionally on the fused Pallas LSTM cell via
     --use-kernel), periodic repro.checkpoint checkpoints
     (--ckpt-every / --ckpt-dir) with deterministic mid-epoch --resume.
  3. CALIBRATE — sweep --thetas x --budgets on the held-out label set;
     pick the cheapest point hitting --target-recall (or the best recall
     within --target-budget). With --expand-depths the sweep gains a
     stage-1 expansion-depth axis (neighbor-graph candidate expansion):
     the selector is retrained on the expanded candidate sequences
     (labels rebuilt from the cached full-dense ids — no re-streaming)
     and the operating point is re-picked at the baseline's budget, so
     extra recall never costs extra read bytes.
  4. PUBLISH (--publish) — weights + calibrated theta/budget commit as an
     atomic generation (zero corpus bytes rewritten); --serve-check N
     serves N queries on a live engine before AND after the commit,
     hot-swaps via RetrievalEngine.reload_selector(), and parity-checks
     the hot-reloaded engine against a fresh engine on the new
     generation (exact top-k ids; exit non-zero on mismatch).

Key flags (full list below / --help):
  --pos-weight {auto,<float>}  BCE positive-class weight; "auto" derives
                               it from the observed label positive rate,
                               default keeps the index config's value
  --no-bucket                  disable sequence-length bucketing
  --use-kernel {auto,0,1}      Pallas LSTM cell in the train step
                               (auto = only on TPU backends)
  --expand-depths 0,1,2        stage-1 expansion depths to sweep; the
                               best (depth, theta) at the baseline
                               budget publishes as config.expand_depth
  --fusion {interp,rrf}        fusion method to publish into the config
                               (default: keep the index config's value)
"""

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro import index as index_lib
from repro import train as train_lib
from repro.data import synth_corpus, synth_queries


def _parse_pos_weight(s):
    if s is None:
        return None, False
    if s == "auto":
        return None, True
    return float(s), False


def _parse_use_kernel(s):
    return "auto" if s == "auto" else bool(int(s))


def _floats(s):
    return [float(x) for x in s.split(",") if x]


def _ints(s):
    return [int(x) for x in s.split(",") if x]


def _corpus_queries(reader, args):
    meta = reader.manifest.get("extra", {}).get("corpus")
    if meta is None or meta.get("kind") != "synthetic":
        raise SystemExit("index lacks synthetic-corpus metadata; cannot "
                         "regenerate training/holdout queries")
    corpus = synth_corpus(meta["seed"], meta["n_docs"], meta["dim"],
                          meta["vocab"])
    train_q = synth_queries(args.seed + 21, corpus, args.train_queries)
    hold_q = synth_queries(args.seed + 22, corpus, args.holdout_queries)
    return corpus, train_q, hold_q


def _labels(reader, cfg, index, store, qs, label_cfg, cache, tag,
            metrics=None):
    key = train_lib.label_cache_key(
        reader.manifest, cfg, label_cfg,
        train_lib.query_fingerprint(qs.q_dense, qs.q_terms, qs.q_weights))
    ls, hit = cache.get_or_build(
        key, lambda: train_lib.make_labels_streaming(
            cfg, index, store, qs.q_dense, qs.q_terms, qs.q_weights,
            label_cfg=label_cfg, metrics=metrics),
        extra={"tag": tag, "generation": reader.generation},
        metrics=metrics)
    src = "cache hit" if hit else (
        f"streamed {ls.stats.blocks_read} blocks / "
        f"{ls.stats.bytes_read / 2**20:.1f} MiB in "
        f"{ls.stats.wall_s:.1f}s")
    print(f"labels[{tag}]: {ls.n_queries} queries, "
          f"pos_rate={ls.pos_rate:.4f} ({src})", flush=True)
    return ls


def _serve_ids(engine, qs, n, batch):
    out = []
    for lo in range(0, n, batch):
        ids, _ = engine.retrieve(qs.q_dense[lo:lo + batch],
                                 qs.q_terms[lo:lo + batch],
                                 qs.q_weights[lo:lo + batch])
        out.append(np.asarray(ids))
    return np.concatenate(out)


def main(argv=None):
    # __doc__ IS the epilog: the module docstring and --help can never
    # drift apart (CI smoke-tests --help for every repro.launch CLI)
    ap = argparse.ArgumentParser(
        description="Train, calibrate, and publish a Stage-II selector "
                    "against a built CluSD index (streaming labels, "
                    "bucketed training, atomic generation publish).",
        epilog=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--index-dir", required=True,
                    help="built index (repro.launch.build_index)")
    ap.add_argument("--train-queries", type=int, default=512)
    ap.add_argument("--holdout-queries", type=int, default=128,
                    help="held-out queries for threshold calibration")
    ap.add_argument("--epochs", type=int, default=None,
                    help="default: the index config's epochs")
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--top-dense", type=int, default=10,
                    help="full-dense top-k that defines a positive cluster")
    ap.add_argument("--chunk-clusters", type=int, default=64,
                    help="cluster blocks per streamed label-gen read")
    ap.add_argument("--label-cache", default=None,
                    help="label cache dir (default <index-dir>.labels)")
    ap.add_argument("--pos-weight", default=None,
                    help="BCE positive weight: float, or 'auto' to derive "
                         "from the label positive rate (default: index "
                         "config value)")
    ap.add_argument("--no-bucket", action="store_true",
                    help="disable power-of-two sequence-length bucketing")
    ap.add_argument("--use-kernel", default="auto",
                    help="Pallas LSTM cell in the train step: auto|0|1")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir (default <index-dir>.selector-ckpt)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N steps (0 = end only)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir")
    ap.add_argument("--thetas", type=_floats,
                    default="0.01,0.02,0.05,0.1,0.2,0.3,0.5,0.7",
                    help="comma list of thresholds to sweep")
    ap.add_argument("--budgets", type=_ints, default=None,
                    help="comma list of cluster budgets (default: powers "
                         "of two up to n_candidates)")
    ap.add_argument("--target-recall", type=float, default=None,
                    help="calibrate to the cheapest point with recall@k "
                         ">= this (default 0.9 when no --target-budget)")
    ap.add_argument("--target-budget", type=int, default=None,
                    help="calibrate to the best recall within this many "
                         "selected clusters")
    ap.add_argument("--expand-depths", type=_ints, default=None,
                    metavar="D0,D1,..",
                    help="stage-1 neighbor-graph expansion depths to sweep "
                         "(retrains the selector on expanded candidates; "
                         "best depth publishes as config.expand_depth)")
    ap.add_argument("--fusion", default=None, choices=("interp", "rrf"),
                    help="fusion method to publish into the index config "
                         "(default: keep the current value)")
    ap.add_argument("--publish", action="store_true",
                    help="commit weights + calibrated thresholds as a new "
                         "index generation")
    ap.add_argument("--serve-check", type=int, default=0,
                    help="with --publish: serve N queries on a live "
                         "engine across the commit (hot reload_selector) "
                         "and parity-check vs a fresh engine")
    ap.add_argument("--verify", default="size",
                    choices=("none", "size", "full"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export one train_selector trace with labels / "
                         "train / calibrate / publish phase spans (.jsonl "
                         "span lines or Chrome trace JSON)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump labels.* / train.* (and serve-check) "
                         "metrics (.prom/.txt = Prometheus text, else "
                         "JSON)")
    args = ap.parse_args(argv)
    if isinstance(args.thetas, str):        # default not routed through type=
        args.thetas = _floats(args.thetas)
    if args.target_recall is not None and args.target_budget is not None:
        ap.error("--target-recall and --target-budget are mutually "
                 "exclusive calibration targets")

    from repro.obs import (NOOP_TRACE, MetricsRegistry, Tracer,
                           write_metrics, write_trace)
    tracer = Tracer(sample_rate=1.0) if args.trace_out else None
    metrics = MetricsRegistry() if args.metrics_out else None
    tr = tracer.trace("train_selector") if tracer is not None else NOOP_TRACE

    def _finish_obs():
        tr.finish()
        if metrics is not None:
            write_metrics(metrics, args.metrics_out)
            print(f"metrics -> {args.metrics_out}")
        if tracer is not None:
            write_trace(tracer, args.trace_out)
            print(f"trace -> {args.trace_out}")

    t0 = time.perf_counter()
    reader = index_lib.IndexReader.open(args.index_dir, verify=args.verify)
    cfg, index = reader.load_index()
    pos_override, pos_auto = _parse_pos_weight(args.pos_weight)
    if pos_auto:
        cfg = dataclasses.replace(cfg, pos_weight=None)
    store = reader.open_store(cluster_docs=index.cluster_docs)
    print(f"index: {reader.index_dir} (format v{reader.format_version}, "
          f"generation {reader.generation}, N={cfg.n_clusters}, "
          f"n_docs={cfg.n_docs})", flush=True)
    corpus, train_q, hold_q = _corpus_queries(reader, args)

    # -- 1. labels (streamed, cached) --------------------------------------
    label_cfg = train_lib.LabelConfig(top_dense=args.top_dense,
                                      chunk_clusters=args.chunk_clusters)
    cache = train_lib.LabelCache(args.label_cache
                                 or args.index_dir.rstrip("/") + ".labels")
    with tr.span("labels", n_train=args.train_queries,
                 n_holdout=args.holdout_queries):
        train_ls = _labels(reader, cfg, index, store, train_q, label_cfg,
                           cache, "train", metrics=metrics)
        hold_ls = _labels(reader, cfg, index, store, hold_q, label_cfg,
                          cache, "holdout", metrics=metrics)

    # -- 2. train ----------------------------------------------------------
    tcfg = train_lib.SelectorTrainConfig(
        epochs=args.epochs, lr=args.lr, batch_size=args.batch_size,
        pos_weight=pos_override, bucket=not args.no_bucket,
        use_kernel=_parse_use_kernel(args.use_kernel), seed=args.seed,
        ckpt_dir=args.ckpt_dir
        or args.index_dir.rstrip("/") + ".selector-ckpt",
        ckpt_every_steps=args.ckpt_every)
    trainer = train_lib.SelectorTrainer(cfg, tcfg)
    t1 = time.perf_counter()
    with tr.span("train"):
        params, hist = trainer.fit(jax.random.key(args.seed + 2),
                                   train_ls.feats, train_ls.labels,
                                   resume=args.resume,
                                   log_every=max(1,
                                                 (args.epochs or cfg.epochs)
                                                 // 5),
                                   metrics=metrics)
    train_wall = time.perf_counter() - t1
    loss_str = (f"loss {hist[0]:.4f} -> {hist[-1]:.4f}" if hist
                else "no steps left (resumed a finished run)")
    print(f"trained: {loss_str} in {train_wall:.1f}s "
          f"(pos_weight={trainer.pos_weight:.2f}, "
          f"buckets={sorted(trainer._steps)})", flush=True)

    # -- 3. calibrate ------------------------------------------------------
    budgets = args.budgets or [b for b in (4, 8, 16, 32, 64)
                               if b <= cfg.n_candidates]
    # calibrate against SERVING numerics: the engine's stage2_select runs
    # the reference LSTM path, so the swept probabilities must too (the
    # kernel forward may differ in low-order bits near a threshold)
    with tr.span("calibrate", n_thetas=len(set(args.thetas + [cfg.theta])),
                 n_budgets=len(budgets)):
        probs = train_lib.selector_probs(params, hold_ls.feats,
                                         use_kernel=False)
        table = train_lib.calibration_table(
            hold_ls, probs, np.asarray(index.doc_cluster),
            thetas=sorted(set(args.thetas + [cfg.theta])), budgets=budgets,
            block_bytes=int(getattr(store, "block_bytes", 0)))
        target_recall = args.target_recall
        if target_recall is None and args.target_budget is None:
            target_recall = 0.9
        op = train_lib.choose_operating_point(
            table, target_recall=target_recall,
            target_budget=args.target_budget)
    print(f"calibrated: theta={op['theta']} budget={op['budget']} -> "
          f"recall@{args.top_dense}={op['recall']:.4f} "
          f"avg_selected={op['avg_selected']} "
          f"(target_met={op['target_met']})", flush=True)

    # -- 3b. hybrid expansion sweep (--expand-depths) ----------------------
    # Stage-1 expansion changes (cand, feats) but not the full-dense ids
    # the labels came from, so retraining + sweeping reuses the cached
    # label sets without touching the corpus.
    hybrid = None
    pub_params, pub_op, pub_table = params, op, table
    pub_depth = None
    if args.expand_depths:
        depths = sorted({max(0, d) for d in args.expand_depths
                         if cfg.n_candidates * (1 + max(0, d))
                         <= cfg.n_clusters})
        dropped = sorted(set(args.expand_depths) - set(depths))
        if dropped:
            print(f"expand-depths {dropped} dropped: expanded candidate "
                  f"count would exceed n_clusters={cfg.n_clusters}")
        dmax = max(depths)
        cfg_h = dataclasses.replace(cfg, expand_depth=dmax)
        with tr.span("hybrid", n_depths=len(depths), max_depth=dmax):
            ls_h = train_lib.relabel_for_config(
                cfg_h, index, train_q.q_dense, train_q.q_terms,
                train_q.q_weights, train_ls.dense_ids,
                stage1=label_cfg.stage1)
            trainer_h = train_lib.SelectorTrainer(
                cfg_h, dataclasses.replace(
                    tcfg, ckpt_dir=tcfg.ckpt_dir + ".hybrid"))
            params_h, hist_h = trainer_h.fit(
                jax.random.key(args.seed + 3), ls_h.feats, ls_h.labels,
                log_every=max(1, (args.epochs or cfg.epochs) // 5),
                metrics=metrics)
            sweep = train_lib.expansion_sweep(
                cfg, index, params_h, hold_q.q_dense, hold_q.q_terms,
                hold_q.q_weights, hold_ls.dense_ids, depths=depths,
                thetas=sorted(set(args.thetas + [cfg.theta])),
                budgets=budgets,
                block_bytes=int(getattr(store, "block_bytes", 0)),
                stage1=label_cfg.stage1)
        rows_h = [r for d in sweep for r in d["rows"]]
        hop = train_lib.choose_operating_point(
            rows_h, target_budget=args.target_budget or op["budget"])
        ceil = {d["depth"]: d["stage1_ceiling"] for d in sweep}
        hybrid = {
            "depth": hop["depth"], "theta": hop["theta"],
            "budget": hop["budget"], "recall": hop["recall"],
            "avg_selected": hop["avg_selected"],
            "stage1_ceiling": ceil[hop["depth"]],
            "baseline_recall": op["recall"],
            "final_loss": round(hist_h[-1], 6) if hist_h else None,
            "sweep": [{"depth": d["depth"],
                       "n_candidates": d["n_candidates"],
                       "stage1_ceiling": d["stage1_ceiling"]}
                      for d in sweep],
        }
        pub_params, pub_op, pub_table = params_h, dict(hop), rows_h
        pub_depth = hop["depth"]
        print(f"hybrid: depth={hop['depth']} theta={hop['theta']} "
              f"budget={hop['budget']} -> "
              f"recall@{args.top_dense}={hop['recall']:.4f} "
              f"(stage1_ceiling={ceil[hop['depth']]:.4f}, "
              f"baseline={op['recall']:.4f})", flush=True)

    if not args.publish:
        _finish_obs()
        print(json.dumps({"operating_point": op, "hybrid": hybrid,
                          "wall_s": round(time.perf_counter() - t0, 1)}))
        return 0

    # -- 4. publish + live hot-reload check --------------------------------
    n_check = min(args.serve_check, args.holdout_queries)
    engine = None
    if n_check:
        engine = reader.engine(max_batch=max(8, n_check), metrics=metrics,
                               tracer=tracer)
        _serve_ids(engine, hold_q, n_check, engine.max_batch)  # pre-commit

    with tr.span("publish"):
        report = train_lib.publish_selector(
            args.index_dir, pub_params, theta=pub_op["theta"],
            budget=pub_op["budget"], calibration=pub_table,
            label_config=dataclasses.asdict(label_cfg),
            train_meta={"n_train_queries": train_ls.n_queries,
                        "n_holdout_queries": hold_ls.n_queries,
                        "epochs": args.epochs or cfg.epochs,
                        "pos_weight": trainer.pos_weight,
                        "final_loss": round(hist[-1], 6) if hist else None,
                        "train_wall_s": round(train_wall, 3),
                        "hybrid": hybrid},
            expand_depth=pub_depth, fusion=args.fusion,
            verify=args.verify)
    print(f"published generation {report['generation']} "
          f"(+{report['bytes_added']} bytes, {report['wall_s']}s)",
          flush=True)

    if n_check:
        gen = engine.reload_selector()
        assert gen == report["generation"], (gen, report)
        got = _serve_ids(engine, hold_q, n_check, engine.max_batch)
        engine.close()
        fresh_reader = index_lib.IndexReader.open(args.index_dir,
                                                  verify=args.verify)
        with fresh_reader.engine(max_batch=max(8, n_check)) as fresh:
            want = _serve_ids(fresh, hold_q, n_check, fresh.max_batch)
        if not np.array_equal(got, want):
            bad = int((got != want).any(axis=1).sum())
            print(f"PARITY FAIL: {bad}/{n_check} queries differ between "
                  f"the hot-reloaded engine and a fresh engine on "
                  f"generation {gen}")
            _finish_obs()
            return 1
        print(f"serve check OK: {n_check} queries, hot reload_selector == "
              f"fresh engine on generation {gen} "
              f"(selector_reloads={engine.stats()['selector_reloads']})")
    _finish_obs()
    print(json.dumps({"operating_point": op, "hybrid": hybrid,
                      "publish": report,
                      "wall_s": round(time.perf_counter() - t0, 1)}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
