"""Offline index build CLI: cluster, pack, and serialize once — then serve
from the built directory (`repro.launch.serve --index-dir`) without ever
rebuilding or materializing the embedding matrix at load time.

  PYTHONPATH=src python -m repro.launch.build_index --out /tmp/idx \
      --docs 20000 --clusters 256 --shards 8 --train-queries 512

Pipeline (repro/index/builder.py): sharded Lloyd's k-means over embedding
shards -> capacity-balanced cluster table -> neighbor graph -> sparse
inverted index -> optional LSTM selector training (labels need the full
embeddings; that is fine offline) -> optional PQ codebooks -> per-shard
cluster-block files + versioned manifest with checksums.
"""

import argparse
import dataclasses
import math
import time

import jax
import numpy as np

from repro import index as index_lib
from repro.configs import get_config
from repro.core import train_lstm as tl
from repro.data import synth_corpus, synth_queries


def build_cfg(args):
    k_sparse = max(32, min(512, args.docs // 4))
    bins = tuple(b for b in (10, 25, 50, 100, 200) if b < k_sparse) + (k_sparse,)
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=args.docs, dim=args.dim, n_clusters=args.clusters,
        vocab=args.vocab, k_sparse=k_sparse, bins=bins,
        n_candidates=min(32, args.clusters), max_selected=16,
        k_final=min(256, args.docs),
        train_queries=args.train_queries, epochs=args.epochs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="index output directory")
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--shards", type=int, default=4,
                    help="block shard files (and k-means embedding shards)")
    ap.add_argument("--train-queries", type=int, default=512,
                    help="0 skips LSTM selector training")
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--pq-nsub", type=int, default=0,
                    help="also train PQ codebooks with this many subspaces")
    ap.add_argument("--kmeans-iters", type=int, default=15)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = build_cfg(args)
    t0 = time.perf_counter()
    print(f"corpus: {cfg.n_docs} docs x {cfg.dim} dim ...", flush=True)
    corpus = synth_corpus(args.seed, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings)

    shard_docs = math.ceil(cfg.n_docs / max(1, args.shards))
    print(f"clustering: {cfg.n_clusters} clusters over "
          f"{args.shards} embedding shard(s) ...", flush=True)
    index = index_lib.build_index_offline(
        cfg, jax.random.key(args.seed), emb, corpus.doc_terms,
        corpus.doc_weights, shard_docs=shard_docs,
        kmeans_iters=args.kmeans_iters)

    if args.train_queries > 0:
        print(f"training LSTM selector on {args.train_queries} queries ...",
              flush=True)
        # labels need full dense retrieval — offline-only embedding use
        index.embeddings = corpus.embeddings
        tq = synth_queries(args.seed + 1, corpus, args.train_queries)
        _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                          tq.q_weights)
        index.lstm_params, hist = tl.train_selector(
            cfg, jax.random.key(args.seed + 2), np.asarray(feats),
            np.asarray(labels))
        print(f"  loss {hist[0]:.4f} -> {hist[-1]:.4f}", flush=True)
        index.embeddings = None

    if args.pq_nsub > 0:
        from repro.core import quant as quant_lib
        print(f"training PQ codebooks (nsub={args.pq_nsub}) ...", flush=True)
        index.quantizer = quant_lib.train_pq(
            jax.random.key(args.seed + 3), corpus.embeddings, args.pq_nsub)

    manifest = index_lib.write_index(
        args.out, cfg, index, emb, n_shards=args.shards,
        extra={"corpus": {"kind": "synthetic", "seed": args.seed,
                          "n_docs": cfg.n_docs, "dim": cfg.dim,
                          "vocab": cfg.vocab}})
    wall = time.perf_counter() - t0
    g = manifest["geometry"]
    print(f"wrote {args.out}: {manifest['total_bytes'] / 2**20:.1f} MiB, "
          f"{len(manifest['block_shards'])} block shard(s), "
          f"N={g['n_clusters']} cap={g['cap']} dim={g['dim']}, "
          f"lstm={'yes' if manifest['lstm'] else 'no'}, "
          f"pq={'yes' if manifest['pq'] else 'no'}, "
          f"build {wall:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
