"""Offline index build CLI: cluster, pack, and serialize once — then serve
from the built directory (`repro.launch.serve --index-dir`) without ever
rebuilding or materializing the embedding matrix at load time, and mutate
it later with `repro.launch.update_index` (incremental deltas).

  PYTHONPATH=src python -m repro.launch.build_index --out /tmp/idx \
      --docs 20000 --clusters 256 --shards 8 --train-queries 512

  # format v2: PQ code shards (4-16x smaller embedding store), built from
  # an np.memmap staged corpus with bounded-chunk reads (corpus > RAM path)
  PYTHONPATH=src python -m repro.launch.build_index --out /tmp/idx_pq \
      --format-version 2 --pq-nsub 8 --memmap --chunk-docs 4096

Key flags (the full list with defaults is below / `--help`):
  --format-version {1,2}  1 = float32 block shards; 2 = PQ code shards +
                          CSR postings (4-16x smaller; served via
                          decode-on-fetch ADC at exact-ADC numerics)
  --memmap                stage the synthetic corpus through an np.memmap
                          and build from it — the corpus>RAM path (LSTM
                          label generation still uses in-RAM embeddings)
  --chunk-docs N          bound every embedding read to N rows (0 = one
                          k-means shard per read); enforced by a capped-
                          read wrapper test in tests/test_index.py
  --pq-nsub N             PQ subspaces (v1: optional side artifacts;
                          v2: the code shards; defaults to 8 under v2)

Pipeline (repro/index/builder.py): sharded Lloyd's k-means over embedding
shards -> capacity-balanced cluster table -> neighbor graph -> sparse
inverted index -> optional LSTM selector training (labels need the full
embeddings; that is fine offline) -> optional PQ codebooks -> per-shard
cluster-block (v1) or code-block (v2) files + versioned, checksummed,
generation-0 manifest (see src/repro/index/README.md).
"""

import argparse
import dataclasses
import math
import os
import tempfile
import time

import jax
import numpy as np

from repro import index as index_lib
from repro.configs import get_config
from repro.core import train_lstm as tl
from repro.data import synth_corpus, synth_queries


def build_cfg(args):
    k_sparse = max(32, min(512, args.docs // 4))
    bins = tuple(b for b in (10, 25, 50, 100, 200) if b < k_sparse) + (k_sparse,)
    return dataclasses.replace(
        get_config("clusd-msmarco", "smoke"),
        n_docs=args.docs, dim=args.dim, n_clusters=args.clusters,
        vocab=args.vocab, k_sparse=k_sparse, bins=bins,
        n_candidates=min(32, args.clusters), max_selected=16,
        k_final=min(256, args.docs),
        train_queries=args.train_queries, epochs=args.epochs)


def main(argv=None):
    # __doc__ IS the epilog: the module docstring and --help can never
    # drift apart (CI smoke-tests --help for every repro.launch CLI)
    ap = argparse.ArgumentParser(
        description="Build a persistent CluSD index offline (cluster, "
                    "pack, serialize + checksummed manifest).",
        epilog=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--out", required=True, help="index output directory")
    ap.add_argument("--docs", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--shards", type=int, default=4,
                    help="block shard files (and k-means embedding shards)")
    ap.add_argument("--train-queries", type=int, default=512,
                    help="0 skips LSTM selector training")
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--pq-nsub", type=int, default=0,
                    help="train PQ codebooks with this many subspaces "
                         "(v1: extra pq/ artifacts; v2: the code shards; "
                         "defaults to 8 under --format-version 2)")
    ap.add_argument("--format-version", type=int, default=1, choices=(1, 2),
                    help="1 = float32 block shards, 2 = PQ code shards")
    ap.add_argument("--memmap", action="store_true",
                    help="stage embeddings through an np.memmap and build "
                         "from it (the corpus>RAM path; LSTM label "
                         "generation still uses in-RAM embeddings)")
    ap.add_argument("--chunk-docs", type=int, default=0,
                    help="bound every embedding read to this many rows "
                         "(0 = per-shard granularity)")
    ap.add_argument("--kmeans-iters", type=int, default=15)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = build_cfg(args)
    t0 = time.perf_counter()
    print(f"corpus: {cfg.n_docs} docs x {cfg.dim} dim ...", flush=True)
    corpus = synth_corpus(args.seed, cfg.n_docs, cfg.dim, cfg.vocab)
    emb = np.asarray(corpus.embeddings)
    if args.memmap:
        staged = os.path.join(tempfile.mkdtemp(), "embeddings.bin")
        np.asarray(emb, np.float32).tofile(staged)
        emb = np.memmap(staged, dtype=np.float32, mode="r", shape=emb.shape)
        print(f"staged embeddings -> np.memmap {staged}", flush=True)

    shard_docs = math.ceil(cfg.n_docs / max(1, args.shards))
    if args.chunk_docs > 0:
        shard_docs = min(shard_docs, args.chunk_docs)
    print(f"clustering: {cfg.n_clusters} clusters over "
          f"{args.shards} embedding shard(s) ...", flush=True)
    index = index_lib.build_index_offline(
        cfg, jax.random.key(args.seed), emb, corpus.doc_terms,
        corpus.doc_weights, shard_docs=shard_docs,
        kmeans_iters=args.kmeans_iters)

    if args.train_queries > 0:
        print(f"training LSTM selector on {args.train_queries} queries ...",
              flush=True)
        # labels need full dense retrieval — offline-only embedding use
        index.embeddings = corpus.embeddings
        tq = synth_queries(args.seed + 1, corpus, args.train_queries)
        _, feats, labels = tl.make_labels(cfg, index, tq.q_dense, tq.q_terms,
                                          tq.q_weights)
        index.lstm_params, hist = tl.train_selector(
            cfg, jax.random.key(args.seed + 2), np.asarray(feats),
            np.asarray(labels))
        print(f"  loss {hist[0]:.4f} -> {hist[-1]:.4f}", flush=True)
        index.embeddings = None

    pq_nsub = args.pq_nsub or (8 if args.format_version == 2 else 0)
    if pq_nsub > 0:
        from repro.core import quant as quant_lib
        print(f"training PQ codebooks (nsub={pq_nsub}) ...", flush=True)
        # streaming train/encode: bounded-chunk reads off the (possibly
        # memmap) source, so the v2 path never materializes the matrix
        index.quantizer = quant_lib.train_pq_stream(
            jax.random.key(args.seed + 3), emb, pq_nsub,
            chunk_docs=args.chunk_docs or index_lib.builder.DEFAULT_CHUNK_DOCS)

    manifest = index_lib.write_index(
        args.out, cfg, index, emb, n_shards=args.shards,
        format_version=args.format_version,
        chunk_docs=args.chunk_docs or index_lib.builder.DEFAULT_CHUNK_DOCS,
        extra={"corpus": {"kind": "synthetic", "seed": args.seed,
                          "n_docs": cfg.n_docs, "dim": cfg.dim,
                          "vocab": cfg.vocab}})
    wall = time.perf_counter() - t0
    g = manifest["geometry"]
    print(f"wrote {args.out} (format v{manifest['format_version']}): "
          f"{manifest['total_bytes'] / 2**20:.1f} MiB, "
          f"{len(manifest['block_shards'])} block shard(s), "
          f"N={g['n_clusters']} cap={g['cap']} dim={g['dim']}, "
          f"lstm={'yes' if manifest['lstm'] else 'no'}, "
          f"pq={'yes' if manifest['pq'] else 'no'}, "
          f"build {wall:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
