"""End-to-end training driver (CPU-scale by default; the same code path the
production mesh would run — select any arch with --arch).

Runs inside the fault-tolerant restartable loop: periodic async sharded
checkpoints, simulated-failure injection for drills, straggler monitoring.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --variant smoke --steps 100 --batch 8 --seq 128 [--fail-at 37]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.metrics import MetricLogger
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import Prefetcher, lm_synthetic_batches
from repro.data.recsys_stream import RecsysStream
from repro.data.graphs import synth_molecules
from repro.optim import adamw_init, make_schedule
from repro.runtime.fault import FailureInjector, restartable_train


def main():
    # __doc__ IS the epilog: the module docstring and --help can never
    # drift apart (CI smoke-tests --help for every repro.launch CLI)
    ap = argparse.ArgumentParser(
        description="Fault-tolerant end-to-end model training driver "
                    "(LM/recsys/GNN archs; the CluSD selector has its own "
                    "driver: repro.launch.train_selector).",
        epilog=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--log", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 1))
    sched = make_schedule("cosine", tc.lr, tc.warmup_steps, tc.total_steps)
    rng = jax.random.key(0)
    logger = MetricLogger(args.log)

    if cfg.family == "lm":
        from repro.models import transformer as model
        params = model.init_params(cfg, rng)
        step_fn_inner = jax.jit(model.make_train_step(cfg, sched, tc))

        def batches_fn(start):
            return iter(Prefetcher(lm_synthetic_batches(
                cfg.vocab_size, args.batch, args.seq,
                args.steps, seed=1000)))
        # deterministic restart: skip consumed batches
        def batches_at(start):
            it = batches_fn(0)
            for _ in range(start):
                next(it)
            return it
    elif cfg.family == "recsys":
        from repro.models import recsys as model
        params = model.init_params(cfg, rng)
        step_fn_inner = jax.jit(model.make_train_step(cfg, tc))

        def batches_at(start):
            stream = RecsysStream(cfg, seed=7)
            def gen():
                for _ in range(start):
                    stream.batch(args.batch)
                while True:
                    yield stream.batch(args.batch)
            return iter(Prefetcher(gen()))
    else:  # gnn
        from repro.models import nequip as model
        params = model.init_params(cfg, rng)
        step_fn_inner = jax.jit(model.make_train_step(cfg, tc))

        def batches_at(start):
            def gen():
                s = start
                while True:
                    yield synth_molecules(1234 + s % 16, 8, 12, 32,
                                          n_species=cfg.n_species)
                    s += 1
            return iter(Prefetcher(gen()))

    opt = adamw_init(params)
    state = {"params": params, "opt": opt}

    def step_fn(state, batch):
        p, o, m = step_fn_inner(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    state, history, restarts = restartable_train(
        init_state=state, step_fn=step_fn, batches_fn=batches_at,
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        failure_injector=FailureInjector(args.fail_at), logger=logger)
    first = [h for h in history if "loss" in h][:3]
    last = [h for h in history if "loss" in h][-3:]
    print(f"done: steps={len(history)} restarts={restarts} "
          f"loss {np.mean([h['loss'] for h in first]):.4f} -> "
          f"{np.mean([h['loss'] for h in last]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
