"""Dry-run cell builders: for every (arch x shape) return the step function,
abstract inputs (ShapeDtypeStruct — never allocated), and input shardings
for a given production mesh. See DESIGN.md §4/§6 for the sharding story.
"""

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec, cell_is_skipped
from repro.models import nequip as nq
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.models.sharding import named_sharding, rules_ctx, spec


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    rules: dict
    meta: dict


def _pad_to(x, m):
    return ((x + m - 1) // m) * m


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _opt_abstract(params_abs, opt_dtype):
    mk = lambda l: _sds(l.shape, opt_dtype if jnp.issubdtype(l.dtype, jnp.floating)
                        else l.dtype)
    return {"mu": jax.tree.map(mk, params_abs),
            "nu": jax.tree.map(mk, params_abs),
            "count": _sds((), jnp.int32)}


def _opt_shardings(param_sh, mesh):
    return {"mu": param_sh, "nu": param_sh,
            "count": NamedSharding(mesh, P())}


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch, cfg, shape, mesh, multi_pod):
    B, S = shape.global_batch, shape.seq_len
    rules = {}
    if shape.mode in ("decode", "prefill"):
        rules["seq_kv"] = ("model",)  # flash-decode / cache-emit seq shard
    if B == 1:
        rules["batch"] = None  # long_500k: batch axis unshardable
    with rules_ctx(rules, mesh=None, pod_dp=multi_pod):
        params_abs = tf.abstract_params(cfg)
        params_sh = tf.param_shardings(cfg, mesh)
        batch_sh = named_sharding(mesh, "batch", None)
        if shape.mode == "train":
            fn = tf.make_train_step(cfg)
            opt_abs = _opt_abstract(params_abs, cfg.opt_state_dtype)
            args = (params_abs, opt_abs,
                    {"tokens": _sds((B, S), jnp.int32),
                     "labels": _sds((B, S), jnp.int32)})
            shardings = (params_sh, _opt_shardings(params_sh, mesh),
                         {"tokens": batch_sh, "labels": batch_sh})
        elif shape.mode == "prefill":
            fn = tf.make_prefill_step(cfg)
            args = (params_abs, _sds((B, S), jnp.int32))
            shardings = (params_sh, batch_sh)
        else:  # decode
            fn = tf.make_decode_step(cfg)
            cache_abs = tf.abstract_cache(cfg, B, S)
            cache_sh = tf.cache_shardings(cfg, mesh, B, S)
            args = (params_abs, cache_abs, _sds((B, 1), jnp.int32),
                    _sds((), jnp.int32))
            shardings = (params_sh, cache_sh, batch_sh,
                         NamedSharding(mesh, P()))
    return fn, args, shardings, rules


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_sampled_sizes(shape):
    """Padded (n_nodes, n_edges) of the fanout-sampled subgraph."""
    n, nodes, edges = shape.batch_nodes, shape.batch_nodes, 0
    for f in shape.fanout:
        edges += n * f
        n = n * f
        nodes += n
    return nodes, edges


def _gnn_cell(arch, cfg, shape, mesh, multi_pod):
    dt = jnp.float32
    rules = {}
    if shape.name == "molecule":
        G = shape.n_graphs
        N = shape.n_nodes * G
        E = _pad_to(shape.n_edges * G, 512)
        d_feat = 0
    elif shape.batch_nodes:  # minibatch_lg — shapes from the neighbor sampler
        N, E = _gnn_sampled_sizes(shape)
        N, E = _pad_to(N, 512), _pad_to(E, 512)
        G = shape.batch_nodes + 1          # +1 ignore bucket for non-targets
        d_feat = shape.d_feat
        rules["nodes"] = ("data",)
    else:
        N = _pad_to(shape.n_nodes, 512) if shape.n_nodes > 100_000 else shape.n_nodes
        E = _pad_to(shape.n_edges, 512)
        G = 1
        d_feat = shape.d_feat
        if shape.n_nodes > 100_000:
            rules["nodes"] = ("data",)

    owner_sharded = getattr(cfg, "msg_impl", "pjit") == "owner_shard_map"
    with rules_ctx(rules, mesh=None, pod_dp=multi_pod):
        params_abs = nq.abstract_params(cfg, d_feat)
        params_sh = nq.param_shardings(cfg, mesh, d_feat)
        node_sh = named_sharding(mesh, "nodes", None)
        node1_sh = named_sharding(mesh, "nodes")
        edge_sh = named_sharding(mesh, "edges")
        batch = {
            "positions": _sds((N, 3), dt),
            "graph_id": _sds((N,), jnp.int32),
            "energy_target": _sds((G,), dt),
        }
        batch_sh = {
            "positions": node_sh,
            "graph_id": node1_sh,
            "energy_target": NamedSharding(mesh, P()),
        }
        if owner_sharded:
            # edges pre-partitioned by dst owner (§Perf); 10% imbalance pad
            n_shards = mesh.devices.size
            e_loc = max(8, ((int(1.1 * E / n_shards) + 7) // 8) * 8)
            shard_spec = NamedSharding(
                mesh, P(tuple(mesh.axis_names), None))
            for k, dtp in (("edge_src_sharded", jnp.int32),
                           ("edge_dst_sharded", jnp.int32),
                           ("edge_mask_sharded", jnp.float32)):
                batch[k] = _sds((n_shards, e_loc), dtp)
                batch_sh[k] = shard_spec
        else:
            batch.update({
                "edge_src": _sds((E,), jnp.int32),
                "edge_dst": _sds((E,), jnp.int32),
                "edge_mask": _sds((E,), dt),
            })
            batch_sh.update({"edge_src": edge_sh, "edge_dst": edge_sh,
                             "edge_mask": edge_sh})
        if d_feat:
            batch["node_feat"] = _sds((N, d_feat), dt)
            batch_sh["node_feat"] = node_sh
        else:
            batch["species"] = _sds((N,), jnp.int32)
            batch_sh["species"] = node1_sh
        if shape.batch_nodes:
            batch["energy_weight"] = _sds((G,), dt)
            batch_sh["energy_weight"] = NamedSharding(mesh, P())

        if owner_sharded:
            from repro.models.nequip_sharded import make_train_step_sharded
            fn = make_train_step_sharded(cfg, mesh, tuple(mesh.axis_names))
        else:
            fn = nq.make_train_step(cfg)
        opt_abs = _opt_abstract(params_abs, "float32")
        args = (params_abs, opt_abs, batch)
        shardings = (params_sh, _opt_shardings(params_sh, mesh), batch_sh)
    return fn, args, shardings, rules


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_batch(cfg, B, with_label=True):
    batch = {"sparse": _sds((B, len(cfg.table_sizes)), jnp.int32)}
    if cfg.kind == "dlrm":
        batch["dense"] = _sds((B, cfg.n_dense), jnp.float32)
    if cfg.kind == "din":
        batch["hist_item"] = _sds((B, cfg.seq_len), jnp.int32)
        batch["hist_cate"] = _sds((B, cfg.seq_len), jnp.int32)
        batch["hist_mask"] = _sds((B, cfg.seq_len), jnp.float32)
    if with_label:
        batch["label"] = _sds((B,), jnp.int32)
    return batch


def _recsys_batch_shardings(batch, mesh):
    b2 = named_sharding(mesh, "batch", None)
    b1 = named_sharding(mesh, "batch")
    return {k: (b1 if v.ndim == 1 else b2) for k, v in batch.items()}


def _recsys_cell(arch, cfg, shape, mesh, multi_pod):
    from repro.core.retrieval import (
        CandidateIndexSpec, brute_force_retrieval, clusd_candidate_retrieval)
    from repro.core.lstm import lstm_init
    rules = {}
    with rules_ctx(rules, mesh=None, pod_dp=multi_pod):
        params_abs = rs.abstract_params(cfg)
        params_sh = rs.param_shardings(cfg, mesh)
        if shape.mode in ("train", "serve"):
            B = shape.batch
            batch = _recsys_batch(cfg, B, with_label=shape.mode == "train")
            batch_sh = _recsys_batch_shardings(batch, mesh)
            if shape.mode == "train":
                fn = rs.make_train_step(cfg)
                opt_abs = _opt_abstract(params_abs, "float32")
                args = (params_abs, opt_abs, batch)
                shardings = (params_sh, _opt_shardings(params_sh, mesh),
                             batch_sh)
            else:
                fn = rs.make_serve_step(cfg)
                args = (params_abs, batch)
                shardings = (params_sh, batch_sh)
        else:  # retrieval_cand: CluSD-accelerated scorer (paper first-class)
            spec_ = CandidateIndexSpec(
                n_candidates=shape.n_candidates, n_clusters=4096, cap=256,
                local_topk=getattr(cfg, "retrieval_local_topk", False))
            N, cap, d = spec_.n_clusters, spec_.cap, cfg.embed_dim
            rules["batch"] = None  # single query
            batch = _recsys_batch(cfg, 1, with_label=False)
            batch_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), batch)
            n_item = 2
            cand_sparse = _sds((N * cap, n_item), jnp.int32)
            item_blocks = _sds((N, cap, d), jnp.float32)
            centroids = _sds((N, d), jnp.float32)
            nb = min(64, N - 1)
            nb_ids = _sds((N, nb), jnp.int32)
            nb_sims = _sds((N, nb), jnp.float32)
            lstm_abs = jax.eval_shape(
                lambda: lstm_init(jax.random.key(0),
                                  1 + spec_.u_bins + 2 * spec_.v_bins, 32))
            repl = NamedSharding(mesh, P())
            cand_sh = named_sharding(mesh, "candidates", None)
            blocks_sh = named_sharding(mesh, "clusters", None, None)

            def fn(params, batch, cand_sparse, item_blocks, centroids,
                   lstm_params, nb_ids, nb_sims):
                return clusd_candidate_retrieval(
                    cfg, spec_, params, batch, cand_sparse, item_blocks,
                    centroids, lstm_params, nb_ids, nb_sims)

            args = (params_abs, batch, cand_sparse, item_blocks, centroids,
                    lstm_abs, nb_ids, nb_sims)
            shardings = (params_sh, batch_sh, cand_sh, blocks_sh, repl,
                         jax.tree.map(lambda _: repl, lstm_abs), repl, repl)
    return fn, args, shardings, rules


# ---------------------------------------------------------------------------
# the paper's own system (clusd-msmarco): distributed serve step
# ---------------------------------------------------------------------------

def _clusd_cell(arch, cfg, shape, mesh, multi_pod):
    """CluSD serving at MS MARCO scale. impl='shard_map' is the optimized
    blocked/owner-sharded pipeline (core/distributed.py); impl='pjit' is the
    naive annotation-only port of the single-host retrieve (its all-gather
    of the 27 GB embedding store is the §Perf baseline finding)."""
    from repro.core import distributed as dist
    from repro.core.lstm import lstm_init
    from repro.core import features as feat_lib
    nm = mesh.shape["model"]
    N, cap, dim, V = cfg.n_clusters, cfg.cluster_cap, cfg.dim, cfg.vocab
    B = shape.batch or cfg.serve_batch
    Tq = 32
    m = min(cfg.n_neighbors, N - 1)
    p_shard = max(8, cfg.max_postings // nm)
    feat_dim = 1 + cfg.u_bins + 2 * cfg.v_bins
    lstm_abs = jax.eval_shape(
        lambda: lstm_init(jax.random.key(0), feat_dim, cfg.lstm_hidden))
    repl = NamedSharding(mesh, P())
    rules = {}
    args_common = {
        "centroids": (_sds((N, dim), jnp.float32), repl),
        "nb_ids": (_sds((N, m), jnp.int32), repl),
        "nb_sims": (_sds((N, m), jnp.float32), repl),
        "lstm": (lstm_abs, jax.tree.map(lambda _: repl, lstm_abs)),
        "qd": (_sds((B, dim), jnp.float32),
               named_sharding(mesh, "queries", None)),
        "qt": (_sds((B, Tq), jnp.int32),
               named_sharding(mesh, "queries", None)),
        "qw": (_sds((B, Tq), jnp.float32),
               named_sharding(mesh, "queries", None)),
    }
    if cfg.impl == "shard_map":
        serve = dist.make_serve_step(cfg, mesh, (N, cap, dim, V, p_shard, m),
                                     feat_dim)
        blocks = (_sds((N, cap, dim), jnp.float32),
                  named_sharding(mesh, "clusters", None, None))
        pd = (_sds((V, nm, p_shard), jnp.int32),
              NamedSharding(mesh, P(None, "model", None)))
        pw = (_sds((V, nm, p_shard), jnp.float32),
              NamedSharding(mesh, P(None, "model", None)))
        order = [blocks, pd, pw, args_common["centroids"],
                 args_common["nb_ids"], args_common["nb_sims"],
                 args_common["lstm"], args_common["qd"], args_common["qt"],
                 args_common["qw"]]
        fn = serve
    else:  # naive pjit port of the single-host pipeline
        from repro.core import clusd as cl
        from repro.core.sparse import SparseIndex
        from repro.core import bins as bins_lib

        bin_ids_const = bins_lib.rank_bin_ids(cfg.bins, cfg.k_sparse)

        def fn(emb, centroids, cluster_docs, doc_cluster, nb_ids, nb_sims,
               pd, pw, lstm, qd, qt, qw):
            index = cl.CluSDIndex(
                centroids=centroids,
                cluster_docs=cluster_docs, doc_cluster=doc_cluster,
                neighbor_ids=nb_ids, neighbor_sims=nb_sims,
                embeddings=emb, sparse_index=SparseIndex(pd, pw, emb.shape[0]),
                lstm_params=lstm, bin_ids=bin_ids_const)
            ids, scores, _ = cl.retrieve(cfg, index, qd, qt, qw,
                                         selector_params=lstm)
            return ids, scores

        D = N * cap
        emb = (_sds((D, dim), jnp.float32), named_sharding(mesh, "docs", None))
        cd_ = (_sds((N, cap), jnp.int32), repl)
        dc = (_sds((D,), jnp.int32), repl)
        pd = (_sds((V, cfg.max_postings), jnp.int32),
              NamedSharding(mesh, P(None, "model")))
        pw = (_sds((V, cfg.max_postings), jnp.float32),
              NamedSharding(mesh, P(None, "model")))
        order = [emb, args_common["centroids"], cd_, dc,
                 args_common["nb_ids"], args_common["nb_sims"],
                 pd, pw, args_common["lstm"], args_common["qd"],
                 args_common["qt"], args_common["qw"]]
    args = tuple(a for a, _ in order)
    shardings = tuple(s for _, s in order)
    return fn, args, shardings, rules


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def build_cell(arch, shape: ShapeSpec, mesh, multi_pod=False,
               overrides=None) -> Cell:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    skip = cell_is_skipped(cfg, shape)
    if skip:
        raise ValueError(f"skipped cell: {skip}")
    fam = cfg.family
    if fam == "lm":
        fn, args, shardings, rules = _lm_cell(arch, cfg, shape, mesh, multi_pod)
    elif fam == "gnn":
        fn, args, shardings, rules = _gnn_cell(arch, cfg, shape, mesh, multi_pod)
    elif fam == "recsys":
        fn, args, shardings, rules = _recsys_cell(arch, cfg, shape, mesh,
                                                  multi_pod)
    elif fam == "retrieval":
        fn, args, shardings, rules = _clusd_cell(arch, cfg, shape, mesh,
                                                 multi_pod)
    else:
        raise ValueError(fam)
    return Cell(arch=arch, shape=shape, fn=fn, args=args,
                in_shardings=shardings, rules=rules,
                meta={"family": fam, "mode": shape.mode})
