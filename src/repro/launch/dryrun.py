import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): AOT-lower + compile every
(architecture x input-shape) cell on the single-pod 16x16 mesh AND the
2x16x16 multi-pod mesh; record memory_analysis / cost_analysis / collective
bytes per cell into artifacts/dryrun/<cell>.json.

No arrays are allocated: inputs are ShapeDtypeStructs; results feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force] [--rules k=v,...] [--tag T]
"""

import argparse
import json
import time
import traceback

import jax

from repro.analysis.hlo import collective_bytes, hlo_cost
from repro.analysis.roofline import model_flops, roofline_terms
from repro.configs import cells, get_config
from repro.configs.shapes import shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models.sharding import rules_ctx

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def run_cell(arch, shape, multi_pod, extra_rules=None, save_hlo=False,
             overrides=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod,
                      overrides=overrides)
    rules = dict(cell.rules)
    if extra_rules:
        rules.update(extra_rules)
    # train: donate params+opt; decode: donate the KV cache (otherwise the
    # input and output caches double HBM)
    donate = {"train": (0, 1), "decode": (1,)}.get(cell.meta.get("mode"), ())
    t0 = time.time()
    with rules_ctx(rules, mesh=mesh, pod_dp=multi_pod):
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          donate_argnums=donate).lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, n_dev)
    parsed = hlo_cost(hlo)
    cost = {"flops": parsed["flops"], "bytes accessed": parsed["hbm_bytes"]}
    terms = roofline_terms(cost, coll, n_dev)
    terms["xla_flops_per_device_loopbody_once"] = float(
        xla_cost.get("flops", 0.0))
    cfg = get_config(arch)
    mf = model_flops(cfg, shape)
    hbm = {
        "argument_gb": mem.argument_size_in_bytes / 2**30,
        "output_gb": mem.output_size_in_bytes / 2**30,
        "temp_gb": mem.temp_size_in_bytes / 2**30,
        "code_gb": mem.generated_code_size_in_bytes / 2**30,
        "alias_gb": mem.alias_size_in_bytes / 2**30,
    }
    hbm["peak_gb"] = (hbm["argument_gb"] + hbm["output_gb"] + hbm["temp_gb"]
                      - hbm["alias_gb"])
    rec = {
        "arch": arch, "shape": shape.name, "mode": shape.mode,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": hbm,
        "fits_hbm_16g": hbm["peak_gb"] <= 16.0,
        "roofline": terms,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(terms["global_flops"], 1.0),
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in rules.items()},
    }
    if save_hlo:
        rec["hlo_path"] = os.path.join(ART_DIR, f"{_cell_key(arch, shape.name, multi_pod)}.hlo")
        with open(rec["hlo_path"], "w") as f:
            f.write(hlo)
    return rec


def _cell_key(arch, shape_name, multi_pod, tag=""):
    m = "multi" if multi_pod else "single"
    t = f"_{tag}" if tag else ""
    return f"{arch}__{shape_name}__{m}{t}".replace("/", "_")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--rules", default="",
                    help="logical=axis1+axis2|none,... sharding-rule overrides")
    ap.add_argument("--set", action="append", default=[],
                    help="arch-config overrides key=value (perf variants)")
    args = ap.parse_args()

    extra_rules = {}
    for kv in args.rules.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        extra_rules[k] = None if v == "none" else tuple(v.split("+"))
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = (int(v) if v.lstrip("-").isdigit()
                        else True if v == "true"
                        else False if v == "false" else v)

    os.makedirs(ART_DIR, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    todo = []
    if args.arch == "clusd-msmarco":
        # the paper's own system — extra cells beyond the 40 assigned
        for shape in shapes_for("retrieval").values():
            if not args.shape or shape.name == args.shape:
                todo.append((args.arch, shape, None))
    else:
        for arch, shape, skip in cells():
            if args.arch and arch != args.arch:
                continue
            if args.shape and shape.name != args.shape:
                continue
            todo.append((arch, shape, skip))

    summary = {"ok": 0, "skip": 0, "fail": 0}
    for arch, shape, skip in todo:
        for multi_pod in meshes:
            key = _cell_key(arch, shape.name, multi_pod, args.tag)
            path = os.path.join(ART_DIR, key + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {key}", flush=True)
                continue
            if skip:
                rec = {"arch": arch, "shape": shape.name,
                       "mesh": "2x16x16" if multi_pod else "16x16",
                       "status": "skip", "reason": skip}
                summary["skip"] += 1
            else:
                print(f"[run] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod, extra_rules,
                                   args.save_hlo, overrides)
                    summary["ok"] += 1
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s "
                          f"peak={rec['memory']['peak_gb']:.2f}GiB "
                          f"dom={r['dominant']} "
                          f"t=({r['compute_s']:.2e},{r['memory_s']:.2e},"
                          f"{r['collective_s']:.2e})s "
                          f"useful={rec['useful_flops_ratio']:.3f}", flush=True)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape.name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "status": "fail", "error": str(e)[-2000:],
                           "traceback": traceback.format_exc()[-4000:]}
                    summary["fail"] += 1
                    print(f"  FAIL {type(e).__name__}: {str(e)[:300]}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print("summary:", summary, flush=True)
    return 0 if summary["fail"] == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
