"""Sharded npz checkpointing with async save and elastic restore.

Layout:
  <dir>/step_<N>/manifest.json        # treedef paths, shapes, dtypes, shard map
  <dir>/step_<N>/shard_<i>.npz        # leaf arrays, chunked ~256MB per shard
  <dir>/step_<N>/.complete            # commit marker (atomic rename)

Restore accepts an optional sharding pytree so a checkpoint written on one
mesh can be loaded onto a different mesh (elastic scaling): arrays are
device_put with the *new* shardings. On real multi-host TPU each host would
write only its addressable shards; here the process owns all shards.
"""

import json
import os
import shutil
import threading

import jax
import numpy as np

_SHARD_BYTES = 256 * 1024 * 1024


def _leaf_paths(tree):
    paths = []
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        paths.append((jax.tree_util.keystr(path), leaf))
    return paths


def save_checkpoint(ckpt_dir, step, tree, *, async_save=False, extra=None):
    """Write `tree` under <ckpt_dir>/step_<step>. Returns join handle or None."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    # Pull to host before a potential async handoff so the caller can donate.
    leaves = _leaf_paths(tree)
    host = [(p, np.asarray(x)) for p, x in leaves]

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": int(step), "leaves": [], "extra": extra or {}}
        shard, shard_bytes, shard_id = {}, 0, 0

        def flush():
            nonlocal shard, shard_bytes, shard_id
            if shard:
                np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
                shard, shard_bytes = {}, 0
                shard_id += 1

        for i, (path, arr) in enumerate(host):
            key = f"leaf_{i}"
            manifest["leaves"].append({
                "path": path, "key": key, "shard": shard_id,
                "shape": list(arr.shape), "dtype": str(arr.dtype)})
            shard[key] = arr
            shard_bytes += arr.nbytes
            if shard_bytes >= _SHARD_BYTES:
                flush()
        flush()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        open(os.path.join(tmp, ".complete"), "w").close()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, ".complete")):
                steps.append(int(d.split("_", 1)[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, step, target_tree, shardings=None):
    """Restore into the structure of target_tree (elastic: new shardings ok)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_shard = {}
    for leaf in manifest["leaves"]:
        by_shard.setdefault(leaf["shard"], []).append(leaf)
    arrays = {}
    for shard_id, leaves in by_shard.items():
        with np.load(os.path.join(d, f"shard_{shard_id}.npz")) as z:
            for leaf in leaves:
                arrays[leaf["path"]] = z[leaf["key"]]
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (treedef.flatten_up_to(shardings) if shardings is not None
                  else [None] * len(flat))
    out = []
    for (path, ref), shd in zip(flat, shard_flat):
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key].astype(ref.dtype) if hasattr(ref, "dtype") else arrays[key]
        out.append(jax.device_put(arr, shd) if shd is not None else arr)
    return treedef.unflatten(out), manifest.get("extra", {})


class CheckpointManager:
    """Keeps at most `keep` checkpoints; async save with join-on-next-save."""

    def __init__(self, ckpt_dir, keep=3, async_save=True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._pending = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step, tree, extra=None):
        if self._pending is not None:
            self._pending.join()
        self._gc()  # previous save is committed now
        self._pending = save_checkpoint(
            self.dir, step, tree, async_save=self.async_save, extra=extra)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_", 1)[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
            and os.path.exists(os.path.join(self.dir, d, ".complete")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    def restore_latest(self, target_tree, shardings=None):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None, None
        tree, extra = restore_checkpoint(self.dir, step, target_tree, shardings)
        return step, tree, extra
