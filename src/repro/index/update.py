"""Incremental index mutation: upsert/delete deltas, tombstones, atomic
generations, and compaction.

The PR-2/PR-3 index is build-once: any corpus change forced a full offline
rebuild. This module applies document **upserts** (new or replaced docs)
and **deletes** to a built index by touching only the affected shards:

  * upserts are assigned to their nearest *existing* centroid with free
    capacity (same greedy next-nearest spill as the offline
    `build_cluster_table`); only shards whose cluster membership changed
    are re-packed (v1) or re-encoded against the existing PQ codebooks
    (v2). Existing documents' vectors/codes are read back from the
    previous generation's shard files — no external embedding source is
    needed to apply a delta.
  * deletes set a per-slot **tombstone bitmap** (`tombstones` array in the
    manifest); the sharded stores mask tombstoned slots at fetch time, so
    a delete rewrites zero shard bytes.
  * postings rows containing dropped docs (and rows gaining upserted
    terms) are re-sorted in impact order with the exact
    `SparseIndex.build` comparator, so sparse retrieval never returns a
    deleted doc.
  * when a shard's upserts overflow their nearest clusters past a
    threshold, the shard is **re-clustered locally**: a deterministic
    Lloyd's refinement (`core.kmeans.lloyd_refine`) over just that
    shard's members, initialized from its current centroids. The
    neighbor graph is recomputed whenever any centroid moved (cheap —
    one (N, dim) @ (dim, N) on the host; rows for untouched clusters can
    change too when a neighboring centroid moves, so recomputing only
    "touched" rows would be wrong).

Commits are **atomic generations** (`write_index_delta`): new artifact
files are staged under `<index_dir>/.stage-g<G>` with generation-suffixed
names, moved into place (never clobbering an existing file), the current
manifest is archived to `manifests/manifest.g<g>.json`, and the new
`generation`-stamped manifest atomically replaces `manifest.json`. A
reader racing the commit sees either generation, never a torn index;
`IndexReader.refresh()` + `RetrievalEngine.reload_index()` let a live
server hop generations between batches with no downtime.

`compact_index` folds tombstones + delta shards back into a clean
single-generation layout. Invariant (tests/test_index_update.py): any
sequence of deltas followed by compaction produces byte-identical (v1) /
code-identical (v2) shards and arrays to `write_index` called on the
same logical state applied in memory (`apply_delta_to_index`).

Known, documented divergences from a true from-scratch rebuild:
  * centroids are the *incrementally maintained* ones — a rebuild would
    re-run global k-means and land on a different (not better) clustering.
    Parity is therefore defined against a rebuild *of the same logical
    state*, which is what compaction produces.
  * a posting entry truncated out of a full row by an earlier build
    cannot be resurrected when a later delete frees space (the index does
    not store full doc term lists); `truncated_postings` tracks the loss.
  * applying a delta to a v1 index drops its *optional* full PQ side
    artifacts (their per-doc codes would go stale); v2 code shards — the
    load-bearing PQ — are incrementally re-encoded instead.
"""

import copy
import dataclasses
import os
import shutil
import time

import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as km
from repro.core import quant as quant_lib
from repro.core.clusd import CluSDIndex
from repro.core.sparse import SparseIndex
from repro.index import builder as builder_lib
from repro.index import format as fmt
from repro.index.reader import IndexReader
from repro.obs import NOOP_TRACE


@dataclasses.dataclass
class IndexDelta:
    """One batch of corpus mutations.

    upsert_ids: (U,) int — ids < n_docs replace that document (its old
      vector/terms are dropped first); ids >= n_docs append and must form
      the contiguous range [n_docs, n_docs + n_new).
    upsert_embeddings: (U, dim) float32 vectors for the upserted docs.
    upsert_terms/weights: (U, T) int32 (-1 pad) / float32 sparse terms.
    delete_ids: (Dd,) int — must be live (not already deleted/unknown).
    format_version: None = apply to whatever format the target index has;
      an explicit version is validated against the index and a mismatch
      (e.g. a v2 delta against a v1 index) raises IndexFormatError.
    """

    upsert_ids: np.ndarray
    upsert_embeddings: np.ndarray
    upsert_terms: np.ndarray
    upsert_weights: np.ndarray
    delete_ids: np.ndarray
    format_version: int = None

    def __post_init__(self):
        self.upsert_ids = np.asarray(self.upsert_ids, np.int64).reshape(-1)
        self.upsert_embeddings = np.asarray(self.upsert_embeddings,
                                            np.float32)
        self.upsert_terms = np.asarray(self.upsert_terms, np.int32)
        self.upsert_weights = np.asarray(self.upsert_weights, np.float32)
        self.delete_ids = np.asarray(self.delete_ids, np.int64).reshape(-1)
        if self.upsert_embeddings.shape[0] != len(self.upsert_ids):
            raise ValueError("upsert_embeddings rows != upsert_ids")
        if self.upsert_terms.shape[:1] != (len(self.upsert_ids),) or \
                self.upsert_weights.shape != self.upsert_terms.shape:
            raise ValueError(
                f"upsert_terms {self.upsert_terms.shape} / upsert_weights "
                f"{self.upsert_weights.shape} must both be "
                f"({len(self.upsert_ids)}, T)")
        if len(np.unique(self.upsert_ids)) != len(self.upsert_ids):
            raise ValueError("duplicate upsert ids in one delta")

    @property
    def n_upserts(self):
        return int(len(self.upsert_ids))

    @property
    def n_deletes(self):
        return int(len(self.delete_ids))


# ---------------------------------------------------------------------------
# canonical delta policy (shared by the in-memory and on-disk paths)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _State:
    """Canonical (tombstone-free) logical index state on the host."""

    centroids: np.ndarray       # (N, dim) f32
    members: list               # per-cluster member id lists, slot order
    doc_cluster: np.ndarray     # (D,) i32, -1 = deleted
    pd: np.ndarray              # (V, P) padded postings (docs)
    pw: np.ndarray              # (V, P) padded postings (weights)
    neighbor_ids: np.ndarray    # (N, m) i32
    neighbor_sims: np.ndarray   # (N, m) f32
    cap: int

    @property
    def n_docs(self):
        return int(self.doc_cluster.shape[0])

    def cluster_docs(self):
        cd = np.full((len(self.members), self.cap), -1, np.int32)
        for c, mem in enumerate(self.members):
            cd[c, :len(mem)] = mem
        return cd


def canonical_members(cluster_docs, tombstones=None):
    """Per-cluster live member lists in slot order (tombstoned and padded
    slots dropped) — the canonical view both delta application and
    compaction operate on."""
    cd = np.asarray(cluster_docs)
    live = cd >= 0
    if tombstones is not None:
        live &= np.asarray(tombstones) == 0
    return [cd[c][live[c]].tolist() for c in range(cd.shape[0])]


def _update_postings(pd, pw, drop_ids, up_ids, up_terms, up_weights):
    """Remove dropped docs and add upserted docs' terms, re-sorting each
    touched row with the exact SparseIndex.build comparator (weight desc,
    doc id desc) and truncating to the padded width. Returns
    (pd, pw, n_truncated)."""
    pd, pw = pd.copy(), pw.copy()
    V, P = pd.shape
    adds = {}
    for i, d in enumerate(np.asarray(up_ids)):
        for t, w in zip(up_terms[i], up_weights[i]):
            if t >= 0 and w > 0:
                adds.setdefault(int(t), []).append((int(d), float(w)))
    touched = set(adds)
    dropmask = np.zeros(pd.shape, bool)
    if len(drop_ids):
        dropmask = np.isin(pd, np.asarray(sorted(set(map(int, drop_ids))),
                                          np.int64))
        touched.update(np.flatnonzero(dropmask.any(axis=1)).tolist())
    keepmask = (pd >= 0) & ~dropmask
    truncated = 0
    for t in sorted(touched):
        d = pd[t][keepmask[t]].astype(np.int64)
        w = pw[t][keepmask[t]].astype(np.float64)
        if t in adds:
            ad = np.asarray([x[0] for x in adds[t]], np.int64)
            aw = np.asarray([x[1] for x in adds[t]], np.float64)
            d, w = np.concatenate([d, ad]), np.concatenate([w, aw])
        # weight desc, ties doc-id desc == sorted(reverse=True) over
        # (w, d) tuples, i.e. exactly SparseIndex.build's impact order
        order = np.lexsort((-d, -w))[:P]
        truncated += max(0, len(d) - P)
        pd[t], pw[t] = -1, 0.0
        pd[t, :len(order)] = d[order]
        pw[t, :len(order)] = w[order]
    return pd, pw, truncated


def _apply_delta_state(state: _State, delta: IndexDelta, get_vec, ranges, *,
                       recluster_overflow=0.5, recluster_min_overflow=4,
                       lloyd_iters=4):
    """Apply `delta` to the canonical state in place. Deterministic.

    get_vec(doc_ids) -> (n, dim) float32 — vectors for EXISTING docs
    (post-replacement), used only by local re-clustering. The on-disk path
    feeds it from the previous generation's shard files; the in-memory
    path from the merged embedding matrix.

    Returns a report dict; `rewrite_clusters` is the set whose member list
    changed by insertion or re-clustering (deletes alone never force a
    shard rewrite — they become tombstones)."""
    n_clusters, cap = len(state.members), state.cap
    shard_of = np.zeros(n_clusters, np.int64)
    for s, (lo, hi) in enumerate(ranges):
        shard_of[lo:hi] = s
    D0 = state.n_docs
    new_ids = np.sort(delta.upsert_ids[delta.upsert_ids >= D0])
    if len(new_ids) and not np.array_equal(
            new_ids, np.arange(D0, D0 + len(new_ids))):
        raise ValueError(f"appended ids must be contiguous from {D0}, "
                         f"got {new_ids.tolist()}")
    if np.any(delta.delete_ids >= D0) or np.any(delta.delete_ids < 0):
        raise ValueError("delete id out of range")

    # -- drops: deletes + the old rows of replaced docs -------------------
    replaced = [int(d) for d in delta.upsert_ids
                if d < D0 and state.doc_cluster[d] >= 0]
    drops = [int(d) for d in delta.delete_ids] + replaced
    if len(set(drops)) != len(drops):
        raise ValueError("a doc appears in both delete_ids and upsert_ids "
                         "(replace already implies delete)")
    delete_only_clusters = set()
    for d in delta.delete_ids:
        c = int(state.doc_cluster[d])
        if c < 0:
            raise ValueError(f"delete of non-live doc {int(d)}")
        state.members[c].remove(int(d))
        state.doc_cluster[d] = -1
        delete_only_clusters.add(c)
    for d in replaced:
        c = int(state.doc_cluster[d])
        state.members[c].remove(d)
        state.doc_cluster[d] = -1
        delete_only_clusters.add(c)
    if len(new_ids):
        state.doc_cluster = np.concatenate(
            [state.doc_cluster,
             np.full(len(new_ids), -1, np.int32)]).astype(np.int32)

    # -- inserts: nearest existing centroid with free capacity ------------
    rewrite_clusters = set()
    n_shards = len(ranges)
    overflow_by_shard = np.zeros(n_shards, np.int64)
    targeted_by_shard = np.zeros(n_shards, np.int64)
    n_overflow = 0
    if delta.n_upserts:
        X = delta.upsert_embeddings
        C = state.centroids
        d2 = (X * X).sum(1)[:, None] + (C * C).sum(1)[None] - 2.0 * X @ C.T
        pref = np.argsort(d2, axis=1, kind="stable")
        for i, d in enumerate(delta.upsert_ids):
            targeted_by_shard[shard_of[pref[i, 0]]] += 1
            for c in pref[i]:
                if len(state.members[c]) < cap:
                    state.members[c].append(int(d))
                    state.doc_cluster[d] = c
                    rewrite_clusters.add(int(c))
                    if c != pref[i, 0]:
                        n_overflow += 1
                        overflow_by_shard[shard_of[pref[i, 0]]] += 1
                    break
            else:
                raise RuntimeError("total index capacity exceeded — "
                                   "compact or rebuild with more clusters")

    # -- local re-clustering of overflowing shards -------------------------
    reclustered = []
    for s, (lo, hi) in enumerate(ranges):
        if targeted_by_shard[s] == 0:
            continue
        frac = overflow_by_shard[s] / targeted_by_shard[s]
        if (overflow_by_shard[s] < recluster_min_overflow
                or frac < recluster_overflow):
            continue
        docs = [d for c in range(lo, hi) for d in state.members[c]]
        if not docs:
            continue
        X = np.asarray(get_vec(np.asarray(docs, np.int64)), np.float32)
        C_new, assign = km.lloyd_refine(X, state.centroids[lo:hi],
                                        iters=lloyd_iters)
        table, assign = km.build_cluster_table(assign, hi - lo, cap, X, C_new)
        table = np.asarray(table)
        for j in range(hi - lo):
            mem = [docs[i] for i in table[j] if i >= 0]
            state.members[lo + j] = mem
            for d in mem:
                state.doc_cluster[d] = lo + j
        state.centroids[lo:hi] = C_new
        rewrite_clusters.update(range(lo, hi))
        reclustered.append(s)

    if reclustered:
        m = state.neighbor_ids.shape[1]
        nb_ids, nb_sims = km.neighbor_graph(jnp.asarray(state.centroids), m)
        state.neighbor_ids = np.asarray(nb_ids)
        state.neighbor_sims = np.asarray(nb_sims)

    # -- postings ----------------------------------------------------------
    state.pd, state.pw, truncated = _update_postings(
        state.pd, state.pw, drops, delta.upsert_ids, delta.upsert_terms,
        delta.upsert_weights)

    return {
        "n_upserts": delta.n_upserts,
        "n_deletes": delta.n_deletes,
        "n_replaced": len(replaced),
        "n_appended": int(len(new_ids)),
        "overflow_placements": int(n_overflow),
        "rewrite_clusters": rewrite_clusters,
        "delete_only_clusters": delete_only_clusters - rewrite_clusters,
        "reclustered_shards": reclustered,
        "truncated_postings_delta": int(truncated),
    }


# ---------------------------------------------------------------------------
# in-memory application (reference semantics + convenience API)
# ---------------------------------------------------------------------------

def apply_delta_to_index(cfg, index, embeddings, delta: IndexDelta, *,
                         n_shards, policy_vectors=None,
                         recluster_overflow=0.5, recluster_min_overflow=4,
                         lloyd_iters=4):
    """Apply a delta to an in-memory CluSDIndex + embedding matrix.

    This is the reference implementation of the delta semantics: the
    on-disk path (`write_index_delta` ... `compact_index`) must produce
    byte-identical (v1) / code-identical (v2) artifacts to
    `write_index(cfg, apply_delta_to_index(...))` — the invariant the
    property suite enforces.

    `n_shards` must match the target index's shard count (re-clustering
    decisions are per shard). `policy_vectors` optionally overrides the
    vectors re-clustering sees (e.g. PQ-decoded vectors, to mirror a v2
    on-disk index that only stores codes). Returns
    (new_index, new_embeddings, report).
    """
    D0 = int(index.doc_cluster.shape[0])
    new_ids = delta.upsert_ids[delta.upsert_ids >= D0]
    emb = np.asarray(embeddings, np.float32)
    emb_new = np.concatenate(
        [emb, np.zeros((len(new_ids), emb.shape[1]), np.float32)])
    emb_new[delta.upsert_ids] = delta.upsert_embeddings

    pv = emb_new if policy_vectors is None \
        else np.asarray(policy_vectors, np.float32)
    state = _State(
        centroids=np.asarray(index.centroids, np.float32).copy(),
        members=canonical_members(index.cluster_docs),
        doc_cluster=np.asarray(index.doc_cluster, np.int32).copy(),
        pd=np.asarray(index.sparse_index.postings_docs).copy(),
        pw=np.asarray(index.sparse_index.postings_weights).copy(),
        neighbor_ids=np.asarray(index.neighbor_ids),
        neighbor_sims=np.asarray(index.neighbor_sims),
        cap=int(np.asarray(index.cluster_docs).shape[1]))
    ranges = builder_lib.shard_ranges(len(state.members), n_shards)
    report = _apply_delta_state(
        state, delta, lambda ids: pv[ids], ranges,
        recluster_overflow=recluster_overflow,
        recluster_min_overflow=recluster_min_overflow,
        lloyd_iters=lloyd_iters)

    sp = SparseIndex(jnp.asarray(state.pd), jnp.asarray(state.pw),
                     state.n_docs)
    sp.truncated_postings = (
        int(getattr(index.sparse_index, "truncated_postings", 0))
        + report["truncated_postings_delta"])
    quantizer = index.quantizer
    if quantizer is not None:
        # re-encode upserted rows against the EXISTING codebooks — delta
        # application never retrains PQ (that is a compact/rebuild decision)
        codes = np.asarray(quantizer.codes)
        codes = np.concatenate(
            [codes, np.zeros((len(new_ids), codes.shape[1]), codes.dtype)])
        codes[delta.upsert_ids] = np.asarray(quant_lib.pq_encode(
            quantizer.codebooks, delta.upsert_embeddings,
            quantizer.rotation), codes.dtype)
        quantizer = quant_lib.PQ(quantizer.codebooks, jnp.asarray(codes),
                                 quantizer.rotation, quantizer.nsub)
    new_index = CluSDIndex(
        centroids=jnp.asarray(state.centroids),
        cluster_docs=jnp.asarray(state.cluster_docs()),
        doc_cluster=jnp.asarray(state.doc_cluster),
        neighbor_ids=jnp.asarray(state.neighbor_ids),
        neighbor_sims=jnp.asarray(state.neighbor_sims),
        embeddings=None, sparse_index=sp, lstm_params=index.lstm_params,
        quantizer=quantizer, bin_ids=index.bin_ids)
    return new_index, emb_new, report


# ---------------------------------------------------------------------------
# on-disk sources: read existing vectors/codes back from shard files
# ---------------------------------------------------------------------------

class _ShardRecords:
    """Random access to the previous generation's per-cluster records
    ((cap, dim) float blocks for v1, (cap, nsub) uint8 codes for v2),
    located through the PRE-delta cluster_docs/doc_cluster snapshot.
    Reads whole cluster records and caches them, so repeated slot lookups
    within a cluster cost one read."""

    def __init__(self, index_dir, manifest):
        g = manifest["geometry"]
        self.is_pq = manifest["format_version"] == fmt.FORMAT_VERSION_PQ
        cap = int(g["cap"])
        if self.is_pq:
            shape, dtype = (cap, int(g["nsub"])), np.uint8
        else:
            shape, dtype = (cap, int(g["dim"])), np.dtype(g["block_dtype"])
        self.record_shape = shape
        self._lo, self._hi, self._mms = [], [], []
        for s in manifest["block_shards"]:
            lo, hi = int(s["cluster_lo"]), int(s["cluster_hi"])
            self._lo.append(lo)
            self._hi.append(hi)
            self._mms.append(np.memmap(
                os.path.join(index_dir, s["file"]), dtype=dtype, mode="r",
                shape=(hi - lo,) + shape))
        self._hi = np.asarray(self._hi, np.int64)
        self._cache = {}

    def cluster_record(self, c):
        rec = self._cache.get(c)
        if rec is None:
            s = int(np.searchsorted(self._hi, c, side="right"))
            rec = np.array(self._mms[s][c - self._lo[s]])
            self._cache[c] = rec
        return rec


class _DeltaRowSource:
    """Row-indexable (D', width) view over the updated corpus: rows for
    upserted docs come from the delta; every other row is read back from
    the previous generation's shards. Exactly the interface
    `pack_blocks` / `_write_code_blocks` gather from."""

    def __init__(self, records: _ShardRecords, cd_old, doc_cluster_old,
                 delta_rows, n_docs, width, dtype):
        self._records = records
        self._cd_old = cd_old
        self._dc_old = doc_cluster_old
        self._delta = delta_rows                 # {doc id -> (width,) row}
        self.shape = (int(n_docs), int(width))
        self.dtype = np.dtype(dtype)
        self._slots = {}

    def _old_row(self, d):
        c = int(self._dc_old[d])
        slot = self._slots.get(d)
        if slot is None:
            slot = int(np.flatnonzero(self._cd_old[c] == d)[0])
            self._slots[d] = slot
        return self._records.cluster_record(c)[slot]

    def __getitem__(self, ids):
        ids = np.asarray(ids, np.int64).reshape(-1)
        out = np.empty((len(ids), self.shape[1]), self.dtype)
        for i, d in enumerate(ids):
            row = self._delta.get(int(d))
            out[i] = self._old_row(int(d)) if row is None else row
        return out


class _ShapeOnly:
    """Stands in for the embedding matrix when only its shape is needed
    (v2 writes: codes are given, floats never touched)."""

    def __init__(self, shape):
        self.shape = tuple(int(x) for x in shape)


# ---------------------------------------------------------------------------
# write_index_delta: the incremental commit
# ---------------------------------------------------------------------------

def _load_padded_postings(reader: IndexReader, max_postings):
    """Current postings as padded (V, max_postings) host arrays — v1 stores
    them padded already; v2 CSR is re-expanded to the build-time width so
    truncation behaves identically to the in-memory reference."""
    if not reader.is_pq:
        return (np.asarray(reader.array("sparse_postings_docs")).copy(),
                np.asarray(reader.array("sparse_postings_weights")).copy())
    return builder_lib.postings_from_csr(
        reader.array("sparse_postings_data"),
        reader.array("sparse_postings_wdata"),
        reader.array("sparse_postings_indptr"), min_width=max_postings)


def write_index_delta(index_dir, delta: IndexDelta, *, verify="size",
                      recluster_overflow=0.5, recluster_min_overflow=4,
                      lloyd_iters=4, tracer=None):
    """Apply `delta` to the index at `index_dir` as a new atomic
    generation. Only shards whose cluster membership changed are
    rewritten; deletes become tombstones; the previous generation's files
    and manifest remain readable. Returns a report dict (generation,
    shards/bytes rewritten, ...). `tracer` (repro.obs.Tracer) records one
    `write_index_delta` trace with a span per phase, bytes annotated.
    """
    tr = tracer.trace("write_index_delta", n_upserts=delta.n_upserts,
                      n_deletes=len(delta.delete_ids)) \
        if tracer is not None else NOOP_TRACE
    t0 = time.perf_counter()
    sp_load = tr.span("load_state")
    manifest = fmt.load_manifest(index_dir)
    fmt.verify_files(index_dir, manifest, level=verify)
    fv = manifest["format_version"]
    if delta.format_version is not None and delta.format_version != fv:
        raise fmt.IndexFormatError(
            f"delta targets format v{delta.format_version} but the index "
            f"at {index_dir} is format v{fv}; re-create the delta for the "
            f"index's format (or compact/rebuild the index first)")
    reader = IndexReader(index_dir, manifest)
    cfg = reader.config()
    g = reader.generation
    G = g + 1
    geom = reader.geometry
    v2 = fv == fmt.FORMAT_VERSION_PQ
    dim, cap = int(geom["dim"]), int(geom["cap"])
    if delta.n_upserts and delta.upsert_embeddings.shape[1] != dim:
        raise ValueError(f"delta dim {delta.upsert_embeddings.shape[1]} "
                         f"!= index dim {dim}")

    # pre-delta snapshot (slot layout incl. tombstone holes, for locating
    # existing docs' bytes) + canonical state the policy operates on
    cd_old = np.asarray(reader.array("cluster_docs")).copy()
    tomb_old = reader.tombstones()
    if tomb_old is None:
        tomb_old = np.zeros(cd_old.shape, np.uint8)
    dc_old = np.asarray(reader.array("doc_cluster")).copy()
    pd, pw = _load_padded_postings(reader, cfg.max_postings)
    state = _State(
        centroids=np.asarray(reader.array("centroids"), np.float32).copy(),
        members=canonical_members(cd_old, tomb_old),
        doc_cluster=dc_old.copy(),
        pd=pd, pw=pw,
        neighbor_ids=np.asarray(reader.array("neighbor_ids")).copy(),
        neighbor_sims=np.asarray(reader.array("neighbor_sims")).copy(),
        cap=cap)
    ranges = [(int(s["cluster_lo"]), int(s["cluster_hi"]))
              for s in manifest["block_shards"]]

    records = _ShardRecords(index_dir, manifest)
    delta_vec = {int(d): delta.upsert_embeddings[i]
                 for i, d in enumerate(delta.upsert_ids)}
    if v2:
        codebooks = reader._pq_array("codebooks")
        rotation = reader._pq_array("rotation")
        delta_codes_arr = np.asarray(quant_lib.pq_encode(
            jnp.asarray(codebooks), delta.upsert_embeddings,
            None if rotation is None else jnp.asarray(rotation)), np.uint8) \
            if delta.n_upserts else np.zeros((0, int(geom["nsub"])), np.uint8)
        delta_codes = {int(d): delta_codes_arr[i]
                       for i, d in enumerate(delta.upsert_ids)}
    sp_load.end()

    def get_vec(ids):
        """Policy vectors: what the index stores (exact floats for v1,
        PQ-decoded floats for v2) with delta rows overriding."""
        out = np.empty((len(ids), dim), np.float32)
        for i, d in enumerate(np.asarray(ids, np.int64)):
            row = delta_vec.get(int(d))
            if row is not None:
                out[i] = row
            elif v2:
                c = int(dc_old[d])
                slot = int(np.flatnonzero(cd_old[c] == d)[0])
                code = records.cluster_record(c)[slot]
                out[i] = quant_lib.decode_code_blocks(
                    codebooks, code[None, :], rotation)[0]
            else:
                c = int(dc_old[d])
                slot = int(np.flatnonzero(cd_old[c] == d)[0])
                out[i] = records.cluster_record(c)[slot]
        return out

    with tr.span("apply_delta"):
        report = _apply_delta_state(
            state, delta, get_vec, ranges,
            recluster_overflow=recluster_overflow,
            recluster_min_overflow=recluster_min_overflow,
            lloyd_iters=lloyd_iters)

    # -- new stored layout -------------------------------------------------
    shard_of = np.zeros(cd_old.shape[0], np.int64)
    for s, (lo, hi) in enumerate(ranges):
        shard_of[lo:hi] = s
    rewrite_shards = sorted({int(shard_of[c])
                             for c in report["rewrite_clusters"]})
    rewrite_set = set(rewrite_shards)
    cd_new, tomb_new = cd_old.copy(), tomb_old.copy()
    canon = state.cluster_docs()
    for s in rewrite_shards:
        lo, hi = ranges[s]
        cd_new[lo:hi] = canon[lo:hi]
        tomb_new[lo:hi] = 0
    for d in [int(x) for x in delta.delete_ids] + [
            int(x) for x in delta.upsert_ids
            if x < len(dc_old) and dc_old[x] >= 0]:
        c = int(dc_old[d])
        if int(shard_of[c]) in rewrite_set:
            continue                      # shard rewritten canonically
        slot = int(np.flatnonzero(cd_old[c] == d)[0])
        tomb_new[c, slot] = 1

    # -- stage new artifact files -----------------------------------------
    stage = os.path.join(index_dir, f".stage-g{G}")
    if os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(os.path.join(stage, "blocks"))
    staged = []                                   # relpaths written

    block_dtype = np.dtype(geom["block_dtype"])
    D_new = state.n_docs
    block_shards = [dict(s) for s in manifest["block_shards"]]
    bytes_rewritten = 0
    sp_stage = tr.span("stage_blocks", n_shards=len(rewrite_shards))
    for s in rewrite_shards:
        lo, hi = ranges[s]
        if v2:
            rel = os.path.join("blocks", f"shard_{s:05d}.g{G}.codes.bin")
            source = _DeltaRowSource(records, cd_old, dc_old, delta_codes,
                                     D_new, geom["nsub"], np.uint8)
            builder_lib._write_code_blocks(os.path.join(stage, rel), source,
                                           cd_new[lo:hi])
        else:
            rel = os.path.join("blocks", f"shard_{s:05d}.g{G}.bin")
            source = _DeltaRowSource(records, cd_old, dc_old, delta_vec,
                                     D_new, dim, np.float32)
            builder_lib._write_float_blocks(
                os.path.join(stage, rel), source, cd_new[lo:hi], block_dtype,
                builder_lib.DEFAULT_CHUNK_DOCS)
        block_shards[s]["file"] = rel
        bytes_rewritten += os.path.getsize(os.path.join(stage, rel))
        staged.append(rel)
    sp_stage.annotate(bytes_rewritten=int(bytes_rewritten)).end()

    sp_arrays = tr.span("stage_arrays")
    arrays = dict(manifest["arrays"])
    new_arrays = {
        "cluster_docs": cd_new,
        "doc_cluster": state.doc_cluster,
        "tombstones": tomb_new,
        "centroids": state.centroids,
        "neighbor_ids": state.neighbor_ids,
        "neighbor_sims": state.neighbor_sims,
    }
    if not report["reclustered_shards"]:
        for name in ("centroids", "neighbor_ids", "neighbor_sims"):
            new_arrays.pop(name)          # unchanged: carry by reference
    if v2:
        data, wdata, indptr = builder_lib.postings_csr(state.pd, state.pw)
        new_arrays.update(sparse_postings_data=data,
                          sparse_postings_wdata=wdata,
                          sparse_postings_indptr=indptr)
    else:
        new_arrays.update(sparse_postings_docs=state.pd,
                          sparse_postings_weights=state.pw)
    for name, arr in new_arrays.items():
        rel = f"{name}.g{G}.npy"
        np.save(os.path.join(stage, rel),
                np.asarray(arr, builder_lib._ARRAY_DTYPES[name]))
        arrays[name] = rel
        staged.append(rel)
    sp_arrays.end()

    # -- manifest for generation G ----------------------------------------
    new_manifest = copy.deepcopy(manifest)
    new_manifest["generation"] = G
    new_manifest["parent_generation"] = g
    new_manifest["arrays"] = arrays
    new_manifest["block_shards"] = block_shards
    new_manifest["geometry"] = dict(geom, n_docs=D_new)
    if not v2:
        new_manifest["pq"] = None         # v1 side PQ codes would be stale
    live_fill = np.where(tomb_new > 0, -1, cd_new)
    old_stats = manifest.get("stats", {})
    new_manifest["stats"] = dict(
        old_stats,
        cluster_fill=builder_lib._cluster_fill_stats(live_fill),
        truncated_postings=int(old_stats.get("truncated_postings", 0))
        + report["truncated_postings_delta"])

    files = {}
    referenced = set(arrays.values()) | {s["file"] for s in block_shards}
    if v2 and new_manifest.get("pq"):
        referenced |= set(new_manifest["pq"]["arrays"].values())
    lstm_dir = (new_manifest.get("lstm") or {}).get("dir")
    for rel, entry in manifest["files"].items():
        if rel in referenced or (lstm_dir and rel.startswith(lstm_dir + "/")):
            files[rel] = entry
    for rel in staged:
        full = os.path.join(stage, rel)
        files[rel] = {"bytes": os.path.getsize(full),
                      "sha256": fmt.file_sha256(full)}
    new_manifest["files"] = files
    new_manifest["total_bytes"] = sum(e["bytes"] for e in files.values())
    shard_bytes_total = sum(files[s["file"]]["bytes"] for s in block_shards)
    wall_s = time.perf_counter() - t0
    new_manifest["update_stats"] = {
        "n_upserts": report["n_upserts"],
        "n_deletes": report["n_deletes"],
        "n_replaced": report["n_replaced"],
        "n_appended": report["n_appended"],
        "overflow_placements": report["overflow_placements"],
        "shards_rewritten": rewrite_shards,
        "reclustered_shards": report["reclustered_shards"],
        "bytes_rewritten": int(bytes_rewritten),
        "shard_bytes_total": int(shard_bytes_total),
        "wall_s": round(wall_s, 3),
    }

    # -- commit: move staged files into place, archive, flip manifest ------
    with tr.span("commit"):
        fmt.commit_generation(index_dir, stage, staged, manifest,
                              new_manifest)
    tr.finish(generation=G, bytes_rewritten=int(bytes_rewritten))

    return {
        "generation": G,
        "parent_generation": g,
        "n_shards": len(ranges),
        "shards_rewritten": rewrite_shards,
        "reclustered_shards": report["reclustered_shards"],
        "n_upserts": report["n_upserts"],
        "n_deletes": report["n_deletes"],
        "n_replaced": report["n_replaced"],
        "n_appended": report["n_appended"],
        "overflow_placements": report["overflow_placements"],
        "bytes_rewritten": int(bytes_rewritten),
        "shard_bytes_total": int(shard_bytes_total),
        "bytes_rewritten_frac": round(
            bytes_rewritten / max(1, shard_bytes_total), 4),
        "truncated_postings_delta": report["truncated_postings_delta"],
        "wall_s": round(wall_s, 3),
    }


# ---------------------------------------------------------------------------
# compaction: fold generations back into a clean single-generation layout
# ---------------------------------------------------------------------------

def _suffix_rel(rel, G):
    """Generation-suffix an artifact relpath the way delta commits do:
    top-level and blocks/ files get `.g<G>` before their extension
    (`centroids.g3.npy`, `blocks/shard_00000.g3.codes.bin`); files under
    an artifact tree (lstm/, pq/) suffix the top-level directory
    (`lstm.g3/step_0/...`) so the whole tree moves as one namespace."""
    d, base = os.path.split(rel)
    if d in ("", "blocks"):
        stem, dot, ext = base.partition(".")
        return os.path.join(d, f"{stem}.g{G}.{ext}" if dot
                            else f"{stem}.g{G}")
    top, rest = rel.split(os.sep, 1)
    return os.path.join(f"{top}.g{G}", rest)


def _commit_compacted_in_place(index_dir, tmp_dir, manifest):
    """Fold a fully-written compacted layout (at `tmp_dir`) into the live
    `index_dir` with the same no-torn-state guarantee as delta commits:
    artifacts move in under fresh generation-suffixed names (never
    clobbering anything the current manifest references), the new
    manifest atomically replaces manifest.json, and only then are the
    old generations' files and the manifest history garbage-collected.
    There is never a moment without a valid current manifest — unlike a
    directory-swap commit, a reader racing the compaction always sees
    the old or the new generation."""
    G = fmt.manifest_generation(manifest)
    mapping = {rel: _suffix_rel(rel, G) for rel in manifest["files"]}
    for rel, new_rel in mapping.items():
        dst = os.path.join(index_dir, new_rel)
        os.makedirs(os.path.dirname(dst) or index_dir, exist_ok=True)
        os.replace(os.path.join(tmp_dir, rel), dst)
    manifest["arrays"] = {k: mapping[v]
                          for k, v in manifest["arrays"].items()}
    manifest["block_shards"] = [dict(s, file=mapping[s["file"]])
                                for s in manifest["block_shards"]]
    if manifest.get("lstm"):
        manifest["lstm"] = dict(manifest["lstm"],
                                dir=f"{manifest['lstm']['dir']}.g{G}")
    if manifest.get("pq"):
        manifest["pq"] = dict(manifest["pq"],
                              arrays={k: mapping[v] for k, v in
                                      manifest["pq"]["arrays"].items()})
    manifest["files"] = {mapping[k]: v
                         for k, v in manifest["files"].items()}
    fmt.commit_manifest(index_dir, manifest)
    # post-flip GC: drop everything this generation doesn't reference
    # (old shards/arrays, archived manifests, crashed stage dirs). A
    # reader still holding a pre-compaction manifest loses its files
    # here — compaction is the one deliberately destructive operation.
    keep = set(manifest["files"]) | {fmt.MANIFEST_NAME}
    for dirpath, _, filenames in os.walk(index_dir, topdown=False):
        for name in filenames:
            full = os.path.join(dirpath, name)
            if os.path.relpath(full, index_dir) not in keep:
                os.remove(full)
        if dirpath != index_dir and not os.listdir(dirpath):
            os.rmdir(dirpath)
    shutil.rmtree(tmp_dir, ignore_errors=True)
    return manifest


def compact_index(index_dir, out_dir=None, *, chunk_docs=None, tracer=None):
    """Rewrite the index's current logical state as a fresh layout:
    tombstones applied, member lists left-compacted, all shards repacked,
    manifest history dropped. In place by default — the compacted
    artifacts are staged to a sibling directory and committed through
    the same atomic manifest-replace protocol as deltas, so a racing
    reader always sees a valid generation — or to a fresh `out_dir`.

    Output invariant: byte-identical (v1) / code-identical (v2) artifacts
    to `write_index` called on the equivalent in-memory state — an
    incrementally updated index compacts to exactly what a from-scratch
    serialization of that state produces.

    `tracer` (repro.obs.Tracer) records one `compact_index` trace
    (load_state / rewrite / commit spans); the rewrite's per-phase byte
    detail lands in a sibling `write_index` trace on the same tracer.
    """
    tr = tracer.trace("compact_index") if tracer is not None else NOOP_TRACE
    sp_load = tr.span("load_state")
    manifest = fmt.load_manifest(index_dir)
    reader = IndexReader(index_dir, manifest)
    geom = reader.geometry
    fv = manifest["format_version"]
    v2 = fv == fmt.FORMAT_VERSION_PQ
    D, dim, cap = int(geom["n_docs"]), int(geom["dim"]), int(geom["cap"])
    cfg = dataclasses.replace(reader.config(), n_docs=D)

    members = canonical_members(np.asarray(reader.array("cluster_docs")),
                                reader.tombstones())
    cd = np.full((len(members), cap), -1, np.int32)
    for c, mem in enumerate(members):
        cd[c, :len(mem)] = mem
    pd, pw = _load_padded_postings(reader, cfg.max_postings)
    sp = SparseIndex(jnp.asarray(pd), jnp.asarray(pw), D)
    sp.truncated_postings = int(
        manifest.get("stats", {}).get("truncated_postings", 0))

    quantizer, embeddings = None, None
    if v2:
        quantizer = reader.quantizer()
        embeddings = _ShapeOnly((D, dim))
    else:
        records = _ShardRecords(index_dir, manifest)
        emb = np.zeros((D, dim), np.float32)
        masked = reader.masked_cluster_docs()
        for c in range(len(members)):
            live = masked[c] >= 0
            if live.any():
                emb[masked[c][live]] = records.cluster_record(c)[live]
        embeddings = emb

    index = CluSDIndex(
        centroids=jnp.asarray(reader.array("centroids")),
        cluster_docs=jnp.asarray(cd),
        doc_cluster=jnp.asarray(np.asarray(reader.array("doc_cluster"))),
        neighbor_ids=jnp.asarray(reader.array("neighbor_ids")),
        neighbor_sims=jnp.asarray(reader.array("neighbor_sims")),
        embeddings=None, sparse_index=sp, lstm_params=reader.lstm_params(),
        quantizer=quantizer,
        bin_ids=jnp.asarray(reader.array("bin_ids")))
    g = reader.generation
    sp_load.end()
    in_place = out_dir is None or \
        os.path.abspath(out_dir) == os.path.abspath(index_dir)
    target = index_dir + f".compact-g{g + 1}" if in_place else out_dir
    with tr.span("rewrite"):
        new_manifest = builder_lib.write_index(
            target, cfg, index, embeddings,
            n_shards=len(manifest["block_shards"]),
            block_dtype=np.dtype(geom["block_dtype"]),
            format_version=fv, pq=quantizer,
            chunk_docs=chunk_docs or builder_lib.DEFAULT_CHUNK_DOCS,
            extra=manifest.get("extra"), generation=g + 1,
            parent_generation=g, tracer=tracer)
    if in_place:
        with tr.span("commit"):
            new_manifest = _commit_compacted_in_place(index_dir, target,
                                                      new_manifest)
    tr.finish(generation=g + 1,
              bytes_rewritten=int(new_manifest["total_bytes"]))
    return new_manifest
