"""Offline IndexBuilder pipeline: build once, serve many times.

Two layers:

  * `build_index_offline(cfg, rng, embeddings, ...)` — the expensive part of
    `core.clusd.build_index`, restructured to stream: sharded Lloyd's k-means
    (`core.kmeans.kmeans_shards`, one embedding shard device-resident at a
    time), capacity-balanced cluster table, neighbor graph, sparse inverted
    index, Stage-I bin table. Returns a `CluSDIndex` with `embeddings=None` —
    the matrix itself never needs to be a device array, an np.memmap works.

  * `write_index(out_dir, cfg, index, embeddings, ...)` — serialize any built
    `CluSDIndex` (from this module or `core.clusd.build_index`) into the
    versioned layout of `index/format.py`: per-index arrays as .npy, cluster
    blocks packed shard-by-shard into raw per-shard .bin files, optional LSTM
    selector weights via `repro.checkpoint`, optional PQ artifacts, and a
    manifest with sha256 checksums over every file. The directory is staged
    under `<out_dir>.tmp` and committed with an atomic rename.

Read side: `index/reader.py`.
"""

import dataclasses
import os
import shutil
import time

import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import bins as bins_lib
from repro.core import disk as disk_lib
from repro.core import kmeans as km
from repro.core import sparse as sparse_lib
from repro.core.clusd import CluSDIndex
from repro.index import format as fmt

_ARRAY_DTYPES = {
    "centroids": np.float32,
    "cluster_docs": np.int32,
    "doc_cluster": np.int32,
    "neighbor_ids": np.int32,
    "neighbor_sims": np.float32,
    "bin_ids": np.int32,
    "sparse_postings_docs": np.int32,
    "sparse_postings_weights": np.float32,
}


def shard_ranges(n_clusters, n_shards):
    """Even [lo, hi) cluster ranges; first shards absorb the remainder."""
    n_shards = max(1, min(n_shards, n_clusters))
    base, rem = divmod(n_clusters, n_shards)
    ranges, lo = [], 0
    for s in range(n_shards):
        hi = lo + base + (1 if s < rem else 0)
        ranges.append((lo, hi))
        lo = hi
    return ranges


def embedding_shards(embeddings, shard_docs):
    """Row-range views over the (memmap-able) embedding matrix."""
    D = embeddings.shape[0]
    shard_docs = max(1, int(shard_docs))
    return [embeddings[lo:min(lo + shard_docs, D)]
            for lo in range(0, D, shard_docs)]


def build_index_offline(cfg, rng, embeddings, doc_terms, doc_weights, *,
                        shard_docs=None, kmeans_iters=15):
    """Sharded/minibatch offline build. `embeddings`: (D, dim) host array or
    np.memmap — clustered shard-by-shard, never moved to device whole.
    Returns a CluSDIndex with `embeddings=None` (blocks live on disk after
    `write_index`)."""
    D = int(embeddings.shape[0])
    shard_docs = shard_docs or min(D, 1 << 16)
    shards = embedding_shards(embeddings, shard_docs)
    centroids, assign = km.kmeans_shards(rng, shards, cfg.n_clusters,
                                         iters=kmeans_iters)
    cluster_docs, doc_cluster = km.build_cluster_table(
        assign, cfg.n_clusters, cfg.cluster_cap, embeddings, centroids)
    m = min(cfg.n_neighbors, cfg.n_clusters - 1)
    nb_ids, nb_sims = km.neighbor_graph(centroids, m)
    sp = sparse_lib.SparseIndex.build(doc_terms, doc_weights, cfg.vocab,
                                      cfg.max_postings)
    return CluSDIndex(
        centroids=centroids, cluster_docs=cluster_docs,
        doc_cluster=doc_cluster, neighbor_ids=nb_ids, neighbor_sims=nb_sims,
        embeddings=None, sparse_index=sp,
        bin_ids=bins_lib.rank_bin_ids(cfg.bins, cfg.k_sparse))


def _cluster_fill_stats(cluster_docs):
    fill = (np.asarray(cluster_docs) >= 0).sum(axis=1)
    return {"min": int(fill.min()), "max": int(fill.max()),
            "mean": round(float(fill.mean()), 2),
            "empty": int((fill == 0).sum())}


def write_index(out_dir, cfg, index, embeddings, *, n_shards=4,
                block_dtype=np.float32, extra=None):
    """Serialize `index` + packed cluster blocks under `out_dir` (atomic:
    staged in `<out_dir>.tmp`, committed by rename). Returns the manifest."""
    t0 = time.perf_counter()
    block_dtype = np.dtype(block_dtype)
    cd = np.asarray(index.cluster_docs)
    n_clusters, cap = cd.shape
    dim = int(embeddings.shape[1])
    out_dir = os.path.abspath(out_dir)
    tmp = out_dir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "blocks"))

    arrays = {
        "centroids": index.centroids,
        "cluster_docs": index.cluster_docs,
        "doc_cluster": index.doc_cluster,
        "neighbor_ids": index.neighbor_ids,
        "neighbor_sims": index.neighbor_sims,
        "bin_ids": index.bin_ids,
        "sparse_postings_docs": index.sparse_index.postings_docs,
        "sparse_postings_weights": index.sparse_index.postings_weights,
    }
    array_paths = {}
    for name, arr in arrays.items():
        rel = f"{name}.npy"
        np.save(os.path.join(tmp, rel),
                np.asarray(arr, _ARRAY_DTYPES[name]))
        array_paths[name] = rel

    # cluster blocks, packed one output shard at a time (bounded memory)
    ranges = shard_ranges(n_clusters, n_shards)
    block_shards = []
    for s, (lo, hi) in enumerate(ranges):
        rel = os.path.join("blocks", f"shard_{s:05d}.bin")
        disk_lib.pack_blocks(embeddings, cd[lo:hi], block_dtype).tofile(
            os.path.join(tmp, rel))
        block_shards.append({"file": rel, "cluster_lo": lo, "cluster_hi": hi})

    lstm_meta = None
    if index.lstm_params is not None:
        params = {k: np.asarray(v) for k, v in index.lstm_params.items()}
        lstm_meta = {"dir": "lstm", "step": 0, "selector": "lstm",
                     "feat_dim": int(params["wx"].shape[0]),
                     "hidden": int(params["wh"].shape[0])}
        save_checkpoint(os.path.join(tmp, "lstm"), 0, params,
                        extra={k: lstm_meta[k]
                               for k in ("selector", "feat_dim", "hidden")})

    pq_meta = None
    if index.quantizer is not None:
        pq = index.quantizer
        os.makedirs(os.path.join(tmp, "pq"))
        pq_arrays = {"codebooks": pq.codebooks, "codes": pq.codes}
        if pq.rotation is not None:
            pq_arrays["rotation"] = pq.rotation
        pq_paths = {}
        for name, arr in pq_arrays.items():
            rel = os.path.join("pq", f"{name}.npy")
            np.save(os.path.join(tmp, rel), np.asarray(arr))
            pq_paths[name] = rel
        pq_meta = {"nsub": int(pq.nsub), "arrays": pq_paths}

    files = fmt.scan_files(tmp)
    manifest = {
        "format_version": fmt.FORMAT_VERSION,
        "kind": "clusd-index",
        "config": dataclasses.asdict(cfg),
        "geometry": {"n_docs": index.n_docs, "dim": dim,
                     "n_clusters": n_clusters, "cap": cap,
                     "block_dtype": block_dtype.name},
        "arrays": array_paths,
        "block_shards": block_shards,
        "lstm": lstm_meta,
        "pq": pq_meta,
        "stats": {
            "cluster_fill": _cluster_fill_stats(cd),
            "truncated_postings": int(getattr(index.sparse_index,
                                              "truncated_postings", 0)),
            "pack_wall_s": round(time.perf_counter() - t0, 3),
        },
        "extra": extra or {},
        "files": files,
        "total_bytes": sum(e["bytes"] for e in files.values()),
    }
    fmt.write_manifest(tmp, manifest)
    # commit: move any previous index aside first, so a crash in the window
    # never leaves out_dir without a readable index
    old = out_dir + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(out_dir):
        os.rename(out_dir, old)
    os.rename(tmp, out_dir)
    shutil.rmtree(old, ignore_errors=True)
    return manifest
