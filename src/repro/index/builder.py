"""Offline IndexBuilder pipeline: build once, serve many times.

Two layers:

  * `build_index_offline(cfg, rng, embeddings, ...)` — the expensive part of
    `core.clusd.build_index`, restructured to stream: sharded Lloyd's k-means
    (`core.kmeans.kmeans_shards`, one embedding shard device-resident at a
    time), capacity-balanced cluster table, neighbor graph, sparse inverted
    index, Stage-I bin table. Returns a `CluSDIndex` with `embeddings=None` —
    the matrix itself never needs to be a device array. Corpora larger than
    RAM work: pass an `np.memmap` — shards are lazy row-range views
    (`RowSlice`), overflow reassignment gathers in bounded chunks, and no
    step ever materializes the full embedding matrix.

  * `write_index(out_dir, cfg, index, embeddings, ...)` — serialize any built
    `CluSDIndex` (from this module or `core.clusd.build_index`) into the
    versioned layout of `index/format.py`. Two on-disk formats:

      format_version=1 — float blocks: per-shard raw (hi-lo, cap, dim)
        cluster-block tensors, packed `chunk_docs` rows at a time. The
        shard dtype may be float32, bfloat16, or int8 (format-additive;
        int8 stamps a global `block_scale` into the manifest geometry and
        readers decode `record * block_scale` at fetch).
      format_version=2 — PQ code shards: per-shard raw (hi-lo, cap, nsub)
        uint8 code tensors plus the (nsub, 256, dsub) codebooks, and sparse
        postings compacted to CSR (lossless; readers re-pad at load). The
        embedding store shrinks by ~4 x itemsize * dim / nsub.

    Both stage under `<out_dir>.tmp` and commit with an atomic rename, with
    sha256 checksums over every artifact in the manifest.

Read side: `index/reader.py`.
"""

import dataclasses
import os
import shutil
import time

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import bins as bins_lib
from repro.core import disk as disk_lib
from repro.core import kmeans as km
from repro.core import quant as quant_lib
from repro.core import sparse as sparse_lib
from repro.core.clusd import CluSDIndex
from repro.index import format as fmt
from repro.obs import NOOP_TRACE

_ARRAY_DTYPES = {
    "centroids": np.float32,
    "cluster_docs": np.int32,
    "doc_cluster": np.int32,
    "neighbor_ids": np.int32,
    "neighbor_sims": np.float32,
    "bin_ids": np.int32,
    "sparse_postings_docs": np.int32,
    "sparse_postings_weights": np.float32,
    # v2 compact (CSR) postings
    "sparse_postings_data": np.int32,
    "sparse_postings_wdata": np.float32,
    "sparse_postings_indptr": np.int64,
    # incremental updates (repro.index.update): per-slot delete bitmap
    "tombstones": np.uint8,
}

DEFAULT_CHUNK_DOCS = 1 << 16


class RowSlice:
    """Lazy row-range view over any row-indexable (D, dim) matrix.

    Nothing is read until the view is indexed or converted; converting reads
    exactly the view's rows. This is what lets `embedding_shards` hand
    `kmeans_shards` a full shard list over a corpus-sized np.memmap while
    only ever holding one shard's rows resident.
    """

    def __init__(self, source, lo, hi):
        self.source, self.lo, self.hi = source, int(lo), int(hi)
        self.shape = (self.hi - self.lo, int(source.shape[1]))
        self.dtype = np.dtype(getattr(source, "dtype", np.float32))

    def __len__(self):
        return self.shape[0]

    def __getitem__(self, key):
        if isinstance(key, slice):
            start, stop, step = key.indices(self.shape[0])
            return self.source[self.lo + start:self.lo + stop:step]
        key = np.asarray(key)
        return self.source[self.lo + key]

    def __array__(self, dtype=None, copy=None):
        out = np.asarray(self.source[self.lo:self.hi])
        return out if dtype is None else out.astype(dtype, copy=False)


def shard_ranges(n_clusters, n_shards):
    """Even [lo, hi) cluster ranges; first shards absorb the remainder."""
    n_shards = max(1, min(n_shards, n_clusters))
    base, rem = divmod(n_clusters, n_shards)
    ranges, lo = [], 0
    for s in range(n_shards):
        hi = lo + base + (1 if s < rem else 0)
        ranges.append((lo, hi))
        lo = hi
    return ranges


def embedding_shards(embeddings, shard_docs):
    """Lazy row-range views over the (memmap-able) embedding matrix — rows
    are read only when a shard is actually consumed."""
    D = int(embeddings.shape[0])
    shard_docs = max(1, int(shard_docs))
    return [RowSlice(embeddings, lo, min(lo + shard_docs, D))
            for lo in range(0, D, shard_docs)]


def build_index_offline(cfg, rng, embeddings, doc_terms, doc_weights, *,
                        shard_docs=None, kmeans_iters=15, tracer=None):
    """Sharded/minibatch offline build. `embeddings`: (D, dim) host array or
    np.memmap — clustered shard-by-shard, never moved to device whole; peak
    resident embedding rows are bounded by `shard_docs`.
    Returns a CluSDIndex with `embeddings=None` (blocks live on disk after
    `write_index`). `tracer` (repro.obs.Tracer) records one `build_index`
    trace with a span per phase."""
    D = int(embeddings.shape[0])
    shard_docs = shard_docs or min(D, 1 << 16)
    tr = tracer.trace("build_index", n_docs=D) if tracer is not None \
        else NOOP_TRACE
    shards = embedding_shards(embeddings, shard_docs)
    with tr.span("kmeans", n_shards=len(shards), iters=kmeans_iters):
        centroids, assign = km.kmeans_shards(rng, shards, cfg.n_clusters,
                                             iters=kmeans_iters)
    with tr.span("cluster_table"):
        cluster_docs, doc_cluster = km.build_cluster_table(
            assign, cfg.n_clusters, cfg.cluster_cap, embeddings, centroids,
            chunk_rows=shard_docs)
    with tr.span("neighbor_graph"):
        m = min(cfg.n_neighbors, cfg.n_clusters - 1)
        nb_ids, nb_sims = km.neighbor_graph(centroids, m)
    with tr.span("sparse_index"):
        sp = sparse_lib.SparseIndex.build(doc_terms, doc_weights, cfg.vocab,
                                          cfg.max_postings)
    tr.finish()
    return CluSDIndex(
        centroids=centroids, cluster_docs=cluster_docs,
        doc_cluster=doc_cluster, neighbor_ids=nb_ids, neighbor_sims=nb_sims,
        embeddings=None, sparse_index=sp,
        bin_ids=bins_lib.rank_bin_ids(cfg.bins, cfg.k_sparse))


def _cluster_fill_stats(cluster_docs):
    fill = (np.asarray(cluster_docs) >= 0).sum(axis=1)
    return {"min": int(fill.min()), "max": int(fill.max()),
            "mean": round(float(fill.mean()), 2),
            "empty": int((fill == 0).sum())}


def _write_float_blocks(path, embeddings, cd, block_dtype, chunk_docs,
                        scale=None):
    """Stream one shard's (n, cap, dim) float blocks to `path`, reading at
    most ~chunk_docs embedding rows per fancy-index gather. `scale`
    quantizes (int8 shards; see pack_blocks)."""
    cap = cd.shape[1]
    group = max(1, int(chunk_docs) // max(1, cap))
    with open(path, "wb") as f:
        for lo in range(0, cd.shape[0], group):
            disk_lib.pack_blocks(embeddings, cd[lo:lo + group],
                                 block_dtype, scale=scale).tofile(f)


def _block_scale(embeddings, chunk_docs):
    """Global int8 dequantization scale max|emb|/127, computed in bounded
    chunk_docs-row reads (memmap-safe)."""
    amax = 0.0
    D = int(embeddings.shape[0])
    for lo in range(0, D, int(chunk_docs)):
        chunk = np.asarray(embeddings[lo:lo + int(chunk_docs)], np.float32)
        if chunk.size:
            amax = max(amax, float(np.abs(chunk).max()))
    return (amax / 127.0) if amax > 0 else 1.0


def _write_code_blocks(path, codes, cd):
    """One shard's (n, cap, nsub) uint8 code blocks; padded slots code 0
    (masked by cluster_docs at read time)."""
    nsub = codes.shape[1]
    block = np.zeros(cd.shape + (nsub,), np.uint8)
    mask = cd >= 0
    block[mask] = codes[cd[mask]]
    block.tofile(path)


def postings_csr(postings_docs, postings_weights):
    """Compact padded (V, P) posting arrays to CSR (lossless: padding never
    affects retrieval — scores are scatter-adds over valid entries). The
    padded width P never influences the CSR bytes, so any re-padded view of
    the same postings serializes identically (the invariant the incremental
    update path relies on)."""
    pd = np.asarray(postings_docs)
    pw = np.asarray(postings_weights)
    valid = pd >= 0
    counts = valid.sum(axis=1)
    indptr = np.zeros(pd.shape[0] + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return pd[valid].astype(np.int32), pw[valid].astype(np.float32), indptr


def _postings_csr(sp):
    return postings_csr(sp.postings_docs, sp.postings_weights)


def postings_from_csr(data, wdata, indptr, min_width=1):
    """Inverse of `postings_csr`: re-pad CSR postings into (V, P) arrays,
    P = max(min_width, longest row). Lossless — the pad width never
    affects retrieval. The single implementation behind both the serving
    re-pad (IndexReader) and the delta path (index/update.py), which
    passes min_width=cfg.max_postings so truncation behaves like the
    original build."""
    data = np.asarray(data)
    wdata = np.asarray(wdata)
    indptr = np.asarray(indptr)
    counts = np.diff(indptr)
    V = len(counts)
    P = int(max(min_width, counts.max() if V else 0, 1))
    pd = np.full((V, P), -1, np.int32)
    pw = np.zeros((V, P), np.float32)
    cols = np.arange(P)[None, :]
    mask = cols < counts[:, None]
    pd[mask] = data
    pw[mask] = wdata
    return pd, pw


def _write_pq_arrays(tmp, pq_arrays, nsub, dtype=None):
    """Serialize PQ artifacts under pq/ and return their manifest entry."""
    os.makedirs(os.path.join(tmp, "pq"))
    pq_paths = {}
    for name, arr in pq_arrays.items():
        rel = os.path.join("pq", f"{name}.npy")
        arr = np.asarray(arr) if dtype is None else np.asarray(arr, dtype)
        np.save(os.path.join(tmp, rel), arr)
        pq_paths[name] = rel
    return {"nsub": int(nsub), "arrays": pq_paths}


def _index_pq(index, embeddings, pq, pq_nsub, chunk_docs):
    """Resolve the PQ used for a v2 write: explicit arg > index.quantizer >
    train now (bounded-memory, deterministic key)."""
    pq = pq if pq is not None else index.quantizer
    if pq is None:
        pq = quant_lib.train_pq_stream(jax.random.key(0), embeddings,
                                       pq_nsub, chunk_docs=chunk_docs)
    codes = np.asarray(pq.codes)
    if codes.shape[0] != index.n_docs:
        raise ValueError(f"PQ codes cover {codes.shape[0]} docs, "
                         f"index has {index.n_docs}")
    if codes.min() < 0 or codes.max() > 255:
        raise ValueError("PQ codes out of uint8 range")
    return pq, codes.astype(np.uint8)


def write_index(out_dir, cfg, index, embeddings, *, n_shards=4,
                block_dtype=np.float32, extra=None,
                format_version=fmt.FORMAT_VERSION, pq=None, pq_nsub=8,
                chunk_docs=DEFAULT_CHUNK_DOCS, generation=0,
                parent_generation=None, tracer=None):
    """Serialize `index` + packed cluster blocks under `out_dir` (atomic:
    staged in `<out_dir>.tmp`, committed by rename). Returns the manifest.

    format_version=1 writes float blocks; format_version=2 writes PQ code
    shards (using `pq`, else `index.quantizer`, else codebooks trained here)
    plus CSR-compacted postings. `embeddings` may be an np.memmap: all reads
    are bounded by `chunk_docs` rows.

    `generation`/`parent_generation` stamp the manifest for the incremental
    update protocol (repro.index.update): fresh builds are generation 0;
    `compact_index` rewrites the whole layout at `old generation + 1`.

    `tracer` (repro.obs.Tracer) records one `write_index` trace with a
    span per phase (arrays, pq, block_shards, lstm, commit) annotated
    with bytes written.
    """
    if format_version not in fmt.SUPPORTED_VERSIONS:
        raise ValueError(f"format_version {format_version} not in "
                         f"{fmt.SUPPORTED_VERSIONS}")
    tr = tracer.trace("write_index", generation=int(generation)) \
        if tracer is not None else NOOP_TRACE
    t0 = time.perf_counter()
    block_dtype = fmt.resolve_block_dtype(block_dtype)
    cd = np.asarray(index.cluster_docs)
    n_clusters, cap = cd.shape
    dim = int(embeddings.shape[1])
    out_dir = os.path.abspath(out_dir)
    tmp = out_dir + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(os.path.join(tmp, "blocks"))

    v2 = format_version == fmt.FORMAT_VERSION_PQ
    arrays = {
        "centroids": index.centroids,
        "cluster_docs": index.cluster_docs,
        "doc_cluster": index.doc_cluster,
        "neighbor_ids": index.neighbor_ids,
        "neighbor_sims": index.neighbor_sims,
        "bin_ids": index.bin_ids,
    }
    if v2:
        data, wdata, indptr = _postings_csr(index.sparse_index)
        arrays.update(sparse_postings_data=data, sparse_postings_wdata=wdata,
                      sparse_postings_indptr=indptr)
    else:
        arrays.update(
            sparse_postings_docs=index.sparse_index.postings_docs,
            sparse_postings_weights=index.sparse_index.postings_weights)
    array_paths = {}
    with tr.span("arrays", n_arrays=len(arrays)):
        for name, arr in arrays.items():
            rel = f"{name}.npy"
            np.save(os.path.join(tmp, rel),
                    np.asarray(arr, _ARRAY_DTYPES[name]))
            array_paths[name] = rel

    pq_meta = None
    geometry = {"n_docs": index.n_docs, "dim": dim,
                "n_clusters": n_clusters, "cap": cap,
                "block_dtype": block_dtype.name}
    ranges = shard_ranges(n_clusters, n_shards)
    block_shards = []
    if v2:
        with tr.span("pq", nsub=int(pq_nsub)):
            the_pq, codes = _index_pq(index, embeddings, pq, pq_nsub,
                                      chunk_docs)
            geometry["nsub"] = int(the_pq.nsub)
            geometry["code_dtype"] = "uint8"
            pq_arrays = {"codebooks": the_pq.codebooks}
            if the_pq.rotation is not None:
                pq_arrays["rotation"] = the_pq.rotation
            pq_meta = _write_pq_arrays(tmp, pq_arrays, the_pq.nsub,
                                       dtype=np.float32)
        with tr.span("block_shards", n_shards=len(ranges)) as sp:
            for s, (lo, hi) in enumerate(ranges):
                rel = os.path.join("blocks", f"shard_{s:05d}.codes.bin")
                _write_code_blocks(os.path.join(tmp, rel), codes, cd[lo:hi])
                block_shards.append({"file": rel, "cluster_lo": lo,
                                     "cluster_hi": hi})
            sp.annotate(bytes=sum(
                os.path.getsize(os.path.join(tmp, b["file"]))
                for b in block_shards))
    else:
        scale = None
        if block_dtype == np.int8:
            scale = _block_scale(embeddings, chunk_docs)
            geometry["block_scale"] = scale
        with tr.span("block_shards", n_shards=len(ranges)) as sp:
            for s, (lo, hi) in enumerate(ranges):
                rel = os.path.join("blocks", f"shard_{s:05d}.bin")
                _write_float_blocks(os.path.join(tmp, rel), embeddings,
                                    cd[lo:hi], block_dtype, chunk_docs,
                                    scale=scale)
                block_shards.append({"file": rel, "cluster_lo": lo,
                                     "cluster_hi": hi})
            sp.annotate(bytes=sum(
                os.path.getsize(os.path.join(tmp, b["file"]))
                for b in block_shards))
        # v1 keeps the PR-2 layout byte-for-byte, including optional full
        # PQ artifacts (codebooks + per-doc codes) for device-side ADC
        if index.quantizer is not None:
            with tr.span("pq"):
                q = index.quantizer
                pq_arrays = {"codebooks": q.codebooks, "codes": q.codes}
                if q.rotation is not None:
                    pq_arrays["rotation"] = q.rotation
                pq_meta = _write_pq_arrays(tmp, pq_arrays, q.nsub)

    lstm_meta = None
    if index.lstm_params is not None:
        with tr.span("lstm"):
            params = {k: np.asarray(v) for k, v in index.lstm_params.items()}
            lstm_meta = {"dir": "lstm", "step": 0, "selector": "lstm",
                         "feat_dim": int(params["wx"].shape[0]),
                         "hidden": int(params["wh"].shape[0])}
            save_checkpoint(os.path.join(tmp, "lstm"), 0, params,
                            extra={k: lstm_meta[k]
                                   for k in ("selector", "feat_dim",
                                             "hidden")})

    files = fmt.scan_files(tmp)
    manifest = {
        "format_version": format_version,
        "kind": "clusd-index",
        "generation": int(generation),
        "parent_generation": None if parent_generation is None
        else int(parent_generation),
        "config": dataclasses.asdict(cfg),
        "geometry": geometry,
        "arrays": array_paths,
        "block_shards": block_shards,
        "lstm": lstm_meta,
        "pq": pq_meta,
        "stats": {
            "cluster_fill": _cluster_fill_stats(cd),
            "truncated_postings": int(getattr(index.sparse_index,
                                              "truncated_postings", 0)),
            "pack_wall_s": round(time.perf_counter() - t0, 3),
        },
        "extra": extra or {},
        "files": files,
        "total_bytes": sum(e["bytes"] for e in files.values()),
    }
    with tr.span("commit"):
        fmt.write_manifest(tmp, manifest)
        # commit: move any previous index aside first, so a crash in the
        # window never leaves out_dir without a readable index
        old = out_dir + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        if os.path.exists(out_dir):
            os.rename(out_dir, old)
        os.rename(tmp, out_dir)
        shutil.rmtree(old, ignore_errors=True)
    tr.finish(total_bytes=int(manifest["total_bytes"]))
    return manifest
