"""Versioned on-disk index layout: manifest schema + integrity checks.

A built index is one directory:

  <index_dir>/
    manifest.json                   # schema below — single source of truth
    centroids.npy ...               # small per-index arrays (np.load mmap-able)
    blocks/shard_00000.bin ...      # packed cluster blocks, raw fixed-shape
    lstm/step_0/...                 # optional selector weights (repro.checkpoint)
    pq/codebooks.npy ...            # optional PQ artifacts

Manifest schema (format_version 1):

  format_version : int — readers hard-reject versions they don't speak
  kind           : "clusd-index"
  config         : dataclasses.asdict(CluSDConfig) used at build time
  geometry       : {n_docs, dim, n_clusters, cap, block_dtype}
  arrays         : {logical name -> relpath of .npy}
  block_shards   : [{file, cluster_lo, cluster_hi}] — shard s owns clusters
                   [cluster_lo, cluster_hi), blocks contiguous in cluster order
  lstm           : {dir, step, selector, feat_dim, hidden} | null
  pq             : {nsub, arrays: {...}} | null
  stats          : build-time stats (cluster fill, truncated postings, ...)
  extra          : caller metadata (e.g. synthetic-corpus recipe)
  files          : {relpath -> {bytes, sha256}} for EVERY artifact file
  total_bytes    : sum of artifact sizes

format_version 2 (PQ-coded block shards) differs only in the embedding
store and the sparse-postings encoding:

  geometry       : gains {nsub, code_dtype: "uint8"}; block_dtype names the
                   DECODE dtype (what fetch_clusters returns)
  block_shards   : shard s holds a raw (hi-lo, cap, nsub) uint8 CODE tensor
                   (blocks/shard_*.codes.bin) instead of float blocks
  pq             : REQUIRED: {nsub, arrays: {codebooks[, rotation]}} — the
                   (nsub, 256, dsub) codebooks that decode the shards; the
                   per-doc codes live in the shards, not in a pq/codes.npy
  arrays         : sparse postings are stored compacted (CSR): logical names
                   sparse_postings_data/sparse_postings_wdata/
                   sparse_postings_indptr replace the padded
                   sparse_postings_docs/weights pair; readers re-pad at load
                   (lossless — padding never affects retrieval)

v1 readers (format PR 2) reject v2 manifests up front via the
format_version check; pass supported=(1,) to load_manifest to emulate one.

Reduced-precision v1 float shards (format-ADDITIVE — the version stays 1):

  geometry.block_dtype : may also be "bfloat16" or "int8" (beyond the
                         original "float32"); shards hold that dtype's raw
                         (hi-lo, cap, dim) records and readers decode to
                         float32 at fetch time (see ShardedDiskStore)
  geometry.block_scale : REQUIRED when block_dtype == "int8": the global
                         dequantization scale (max|emb| / 127 at build
                         time); decode is `record * block_scale`. Absent
                         for other dtypes.

Additive per the compat rules above: a reader that predates these dtypes
never sees them unless an index was built with them, and then fails
loudly at dtype resolution rather than misreading bytes.

Generations (incremental updates, repro.index.update):

  generation        : int — 0 for a fresh `write_index` build; each
                      committed delta bumps it by one. ADDITIVE: readers
                      that predate generations treat a missing key as 0.
  parent_generation : the generation this manifest was derived from
                      (null for a fresh build)
  arrays.tombstones : optional (n_clusters, cap) uint8 bitmap; slot
                      (c, i) == 1 means cluster_docs[c, i] is deleted.
                      Stores mask tombstoned slots at fetch time — the
                      shard bytes on disk are NOT rewritten for deletes.
  update_stats      : bookkeeping of the last delta commit (bytes
                      rewritten, shards touched, upsert/delete counts)

Selector publishes (repro.train.publish_selector) are generations too:
trained LSTM weights land as `lstm.g<G>/` (the `lstm` key moves with
them), the calibrated operating point is written straight into
`config.theta` / `config.max_selected` (so every reader serves it with no
extra wiring), and an ADDITIVE `selector` key records the bookkeeping:

  selector          : {selector, published_generation, theta, budget,
                      calibration: [{theta, budget, recall, avg_selected,
                      est_read_bytes}, ...], label_config, train} | absent
                      for indexes whose selector came from the offline
                      build. Dropped by compaction (weights + calibrated
                      config survive — they live in the checkpoint and
                      `config`).

  Delta commits never mutate existing artifact files. New/changed
  artifacts get generation-suffixed names (`centroids.g3.npy`,
  `blocks/shard_00002.g3.bin`); unchanged artifacts are carried by
  reference in `arrays`/`block_shards`. The previous manifest is archived
  to `manifests/manifest.g<g>.json` before the new one atomically
  replaces `manifest.json` — so every older generation stays readable
  (`load_manifest(dir, generation=g)`) until `compact_index` folds the
  history into a fresh single-generation layout.

Integrity levels (IndexReader.open(verify=...)):
  "none" — trust the manifest
  "size" — every listed file exists with the exact byte size (cheap; default)
  "full" — additionally sha256 every file (reads everything once)

`files`/`total_bytes` always describe the LIVE artifact set of that
manifest's generation — files belonging only to older generations are
not listed (their checksums live in the archived manifests).
"""

import hashlib
import json
import os

FORMAT_VERSION = 1            # float32 block shards (PR 2 layout)
FORMAT_VERSION_PQ = 2         # PQ code shards + CSR postings
SUPPORTED_VERSIONS = (FORMAT_VERSION, FORMAT_VERSION_PQ)
MANIFEST_NAME = "manifest.json"
MANIFEST_HISTORY_DIR = "manifests"
VERIFY_LEVELS = ("none", "size", "full")

# v1 float-shard record dtypes this reader/builder speaks (format-additive;
# see module docstring). "bfloat16" resolves through ml_dtypes (bundled
# with jax); "int8" additionally needs geometry.block_scale to decode.
BLOCK_DTYPES_V1 = ("float32", "bfloat16", "int8")


def resolve_block_dtype(name):
    """geometry.block_dtype -> np.dtype, for the v1 shard dtypes.

    Rejects names outside BLOCK_DTYPES_V1 loudly — an unknown dtype means
    an index newer than this reader, and misreading raw shard bytes under
    the wrong itemsize would be silent corruption."""
    import numpy as np
    name = np.dtype(name).name if not isinstance(name, str) else name
    if name not in BLOCK_DTYPES_V1:
        raise IndexFormatError(
            f"block_dtype {name!r} unsupported (reader speaks "
            f"{BLOCK_DTYPES_V1}); upgrade the reader")
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


class IndexFormatError(ValueError):
    """Manifest missing/unreadable, wrong version, or malformed layout."""


class IndexChecksumError(IndexFormatError):
    """An artifact file is missing, truncated, or fails its checksum."""


def file_sha256(path, chunk_bytes=1 << 20):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            chunk = f.read(chunk_bytes)
            if not chunk:
                break
            h.update(chunk)
    return h.hexdigest()


def scan_files(root):
    """{relpath: {bytes, sha256}} over every file under `root` except the
    manifest itself. Called at pack time, after all artifacts are written."""
    out = {}
    for dirpath, _, names in os.walk(root):
        for name in sorted(names):
            full = os.path.join(dirpath, name)
            rel = os.path.relpath(full, root)
            if rel == MANIFEST_NAME:
                continue
            out[rel] = {"bytes": os.path.getsize(full),
                        "sha256": file_sha256(full)}
    return out


def write_manifest(index_dir, manifest):
    with open(os.path.join(index_dir, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def manifest_generation(manifest):
    """Generation of a parsed manifest; pre-generation manifests are 0."""
    return int(manifest.get("generation", 0))


def archive_manifest(index_dir, manifest):
    """Preserve the CURRENT manifest under manifests/manifest.g<g>.json so
    its generation stays readable after a newer one replaces manifest.json.
    Called by the delta commit path just before the atomic flip."""
    hist = os.path.join(index_dir, MANIFEST_HISTORY_DIR)
    os.makedirs(hist, exist_ok=True)
    g = manifest_generation(manifest)
    path = os.path.join(hist, f"manifest.g{g}.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return path


def commit_manifest(index_dir, manifest):
    """Atomically replace manifest.json (write-to-temp + os.replace): a
    reader racing the commit sees either the old or the new generation,
    never a torn file."""
    final = os.path.join(index_dir, MANIFEST_NAME)
    tmp = final + f".tmp-g{manifest_generation(manifest)}"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)


def commit_generation(index_dir, stage, staged, old_manifest, new_manifest):
    """The shared tail of every generation commit (delta apply, selector
    publish): move the staged artifact files into place under their
    generation-suffixed names (never clobbering a file the live manifest
    references), archive the current manifest so its generation stays
    readable, atomically flip manifest.json, and drop the stage dir.
    Keeping this in ONE place keeps the no-torn-state guarantee in one
    place.

    stage: staging dir holding the new files; staged: their relpaths."""
    import shutil
    for rel in staged:
        dst = os.path.join(index_dir, rel)
        os.makedirs(os.path.dirname(dst) or index_dir, exist_ok=True)
        os.replace(os.path.join(stage, rel), dst)
    archive_manifest(index_dir, old_manifest)
    commit_manifest(index_dir, new_manifest)
    shutil.rmtree(stage, ignore_errors=True)


def load_manifest(index_dir, supported=SUPPORTED_VERSIONS, generation=None):
    """Parse + version-check the manifest. `supported` restricts which
    format versions this reader speaks — a PR-2 (v1-only) reader is
    `supported=(1,)` and must reject v2 indexes cleanly, which is exactly
    what this check does.

    `generation=None` (default) loads the current manifest.json; an int
    loads that archived generation from manifests/ (delta commits keep
    every older generation readable until compaction)."""
    path = os.path.join(index_dir, MANIFEST_NAME)
    if generation is not None:
        current = load_manifest(index_dir, supported=supported)
        if manifest_generation(current) == int(generation):
            return current
        path = os.path.join(index_dir, MANIFEST_HISTORY_DIR,
                            f"manifest.g{int(generation)}.json")
        if not os.path.isfile(path):
            raise IndexFormatError(
                f"generation {generation} not found in {index_dir} "
                f"(current is {manifest_generation(current)}; older "
                f"generations are dropped by compaction)")
    if not os.path.isfile(path):
        raise IndexFormatError(f"no {MANIFEST_NAME} in {index_dir}")
    try:
        with open(path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise IndexFormatError(f"unreadable manifest in {index_dir}: {e}")
    version = manifest.get("format_version")
    if version not in supported:
        raise IndexFormatError(
            f"index format version {version!r} unsupported "
            f"(reader speaks {tuple(supported)}); rebuild the index or "
            f"upgrade the reader")
    if manifest.get("kind") != "clusd-index":
        raise IndexFormatError(f"not a clusd-index: kind={manifest.get('kind')!r}")
    return manifest


def verify_files(index_dir, manifest, level="size"):
    """Check every artifact listed in manifest['files'] at the given level.
    Raises IndexChecksumError naming the first bad file."""
    if level not in VERIFY_LEVELS:
        raise ValueError(f"verify level {level!r} not in {VERIFY_LEVELS}")
    if level == "none":
        return
    files = manifest.get("files") or {}
    if not files:
        raise IndexFormatError("manifest lists no artifact checksums "
                               "('files' missing/empty) — cannot verify")
    # every referenced artifact must be covered by the checksum map
    referenced = list(manifest.get("arrays", {}).values()) + \
        [s["file"] for s in manifest.get("block_shards", [])]
    for rel in referenced:
        if rel.replace("/", os.sep) not in files and rel not in files:
            raise IndexFormatError(f"artifact {rel} has no checksum entry")
    for rel, entry in files.items():
        full = os.path.join(index_dir, rel)
        if not os.path.isfile(full):
            raise IndexChecksumError(f"missing artifact: {rel}")
        size = os.path.getsize(full)
        if size != entry["bytes"]:
            raise IndexChecksumError(
                f"{rel}: size {size} != manifest {entry['bytes']} (truncated?)")
        if level == "full" and file_sha256(full) != entry["sha256"]:
            raise IndexChecksumError(f"{rel}: sha256 mismatch (corrupted)")
