"""Sharded on-disk ClusterStore backends over a built index's per-shard
block files.

Shard s memmaps `blocks/shard_s.bin` (or `.codes.bin`), owning clusters
[lo_s, hi_s). `fetch_blocks` routes each requested cluster to its shard and
coalesces runs of adjacent cluster ids *within* a shard into single
contiguous memmap reads — `IOStats.n_ops` counts runs, not blocks, matching
the coalesced `DiskClusterStore.fetch_clusters`. Thread-safe stats so the
engine's background prefetcher can share the store with serving.

Two record encodings behind the same routing:

  * ShardedDiskStore — raw float blocks (format v1): one (cap, dim) tensor
    per cluster, returned as read.
  * ShardedPQStore — PQ code blocks (format v2): one (cap, nsub) uint8
    tensor per cluster. Two fetch paths: `fetch_blocks` decodes through
    the (nsub, 256, dsub) codebooks on the host (the legacy
    decode-then-score path, still used by label streaming), while
    `fetch_code_blocks` returns the RAW uint8 codes (`is_coded=True`) so
    the engine can cache codes (16x more clusters per cache byte) and
    score them in-kernel via ADC lookup tables (repro.kernels.adc) —
    the same per-subspace dot terms as dot(q, decode(codes)), summed in
    the documented ascending-subspace order, with no float block ever
    materialized on the host. Either way the bytes that cross the disk
    boundary shrink by 4*dim/nsub vs float32 blocks.

ShardedDiskStore additionally speaks the reduced-precision v1 shard
dtypes (format-additive): bfloat16 records decode to float32 on fetch,
int8 records decode as `record * block_scale` with the per-index scale
from the manifest geometry.

Both plug into `repro.engine` exactly like `DiskStore` (is_host backends):
selection runs batched on device; the pipeline fetches deduplicated,
sorted unique cluster ids — which is what makes run coalescing pay off.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.disk import IOStats, read_blocks_coalesced
from repro.core.quant import decode_code_blocks


class _ShardedBlockFiles:
    """Shared routing + run-coalescing over per-shard fixed-record files.

    Subclasses define the on-disk record (shape/dtype per cluster) and how
    a batch of raw records decodes into float embedding blocks."""

    is_host = True
    # True on subclasses whose raw records are PQ codes the engine may
    # fetch undecoded (fetch_code_blocks) and score via ADC LUTs.
    is_coded = False

    def __init__(self, shard_paths, shard_ranges, record_shape, record_dtype,
                 cluster_docs, tombstones=None, stats: IOStats = None):
        if len(shard_paths) != len(shard_ranges) or not shard_paths:
            raise ValueError("need one path per shard range")
        self.record_shape = tuple(int(x) for x in record_shape)
        self.record_dtype = np.dtype(record_dtype)
        self._lo = np.asarray([lo for lo, _ in shard_ranges], np.int64)
        self._hi = np.asarray([hi for _, hi in shard_ranges], np.int64)
        # ranges must be ascending and non-overlapping; gaps ARE allowed:
        # a multi-host serving tier opens one store per host over only the
        # shard files that host owns (engine/router.py), so the ranges no
        # longer have to tile [0, N). Fetching a cluster in a gap raises.
        if np.any(self._lo >= self._hi) or np.any(self._lo[1:] < self._hi[:-1]):
            raise ValueError(f"shard ranges must be ascending and "
                             f"non-overlapping: "
                             f"{list(zip(self._lo, self._hi))}")
        self.n_clusters = int(self._hi[-1])
        self.owned_ranges = [(int(lo), int(hi))
                             for lo, hi in zip(self._lo, self._hi)]
        self.is_subset = bool(self._lo[0] != 0
                              or np.any(self._lo[1:] != self._hi[:-1]))
        self._mms = [
            np.memmap(p, dtype=self.record_dtype, mode="r",
                      shape=(int(hi - lo),) + self.record_shape)
            for p, (lo, hi) in zip(shard_paths, shard_ranges)]
        # tombstone masking happens HERE, at the doc-id table the fetch
        # paths consult: a deleted slot's bytes stay on disk (deletes are
        # zero-rewrite), but fetch_blocks reports it as docs=-1/valid=False
        # and the host scoring path never scores it.
        cd = np.asarray(cluster_docs)
        if tombstones is not None:
            tomb = np.asarray(tombstones)
            if tomb.shape != cd.shape:
                raise ValueError(f"tombstones shape {tomb.shape} != "
                                 f"cluster_docs shape {cd.shape}")
            cd = np.where(tomb > 0, -1, cd)
        self.tombstones = tombstones
        self.cluster_docs = jnp.asarray(cd)
        self.cluster_docs_np = cd
        # bytes that actually cross the disk boundary per cluster record
        self.block_bytes = int(np.prod(self.record_shape)) * \
            self.record_dtype.itemsize
        self.stats = stats if stats is not None else IOStats()
        self.decode_ms = 0.0          # host decode time, outside IOStats
        self._lock = threading.Lock()

    @property
    def n_shards(self):
        return len(self._mms)

    # -- decoding hook ------------------------------------------------------

    def _decode(self, records):
        """(n,) + record_shape raw records -> (n, cap, dim) float blocks."""
        return records

    def _empty_blocks(self):
        return np.zeros((0,) + self.record_shape, self.record_dtype)

    # -- fetch --------------------------------------------------------------

    def _fetch_records(self, cluster_ids):
        """1-D host sequence of cluster ids -> (raw records, docs, valid).

        Does the shard routing + run-coalesced reads and charges IOStats;
        returns records UNDECODED (decode accounting is the caller's)."""
        ids = np.asarray(cluster_ids, np.int64).reshape(-1)
        docs = self.cluster_docs_np[ids]
        valid = docs >= 0
        n = len(ids)
        if n == 0:
            return self._empty_blocks(), docs, valid
        t0 = time.perf_counter()
        out = np.empty((n,) + self.record_shape, self.record_dtype)
        sid = np.searchsorted(self._hi, ids, side="right")
        oob = (ids < 0) | (sid >= len(self._mms))
        if np.any(oob) or np.any(
                ids < self._lo[np.minimum(sid, len(self._mms) - 1)]):
            bad = ids[oob | (ids < self._lo[np.minimum(
                sid, len(self._mms) - 1)])]
            raise KeyError(f"cluster ids {bad[:8].tolist()} not owned by "
                           f"this store (owned ranges {self.owned_ranges})")
        # split at shard changes OR non-adjacent ids; coalesce inside a run
        brk = np.flatnonzero((np.diff(ids) != 1) | (np.diff(sid) != 0)) + 1
        bounds = np.concatenate([[0], brk, [n]])
        n_ops = 0
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            s = int(sid[lo])
            local = ids[lo:hi] - self._lo[s]
            _, runs = read_blocks_coalesced(self._mms[s], local, out,
                                            out_offset=int(lo))
            n_ops += runs
        with self._lock:
            self.stats.add(n_ops, n * self.block_bytes,
                           (time.perf_counter() - t0) * 1e3)
        return out, docs, valid

    def fetch_blocks(self, cluster_ids):
        """1-D host sequence of cluster ids -> (vecs, docs, valid)."""
        records, docs, valid = self._fetch_records(cluster_ids)
        t1 = time.perf_counter()
        vecs = self._decode(records)
        # IOStats.wall_ms measures only the disk reads; decode is host
        # compute and accounted separately so format v1/v2 I/O stays
        # comparable in the BENCH trajectory
        with self._lock:
            self.decode_ms += (time.perf_counter() - t1) * 1e3
        return vecs, docs, valid

    def fetch_clusters(self, cluster_ids, stats: IOStats = None):
        """DiskClusterStore-compatible view: blocks only, optional extra
        stats sink (the store's own IOStats always accumulates)."""
        t0 = time.perf_counter()
        before = (self.stats.n_ops, self.stats.bytes)
        vecs, _, _ = self.fetch_blocks(cluster_ids)
        if stats is not None:
            stats.add(self.stats.n_ops - before[0],
                      self.stats.bytes - before[1],
                      (time.perf_counter() - t0) * 1e3)
        return jnp.asarray(vecs)


class ShardedDiskStore(_ShardedBlockFiles):
    """Format-v1 backend: raw cluster blocks in float32, bfloat16 or int8.

    float32 records are returned as read. The reduced-precision dtypes
    (format-additive, see index README) decode to float32 on fetch:
    bfloat16 by widening, int8 by `record * block_scale` with the
    per-index scale stamped in the manifest geometry at build time.
    """

    def __init__(self, shard_paths, shard_ranges, cap, dim, cluster_docs,
                 dtype=np.float32, block_scale=None, tombstones=None,
                 stats: IOStats = None):
        """shard_paths[i] holds clusters [shard_ranges[i][0], shard_ranges[i][1])
        as a raw (hi-lo, cap, dim) block tensor."""
        super().__init__(shard_paths, shard_ranges, (int(cap), int(dim)),
                         dtype, cluster_docs, tombstones=tombstones,
                         stats=stats)
        self.cap, self.dim = int(cap), int(dim)
        self.dtype = self.record_dtype
        if self.record_dtype == np.int8:
            if block_scale is None:
                raise ValueError("int8 shards need the manifest geometry's "
                                 "block_scale to decode")
            self.block_scale = float(block_scale)
        else:
            self.block_scale = None

    def _decode(self, records):
        if self.record_dtype == np.float32:
            return records
        if self.record_dtype == np.int8:
            return records.astype(np.float32) * np.float32(self.block_scale)
        return records.astype(np.float32)      # bfloat16 and friends: widen


class ShardedPQStore(_ShardedBlockFiles):
    """Format-v2 backend: PQ code shards decoded through the codebooks.

    Each cluster record is (cap, nsub) uint8; `fetch_blocks` reads codes
    with the same run coalescing as ShardedDiskStore, then reconstructs
    (cap, dim) float blocks on the host: vec[slot] = concat_s
    codebooks[s, code[slot, s]] (optionally un-rotated). `IOStats.bytes`
    counts CODE bytes — the 4*dim/nsub I/O reduction is visible there.
    """

    def __init__(self, shard_paths, shard_ranges, cap, codebooks,
                 cluster_docs, rotation=None, out_dtype=np.float32,
                 tombstones=None, stats: IOStats = None):
        self.codebooks = np.asarray(codebooks, np.float32)
        if self.codebooks.ndim != 3:
            raise ValueError(f"codebooks must be (nsub, n_codes, dsub), "
                             f"got {self.codebooks.shape}")
        self.nsub = int(self.codebooks.shape[0])
        self.rotation = None if rotation is None \
            else np.asarray(rotation, np.float32)
        super().__init__(shard_paths, shard_ranges, (int(cap), self.nsub),
                         np.uint8, cluster_docs, tombstones=tombstones,
                         stats=stats)
        self.cap = int(cap)
        self.dim = int(self.nsub * self.codebooks.shape[2])
        self.dtype = np.dtype(out_dtype)

    is_coded = True

    def _decode(self, records):
        return decode_code_blocks(self.codebooks, records,
                                  self.rotation).astype(self.dtype,
                                                        copy=False)

    def _empty_blocks(self):
        return np.zeros((0, self.cap, self.nsub), np.uint8)

    def fetch_code_blocks(self, cluster_ids):
        """Like fetch_blocks but returns the RAW (n, cap, nsub) uint8 code
        records — no host decode (decode_ms untouched). The engine caches
        these (16x more clusters per cache byte than float blocks) and
        scores them via ADC lookup tables (repro.kernels.adc)."""
        return self._fetch_records(cluster_ids)
