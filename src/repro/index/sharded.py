"""ShardedDiskStore: engine ClusterStore backend over a built index's
per-shard block files.

Shard s memmaps `blocks/shard_s.bin`, owning clusters [lo_s, hi_s).
`fetch_blocks` routes each requested cluster to its shard and coalesces
runs of adjacent cluster ids *within* a shard into single contiguous
memmap reads — `IOStats.n_ops` counts runs, not blocks, matching the
coalesced `DiskClusterStore.fetch_clusters`. Thread-safe stats so the
engine's background prefetcher can share the store with serving.

Plugs into `repro.engine` exactly like `DiskStore` (is_host backend):
selection runs batched on device; the pipeline fetches deduplicated,
sorted unique cluster ids — which is what makes run coalescing pay off.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.disk import IOStats, read_blocks_coalesced


class ShardedDiskStore:
    is_host = True

    def __init__(self, shard_paths, shard_ranges, cap, dim, cluster_docs,
                 dtype=np.float32, stats: IOStats = None):
        """shard_paths[i] holds clusters [shard_ranges[i][0], shard_ranges[i][1])
        as a raw (hi-lo, cap, dim) block tensor."""
        if len(shard_paths) != len(shard_ranges) or not shard_paths:
            raise ValueError("need one path per shard range")
        self.dtype = np.dtype(dtype)
        self.cap, self.dim = int(cap), int(dim)
        self._lo = np.asarray([lo for lo, _ in shard_ranges], np.int64)
        self._hi = np.asarray([hi for _, hi in shard_ranges], np.int64)
        if (self._lo[0] != 0 or np.any(self._lo[1:] != self._hi[:-1])):
            raise ValueError(f"shard ranges must tile [0, N): "
                             f"{list(zip(self._lo, self._hi))}")
        self.n_clusters = int(self._hi[-1])
        self._mms = [
            np.memmap(p, dtype=self.dtype, mode="r",
                      shape=(int(hi - lo), self.cap, self.dim))
            for p, (lo, hi) in zip(shard_paths, shard_ranges)]
        self.cluster_docs = jnp.asarray(cluster_docs)
        self.cluster_docs_np = np.asarray(cluster_docs)
        self.block_bytes = self.cap * self.dim * self.dtype.itemsize
        self.stats = stats if stats is not None else IOStats()
        self._lock = threading.Lock()

    @property
    def n_shards(self):
        return len(self._mms)

    def fetch_blocks(self, cluster_ids):
        """1-D host sequence of cluster ids -> (vecs, docs, valid)."""
        ids = np.asarray(cluster_ids, np.int64).reshape(-1)
        docs = self.cluster_docs_np[ids]
        valid = docs >= 0
        n = len(ids)
        if n == 0:
            return (np.zeros((0, self.cap, self.dim), self.dtype),
                    docs, valid)
        t0 = time.perf_counter()
        out = np.empty((n, self.cap, self.dim), self.dtype)
        sid = np.searchsorted(self._hi, ids, side="right")
        # split at shard changes OR non-adjacent ids; coalesce inside a run
        brk = np.flatnonzero((np.diff(ids) != 1) | (np.diff(sid) != 0)) + 1
        bounds = np.concatenate([[0], brk, [n]])
        n_ops = 0
        for lo, hi in zip(bounds[:-1], bounds[1:]):
            s = int(sid[lo])
            local = ids[lo:hi] - self._lo[s]
            _, runs = read_blocks_coalesced(self._mms[s], local, out,
                                            out_offset=int(lo))
            n_ops += runs
        wall = (time.perf_counter() - t0) * 1e3
        with self._lock:
            self.stats.add(n_ops, n * self.block_bytes, wall)
        return out, docs, valid

    def fetch_clusters(self, cluster_ids, stats: IOStats = None):
        """DiskClusterStore-compatible view: blocks only, optional extra
        stats sink (the store's own IOStats always accumulates)."""
        t0 = time.perf_counter()
        before = (self.stats.n_ops, self.stats.bytes)
        vecs, _, _ = self.fetch_blocks(cluster_ids)
        if stats is not None:
            stats.add(self.stats.n_ops - before[0],
                      self.stats.bytes - before[1],
                      (time.perf_counter() - t0) * 1e3)
        return jnp.asarray(vecs)
