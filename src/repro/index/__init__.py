"""Persistent sharded index subsystem: offline build pipeline, versioned
on-disk format (v1 float blocks, v2 PQ code shards), an mmap loader that
feeds the engine stores, and incremental updates (upsert/delete deltas,
tombstones, atomic generations, compaction). See README.md in this
directory for the manifest schema, shard layout, and update protocol."""

from repro.index.builder import (
    RowSlice, build_index_offline, embedding_shards, postings_csr,
    shard_ranges, write_index)
from repro.index.format import (
    FORMAT_VERSION, FORMAT_VERSION_PQ, SUPPORTED_VERSIONS,
    IndexChecksumError, IndexFormatError, file_sha256, load_manifest,
    manifest_generation, verify_files)
from repro.index.reader import IndexReader
from repro.index.sharded import ShardedDiskStore, ShardedPQStore
from repro.index.update import (
    IndexDelta, apply_delta_to_index, compact_index, write_index_delta)

__all__ = [
    "FORMAT_VERSION", "FORMAT_VERSION_PQ", "IndexChecksumError",
    "IndexDelta", "IndexFormatError", "IndexReader", "RowSlice",
    "SUPPORTED_VERSIONS", "ShardedDiskStore", "ShardedPQStore",
    "apply_delta_to_index", "build_index_offline", "compact_index",
    "embedding_shards", "file_sha256", "load_manifest",
    "manifest_generation", "postings_csr", "shard_ranges", "verify_files",
    "write_index", "write_index_delta",
]
