"""Persistent sharded index subsystem: offline build pipeline, versioned
on-disk format (v1 float blocks, v2 PQ code shards), and an mmap loader
that feeds the engine stores. See README.md in this directory for the
manifest schema and shard layout."""

from repro.index.builder import (
    RowSlice, build_index_offline, embedding_shards, shard_ranges,
    write_index)
from repro.index.format import (
    FORMAT_VERSION, FORMAT_VERSION_PQ, SUPPORTED_VERSIONS,
    IndexChecksumError, IndexFormatError, file_sha256, load_manifest,
    verify_files)
from repro.index.reader import IndexReader
from repro.index.sharded import ShardedDiskStore, ShardedPQStore

__all__ = [
    "FORMAT_VERSION", "FORMAT_VERSION_PQ", "IndexChecksumError",
    "IndexFormatError", "IndexReader", "RowSlice", "SUPPORTED_VERSIONS",
    "ShardedDiskStore", "ShardedPQStore", "build_index_offline",
    "embedding_shards", "file_sha256", "load_manifest", "shard_ranges",
    "verify_files", "write_index",
]
