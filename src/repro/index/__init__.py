"""Persistent sharded index subsystem: offline build pipeline, versioned
on-disk format, and an mmap loader that feeds the engine stores. See
README.md in this directory for the manifest schema and shard layout."""

from repro.index.builder import (
    build_index_offline, embedding_shards, shard_ranges, write_index)
from repro.index.format import (
    FORMAT_VERSION, IndexChecksumError, IndexFormatError, file_sha256,
    load_manifest, verify_files)
from repro.index.reader import IndexReader
from repro.index.sharded import ShardedDiskStore

__all__ = [
    "FORMAT_VERSION", "IndexChecksumError", "IndexFormatError",
    "IndexReader", "ShardedDiskStore", "build_index_offline",
    "embedding_shards", "file_sha256", "load_manifest", "shard_ranges",
    "verify_files", "write_index",
]
