"""IndexReader: open a built index directory and serve from it.

Opening is cheap: the manifest is validated (format version always; file
sizes by default; sha256 with verify="full"), per-index arrays are
np.load-ed with mmap_mode="r", and cluster blocks stay in their per-shard
files behind a `ShardedDiskStore`. The document embedding matrix is never
materialized — `load_index()` returns a CluSDIndex with `embeddings=None`,
and Step-3 dense scoring reads only selected cluster blocks.

    reader = IndexReader.open("/path/to/index", verify="full")
    cfg, index = reader.load_index()
    engine = reader.engine(max_batch=32)        # RetrievalEngine, sharded I/O
    ids, scores = engine.retrieve(q_dense, q_terms, q_weights)
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint
from repro.configs.base import CluSDConfig
from repro.core.clusd import CluSDIndex
from repro.core.disk import IOStats
from repro.core.lstm import lstm_init
from repro.core.sparse import SparseIndex
from repro.index import format as fmt
from repro.index.sharded import ShardedDiskStore


class IndexReader:
    def __init__(self, index_dir, manifest):
        self.index_dir = os.path.abspath(index_dir)
        self.manifest = manifest
        self.geometry = manifest["geometry"]

    @classmethod
    def open(cls, index_dir, verify="size"):
        """Validate and open. verify: "none" | "size" (default) | "full"."""
        manifest = fmt.load_manifest(index_dir)
        fmt.verify_files(index_dir, manifest, level=verify)
        return cls(index_dir, manifest)

    # -- raw artifacts ------------------------------------------------------

    def array(self, name):
        """Mmap a per-index array by logical name (no copy)."""
        rel = self.manifest["arrays"][name]
        return np.load(os.path.join(self.index_dir, rel), mmap_mode="r")

    def config(self) -> CluSDConfig:
        d = dict(self.manifest["config"])
        d["bins"] = tuple(d["bins"])
        return CluSDConfig(**d)

    def lstm_params(self):
        meta = self.manifest["lstm"]
        if meta is None:
            return None
        target = lstm_init(jax.random.key(0), meta["feat_dim"],
                           meta["hidden"])
        params, _ = restore_checkpoint(
            os.path.join(self.index_dir, meta["dir"]), meta["step"], target)
        return params

    def quantizer(self):
        meta = self.manifest["pq"]
        if meta is None:
            return None
        from repro.core.quant import PQ
        load = lambda rel: jnp.asarray(
            np.load(os.path.join(self.index_dir, rel)))
        rot = meta["arrays"].get("rotation")
        return PQ(codebooks=load(meta["arrays"]["codebooks"]),
                  codes=load(meta["arrays"]["codes"]),
                  rotation=load(rot) if rot else None,
                  nsub=meta["nsub"])

    # -- engine-level objects ----------------------------------------------

    def load_index(self):
        """(cfg, CluSDIndex) with embeddings=None; small arrays go to device,
        blocks stay on disk (serve via `open_store()` / `engine()`)."""
        cfg = self.config()
        sp = SparseIndex(
            postings_docs=jnp.asarray(self.array("sparse_postings_docs")),
            postings_weights=jnp.asarray(
                self.array("sparse_postings_weights")),
            n_docs=self.geometry["n_docs"])
        index = CluSDIndex(
            centroids=jnp.asarray(self.array("centroids")),
            cluster_docs=jnp.asarray(self.array("cluster_docs")),
            doc_cluster=jnp.asarray(self.array("doc_cluster")),
            neighbor_ids=jnp.asarray(self.array("neighbor_ids")),
            neighbor_sims=jnp.asarray(self.array("neighbor_sims")),
            embeddings=None, sparse_index=sp,
            lstm_params=self.lstm_params(), quantizer=self.quantizer(),
            bin_ids=jnp.asarray(self.array("bin_ids")))
        return cfg, index

    def open_store(self, cluster_docs=None, stats: IOStats = None):
        """ShardedDiskStore over the block shard files (mmap, read-only)."""
        g = self.geometry
        shards = self.manifest["block_shards"]
        if cluster_docs is None:
            cluster_docs = self.array("cluster_docs")
        return ShardedDiskStore(
            [os.path.join(self.index_dir, s["file"]) for s in shards],
            [(s["cluster_lo"], s["cluster_hi"]) for s in shards],
            g["cap"], g["dim"], cluster_docs,
            dtype=np.dtype(g["block_dtype"]), stats=stats)

    def engine(self, cfg=None, index=None, **engine_kw):
        """RetrievalEngine serving this index through the sharded store."""
        from repro.engine.server import RetrievalEngine
        if index is None:
            loaded_cfg, index = self.load_index()
            cfg = cfg or loaded_cfg
        cfg = cfg if cfg is not None else self.config()
        store = self.open_store(cluster_docs=index.cluster_docs)
        return RetrievalEngine(cfg, index, store=store, **engine_kw)
