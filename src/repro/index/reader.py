"""IndexReader: open a built index directory and serve from it.

Opening is cheap: the manifest is validated (format version always; file
sizes by default; sha256 with verify="full"), per-index arrays are
np.load-ed with mmap_mode="r", and cluster blocks stay in their per-shard
files behind a sharded store. The document embedding matrix is never
materialized — `load_index()` returns a CluSDIndex with `embeddings=None`,
and Step-3 dense scoring reads only selected cluster blocks.

Both on-disk formats are served through the same API:

  format_version 1 — float block shards -> ShardedDiskStore
  format_version 2 — PQ code shards -> ShardedPQStore (codes decoded
    through the manifest's codebooks at fetch time; asymmetric-distance
    scoring), CSR postings re-padded at load (lossless)

    reader = IndexReader.open("/path/to/index", verify="full")
    cfg, index = reader.load_index()
    engine = reader.engine(max_batch=32)        # RetrievalEngine, sharded I/O
    ids, scores = engine.retrieve(q_dense, q_terms, q_weights)
"""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint
from repro.configs.base import CluSDConfig
from repro.core.clusd import CluSDIndex
from repro.core.disk import IOStats
from repro.core.lstm import lstm_init
from repro.core.sparse import SparseIndex
from repro.index import format as fmt
from repro.index.sharded import ShardedDiskStore, ShardedPQStore


class IndexReader:
    def __init__(self, index_dir, manifest):
        self.index_dir = os.path.abspath(index_dir)
        self.manifest = manifest
        self.geometry = manifest["geometry"]

    @classmethod
    def open(cls, index_dir, verify="size",
             supported=fmt.SUPPORTED_VERSIONS):
        """Validate and open. verify: "none" | "size" (default) | "full".
        `supported` narrows the format versions this reader accepts — a
        PR-2-era (v1-only) reader is `supported=(1,)`."""
        manifest = fmt.load_manifest(index_dir, supported=supported)
        fmt.verify_files(index_dir, manifest, level=verify)
        return cls(index_dir, manifest)

    @property
    def format_version(self):
        return self.manifest["format_version"]

    @property
    def is_pq(self):
        return self.format_version == fmt.FORMAT_VERSION_PQ

    @property
    def generation(self):
        """Index generation: 0 for a fresh build, +1 per committed delta
        (repro.index.update). Missing key (pre-generation manifests) = 0."""
        return fmt.manifest_generation(self.manifest)

    def refresh(self, verify="none"):
        """Re-read manifest.json and adopt a newer generation if one was
        committed since open. Returns True when the generation changed
        (callers should then rebuild stores/engines — see
        `RetrievalEngine.reload_index`), False when nothing moved.
        Delta commits replace the manifest atomically, so this never
        observes a torn state."""
        manifest = fmt.load_manifest(self.index_dir)
        if fmt.manifest_generation(manifest) == self.generation:
            return False
        fmt.verify_files(self.index_dir, manifest, level=verify)
        self.manifest = manifest
        self.geometry = manifest["geometry"]
        return True

    # -- raw artifacts ------------------------------------------------------

    def array(self, name):
        """Mmap a per-index array by logical name (no copy)."""
        rel = self.manifest["arrays"][name]
        return np.load(os.path.join(self.index_dir, rel), mmap_mode="r")

    def tombstones(self):
        """(n_clusters, cap) uint8 delete bitmap, or None when this
        generation has no deletes (fresh builds, compacted indexes)."""
        if "tombstones" not in self.manifest["arrays"]:
            return None
        return np.asarray(self.array("tombstones"))

    def masked_cluster_docs(self):
        """cluster_docs with tombstoned slots already masked to -1 — the
        doc-id table every serving path should see (deleted docs score as
        invalid without any shard bytes having been rewritten)."""
        cd = np.asarray(self.array("cluster_docs"))
        tomb = self.tombstones()
        if tomb is None:
            return cd
        return np.where(tomb > 0, -1, cd)

    def config(self) -> CluSDConfig:
        d = dict(self.manifest["config"])
        d["bins"] = tuple(d["bins"])
        return CluSDConfig(**d)

    def selector_meta(self):
        """Selector-publish metadata (repro.train.publish_selector): the
        calibrated operating point {theta, budget}, the full calibration
        table, label config, and training stats — or None for indexes
        whose selector came from the offline build (no publish yet)."""
        return self.manifest.get("selector")

    def lstm_params(self):
        meta = self.manifest["lstm"]
        if meta is None:
            return None
        target = lstm_init(jax.random.key(0), meta["feat_dim"],
                           meta["hidden"])
        params, _ = restore_checkpoint(
            os.path.join(self.index_dir, meta["dir"]), meta["step"], target)
        return params

    def _pq_array(self, name):
        rel = self.manifest["pq"]["arrays"].get(name)
        if rel is None:
            return None
        return np.load(os.path.join(self.index_dir, rel))

    def _doc_codes(self):
        """Rebuild per-doc (D, nsub) codes from the v2 code shards (cheap:
        nsub bytes per doc) — lets device-side ADC (PQStore) serve a v2
        index for parity checks and small corpora."""
        g = self.geometry
        codes = np.zeros((g["n_docs"], g["nsub"]), np.uint8)
        cd = self.masked_cluster_docs()   # a replaced doc's stale slot is
        for s in self.manifest["block_shards"]:   # tombstoned — skip it
            lo, hi = s["cluster_lo"], s["cluster_hi"]
            mm = np.memmap(os.path.join(self.index_dir, s["file"]),
                           dtype=np.uint8, mode="r",
                           shape=(hi - lo, g["cap"], g["nsub"]))
            local_cd = cd[lo:hi]
            mask = local_cd >= 0
            codes[local_cd[mask]] = mm[mask]
        return codes

    def quantizer(self):
        meta = self.manifest["pq"]
        if meta is None:
            return None
        from repro.core.quant import PQ
        rot = self._pq_array("rotation")
        if self.is_pq:
            return PQ(codebooks=jnp.asarray(self._pq_array("codebooks")),
                      codes=jnp.asarray(self._doc_codes().astype(np.int32)),
                      rotation=None if rot is None else jnp.asarray(rot),
                      nsub=meta["nsub"])
        return PQ(codebooks=jnp.asarray(self._pq_array("codebooks")),
                  codes=jnp.asarray(self._pq_array("codes")),
                  rotation=None if rot is None else jnp.asarray(rot),
                  nsub=meta["nsub"])

    # -- engine-level objects ----------------------------------------------

    def _sparse_index(self):
        if not self.is_pq:
            return SparseIndex(
                postings_docs=jnp.asarray(self.array("sparse_postings_docs")),
                postings_weights=jnp.asarray(
                    self.array("sparse_postings_weights")),
                n_docs=self.geometry["n_docs"])
        # v2: re-pad the CSR postings (lossless — sparse scoring is a
        # scatter-add over valid entries; pad width never changes scores)
        from repro.index.builder import postings_from_csr
        pd, pw = postings_from_csr(self.array("sparse_postings_data"),
                                   self.array("sparse_postings_wdata"),
                                   self.array("sparse_postings_indptr"))
        return SparseIndex(postings_docs=jnp.asarray(pd),
                           postings_weights=jnp.asarray(pw),
                           n_docs=self.geometry["n_docs"])

    def load_index(self, load_quantizer=None):
        """(cfg, CluSDIndex) with embeddings=None; small arrays go to device,
        blocks stay on disk (serve via `open_store()` / `engine()`).

        load_quantizer: by default PQ artifacts load for v1 (cheap — they
        sit in pq/*.npy) but NOT for v2, where rebuilding the per-doc code
        view would read every code shard at open time; v2 serving decodes
        straight from the shards (`open_store()`), so cold open stays
        manifest + mmap only. Pass True to force (device-side ADC over a
        v2 index), or call `reader.quantizer()` directly."""
        if load_quantizer is None:
            load_quantizer = not self.is_pq
        cfg = self.config()
        index = CluSDIndex(
            centroids=jnp.asarray(self.array("centroids")),
            cluster_docs=jnp.asarray(self.masked_cluster_docs()),
            doc_cluster=jnp.asarray(self.array("doc_cluster")),
            neighbor_ids=jnp.asarray(self.array("neighbor_ids")),
            neighbor_sims=jnp.asarray(self.array("neighbor_sims")),
            embeddings=None, sparse_index=self._sparse_index(),
            lstm_params=self.lstm_params(),
            quantizer=self.quantizer() if load_quantizer else None,
            bin_ids=jnp.asarray(self.array("bin_ids")))
        return cfg, index

    def n_block_shards(self):
        return len(self.manifest["block_shards"])

    def open_store(self, cluster_docs=None, stats: IOStats = None,
                   shards=None):
        """Sharded store over the block shard files (mmap, read-only):
        ShardedDiskStore for v1 float blocks, ShardedPQStore for v2 code
        shards (decode-on-fetch ADC). The generation's tombstone bitmap is
        handed to the store, which masks deleted slots at fetch time.

        `shards`: optional iterable of shard indices (into the manifest's
        block_shards list) to open a SUBSET store over — the multi-host
        serving tier gives each host a store over only the shards it
        owns. Fetching a cluster outside the subset raises; cluster_docs
        and tombstones stay full-size (they are global tables)."""
        g = self.geometry
        all_shards = self.manifest["block_shards"]
        if shards is None:
            shards = all_shards
        else:
            idx = sorted(set(int(s) for s in shards))
            if not idx or idx[0] < 0 or idx[-1] >= len(all_shards):
                raise ValueError(f"shard subset {idx} out of range for "
                                 f"{len(all_shards)} block shards")
            shards = [all_shards[i] for i in idx]
        paths = [os.path.join(self.index_dir, s["file"]) for s in shards]
        ranges = [(s["cluster_lo"], s["cluster_hi"]) for s in shards]
        tomb = self.tombstones()
        if cluster_docs is None:
            cluster_docs = self.array("cluster_docs")
        if self.is_pq:
            return ShardedPQStore(
                paths, ranges, g["cap"], self._pq_array("codebooks"),
                cluster_docs, rotation=self._pq_array("rotation"),
                out_dtype=np.dtype(g["block_dtype"]), tombstones=tomb,
                stats=stats)
        return ShardedDiskStore(
            paths, ranges, g["cap"], g["dim"], cluster_docs,
            dtype=fmt.resolve_block_dtype(g["block_dtype"]),
            block_scale=g.get("block_scale"), tombstones=tomb, stats=stats)

    def engine(self, cfg=None, index=None, **engine_kw):
        """RetrievalEngine serving this index through the sharded store.
        The engine keeps a handle on this reader, so
        `engine.reload_index()` hot-swaps to a newer committed generation
        (repro.index.update) with no restart."""
        from repro.engine.server import RetrievalEngine
        if index is None:
            loaded_cfg, index = self.load_index()
            cfg = cfg or loaded_cfg
        cfg = cfg if cfg is not None else self.config()
        store = self.open_store(cluster_docs=index.cluster_docs)
        return RetrievalEngine(cfg, index, store=store, reader=self,
                               **engine_kw)
