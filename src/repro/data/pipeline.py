"""Host->device input pipeline: background-thread prefetch of host batches,
device_put with the cell's shardings (so arrays land already distributed),
and deterministic per-step RNG streams for restart reproducibility."""

import queue
import threading

import jax
import numpy as np


class Prefetcher:
    """Wrap a host batch iterator; overlaps host batch construction with
    device compute by `depth` slots (classic double buffering)."""

    def __init__(self, it, shardings=None, depth=2):
        self.it = it
        self.shardings = shardings
        self.q = queue.Queue(maxsize=depth)
        self._done = object()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        try:
            for batch in self.it:
                if self.shardings is not None:
                    batch = jax.tree.map(
                        lambda x, s: jax.device_put(x, s), batch,
                        self.shardings)
                else:
                    batch = jax.tree.map(jax.device_put, batch)
                self.q.put(batch)
        finally:
            self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._done:
            raise StopIteration
        return item


def lm_synthetic_batches(vocab, batch, seq, steps, seed=0):
    """Deterministic synthetic LM token stream (ngram-ish structure so the
    loss actually falls): next token = (3*tok + noise) % vocab."""
    rng = np.random.default_rng(seed)
    for step in range(steps):
        first = rng.integers(0, vocab, (batch, 1))
        toks = [first]
        for _ in range(seq):
            nxt = (3 * toks[-1] + rng.integers(0, 7, (batch, 1))) % vocab
            toks.append(nxt)
        arr = np.concatenate(toks, axis=1).astype(np.int32)
        yield {"tokens": arr[:, :seq], "labels": arr[:, 1:seq + 1]}
