from repro.data.synthetic import (
    Corpus, QuerySet, synth_corpus, synth_queries, mrr_at, recall_at)
