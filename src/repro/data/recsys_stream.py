"""Synthetic Criteo-like click stream for the recsys archs: categorical
draws follow a Zipf over each table's vocabulary (real id traffic is heavy
tailed — this is what makes mod-sharded tables imbalanced, which the
embedding tests exercise) and the label depends on a sparse logistic ground
truth so AUC is learnable."""

import numpy as np


class RecsysStream:
    def __init__(self, cfg, seed=0, zipf_a=1.3):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.zipf_a = zipf_a
        # hidden ground-truth: one weight per (field, bucket-of-64)
        self.true_w = {
            i: self.rng.standard_normal(max(rows // 64, 1)) * 0.5
            for i, rows in enumerate(cfg.table_sizes)}
        self.dense_w = self.rng.standard_normal(max(cfg.n_dense, 1)) * 0.3

    def _draw_ids(self, rows, size):
        z = self.rng.zipf(self.zipf_a, size=size)
        return np.minimum(z - 1, rows - 1).astype(np.int32)

    def batch(self, batch_size):
        cfg = self.cfg
        sparse = np.stack(
            [self._draw_ids(rows, batch_size)
             for rows in cfg.table_sizes], axis=1)
        logit = np.zeros(batch_size, np.float32)
        for i, rows in enumerate(cfg.table_sizes):
            logit += self.true_w[i][np.minimum(sparse[:, i] // 64,
                                               len(self.true_w[i]) - 1)]
        out = {"sparse": sparse}
        if cfg.n_dense:
            dense = self.rng.standard_normal(
                (batch_size, cfg.n_dense)).astype(np.float32)
            logit += dense @ self.dense_w
            out["dense"] = dense
        if cfg.kind == "din":
            L = cfg.seq_len
            out["hist_item"] = self._draw_ids(cfg.table_sizes[0],
                                              batch_size * L).reshape(-1, L)
            out["hist_cate"] = self._draw_ids(cfg.table_sizes[1],
                                              batch_size * L).reshape(-1, L)
            lens = self.rng.integers(1, L + 1, batch_size)
            out["hist_mask"] = (np.arange(L)[None] < lens[:, None]).astype(
                np.float32)
        p = 1 / (1 + np.exp(-logit))
        out["label"] = (self.rng.random(batch_size) < p).astype(np.int32)
        return out
