"""CSR neighbor sampler for minibatch GNN training (GraphSAGE-style fixed
fanout, e.g. 15-10). Host-side numpy (the sampler is data-pipeline work);
emits padded, static-shape subgraph batches that the jitted train step
consumes directly (the `minibatch_lg` dry-run cell uses exactly these
shapes).
"""

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,)
    n_nodes: int

    @staticmethod
    def from_edges(src, dst, n_nodes):
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(src_s, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr, dst_s.astype(np.int32), n_nodes)

    def degree(self, u):
        return self.indptr[u + 1] - self.indptr[u]

    def neighbors(self, u):
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def sample_fanout(graph: CSRGraph, seed_nodes, fanout, rng):
    """Layer-wise fanout sampling. Returns (nodes, edge_src, edge_dst) where
    edge endpoints index into `nodes` (local ids); nodes[0:len(seeds)] are
    the seeds. Sampling WITH replacement when degree < fanout (standard)."""
    nodes = list(seed_nodes)
    local = {int(n): i for i, n in enumerate(seed_nodes)}
    esrc, edst = [], []
    frontier = list(seed_nodes)
    for f in fanout:
        nxt = []
        for u in frontier:
            nbrs = graph.neighbors(int(u))
            if len(nbrs) == 0:
                continue
            pick = rng.choice(nbrs, f, replace=len(nbrs) < f)
            for v in pick:
                v = int(v)
                if v not in local:
                    local[v] = len(nodes)
                    nodes.append(v)
                    nxt.append(v)
                # message flows neighbor -> center
                esrc.append(local[v])
                edst.append(local[int(u)])
        frontier = nxt
    return (np.asarray(nodes, np.int32), np.asarray(esrc, np.int32),
            np.asarray(edst, np.int32))


def padded_batch(graph, feats, seed_nodes, fanout, rng, *, max_nodes,
                 max_edges, targets=None):
    """Sample + pad to static shapes for the jitted step.

    Returns a dict matching models/nequip.py's batch contract: target node i
    maps to graph_id i; non-targets go to the ignore bucket (n_targets)."""
    nodes, esrc, edst = sample_fanout(graph, seed_nodes, fanout, rng)
    n, e = len(nodes), len(esrc)
    if n > max_nodes or e > max_edges:
        raise ValueError(f"sample exceeded pad budget: {n}/{max_nodes} nodes "
                         f"{e}/{max_edges} edges")
    nt = len(seed_nodes)
    node_pad = np.zeros(max_nodes, np.int32)
    node_pad[:n] = nodes
    graph_id = np.full(max_nodes, nt, np.int32)
    graph_id[:nt] = np.arange(nt)
    batch = {
        "node_feat": feats[node_pad].astype(np.float32),
        "edge_src": np.pad(esrc, (0, max_edges - e)),
        "edge_dst": np.pad(edst, (0, max_edges - e)),
        "edge_mask": np.pad(np.ones(e, np.float32), (0, max_edges - e)),
        "graph_id": graph_id,
        "energy_target": np.zeros(nt + 1, np.float32),
        "energy_weight": np.concatenate(
            [np.ones(nt, np.float32), np.zeros(1, np.float32)]),
        "node_mask": np.concatenate(
            [np.ones(n, np.float32), np.zeros(max_nodes - n, np.float32)]),
    }
    if targets is not None:
        batch["energy_target"][:nt] = targets[seed_nodes]
    return batch
