"""Synthetic graph builders matching the assigned GNN shape cells, plus 3-D
position synthesis for non-molecular graphs (NequIP needs geometry;
DESIGN.md §6 records this adaptation)."""

import numpy as np


def synth_graph(seed, n_nodes, n_edges, d_feat=0, pos_scale=3.0):
    """Random graph with positions and optional node features; returns a
    dict batch for models/nequip.forward (single graph, energy target from a
    smooth function of geometry so training has learnable signal)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    pos = (pos_scale * rng.standard_normal((n_nodes, 3))).astype(np.float32)
    batch = {
        "positions": pos,
        "edge_src": src,
        "edge_dst": dst,
        "edge_mask": np.ones(n_edges, np.float32),
        "graph_id": np.zeros(n_nodes, np.int32),
        "energy_target": np.asarray(
            [np.tanh(pos).sum() / n_nodes], np.float32),
    }
    if d_feat:
        batch["node_feat"] = rng.standard_normal(
            (n_nodes, d_feat)).astype(np.float32) / np.sqrt(d_feat)
    else:
        batch["species"] = rng.integers(0, 8, n_nodes).astype(np.int32)
    return batch


def synth_molecules(seed, n_graphs, n_nodes, n_edges, n_species=8,
                    cutoff=5.0):
    """Batched small molecules (the `molecule` shape): nodes within cutoff
    are connected; energy = sum of a pairwise Morse-like term (learnable)."""
    rng = np.random.default_rng(seed)
    N = n_graphs * n_nodes
    pos = np.zeros((N, 3), np.float32)
    species = rng.integers(0, n_species, N).astype(np.int32)
    esrc, edst = [], []
    energies = np.zeros(n_graphs, np.float32)
    for g in range(n_graphs):
        base = g * n_nodes
        p = 1.8 * rng.standard_normal((n_nodes, 3)).astype(np.float32)
        pos[base:base + n_nodes] = p
        d2 = ((p[:, None] - p[None]) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        pairs = np.argwhere(d2 < cutoff ** 2)
        order = np.argsort(d2[pairs[:, 0], pairs[:, 1]])
        pairs = pairs[order[:n_edges]]
        for i, j in pairs:
            esrc.append(base + i)
            edst.append(base + j)
        r = np.sqrt(d2[pairs[:, 0], pairs[:, 1]])
        energies[g] = np.sum(np.exp(-r) - 0.5 * np.exp(-0.5 * r))
    E = len(esrc)
    return {
        "positions": pos,
        "species": species,
        "edge_src": np.asarray(esrc, np.int32),
        "edge_dst": np.asarray(edst, np.int32),
        "edge_mask": np.ones(E, np.float32),
        "graph_id": np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32),
        "energy_target": energies,
    }
