"""Synthetic MS MARCO-like corpus with *correlated* sparse and dense
relevance: documents live in latent topics; each topic owns a term
distribution, so sparse (lexical) top-k overlaps dense embedding clusters —
the signal CluSD's Stage I/II learn to exploit. Queries are generated from a
source document (its id is the relevance label, like MS MARCO's mostly-1
qrels), enabling MRR@10 / Recall@k without external data.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class Corpus:
    embeddings: jnp.ndarray    # (D, dim), L2-normalized
    doc_terms: np.ndarray      # (D, T) int32, -1 pad
    doc_weights: np.ndarray    # (D, T) f32
    topic_of: np.ndarray       # (D,)
    vocab: int


@dataclasses.dataclass
class QuerySet:
    q_dense: jnp.ndarray       # (B, dim)
    q_terms: jnp.ndarray       # (B, Tq) int32
    q_weights: jnp.ndarray     # (B, Tq)
    rel_doc: np.ndarray        # (B,) ground-truth relevant doc id
    topic_of: np.ndarray       # (B,)


def synth_corpus(seed, n_docs, dim, vocab, n_topics=None, doc_terms=16,
                 terms_per_topic=64, topic_noise=0.55, bg_frac=0.25):
    rng = np.random.default_rng(seed)
    n_topics = n_topics or max(8, n_docs // 64)
    centers = rng.standard_normal((n_topics, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    topic = rng.integers(0, n_topics, n_docs)
    emb = centers[topic] + topic_noise * rng.standard_normal(
        (n_docs, dim)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)

    topic_terms = rng.integers(0, vocab, (n_topics, terms_per_topic))
    dt = np.full((n_docs, doc_terms), -1, np.int32)
    dw = np.zeros((n_docs, doc_terms), np.float32)
    n_bg = max(1, int(doc_terms * bg_frac))
    n_tp = doc_terms - n_bg
    for d in range(n_docs):
        tt = rng.choice(topic_terms[topic[d]], n_tp, replace=False)
        bg = rng.integers(0, vocab, n_bg)
        terms = np.concatenate([tt, bg])
        w = rng.lognormal(0.0, 0.5, doc_terms).astype(np.float32)
        dt[d], dw[d] = terms, w
    return Corpus(jnp.asarray(emb), dt, dw, topic, vocab)


def synth_queries(seed, corpus: Corpus, n_queries, q_terms=8,
                  dense_noise=0.35, term_noise_frac=0.25):
    rng = np.random.default_rng(seed)
    D, dim = corpus.embeddings.shape
    src = rng.integers(0, D, n_queries)
    emb = np.asarray(corpus.embeddings)
    qd = emb[src] + dense_noise * rng.standard_normal(
        (n_queries, dim)).astype(np.float32)
    qd /= np.linalg.norm(qd, axis=1, keepdims=True)

    qt = np.full((n_queries, q_terms), -1, np.int32)
    qw = np.zeros((n_queries, q_terms), np.float32)
    n_noise = max(0, int(q_terms * term_noise_frac))
    n_doc = q_terms - n_noise
    for i, d in enumerate(src):
        dterms = corpus.doc_terms[d]
        dterms = dterms[dterms >= 0]
        pick = rng.choice(dterms, min(n_doc, len(dterms)), replace=False)
        noise = rng.integers(0, corpus.vocab, n_noise)
        terms = np.concatenate([pick, noise])[:q_terms]
        qt[i, :len(terms)] = terms
        qw[i, :len(terms)] = rng.lognormal(0.0, 0.4, len(terms))
    return QuerySet(jnp.asarray(qd), jnp.asarray(qt), jnp.asarray(qw),
                    src, corpus.topic_of[src])


# ---------------------------------------------------------------------------
# metrics (MS MARCO-style single relevant doc)
# ---------------------------------------------------------------------------

def mrr_at(ids, rel_doc, k=10):
    """ids: (B, K) result doc ids; rel_doc: (B,)."""
    ids = np.asarray(ids)[:, :k]
    rel = np.asarray(rel_doc)[:, None]
    hit = ids == rel
    ranks = np.argmax(hit, axis=1) + 1.0
    rr = np.where(hit.any(axis=1), 1.0 / ranks, 0.0)
    return float(rr.mean())


def recall_at(ids, rel_doc, k=1000):
    ids = np.asarray(ids)[:, :k]
    rel = np.asarray(rel_doc)[:, None]
    return float((ids == rel).any(axis=1).mean())
