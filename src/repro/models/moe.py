"""Mixture-of-experts FFN with sort-based capacity dispatch (GShard-style
token dropping), expert-parallel (EP) when n_experts divides the model axis
and tensor-parallel (TP) within experts otherwise.

Active-FLOP faithful: expert compute is E x C x (3 d f) ~= tokens * top_k *
capacity_factor * ffn_flops — never the dense all-experts product.
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import sharding as sh
from repro.models.sharding import logical

CAPACITY_FACTOR = 1.25


def capacity(n_tokens, n_experts, top_k, factor=CAPACITY_FACTOR):
    c = int(factor * n_tokens * top_k / n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k, ep: bool):
    """x: (T, d). Expert weights: (E, d, f) / (E, f, d). Returns (T, d), aux.

    ep=True: shard experts over 'model'; ep=False (few experts): shard the
    capacity axis over 'data' and the ff axis over 'model'.
    """
    T, d = x.shape
    E = router_w.shape[-1]
    C = capacity(T, E, top_k)

    logits = jnp.einsum("td,de->te", x, router_w.astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, eidx = jax.lax.top_k(probs, top_k)                   # (T, K)
    gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- dispatch: sort (token,k) pairs by expert, rank within expert ----
    flat_e = eidx.reshape(-1)                                  # (T*K,)
    sort_idx = jnp.argsort(flat_e)                             # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=E)                    # (E,)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    ranks = jnp.arange(T * top_k) - starts[sorted_e]
    valid = ranks < C
    dest = jnp.where(valid, sorted_e * C + ranks, E * C)       # overflow slot
    token_id = sort_idx // top_k

    gathered = jnp.take(x, token_id, axis=0)                   # (T*K, d)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].add(
        jnp.where(valid[:, None], gathered, 0))
    expert_in = buf[:-1].reshape(E, C, d)
    if ep:
        expert_in = logical(expert_in, "experts", None, None)
    else:
        expert_in = logical(expert_in, None, "batch", None)

    # ---- expert SwiGLU ----
    h = jnp.einsum("ecd,edf->ecf", expert_in, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in, w_up.astype(x.dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    # EP: f stays unsharded (the 'model' axis is spent on experts)
    h = logical(h, "experts", None, None) if ep \
        else logical(h, None, "batch", "ff_act")
    eo = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))
    if ep:
        eo = logical(eo, "experts", None, None)

    # ---- combine ----
    rows = jnp.concatenate([eo.reshape(E * C, d),
                            jnp.zeros((1, d), x.dtype)], axis=0)
    back = jnp.take(rows, dest, axis=0)                        # (T*K, d)
    gate_sorted = gate.reshape(-1)[sort_idx]
    back = back * gate_sorted[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[token_id].add(back)
    return out, aux


# ---------------------------------------------------------------------------
# §Perf optimized path: expert-parallel dispatch WITHOUT the global sort.
#
# Under pjit, the sort-based dispatch's cross-shard gather/scatter lowers to
# masked all-reduces of the full (T*K, d) token tensor per layer (measured:
# ~22 TiB/device/step on arctic-480b train_4k). Key insight: activations are
# REPLICATED across the 'model' axis, so every model shard already holds
# every token — each (data, model) device can dispatch ITS tokens to ITS
# experts entirely locally; a single bf16 psum over 'model' combines the
# per-expert-shard outputs. Tokens never move; only (T_local, d) partial
# outputs do.
# ---------------------------------------------------------------------------

def moe_ffn_ep_shardmap(x, router_w, w_gate, w_up, w_down, *, top_k,
                        mesh, batch_axes=("data",), model_axis="model"):
    """x: (T, d) batch-sharded; expert weights (E, d, f) sharded over
    model_axis on E. Semantically equivalent to moe_ffn (up to per-shard
    capacity dropping); collective cost = one psum of (T_local, d)."""
    return _moe_shardmap(x, router_w, w_gate, w_up, w_down, top_k=top_k,
                         mesh=mesh, batch_axes=batch_axes,
                         model_axis=model_axis, mode="ep")


def moe_ffn_tp_shardmap(x, router_w, w_gate, w_up, w_down, *, top_k,
                        mesh, batch_axes=("data",), model_axis="model"):
    """Few-experts variant (mixtral E=8 < 16-way model axis): every shard
    holds ALL experts with the d_ff axis sharded; dispatch is still local
    per data shard and the down-projection's partial sums ride the same
    single psum over 'model' that EP uses."""
    return _moe_shardmap(x, router_w, w_gate, w_up, w_down, top_k=top_k,
                         mesh=mesh, batch_axes=batch_axes,
                         model_axis=model_axis, mode="tp")


def _moe_shardmap(x, router_w, w_gate, w_up, w_down, *, top_k, mesh,
                  batch_axes, model_axis, mode):
    E = router_w.shape[-1]
    nm = mesh.shape[model_axis]
    if mode == "ep":
        assert E % nm == 0, (E, nm)

    def local_fn(x_l, rw, wg_l, wu_l, wd_l):
        T_l, d = x_l.shape
        E_loc = wg_l.shape[0]                             # E/nm (ep) or E (tp)
        C = capacity(T_l, E, top_k)

        logits = jnp.einsum("td,de->te", x_l, rw.astype(x_l.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate, eidx = jax.lax.top_k(probs, top_k)
        gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32),
                              axis=1), axis=0)
        aux = E * jnp.sum(me * ce)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes[-1])

        if mode == "ep":
            # keep (token, k) pairs owned by this expert shard
            mi = jax.lax.axis_index(model_axis)
            local_e = eidx - mi * E_loc                   # (T_l, K)
            mine = (local_e >= 0) & (local_e < E_loc)
            flat_e = jnp.where(mine, local_e, E_loc).reshape(-1)
        else:
            flat_e = eidx.reshape(-1)                     # all pairs local
        sort_idx = jnp.argsort(flat_e)                    # local, T_l*K
        sorted_e = flat_e[sort_idx]
        counts = jnp.bincount(flat_e, length=E_loc + 1)
        starts = jnp.cumsum(counts) - counts
        ranks = jnp.arange(T_l * top_k) - starts[sorted_e]
        valid = (ranks < C) & (sorted_e < E_loc)
        dest = jnp.where(valid, sorted_e * C + ranks, E_loc * C)
        token_id = sort_idx // top_k

        gathered = jnp.take(x_l, token_id, axis=0)
        buf = jnp.zeros((E_loc * C + 1, d), x_l.dtype).at[dest].add(
            jnp.where(valid[:, None], gathered, 0))
        ein = buf[:-1].reshape(E_loc, C, d)
        h = jnp.einsum("ecd,edf->ecf", ein, wg_l.astype(x_l.dtype))
        u = jnp.einsum("ecd,edf->ecf", ein, wu_l.astype(x_l.dtype))
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x_l.dtype) * u
        eo = jnp.einsum("ecf,efd->ecd", h, wd_l.astype(x_l.dtype))

        rows = jnp.concatenate([eo.reshape(E_loc * C, d),
                                jnp.zeros((1, d), x_l.dtype)], axis=0)
        back = jnp.take(rows, dest, axis=0)
        gate_sorted = gate.reshape(-1)[sort_idx]
        back = back * gate_sorted[:, None].astype(x_l.dtype)
        out = jnp.zeros((T_l, d), x_l.dtype).at[token_id].add(back)
        # ep: combine expert-shard outputs; tp: combine d_ff partial sums —
        # either way it is ONE psum of (T_local, d) over the model axis
        out = jax.lax.psum(out, model_axis)
        return out, aux

    if not batch_axes:
        bspec = None                       # replicated batch (batch-1 decode)
    elif len(batch_axes) > 1:
        bspec = batch_axes
    else:
        bspec = batch_axes[0]
    if mode == "ep":
        wspec = (P(model_axis, None, None),) * 3
    else:
        wspec = (P(None, None, model_axis), P(None, None, model_axis),
                 P(None, model_axis, None))
    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None), P(None, None)) + wspec,
        out_specs=(P(bspec, None), P()),
        check_vma=False)
    return fn(x, router_w, w_gate, w_up, w_down)
