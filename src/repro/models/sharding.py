"""Logical-axis sharding: models annotate tensors with logical names; the
launcher installs a rules table mapping logical names -> mesh axes.

This keeps model code mesh-agnostic (MaxText-style) and makes sharding a
config/hillclimb knob rather than a code change.
"""

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


# Default rules for the production (data, model) mesh; the multi-pod mesh
# prepends a pure-DP "pod" axis to every "batch"-like logical axis.
BASE_RULES = {
    # activations
    "batch": ("data",),
    "seq": None,
    "seq_kv": None,           # decode KV-sequence axis (flash-decode shards it)
    "embed": None,
    "heads": ("model",),
    "kv_heads": None,
    "head_dim": None,
    "ff_act": ("model",),
    # weights
    "vocab": ("model",),
    "embed_w": ("data",),     # FSDP row shard
    "ff_w": ("model",),
    "heads_w": ("model",),
    "experts": ("model",),
    "layers": None,
    # gnn / recsys
    "edges": ("data", "model"),
    "nodes": None,
    "table_rows": ("data", "model"),
    "candidates": ("model",),
    # retrieval (CluSD)
    "docs": ("model",),
    "clusters": ("model",),
    "queries": ("data",),
}


def install_rules(rules=None, mesh=None, pod_dp=False):
    """Install rules (dict logical->mesh-axis tuple or None) + active mesh.

    pod_dp extensions are applied BEFORE per-cell overrides so an override
    like batch=None (unshardable batch-1 decode) always wins.
    """
    table = dict(BASE_RULES)
    if pod_dp:
        # pure-DP pod axis on batch-like axes; FSDP weight shards and
        # embedding-table rows also span the pod axis so the 480B-param /
        # 188M-row configs fit per-chip HBM.
        for key in ("batch", "queries", "embed_w", "table_rows"):
            cur = table.get(key) or ()
            table[key] = ("pod",) + tuple(cur)
    if rules:
        table.update(rules)
    _state.rules = table
    _state.mesh = mesh
    return table


def current_rules():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def rules_ctx(rules=None, mesh=None, pod_dp=False):
    prev = (getattr(_state, "rules", None), getattr(_state, "mesh", None))
    install_rules(rules, mesh, pod_dp)
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def spec(*names) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated)."""
    rules = current_rules() or BASE_RULES
    parts = []
    for nm in names:
        if nm is None:
            parts.append(None)
            continue
        ax = rules.get(nm)
        if ax is None:
            parts.append(None)
        elif isinstance(ax, (tuple, list)):
            parts.append(tuple(ax) if len(ax) > 1 else ax[0])
        else:
            parts.append(ax)
    return P(*parts)


def logical(x, *names):
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec(*names)))


def named_sharding(mesh, *names):
    return jax.sharding.NamedSharding(mesh, spec(*names))
