"""NequIP — E(3)-equivariant interatomic potential [arXiv:2101.03164].

TPU-native formulation: irreps are kept in *Cartesian* form — l=0 scalars
(N, C), l=1 vectors (N, C, 3), l=2 traceless-symmetric matrices stored in an
orthonormal 5-component basis (N, C, 5) and reconstructed to 3x3 on edges.
Every tensor-product path (l1 x l2 -> l3, all 15 with l<=2) is implemented as
a manifestly SO(3)-covariant bilinear map (dot / cross / matrix action /
epsilon contraction / symmetric-traceless projection). For SO(3) irreps the
space of equivariant bilinear maps V_l1 x V_l2 -> V_l3 is one-dimensional,
so these agree with the Clebsch-Gordan formulation up to per-path scale —
absorbed by the learned radial weights. (Parity/O(3) note: pseudo-tensor
paths are used without parity bookkeeping; see DESIGN.md.)

Message passing uses `jax.ops.segment_sum` over an edge list — JAX sparse is
BCOO-only, so the scatter pipeline IS part of the system (assignment note).
"""

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init
from repro.models.sharding import logical, named_sharding
from repro.models.transformer import Leaf, _is_leaf

# ---------------------------------------------------------------------------
# l=2 basis: 5 orthonormal traceless-symmetric 3x3 matrices (Frobenius o.n.)
# ---------------------------------------------------------------------------

def _l2_basis():
    B = np.zeros((5, 3, 3))
    s = 1 / np.sqrt(2)
    B[0, 0, 1] = B[0, 1, 0] = s                      # xy
    B[1, 1, 2] = B[1, 2, 1] = s                      # yz
    B[2, 0, 2] = B[2, 2, 0] = s                      # xz
    B[3, 0, 0], B[3, 1, 1] = s, -s                   # xx - yy
    B[4, 0, 0] = B[4, 1, 1] = -1 / np.sqrt(6)        # 2zz - xx - yy
    B[4, 2, 2] = 2 / np.sqrt(6)
    return B

L2_BASIS = jnp.asarray(_l2_basis())                  # (5, 3, 3)


def to5(M):
    """(..., 3, 3) symmetric-traceless -> (..., 5)."""
    return jnp.einsum("...ij,kij->...k", M, L2_BASIS.astype(M.dtype))


def from5(f):
    """(..., 5) -> (..., 3, 3)."""
    return jnp.einsum("...k,kij->...ij", f, L2_BASIS.astype(f.dtype))


def symtr(A):
    """Symmetric traceless projection of (..., 3, 3)."""
    S = 0.5 * (A + jnp.swapaxes(A, -1, -2))
    tr = jnp.trace(S, axis1=-2, axis2=-1)[..., None, None]
    return S - tr * jnp.eye(3, dtype=A.dtype) / 3.0


EPS3 = jnp.asarray(np.array(
    [[[int((i - j) * (j - k) * (k - i) / 2) for k in range(3)]
      for j in range(3)] for i in range(3)], dtype=np.float32))

# path list: (l1, l2, l3) for feature l1 x filter(SH) l2 -> message l3
PATHS = [(0, 0, 0), (0, 1, 1), (0, 2, 2),
         (1, 0, 1), (1, 1, 0), (1, 1, 1), (1, 1, 2), (1, 2, 1), (1, 2, 2),
         (2, 0, 2), (2, 1, 1), (2, 1, 2), (2, 2, 0), (2, 2, 1), (2, 2, 2)]
N_PATHS = len(PATHS)


def tensor_product(h0, h1, h2m, y0, y1, y2m, w):
    """Per-edge weighted tensor products.

    h0: (E, C); h1: (E, C, 3); h2m: (E, C, 3, 3) — sender features (gathered)
    y0: (E,);  y1: (E, 3);   y2m: (E, 3, 3)     — edge spherical harmonics
    w:  (E, n_paths, C)                          — radial weights
    Returns messages (m0 (E,C), m1 (E,C,3), m2 (E,C,3,3)).
    """
    out = {0: 0., 1: 0., 2: 0.}
    EPS3 = globals()["EPS3"].astype(h0.dtype)

    def acc(l3, val):
        out[l3] = out[l3] + val

    for p, (l1, l2, l3) in enumerate(PATHS):
        wp = w[:, p, :]                                    # (E, C)
        if (l1, l2) == (0, 0):
            r = h0 * y0[:, None]
        elif (l1, l2) == (0, 1):
            r = h0[..., None] * y1[:, None, :]
        elif (l1, l2) == (0, 2):
            r = h0[..., None, None] * y2m[:, None]
        elif (l1, l2) == (1, 0):
            r = h1 * y0[:, None, None]
        elif (l1, l2) == (1, 1):
            if l3 == 0:
                r = jnp.einsum("eci,ei->ec", h1, y1)
            elif l3 == 1:
                r = jnp.cross(h1, y1[:, None, :])
            else:
                r = symtr(jnp.einsum("eci,ej->ecij", h1, y1))
        elif (l1, l2) == (1, 2):
            if l3 == 1:
                r = jnp.einsum("eij,ecj->eci", y2m, h1)
            else:  # epsilon contraction: bilinear 1x2 -> 2
                r = symtr(jnp.einsum("ikl,eck,elj->ecij", EPS3, h1, y2m))
        elif (l1, l2) == (2, 0):
            r = h2m * y0[:, None, None, None]
        elif (l1, l2) == (2, 1):
            if l3 == 1:
                r = jnp.einsum("ecij,ej->eci", h2m, y1)
            else:
                r = symtr(jnp.einsum("ikl,ek,eclj->ecij", EPS3, y1, h2m))
        else:  # (2, 2)
            mn = jnp.einsum("ecik,ekj->ecij", h2m, y2m)
            if l3 == 0:
                r = jnp.einsum("ecij,eij->ec", h2m, y2m)
            elif l3 == 1:
                r = jnp.einsum("ijk,ecjk->eci", EPS3, mn)
            else:
                r = symtr(mn)
        if l3 == 0:
            acc(0, wp * r)
        elif l3 == 1:
            acc(1, wp[..., None] * r)
        else:
            acc(2, wp[..., None, None] * r)
    return out[0], out[1], out[2]


# ---------------------------------------------------------------------------
# radial basis + cutoff
# ---------------------------------------------------------------------------

def bessel_rbf(r, n_rbf, cutoff):
    """sqrt(2/rc) sin(n pi r / rc) / r, n = 1..n_rbf, with p=6 envelope."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    rb = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[..., None] / cutoff) / r[..., None]
    x = jnp.clip(r / cutoff, 0, 1)
    p = 6.0
    env = (1 - (p + 1) * (p + 2) / 2 * x ** p + p * (p + 2) * x ** (p + 1)
           - p * (p + 1) / 2 * x ** (p + 2))
    return rb * env[..., None]


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

RADIAL_HIDDEN = 64


def param_template(cfg, d_feat=0):
    C, L = cfg.d_hidden, cfg.n_layers
    pdt = cfg.param_dtype
    f_in = d_feat if d_feat else cfg.n_species
    t = {
        "embed": Leaf((f_in, C), pdt, (None, None)),
        "layers": {
            "radial_w1": Leaf((L, cfg.n_rbf, RADIAL_HIDDEN), pdt, ("layers", None, None)),
            "radial_b1": Leaf((L, RADIAL_HIDDEN), pdt, ("layers", None), init="zeros"),
            "radial_w2": Leaf((L, RADIAL_HIDDEN, N_PATHS * C), pdt, ("layers", None, None)),
            "self0": Leaf((L, C, C), pdt, ("layers", None, None)),
            "self1": Leaf((L, C, C), pdt, ("layers", None, None)),
            "self2": Leaf((L, C, C), pdt, ("layers", None, None)),
            "skip0": Leaf((L, C, C), pdt, ("layers", None, None)),
            "skip1": Leaf((L, C, C), pdt, ("layers", None, None)),
            "skip2": Leaf((L, C, C), pdt, ("layers", None, None)),
            "gate_w": Leaf((L, C, 2 * C), pdt, ("layers", None, None)),
            "gate_b": Leaf((L, 2 * C), pdt, ("layers", None), init="zeros"),
        },
        "readout_w": Leaf((C, 16), pdt, (None, None)),
        "readout_w2": Leaf((16, 1), pdt, (None, None)),
    }
    return t


def init_params(cfg, rng, d_feat=0):
    template = param_template(cfg, d_feat)
    flat, treedef = jax.tree.flatten(template, is_leaf=_is_leaf)
    rngs = jax.random.split(rng, len(flat))
    leaves = []
    for leaf, r in zip(flat, rngs):
        if leaf.init == "zeros":
            leaves.append(jnp.zeros(leaf.shape, leaf.dtype))
        else:
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            leaves.append(dense_init(r, leaf.shape, leaf.dtype, scale=fan_in ** -0.5))
    return treedef.unflatten(leaves)


def abstract_params(cfg, d_feat=0):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.dtype(l.dtype)),
                        param_template(cfg, d_feat), is_leaf=_is_leaf)


def param_shardings(cfg, mesh, d_feat=0):
    return jax.tree.map(lambda l: named_sharding(mesh, *l.axes),
                        param_template(cfg, d_feat), is_leaf=_is_leaf)


def forward(cfg, params, batch):
    """batch: positions (N,3), node_feat (N,F)|species (N,), edge_src/dst (E,),
    edge_mask (E,), graph_id (N,), n_graphs. Returns per-graph energy (G,)."""
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch["edge_mask"].astype(pos.dtype)
    N = pos.shape[0]

    cd = jnp.dtype(cfg.dtype)  # bf16 halves gather/psum wire bytes (§Perf)
    if "node_feat" in batch:
        feat = batch["node_feat"]
    else:
        feat = jax.nn.one_hot(batch["species"], cfg.n_species, dtype=pos.dtype)
    h0 = (feat @ params["embed"]).astype(cd)              # (N, C)
    C = h0.shape[-1]
    h1 = jnp.zeros((N, C, 3), h0.dtype)
    h2 = jnp.zeros((N, C, 5), h0.dtype)

    # --- edge geometry (shared across layers; computed in f32, stored cd) ---
    rel = pos[dst] - pos[src]                             # (E, 3)
    rel = logical(rel, "edges", None)
    dist = jnp.sqrt(jnp.sum(rel * rel, -1) + 1e-12)
    rhat = rel / dist[:, None]
    y0 = jnp.ones_like(dist, dtype=cd)
    y1 = rhat.astype(cd)
    y2m = symtr(jnp.einsum("ei,ej->eij", rhat, rhat)).astype(cd)
    rbf = (bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)
           * emask[:, None]).astype(cd)

    def layer(carry, lp):
        h0, h1, h2 = carry
        # radial weights per edge
        rw = jax.nn.silu(rbf @ lp["radial_w1"].astype(cd)
                         + lp["radial_b1"].astype(cd))
        rw = (rw @ lp["radial_w2"].astype(cd)).reshape(-1, N_PATHS, C)
        rw = rw * emask[:, None, None].astype(cd)
        # gather sender features to edges
        e0 = jnp.take(h0, src, axis=0)
        e1 = jnp.take(h1, src, axis=0)
        e2 = from5(jnp.take(h2, src, axis=0))
        m0, m1, m2 = tensor_product(e0, e1, e2, y0, y1, y2m, rw)
        # scatter to receivers
        a0 = jax.ops.segment_sum(m0, dst, num_segments=N)
        a1 = jax.ops.segment_sum(m1, dst, num_segments=N)
        a2 = jax.ops.segment_sum(to5(m2), dst, num_segments=N)
        # self-interaction + skip
        n0 = jnp.einsum("nc,cd->nd", a0, lp["self0"].astype(cd)) + jnp.einsum(
            "nc,cd->nd", h0, lp["skip0"].astype(cd))
        n1 = jnp.einsum("nci,cd->ndi", a1, lp["self1"].astype(cd)) + jnp.einsum(
            "nci,cd->ndi", h1, lp["skip1"].astype(cd))
        n2 = jnp.einsum("nck,cd->ndk", a2, lp["self2"].astype(cd)) + jnp.einsum(
            "nck,cd->ndk", h2, lp["skip2"].astype(cd))
        # gate nonlinearity (f32 sigmoid for stability, output back to cd)
        gates = jax.nn.sigmoid(
            (jnp.einsum("nc,cg->ng", n0, lp["gate_w"].astype(cd))
             + lp["gate_b"].astype(cd)).astype(jnp.float32)).astype(cd)
        g1, g2 = gates[:, :C], gates[:, C:]
        h0 = jax.nn.silu(n0.astype(jnp.float32)).astype(cd)
        h1 = n1 * g1[..., None]
        h2 = n2 * g2[..., None]
        return (h0, h1, h2), None

    (h0, h1, h2), _ = jax.lax.scan(layer, (h0, h1, h2), params["layers"])

    h0 = h0.astype(jnp.float32)
    node_e = jax.nn.silu(h0 @ params["readout_w"]) @ params["readout_w2"]  # (N,1)
    if "node_mask" in batch:
        node_e = node_e * batch["node_mask"][:, None].astype(node_e.dtype)
    # number of graphs is static: taken from the target's shape
    n_graphs = batch["energy_target"].shape[0]
    energy = jax.ops.segment_sum(node_e[:, 0], batch["graph_id"],
                                 num_segments=n_graphs)
    return energy


def make_train_step(cfg, train_cfg=None):
    from repro.configs.base import TrainConfig
    from repro.optim import adamw_update
    tc = train_cfg or TrainConfig()

    def loss_fn(params, batch):
        e = forward(cfg, params, batch)
        err = jnp.square(e - batch["energy_target"])
        if "energy_weight" in batch:
            w = batch["energy_weight"]
            return jnp.sum(err * w) / jnp.maximum(jnp.sum(w), 1.0)
        return jnp.mean(err)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, lr=tc.lr, grad_clip=tc.grad_clip)
        return params, opt_state, {"loss": loss, **stats}

    return train_step
