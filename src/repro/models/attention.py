"""Attention: GQA with optional sliding window; chunked-q train/prefill and
flash-decode style single-token decode (KV-seq sharded, logsumexp combine
inserted by SPMD partitioner through the softmax).

Baseline train/prefill computes full masked scores per q-chunk (2x causal
FLOP waste — tracked in the roofline MODEL_FLOPS ratio; the Pallas/banded
variants are the §Perf hillclimb).
"""

import functools

import jax
import jax.numpy as jnp

from repro.models.sharding import logical


def _chunk_attend(q, k, v, q_start, *, causal, window, softmax_dtype=jnp.float32):
    """q: (B, C, Hkv, G, hd); k/v: (B, S, Hkv, hd). Returns (B, C, Hkv, G, hd)."""
    B, C, Hkv, G, hd = q.shape
    S = k.shape[1]
    scores = jnp.einsum("bchgd,bshd->bhgcs", q, k) / (hd ** 0.5)
    qpos = q_start + jnp.arange(C)[:, None]            # (C, 1)
    kpos = jnp.arange(S)[None, :]                      # (1, S)
    mask = jnp.ones((C, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores.astype(softmax_dtype), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgcs,bshd->bchgd", probs, v)


def attention(q, k, v, *, causal=True, window=None, q_chunk=512):
    """q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd) -> (B, S, Hq, hd)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    q = q.reshape(B, S, Hkv, G, hd)
    if S <= q_chunk:
        out = _chunk_attend(q, k, v, 0, causal=causal, window=window)
        return out.reshape(B, S, Hq, hd)
    assert S % q_chunk == 0, (S, q_chunk)
    n = S // q_chunk
    qc = q.reshape(B, n, q_chunk, Hkv, G, hd)

    def body(_, xs):
        i, q_i = xs
        out = _chunk_attend(q_i, k, v, i * q_chunk, causal=causal, window=window)
        return None, out

    # remat: backward recomputes the (C, S) score slab instead of storing it
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(
        body, None, (jnp.arange(n), jnp.moveaxis(qc, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, hd)
    return out


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode. q: (B, 1, Hq, hd); caches: (B, S, Hkv, hd).

    The KV-seq axis is annotated `seq_kv`; when sharded over 'model' the
    partitioner emits the flash-decode combine (all-reduce of max/sum/out).
    """
    B, S, Hkv, hd = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    q = q.reshape(B, Hkv, G, hd)
    k_cache = logical(k_cache, "batch", "seq_kv", "kv_heads", "head_dim")
    v_cache = logical(v_cache, "batch", "seq_kv", "kv_heads", "head_dim")
    scores = jnp.einsum("bhgd,bshd->bhgs", q, k_cache) / (hd ** 0.5)
    kpos = jnp.arange(S)[None, None, None, :]
    scores = jnp.where(kpos < cache_len, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache)
    return out.reshape(B, 1, Hq, hd)
