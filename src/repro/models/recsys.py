"""RecSys model zoo: dlrm-mlperf / deepfm / wide-deep / din.

JAX has no native EmbeddingBag and no CSR sparse — the embedding lookup
substrate here is built from `jnp.take` + `jax.ops.segment_sum` (assignment
requirement). Tables are row-sharded over ('data','model') (mod-sharding is
the shard_map/a2a hillclimb variant in repro/runtime/collectives.py).

`make_retrieval_step` scores one query against n_candidates items two-tower
style — the surface where the paper's CluSD technique plugs in first-class
(see repro/core/retrieval.py).
"""

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.models.sharding import logical, named_sharding
from repro.models.transformer import Leaf, _is_leaf


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def embedding_lookup(table, idx):
    """table: (rows, d) [row-sharded]; idx: int32 (...,) -> (..., d)."""
    return jnp.take(table, idx, axis=0)


def embedding_bag(table, idx, weights=None, combine="sum"):
    """Fixed-hotness bag: idx (..., hot) -> (..., d)."""
    emb = jnp.take(table, idx, axis=0)                     # (..., hot, d)
    if weights is not None:
        emb = emb * weights[..., None]
    if combine == "sum":
        return jnp.sum(emb, axis=-2)
    if combine == "mean":
        return jnp.mean(emb, axis=-2)
    if combine == "max":
        return jnp.max(emb, axis=-2)
    raise ValueError(combine)


def embedding_bag_ragged(table, flat_idx, segment_ids, n_bags, weights=None):
    """Ragged bag (EmbeddingBag semantics): gather + segment_sum."""
    emb = jnp.take(table, flat_idx, axis=0)                # (nnz, d)
    if weights is not None:
        emb = emb * weights[:, None]
    return jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _mlp_leaves(name, dims_in, dims, pdt, prefix):
    out = {}
    d = dims_in
    for i, h in enumerate(dims):
        out[f"{prefix}_w{i}"] = Leaf((d, h), pdt, (None, None))
        out[f"{prefix}_b{i}"] = Leaf((h,), pdt, (None,), init="zeros")
        d = h
    return out, d


def _padded_rows(rows, mult=512):
    """Tables are padded to a shardable row count (512 = lcm of every mesh
    factor used for 'table_rows'); indices never reach the pad rows."""
    return max(mult, ((rows + mult - 1) // mult) * mult)


def param_template(cfg):
    pdt = cfg.param_dtype
    t = {"tables": {f"t{i}": Leaf((_padded_rows(rows), cfg.embed_dim), pdt,
                                  ("table_rows", None))
                    for i, rows in enumerate(cfg.table_sizes)}}
    if cfg.kind in ("wide_deep", "deepfm"):
        # dim-1 tables for the wide / first-order-FM branch
        t["wide"] = {f"t{i}": Leaf((_padded_rows(rows), 1), pdt,
                                   ("table_rows", None))
                     for i, rows in enumerate(cfg.table_sizes)}
        t["wide_bias"] = Leaf((1,), pdt, (None,), init="zeros")

    if cfg.kind == "dlrm":
        bot, d = _mlp_leaves("bot", cfg.n_dense, cfg.bot_mlp, pdt, "bot")
        t.update(bot)
        n_f = cfg.n_sparse + 1
        n_int = n_f * (n_f - 1) // 2
        top_in = n_int + cfg.embed_dim
        top, _ = _mlp_leaves("top", top_in, cfg.top_mlp, pdt, "top")
        t.update(top)
    elif cfg.kind == "deepfm":
        deep_in = cfg.n_sparse * cfg.embed_dim
        deep, d = _mlp_leaves("deep", deep_in, cfg.mlp, pdt, "deep")
        t.update(deep)
        t["deep_out_w"] = Leaf((d, 1), pdt, (None, None))
        t["deep_out_b"] = Leaf((1,), pdt, (None,), init="zeros")
    elif cfg.kind == "wide_deep":
        deep_in = cfg.n_sparse * cfg.embed_dim
        deep, d = _mlp_leaves("deep", deep_in, cfg.mlp, pdt, "deep")
        t.update(deep)
        t["deep_out_w"] = Leaf((d, 1), pdt, (None, None))
        t["deep_out_b"] = Leaf((1,), pdt, (None,), init="zeros")
    elif cfg.kind == "din":
        # behavior = concat(item, cate) embeddings
        be = 2 * cfg.embed_dim
        attn_in = 4 * be
        attn, d = _mlp_leaves("attn", attn_in, cfg.attn_mlp, pdt, "attn")
        t.update(attn)
        t["attn_out_w"] = Leaf((d, 1), pdt, (None, None))
        t["attn_out_b"] = Leaf((1,), pdt, (None,), init="zeros")
        # final mlp over [user_emb..., pooled, target]
        user_dim = (len(cfg.table_sizes) - 2) * cfg.embed_dim
        mlp_in = user_dim + 2 * be
        deep, d = _mlp_leaves("deep", mlp_in, cfg.mlp, pdt, "deep")
        t.update(deep)
        t["deep_out_w"] = Leaf((d, 1), pdt, (None, None))
        t["deep_out_b"] = Leaf((1,), pdt, (None,), init="zeros")
    else:
        raise ValueError(cfg.kind)
    return t


def init_params(cfg, rng):
    template = param_template(cfg)
    flat, treedef = jax.tree.flatten(template, is_leaf=_is_leaf)
    rngs = jax.random.split(rng, len(flat))
    leaves = []
    for leaf, r in zip(flat, rngs):
        if leaf.init == "zeros":
            leaves.append(jnp.zeros(leaf.shape, leaf.dtype))
        else:
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            leaves.append(dense_init(r, leaf.shape, leaf.dtype,
                                     scale=fan_in ** -0.5))
    return treedef.unflatten(leaves)


def abstract_params(cfg):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.dtype(l.dtype)),
                        param_template(cfg), is_leaf=_is_leaf)


def param_shardings(cfg, mesh):
    return jax.tree.map(lambda l: named_sharding(mesh, *l.axes),
                        param_template(cfg), is_leaf=_is_leaf)


def _mlp_apply(params, prefix, x, act=jax.nn.relu, final_act=True):
    i = 0
    while f"{prefix}_w{i}" in params:
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        last = f"{prefix}_w{i+1}" not in params
        if (not last) or final_act:
            x = act(x)
        i += 1
    return x


# ---------------------------------------------------------------------------
# forward per kind — returns logits (B,)
# ---------------------------------------------------------------------------

def forward(cfg, params, batch):
    sparse = batch["sparse"]                    # (B, n_sparse) int32
    B = sparse.shape[0]
    sparse = logical(sparse, "batch", None)
    embs = jnp.stack(
        [embedding_lookup(params["tables"][f"t{i}"], sparse[:, i])
         for i in range(len(cfg.table_sizes))], axis=1)   # (B, F, d)
    embs = logical(embs, "batch", None, None)

    if cfg.kind == "dlrm":
        dense = batch["dense"]                  # (B, n_dense)
        dv = _mlp_apply(params, "bot", dense)   # (B, d)
        x = jnp.concatenate([dv[:, None, :], embs], axis=1)   # (B, F+1, d)
        z = jnp.einsum("bfd,bgd->bfg", x, x)
        f = x.shape[1]
        iu, ju = jnp.triu_indices(f, k=1)
        inter = z[:, iu, ju]                    # (B, F(F-1)/2)
        top_in = jnp.concatenate([inter, dv], axis=-1)
        logit = _mlp_apply(params, "top", top_in, final_act=False)[:, 0]
    elif cfg.kind == "deepfm":
        # FM 2nd order
        s = jnp.sum(embs, axis=1)
        fm2 = 0.5 * jnp.sum(s * s - jnp.sum(embs * embs, axis=1), axis=-1)
        fm1 = sum(embedding_lookup(params["wide"][f"t{i}"], sparse[:, i])[:, 0]
                  for i in range(len(cfg.table_sizes))) + params["wide_bias"][0]
        deep = _mlp_apply(params, "deep", embs.reshape(B, -1))
        deep = (deep @ params["deep_out_w"] + params["deep_out_b"])[:, 0]
        logit = fm1 + fm2 + deep
    elif cfg.kind == "wide_deep":
        wide = sum(embedding_lookup(params["wide"][f"t{i}"], sparse[:, i])[:, 0]
                   for i in range(len(cfg.table_sizes))) + params["wide_bias"][0]
        deep = _mlp_apply(params, "deep", embs.reshape(B, -1))
        deep = (deep @ params["deep_out_w"] + params["deep_out_b"])[:, 0]
        logit = wide + deep
    elif cfg.kind == "din":
        logit = _din_forward(cfg, params, batch)
    else:
        raise ValueError(cfg.kind)
    return logit


def _din_forward(cfg, params, batch):
    """tables: t0=item, t1=cate, t2..=user profile fields."""
    d = cfg.embed_dim
    hist_item = batch["hist_item"]              # (B, L)
    hist_cate = batch["hist_cate"]              # (B, L)
    hist_mask = batch["hist_mask"]              # (B, L)
    B, L = hist_item.shape
    e_hist = jnp.concatenate(
        [embedding_lookup(params["tables"]["t0"], hist_item),
         embedding_lookup(params["tables"]["t1"], hist_cate)], axis=-1)  # (B,L,2d)
    tgt = batch["sparse"]                        # (B, n_sparse): item,cate,user...
    e_tgt = jnp.concatenate(
        [embedding_lookup(params["tables"]["t0"], tgt[:, 0]),
         embedding_lookup(params["tables"]["t1"], tgt[:, 1])], axis=-1)  # (B,2d)
    # local activation unit
    t = jnp.broadcast_to(e_tgt[:, None, :], e_hist.shape)
    af = jnp.concatenate([e_hist, t, e_hist - t, e_hist * t], axis=-1)
    a = _mlp_apply(params, "attn", af, act=jax.nn.sigmoid)
    a = (a @ params["attn_out_w"] + params["attn_out_b"])[..., 0]        # (B,L)
    a = jnp.where(hist_mask > 0, a, -1e30)
    w = jax.nn.softmax(a, axis=-1)
    pooled = jnp.einsum("bl,bld->bd", w, e_hist)                         # (B,2d)
    user = jnp.concatenate(
        [embedding_lookup(params["tables"][f"t{i}"], tgt[:, i])
         for i in range(2, len(cfg.table_sizes))], axis=-1)
    x = jnp.concatenate([user, pooled, e_tgt], axis=-1)
    deep = _mlp_apply(params, "deep", x)
    return (deep @ params["deep_out_w"] + params["deep_out_b"])[:, 0]


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, train_cfg=None):
    from repro.configs.base import TrainConfig
    from repro.optim import adamw_update
    tc = train_cfg or TrainConfig()

    def loss_fn(params, batch):
        logit = forward(cfg, params, batch)
        y = batch["label"].astype(jnp.float32)
        loss = jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        return loss

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, lr=tc.lr, grad_clip=tc.grad_clip)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def make_serve_step(cfg):
    def serve(params, batch):
        return jax.nn.sigmoid(forward(cfg, params, batch))
    return serve


# ---------------------------------------------------------------------------
# retrieval (two-tower): 1 query vs n_candidates — CluSD's host surface
# ---------------------------------------------------------------------------

def user_tower(cfg, params, batch):
    """(B, d) user/query vector."""
    if cfg.kind == "dlrm":
        return _mlp_apply(params, "bot", batch["dense"])
    if cfg.kind == "din":
        tgt = batch["sparse"]
        user = sum(embedding_lookup(params["tables"][f"t{i}"], tgt[:, i])
                   for i in range(2, len(cfg.table_sizes)))
        hist = embedding_lookup(params["tables"]["t0"], batch["hist_item"])
        pooled = jnp.mean(hist * batch["hist_mask"][..., None], axis=1)
        return user + pooled
    # deepfm / wide_deep: pooled user-field embeddings
    sparse = batch["sparse"]
    n_user = len(cfg.table_sizes) // 2
    return sum(embedding_lookup(params["tables"][f"t{i}"], sparse[:, i])
               for i in range(n_user))


def candidate_tower(cfg, params, cand_sparse):
    """cand_sparse: (n_cand, n_item_fields) -> (n_cand, d)."""
    n_item = cand_sparse.shape[1]
    v = sum(embedding_lookup(params["tables"][f"t{i}"], cand_sparse[:, i])
            for i in range(n_item))
    return logical(v, "candidates", None)


def make_retrieval_step(cfg, k=100):
    def retrieve(params, batch, cand_sparse):
        u = user_tower(cfg, params, batch)                # (B, d)
        v = candidate_tower(cfg, params, cand_sparse)     # (n_cand, d)
        scores = jnp.einsum("bd,nd->bn", u, v)
        scores = logical(scores, "batch", "candidates")
        return jax.lax.top_k(scores, k)
    return retrieve
