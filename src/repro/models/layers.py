"""Shared layers: init helpers, RMSNorm, MLPs, rotary embeddings."""

import jax
import jax.numpy as jnp

from repro.models.sharding import logical


def dense_init(rng, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def rmsnorm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU FFN. x: (B, S, d); w_gate/w_up: (d, f); w_down: (f, d).

    NOTE: PartitionSpec None means REPLICATED, not "unspecified" — the batch
    axis must be named in every constraint or GSPMD gathers it globally.
    """
    h = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = logical(h, "batch", "seq", "ff_act")
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


def mlp(x, ws, bs, act=jax.nn.relu, final_act=False):
    """Plain MLP over last dim; ws/bs lists."""
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = jnp.einsum("...d,df->...f", x, w.astype(x.dtype)) + b.astype(x.dtype)
        if i + 1 < len(ws) or final_act:
            x = act(x)
    return x


def rope_freqs(head_dim, theta):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
