"""Decoder-only transformer LM: GQA + RoPE (+ QKV bias, SWA, MoE with dense
residual), `lax.scan` over stacked layer params, remat policy, chunked
cross-entropy so full logits are never materialized.

Covers arctic-480b / mixtral-8x7b / qwen2-1.5b / deepseek-67b / qwen2.5-32b.
"""

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models.attention import attention, decode_attention
from repro.models.layers import apply_rope, dense_init, rmsnorm, swiglu
from repro.models.sharding import logical, spec, named_sharding


@dataclasses.dataclass
class Leaf:
    """Parameter leaf spec: shape + dtype + logical sharding axes.

    Not registered as a pytree node on purpose: tree ops treat it as a leaf.
    """
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | ones | zeros


def _expert_parallel(cfg) -> bool:
    # EP when the expert count can cover a 16-way model axis; else TP-in-expert.
    return cfg.moe and cfg.n_experts % 16 == 0


def param_template(cfg):
    d, hd = cfg.d_model, cfg.hd
    L, Hq, Hkv = cfg.n_layers, cfg.n_heads, cfg.n_kv_heads
    pdt = cfg.param_dtype
    t = {
        # vocab-sharded over 'model'; rows replicated (row-FSDP on these two
        # tables would force a 1GB lm_head all-gather per loss chunk)
        "embed": Leaf((cfg.vocab_size, d), pdt, ("vocab", "embed")),
        "lm_head": Leaf((d, cfg.vocab_size), pdt, ("embed", "vocab")),
        "final_norm": Leaf((d,), pdt, ("embed",), init="ones"),
    }
    lay = {
        "ln1": Leaf((L, d), pdt, ("layers", "embed"), init="ones"),
        "ln2": Leaf((L, d), pdt, ("layers", "embed"), init="ones"),
        "wq": Leaf((L, d, Hq * hd), pdt, ("layers", "embed_w", "heads_w")),
        "wk": Leaf((L, d, Hkv * hd), pdt, ("layers", "embed_w", None)),
        "wv": Leaf((L, d, Hkv * hd), pdt, ("layers", "embed_w", None)),
        "wo": Leaf((L, Hq * hd, d), pdt, ("layers", "heads_w", "embed_w")),
    }
    if cfg.qkv_bias:
        lay["bq"] = Leaf((L, Hq * hd), pdt, ("layers", "heads_w"), init="zeros")
        lay["bk"] = Leaf((L, Hkv * hd), pdt, ("layers", None), init="zeros")
        lay["bv"] = Leaf((L, Hkv * hd), pdt, ("layers", None), init="zeros")
    if cfg.moe:
        E, f = cfg.n_experts, cfg.moe_d_ff
        ep = _expert_parallel(cfg)
        eax = ("layers", "experts", "embed_w", None) if ep \
            else ("layers", None, "embed_w", "ff_w")
        dax = ("layers", "experts", None, "embed_w") if ep \
            else ("layers", None, "ff_w", "embed_w")
        lay["router"] = Leaf((L, d, E), pdt, ("layers", None, None))
        lay["moe_wg"] = Leaf((L, E, d, f), pdt, eax)
        lay["moe_wu"] = Leaf((L, E, d, f), pdt, eax)
        lay["moe_wd"] = Leaf((L, E, f, d), pdt, dax)
    if (not cfg.moe) or cfg.dense_residual:
        lay["ffn_wg"] = Leaf((L, d, cfg.d_ff), pdt, ("layers", "embed_w", "ff_w"))
        lay["ffn_wu"] = Leaf((L, d, cfg.d_ff), pdt, ("layers", "embed_w", "ff_w"))
        lay["ffn_wd"] = Leaf((L, cfg.d_ff, d), pdt, ("layers", "ff_w", "embed_w"))
    t["layers"] = lay
    return t


def _is_leaf(x):
    return isinstance(x, Leaf)


def init_params(cfg, rng):
    template = param_template(cfg)
    flat, treedef = jax.tree.flatten(template, is_leaf=_is_leaf)
    rngs = jax.random.split(rng, len(flat))
    leaves = []
    for leaf, r in zip(flat, rngs):
        if leaf.init == "ones":
            init = jnp.ones(leaf.shape, leaf.dtype)
        elif leaf.init == "zeros":
            init = jnp.zeros(leaf.shape, leaf.dtype)
        else:
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            init = dense_init(r, leaf.shape, leaf.dtype, scale=fan_in ** -0.5)
        leaves.append(init)
    return treedef.unflatten(leaves)


def abstract_params(cfg):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.dtype(l.dtype)),
                        param_template(cfg), is_leaf=_is_leaf)


def param_shardings(cfg, mesh):
    return jax.tree.map(lambda l: named_sharding(mesh, *l.axes),
                        param_template(cfg), is_leaf=_is_leaf)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _gather_weights(cfg, lp):
    """FSDP: re-annotate layer weights as gathered over the data axis at the
    point of use (storage keeps the ('data','model') 2-D shard). Without
    this, GSPMD resolves the weight-row/batch axis conflict by all-gathering
    the ACTIVATIONS to the full global batch — 25x more wire bytes."""
    g = dict(lp)
    ep = cfg.moe and _expert_parallel(cfg)
    plans = {
        "wq": ("embed", "heads_w"), "wk": ("embed", None),
        "wv": ("embed", None), "wo": ("heads_w", "embed"),
        "ffn_wg": ("embed", "ff_w"), "ffn_wu": ("embed", "ff_w"),
        "ffn_wd": ("ff_w", "embed"),
        "moe_wg": ("experts", "embed", None) if ep else (None, "embed", "ff_w"),
        "moe_wu": ("experts", "embed", None) if ep else (None, "embed", "ff_w"),
        "moe_wd": ("experts", None, "embed") if ep else (None, "ff_w", "embed"),
    }
    for k, axes in plans.items():
        if k in g:
            g[k] = logical(g[k], *axes)
    return g


def _moe_dispatch(cfg, flat, lp):
    """Select the MoE dispatch implementation (the §Perf hillclimb knob)."""
    from repro.models import sharding as sh
    mesh = getattr(sh._state, "mesh", None)
    if (cfg.moe_impl in ("ep_shard_map", "tp_shard_map")
            and mesh is not None and "model" in mesh.shape):
        rules = sh.current_rules() or {}
        batch = rules.get("batch", ("data",))
        batch_axes = tuple(batch) if batch else ()  # () = replicated batch
        ep_ok = _expert_parallel(cfg) and cfg.moe_impl == "ep_shard_map"
        fn = (moe_lib.moe_ffn_ep_shardmap if ep_ok
              else moe_lib.moe_ffn_tp_shardmap)
        return fn(flat, lp["router"], lp["moe_wg"], lp["moe_wu"],
                  lp["moe_wd"], top_k=cfg.moe_top_k, mesh=mesh,
                  batch_axes=batch_axes)
    return moe_lib.moe_ffn(
        flat, lp["router"], lp["moe_wg"], lp["moe_wu"], lp["moe_wd"],
        top_k=cfg.moe_top_k, ep=_expert_parallel(cfg))


def _layer(cfg, x, lp, positions):
    """One transformer layer (train/prefill). x: (B, S, d)."""
    B, S, d = x.shape
    cd = x.dtype
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    lp = _gather_weights(cfg, lp)

    h = rmsnorm(x, lp["ln1"])
    q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(cd))
    k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(cd))
    v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(cd))
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"].astype(cd), k + lp["bk"].astype(cd), v + lp["bv"].astype(cd)
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    q = logical(apply_rope(q, positions, cfg.rope_theta),
                "batch", "seq", "heads", "head_dim")
    k = apply_rope(k, positions, cfg.rope_theta)
    attn = attention(q, k, v, causal=True, window=cfg.sliding_window)
    attn = logical(attn, "batch", "seq", "heads", "head_dim")
    x = x + logical(jnp.einsum("bsh,hd->bsd", attn.reshape(B, S, Hq * hd),
                               lp["wo"].astype(cd)), "batch", "seq", "embed")

    h2 = rmsnorm(x, lp["ln2"])
    aux = jnp.zeros((), jnp.float32)
    out = 0.
    if cfg.moe:
        flat = h2.reshape(B * S, d)
        moe_out, aux = _moe_dispatch(cfg, flat, lp)
        out = out + moe_out.reshape(B, S, d)
    if (not cfg.moe) or cfg.dense_residual:
        out = out + swiglu(h2, lp["ffn_wg"], lp["ffn_wu"], lp["ffn_wd"])
    x = x + logical(out, "batch", "seq", "embed")
    # SWA archs only ever serve from a window-sized cache: slice before the
    # scan stacks per-layer KV (full-S stacking is O(L*B*S*kv) HBM).
    if cfg.sliding_window and S > cfg.sliding_window:
        k = k[:, -cfg.sliding_window:]
        v = v[:, -cfg.sliding_window:]
    k = logical(k, "batch", "seq_kv", "kv_heads", "head_dim")
    v = logical(v, "batch", "seq_kv", "kv_heads", "head_dim")
    return logical(x, "batch", "seq", "embed"), (k, v), aux


def forward(cfg, params, tokens, *, return_kv=False):
    """tokens: (B, S) -> hidden (B, S, d); optionally per-layer (k, v)."""
    B, S = tokens.shape
    cd = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = logical(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        x = carry
        x, (k, v), aux = _layer(cfg, x, lp, positions)
        ys = ((k, v) if return_kv else None, aux)
        return x, ys

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (kv, auxs) = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"])
    return x, kv, jnp.mean(auxs)


def lm_loss(cfg, hidden, lm_head, labels):
    """Chunked softmax cross-entropy over the SEQUENCE axis: the batch axis
    stays sharded, logits exist one (B, chunk, V/model) slab at a time, and
    the chunk body is rematerialized so backward never stores logits."""
    B, S, d = hidden.shape
    chunk = cfg.logits_chunk or S
    if S % chunk:
        chunk = S
    n = S // chunk

    def body(acc, xs):
        hc, yc = xs                                   # (B, chunk, d), (B, chunk)
        logits = jnp.einsum("bcd,dv->bcv", hc, lm_head.astype(hc.dtype))
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)       # (B, chunk)
        ll = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - ll), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    hs = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)
    ys = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    acc, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    return acc / (B * S)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg, schedule=None, train_cfg=None):
    from repro.optim import adamw_update
    from repro.configs.base import TrainConfig
    tc = train_cfg or TrainConfig()
    sched = schedule or (lambda step: tc.lr)

    def loss_fn(params, batch):
        hidden, _, aux = forward(cfg, params, batch["tokens"])
        loss = lm_loss(cfg, hidden, params["lm_head"], batch["labels"])
        return loss + 0.01 * aux, (loss, aux)

    def grads_fn(params, batch):
        m = cfg.microbatch
        if not m or batch["tokens"].shape[0] % m:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: the per-layer activation stash only has to
        # hold one microbatch (O(L*T*d / m) HBM), at the cost of m scan trips
        B = batch["tokens"].shape[0]
        mb = {k: v.reshape(m, B // m, *v.shape[1:]) for k, v in batch.items()}

        def body(acc, mbatch):
            (tot, (loss, aux)), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            acc_g, acc_m = acc
            acc_g = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc_g, g)
            return (acc_g, (acc_m[0] + tot, (acc_m[1][0] + loss,
                                             acc_m[1][1] + aux))), None

        acc_dt = jnp.dtype(cfg.grad_accum_dtype)
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        zero_m = (jnp.zeros((), jnp.float32),
                  (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)))
        (g, (tot, (loss, aux))), _ = jax.lax.scan(body, (zero_g, zero_m), mb)
        scale = 1.0 / m
        g = jax.tree.map(lambda x: x * scale, g)
        return (tot * scale, (loss * scale, aux * scale)), g

    def train_step(params, opt_state, batch):
        (tot, (loss, aux)), grads = grads_fn(params, batch)
        lr = sched(opt_state["count"])
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, lr=lr, b1=tc.b1, b2=tc.b2, eps=tc.eps,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
        metrics = {"loss": loss, "aux_loss": aux, "lr": lr, **stats}
        return params, opt_state, metrics

    return train_step


def cache_template(cfg, batch, seq_len):
    """KV cache specs. SWA archs keep a ring buffer of `window` slots."""
    W = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (cfg.n_layers, batch, W, cfg.n_kv_heads, cfg.hd)
    axes = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
    dt = jnp.dtype(cfg.dtype)
    return {"k": Leaf(shape, dt, axes), "v": Leaf(shape, dt, axes)}


def abstract_cache(cfg, batch, seq_len):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                        cache_template(cfg, batch, seq_len), is_leaf=_is_leaf)


def cache_shardings(cfg, mesh, batch, seq_len):
    return jax.tree.map(lambda l: named_sharding(mesh, *l.axes),
                        cache_template(cfg, batch, seq_len), is_leaf=_is_leaf)


def make_prefill_step(cfg):
    def prefill(params, tokens):
        hidden, kv, _ = forward(cfg, params, tokens, return_kv=True)
        k, v = kv  # already window-sliced per layer for SWA archs
        last = hidden[:, -1]
        logits = jnp.einsum("bd,dv->bv", last,
                            params["lm_head"].astype(last.dtype))
        return logits.astype(jnp.float32), {"k": k, "v": v}
    return prefill


def make_decode_step(cfg):
    """One token for the whole batch; `pos` is the scalar write position
    (cache holds `pos` valid entries)."""

    def decode(params, cache, tokens, pos):
        B = tokens.shape[0]
        cd = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(cd)  # (B,1,d)
        x = logical(x, "batch", "seq", "embed")
        positions = jnp.full((B, 1), pos, jnp.int32)
        W = cache["k"].shape[2]
        slot = pos % W if cfg.sliding_window else jnp.minimum(pos, W - 1)

        def body(carry, xs):
            x = carry
            lp, kc, vc = xs
            lp = _gather_weights(cfg, lp)
            B, S, d = x.shape
            hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
            h = rmsnorm(x, lp["ln1"])
            q = jnp.einsum("bsd,dh->bsh", h, lp["wq"].astype(cd))
            k = jnp.einsum("bsd,dh->bsh", h, lp["wk"].astype(cd))
            v = jnp.einsum("bsd,dh->bsh", h, lp["wv"].astype(cd))
            if cfg.qkv_bias:
                q, k, v = (q + lp["bq"].astype(cd), k + lp["bk"].astype(cd),
                           v + lp["bv"].astype(cd))
            q = apply_rope(q.reshape(B, 1, Hq, hd), positions, cfg.rope_theta)
            k = apply_rope(k.reshape(B, 1, Hkv, hd), positions, cfg.rope_theta)
            v = v.reshape(B, 1, Hkv, hd)
            # one-hot masked update: dynamic_update_slice across the
            # 'model'-sharded seq axis makes GSPMD gather the whole cache;
            # the select keeps every shard local.
            onehot = (jnp.arange(W) == slot)[None, :, None, None]
            kc = jnp.where(onehot, k.astype(kc.dtype), kc)
            vc = jnp.where(onehot, v.astype(vc.dtype), vc)
            cache_len = jnp.minimum(pos + 1, W)
            out = decode_attention(q, kc, vc, cache_len)
            x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, Hq * hd),
                               lp["wo"].astype(cd))
            h2 = rmsnorm(x, lp["ln2"])
            o = 0.
            if cfg.moe:
                mo, _ = _moe_dispatch(cfg, h2.reshape(B, d), lp)
                o = o + mo.reshape(B, 1, d)
            if (not cfg.moe) or cfg.dense_residual:
                o = o + swiglu(h2, lp["ffn_wg"], lp["ffn_wu"], lp["ffn_wd"])
            return x + o, (kc, vc)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        x = rmsnorm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(cd))
        return logits[:, 0].astype(jnp.float32), {"k": nk, "v": nv}

    return decode
