"""Owner-sharded NequIP message passing (§Perf iteration 2 for the
ogb_products cell).

Under plain pjit, every layer costs (a) an implicit all-gather of the node
arrays for the `h[src]` gather AND (b) a full-node-array psum for the
`segment_sum` scatter (edges are sharded arbitrarily, so every shard
produces partial sums for every node). With edges PRE-SORTED BY DESTINATION
OWNER (a data-pipeline job — `CSRGraph.from_edges` already emits sorted
edges), each shard owns a contiguous node range plus exactly the edges that
point into it:

  - one explicit all-gather of the (bf16) node features per layer
    (its transpose is a reduce-scatter — the backward stays cheap),
  - src gathers read the gathered replica locally,
  - segment_sum lands in the shard-local (N_loc, ...) range: NO psum.

Napkin (ogb_products, C=32, bf16): 8.3 GB -> 2.8 GB wire per layer (3x),
and the (N, C, 9) full-size scatter buffers disappear from HBM.

Batch format (built by `shard_edges_by_owner`):
  node arrays  (N, ...)          sharded over ('data','model') flat
  edge arrays  (n_shards, E_loc) sharded on axis 0
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import nequip as nq


def shard_edges_by_owner(src, dst, edge_mask, n_nodes, n_shards):
    """Host-side: partition edges by dst owner (contiguous node ranges),
    pad each shard to a common E_loc. Returns (src, dst, mask) with shape
    (n_shards, E_loc)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    mask = np.asarray(edge_mask) > 0
    n_loc = -(-n_nodes // n_shards)
    owner = np.where(mask, dst // n_loc, n_shards - 1)
    e_loc = 0
    for s in range(n_shards):
        e_loc = max(e_loc, int(((owner == s) & mask).sum()))
    e_loc = max(8, -(-e_loc // 8) * 8)
    out_s = np.zeros((n_shards, e_loc), np.int32)
    out_d = np.zeros((n_shards, e_loc), np.int32)
    out_m = np.zeros((n_shards, e_loc), np.float32)
    for s in range(n_shards):
        sel = (owner == s) & mask
        n = int(sel.sum())
        out_s[s, :n] = src[sel]
        out_d[s, :n] = dst[sel]
        out_m[s, :n] = 1.0
    return out_s, out_d, out_m


def forward_sharded(cfg, params, batch, mesh, axes=("data", "model")):
    """Owner-sharded forward. batch edge arrays are (n_shards, E_loc)."""
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    pos = batch["positions"]
    N = pos.shape[0]
    n_loc = -(-N // n_shards)
    N_pad = n_loc * n_shards
    cd = jnp.dtype(cfg.dtype)

    if "node_feat" in batch:
        feat = batch["node_feat"]
    else:
        feat = jax.nn.one_hot(batch["species"], cfg.n_species, dtype=pos.dtype)
    h0_full = (feat @ params["embed"]).astype(cd)
    C = h0_full.shape[-1]

    def pad_nodes(x):
        return jnp.pad(x, ((0, N_pad - N),) + ((0, 0),) * (x.ndim - 1))

    pos_p = pad_nodes(pos)
    h0_p = pad_nodes(h0_full)

    aspec = tuple(axes) if len(axes) > 1 else axes[0]

    def shard_fn(pos_g, h0_l, esrc_l, edst_l, emask_l, layers):
        # pos_g replicated (N_pad, 3); h0_l local (n_loc, C);
        # edge arrays local (1, E_loc)
        si = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            si = si * mesh.shape[axes[1]] + jax.lax.axis_index(axes[1])
        src = esrc_l[0]
        dst_local = edst_l[0] - si * n_loc
        dst_local = jnp.clip(dst_local, 0, n_loc - 1)
        em = emask_l[0].astype(cd)

        rel = (pos_g[edst_l[0]] - pos_g[src]).astype(jnp.float32)
        dist = jnp.sqrt(jnp.sum(rel * rel, -1) + 1e-12)
        rhat = rel / dist[:, None]
        y0 = jnp.ones_like(dist, dtype=cd)
        y1 = rhat.astype(cd)
        y2m = nq.symtr(jnp.einsum("ei,ej->eij", rhat, rhat)).astype(cd)
        rbf = (nq.bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)
               * em.astype(jnp.float32)[:, None]).astype(cd)

        h0 = h0_l
        h1 = jnp.zeros((n_loc, C, 3), cd)
        h2 = jnp.zeros((n_loc, C, 5), cd)

        def layer(carry, lp):
            h0, h1, h2 = carry
            # ONE explicit all-gather per layer (transpose = reduce-scatter)
            h0_g = jax.lax.all_gather(h0, axes, tiled=True)
            h1_g = jax.lax.all_gather(h1, axes, tiled=True)
            h2_g = jax.lax.all_gather(h2, axes, tiled=True)
            rw = jax.nn.silu(rbf @ lp["radial_w1"].astype(cd)
                             + lp["radial_b1"].astype(cd))
            rw = (rw @ lp["radial_w2"].astype(cd)).reshape(-1, nq.N_PATHS, C)
            rw = rw * em[:, None, None]
            e0 = jnp.take(h0_g, src, axis=0)
            e1 = jnp.take(h1_g, src, axis=0)
            e2 = nq.from5(jnp.take(h2_g, src, axis=0))
            m0, m1, m2 = nq.tensor_product(e0, e1, e2, y0, y1, y2m, rw)
            # dst is LOCAL: segment_sum lands in (n_loc, ...) — no psum
            a0 = jax.ops.segment_sum(m0, dst_local, num_segments=n_loc)
            a1 = jax.ops.segment_sum(m1, dst_local, num_segments=n_loc)
            a2 = jax.ops.segment_sum(nq.to5(m2), dst_local,
                                     num_segments=n_loc)
            n0 = jnp.einsum("nc,cd->nd", a0, lp["self0"].astype(cd)) \
                + jnp.einsum("nc,cd->nd", h0, lp["skip0"].astype(cd))
            n1 = jnp.einsum("nci,cd->ndi", a1, lp["self1"].astype(cd)) \
                + jnp.einsum("nci,cd->ndi", h1, lp["skip1"].astype(cd))
            n2 = jnp.einsum("nck,cd->ndk", a2, lp["self2"].astype(cd)) \
                + jnp.einsum("nck,cd->ndk", h2, lp["skip2"].astype(cd))
            gates = jax.nn.sigmoid(
                (jnp.einsum("nc,cg->ng", n0, lp["gate_w"].astype(cd))
                 + lp["gate_b"].astype(cd)).astype(jnp.float32)).astype(cd)
            g1, g2 = gates[:, :C], gates[:, C:]
            h0 = jax.nn.silu(n0.astype(jnp.float32)).astype(cd)
            h1 = n1 * g1[..., None]
            h2 = n2 * g2[..., None]
            return (h0, h1, h2), None

        (h0, h1, h2), _ = jax.lax.scan(layer, (h0, h1, h2), layers)
        return h0

    h0_out = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(aspec, None), P(aspec, None), P(aspec, None),
                  P(aspec, None), P()),
        out_specs=P(aspec, None),
        check_vma=False)(
        pos_p, h0_p.reshape(N_pad, C), batch["edge_src_sharded"],
        batch["edge_dst_sharded"], batch["edge_mask_sharded"],
        params["layers"])

    h0_out = h0_out[:N].astype(jnp.float32)
    node_e = jax.nn.silu(h0_out @ params["readout_w"]) @ params["readout_w2"]
    if "node_mask" in batch:
        node_e = node_e * batch["node_mask"][:, None].astype(node_e.dtype)
    n_graphs = batch["energy_target"].shape[0]
    return jax.ops.segment_sum(node_e[:, 0], batch["graph_id"],
                               num_segments=n_graphs)


def make_train_step_sharded(cfg, mesh, axes=("data", "model"),
                            train_cfg=None):
    from repro.configs.base import TrainConfig
    from repro.optim import adamw_update
    tc = train_cfg or TrainConfig()

    def loss_fn(params, batch):
        e = forward_sharded(cfg, params, batch, mesh, axes)
        err = jnp.square(e - batch["energy_target"])
        if "energy_weight" in batch:
            w = batch["energy_weight"]
            return jnp.sum(err * w) / jnp.maximum(jnp.sum(w), 1.0)
        return jnp.mean(err)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, lr=tc.lr, grad_clip=tc.grad_clip)
        return params, opt_state, {"loss": loss, **stats}

    return train_step
