"""HLO-text collective accounting.

`cost_analysis()` has no collective figures, so we parse the compiled
module's text: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes its operand/output bytes. Collectives
inside `while` bodies (lax.scan over layers!) execute trip-count times, so
we recover trip counts from the loop-condition constant and multiply.

Reported per collective ring model (per-device wire bytes):
  all-gather:        (g-1)/g * out_bytes
  reduce-scatter:    (g-1)/g * in_bytes
  all-reduce:        2 (g-1)/g * bytes          (RS + AG)
  all-to-all:        (g-1)/g * bytes
  collective-permute: bytes
plus the raw operand-byte sum (`raw_bytes`) per the assignment formula.
"""

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"(?:^|\s)(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(txt):
    """Sum byte sizes of all shapes in a type string like f32[8,128]."""
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _split_computations(hlo_text):
    """Return {name: [lines]} for every computation in the module."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _HEADER_RE.match(line.strip())
            if m:
                if cur_name is not None:
                    comps[cur_name] = cur_lines
                cur_name, cur_lines = m.group(1), []
                continue
            if line.strip() == "}":
                if cur_name is not None:
                    comps[cur_name] = cur_lines
                cur_name, cur_lines = None, []
                continue
        if cur_name is not None:
            cur_lines.append(line.strip())
    if cur_name is not None:
        comps[cur_name] = cur_lines
    return comps


def _group_size(line, default):
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _line_collective(line, n_devices):
    """Returns (kind, raw_bytes, wire_bytes) or None.

    Scheduled HLO format: `%name = TYPE opcode(%operand, ...), attrs...`
    Operand references carry no type, so sizes derive from the output TYPE
    (exact for all-gather/all-reduce/all-to-all/permute; reduce-scatter
    input = output * group).
    """
    _, eq, rhs = line.partition("=")
    if not eq:
        return None
    m = _COLL_RE.search(rhs)
    if m is None:
        return None
    kind, suffix = m.group(1), m.group(2)
    if suffix == "-done":
        return None  # counted at -start
    out_b = _shape_bytes(rhs[:m.start()])
    g = _group_size(line, n_devices)
    ring = (g - 1) / max(g, 1)
    if kind == "all-gather":
        raw, wire = out_b, ring * out_b
    elif kind == "reduce-scatter":
        in_b = out_b * g
        raw, wire = in_b, ring * in_b
    elif kind == "all-reduce":
        raw, wire = out_b, 2 * ring * out_b
    elif kind == "all-to-all":
        raw, wire = out_b, ring * out_b
    else:  # collective-permute
        raw, wire = out_b, out_b
    return kind, raw, wire


_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|conditional)\(.*?to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _trip_count(cond_lines):
    """Heuristic: the compare constant in the loop condition."""
    consts = []
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for m in _CONST_RE.finditer(line):
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _comp_bytes(name, comps, n_devices, memo):
    if name in memo:
        return memo[name]
    memo[name] = defaultdict(float)  # cycle guard
    totals = defaultdict(float)
    for line in comps.get(name, ()):
        got = _line_collective(line, n_devices)
        if got:
            kind, raw, wire = got
            totals[f"{kind}_raw"] += raw
            totals[f"{kind}_wire"] += wire
            totals["raw"] += raw
            totals["wire"] += wire
            totals["count"] += 1
        wm = _WHILE_RE.search(line)
        if wm:
            cond, body = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ()))
            sub = _comp_bytes(body, comps, n_devices, memo)
            for k, v in sub.items():
                totals[k] += trips * v
        cm = _CALL_RE.search(line)
        if cm:
            sub = _comp_bytes(cm.group(1), comps, n_devices, memo)
            for k, v in sub.items():
                totals[k] += v
        # fusions can't contain collectives; skip
    memo[name] = totals
    return totals


def _entry_name(hlo_text, comps):
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
            if m:
                return m.group(1)
    return max(comps, key=lambda k: len(comps[k])) if comps else ""


def collective_bytes(hlo_text, n_devices):
    """Aggregate collective bytes for the entry computation (trip-count
    aware). Returns dict with per-kind raw/wire byte totals (per device)."""
    comps = _split_computations(hlo_text)
    memo = {}
    totals = _comp_bytes(_entry_name(hlo_text, comps), comps, n_devices, memo)
    return dict(totals)


# ---------------------------------------------------------------------------
# trip-count-aware FLOPs + HBM-byte estimation
# (XLA's compiled.cost_analysis() counts while bodies ONCE — verified — so a
#  scan-over-layers model would be undercounted by n_layers without this.)
# ---------------------------------------------------------------------------

_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_ARG_RE = re.compile(r"%([\w.\-]+)")
_FIRST_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# ops whose operands/outputs are views / no real HBM traffic
_VIEW_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "copy", "after-all", "partition-id", "replica-id", "iota"}


def _parse_ops(lines):
    """Symbol table {name: type_str} + op list [(name, type, opcode, rest)]."""
    table, ops = {}, []
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, typ, opcode, rest = m.groups()
        table[name] = typ
        ops.append((name, typ, opcode, rest))
    return table, ops


def _shape_elems(typ):
    m = _FIRST_SHAPE_RE.search(typ)
    if not m:
        return 0, ""
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n, m.group(1)


def _dot_flops(typ, rest, table):
    out_elems, _ = _shape_elems(typ)
    args = _ARG_RE.findall(rest.split("lhs_contracting_dims")[0])
    if not args:
        return 0.0
    lhs_typ = table.get(args[0], "")
    m = _FIRST_SHAPE_RE.search(lhs_typ)
    dm = _DIMS_RE.search(rest)
    if not m or not dm:
        return 0.0
    lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    contract = 1
    for i in dm.group(1).split(","):
        if i != "" and int(i) < len(lhs_dims):
            contract *= lhs_dims[int(i)]
    return 2.0 * out_elems * contract


def _comp_cost(name, comps, memo):
    """Returns (flops, hbm_bytes) for one computation, recursing into
    while bodies (x trip count) and fusion/call subcomputations (flops only
    for fusion internals; fusion bytes are counted at the call site)."""
    if name in memo:
        return memo[name]
    memo[name] = (0.0, 0.0)  # cycle guard
    lines = comps.get(name, ())
    table, ops = _parse_ops(lines)
    flops, hbm = 0.0, 0.0
    for op_name, typ, opcode, rest in ops:
        if opcode == "dot":
            flops += _dot_flops(typ, rest, table)
        if opcode == "while":
            wm = _WHILE_RE.search(f"while({rest}")
            # rest starts after "while(" already; reconstruct minimal
            cm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", rest)
            if cm:
                trips = _trip_count(comps.get(cm.group(1), ()))
                f, b = _comp_cost(cm.group(2), comps, memo)
                flops += trips * f
                hbm += trips * b
            continue
        cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rest)
        if cm and opcode in ("fusion", "call", "conditional"):
            f, _ = _comp_cost(cm.group(1), comps, memo)
            flops += f
        # HBM model: output + operand bytes for every materializing op
        if opcode in _VIEW_OPS:
            continue
        hbm += _shape_bytes(typ)
        arg_str = rest.split(", calls=")[0].split(", to_apply=")[0]
        arg_str = arg_str.split("metadata=")[0]
        depth, end = 0, len(arg_str)
        for i, ch in enumerate(arg_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth < 0:
                    end = i
                    break
        for a in _ARG_RE.findall(arg_str[:end]):
            hbm += _shape_bytes(table.get(a, ""))
    memo[name] = (flops, hbm)
    return memo[name]


def hlo_cost(hlo_text):
    """Trip-count-aware per-device (flops, hbm_bytes) from scheduled HLO."""
    comps = _split_computations(hlo_text)
    memo = {}
    flops, hbm = _comp_cost(_entry_name(hlo_text, comps), comps, memo)
    return {"flops": flops, "hbm_bytes": hbm}
