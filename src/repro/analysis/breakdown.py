"""Per-collective breakdown of a dry-run cell: which ops move the bytes
(trip-count aware). The §Perf hypothesis loop starts here.

Usage:
  PYTHONPATH=src python -m repro.analysis.breakdown --arch arctic-480b \
      --shape train_4k [--multi] [--set moe_impl=shard_map] [--top 15]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import Counter

import jax

from repro.analysis import hlo as H


def collective_breakdown(hlo_text, n_devices, top=15):
    comps = H._split_computations(hlo_text)
    entry = H._entry_name(hlo_text, comps)
    acc = Counter()

    def walk(name, mult):
        for line in comps.get(name, ()):
            got = H._line_collective(line, n_devices)
            if got:
                kind, raw, wire = got
                m = re.search(
                    r"=\s*((?:\([^=]*?\))|(?:\S+))\s+(all-\w+|reduce-scatter|"
                    r"collective-permute)", line)
                shape = m.group(1)[:70] if m else "?"
                meta = re.search(r'op_name="([^"]*)"', line)
                op = meta.group(1)[-70:] if meta else ""
                acc[(kind, shape, op)] += wire * mult
            cm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", line)
            if cm and "while" in line:
                walk(cm.group(2),
                     mult * H._trip_count(comps.get(cm.group(1), ())))
            else:
                cm2 = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
                if cm2 and re.search(r"\s(call|conditional)\(", line):
                    walk(cm2.group(1), mult)

    walk(entry, 1)
    return acc.most_common(top)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides key=value")
    args = ap.parse_args()

    import dataclasses
    from repro.configs import get_config
    from repro.configs.shapes import shapes_for
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.models.sharding import rules_ctx

    mesh = make_production_mesh(multi_pod=args.multi)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = (int(v) if v.isdigit()
                        else v == "true" if v in ("true", "false") else v)
    cfg0 = get_config(args.arch)
    shape = shapes_for(cfg0.family)[args.shape]
    cell = build_cell(args.arch, shape, mesh, multi_pod=args.multi,
                      overrides=overrides)
    with rules_ctx(cell.rules, mesh=mesh, pod_dp=args.multi):
        comp = jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(
            *cell.args).compile()
    txt = comp.as_text()
    n = mesh.devices.size
    total = H.collective_bytes(txt, n)
    print(f"total wire {total.get('wire', 0)/2**30:.2f} GiB/device "
          f"({total.get('count', 0):.0f} collective sites)")
    for (kind, shape_s, op), wire in collective_breakdown(txt, n, args.top):
        print(f"{wire/2**30:9.2f} GiB  {kind:18s} {shape_s:45s} {op}")


if __name__ == "__main__":
    main()
