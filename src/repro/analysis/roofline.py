"""Three-term roofline model from the dry-run's compiled artifact.

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = per-device wire bytes (ring model) / ICI link bw

(`cost_analysis()` reports PER-DEVICE figures for an SPMD-partitioned
module — verified empirically — so the spec's "/ chips" is already applied.)

MODEL_FLOPS is the analytic useful work: 6*N_active*D tokens for LM training
(2*N for inference) + exact-causal attention, per-edge tensor-product work
for the GNN, MLP+interaction for recsys, guide+selected-block scoring for
CluSD retrieval. The ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat
and masked-attention waste.
"""

import dataclasses

from repro.common.hw import TPU_V5E


def roofline_terms(cost, coll, n_devices, hw=TPU_V5E):
    """cost: dict with per-device 'flops' and 'bytes accessed' (from the
    trip-count-aware analysis.hlo.hlo_cost; XLA's own cost_analysis counts
    while bodies once); coll: analysis.hlo.collective_bytes dict."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    wire_dev = float(coll.get("wire", 0.0))
    raw_dev = float(coll.get("raw", 0.0))
    t_compute = flops_dev / hw.peak_flops_bf16
    t_memory = bytes_dev / hw.hbm_bandwidth
    t_collective = wire_dev / hw.ici_link_bandwidth
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective,
             "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
             "coll_wire_bytes_per_device": wire_dev,
             "coll_raw_bytes_per_device": raw_dev,
             "global_flops": flops_dev * n_devices}
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    bound = max(t_compute, t_memory, t_collective)
    terms["step_time_lower_bound_s"] = bound
    terms["mfu_upper_bound"] = (t_compute / bound) if bound > 0 else 0.0
    return terms


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS per cell
# ---------------------------------------------------------------------------

def _lm_attention_flops(cfg, B, S, causal_train):
    W = cfg.sliding_window
    # average effective kv length per query position
    if W and W < S:
        s_eff = W / 2 + (S - W) * W / S  # approx: ramp then constant window
    else:
        s_eff = (S + 1) / 2
    fwd = 4 * B * S * s_eff * cfg.n_heads * cfg.hd  # QK^T + PV, 2 flops/MAC
    return 3 * fwd if causal_train else fwd


def model_flops(arch_cfg, shape, clusd_cfg=None):
    fam = getattr(arch_cfg, "family", "lm")
    if fam == "retrieval":
        # CluSD serve: query-centroid sims + selected-block scoring + LSTM
        B = shape.batch or arch_cfg.serve_batch
        sel = arch_cfg.max_selected * arch_cfg.cluster_cap
        lstm = arch_cfg.n_candidates * 4 * arch_cfg.lstm_hidden * (
            arch_cfg.lstm_hidden + 1 + arch_cfg.u_bins + 2 * arch_cfg.v_bins)
        return B * (2 * arch_cfg.n_clusters * arch_cfg.dim
                    + 2 * sel * arch_cfg.dim + 2 * lstm)
    if fam == "lm":
        B, S = shape.global_batch, shape.seq_len
        N_act = arch_cfg.active_param_count()
        if shape.mode == "train":
            return 6 * N_act * B * S + _lm_attention_flops(arch_cfg, B, S, True)
        if shape.mode == "prefill":
            return 2 * N_act * B * S + _lm_attention_flops(arch_cfg, B, S, False)
        # decode: one token, cache length = S (or window)
        W = arch_cfg.sliding_window
        kv = min(S, W) if W else S
        attn = 4 * B * arch_cfg.n_heads * arch_cfg.hd * kv
        return 2 * N_act * B + attn
    if fam == "gnn":
        C = arch_cfg.d_hidden
        L = arch_cfg.n_layers
        E = shape.n_edges if not shape.batch_nodes else _sampled_edges(shape)
        N = shape.n_nodes if not shape.batch_nodes else _sampled_nodes(shape)
        if shape.n_graphs:
            E, N = shape.n_edges * shape.n_graphs, shape.n_nodes * shape.n_graphs
        # per-edge: radial MLP + 15 TP paths (~dim(l1)*dim(l2)*dim(l3) MACs)
        from repro.models.nequip import PATHS, RADIAL_HIDDEN, N_PATHS
        dim = {0: 1, 1: 3, 2: 9}
        tp = sum(dim[a] * dim[b] * dim[c] for a, b, c in PATHS)
        per_edge = 2 * (arch_cfg.n_rbf * RADIAL_HIDDEN
                        + RADIAL_HIDDEN * N_PATHS * C) + 2 * tp * C
        per_node = 2 * (6 * C * C * 4.3 + 2 * C * C)  # self/skip over l dims + gate
        fwd = L * (E * per_edge + N * per_node)
        return 3 * fwd  # train
    if fam == "recsys":
        B = shape.batch
        if shape.mode == "retrieval":
            n_cand = shape.n_candidates
            d = arch_cfg.embed_dim
            guide = 2 * n_cand * 2              # wide guide (2 item fields)
            if clusd_cfg is not None:
                scanned = clusd_cfg.max_selected * clusd_cfg.cluster_cap
            else:
                scanned = n_cand
            return guide + 2 * scanned * d + 2 * B * d
        d = arch_cfg.embed_dim
        F = arch_cfg.n_sparse
        mlp = 0
        dims_chain = []
        if arch_cfg.bot_mlp:
            dims_chain.append((arch_cfg.n_dense,) + tuple(arch_cfg.bot_mlp))
        if arch_cfg.top_mlp:
            n_f = F + 1
            dims_chain.append((n_f * (n_f - 1) // 2 + d,) + tuple(arch_cfg.top_mlp))
        if arch_cfg.mlp:
            dims_chain.append((F * d,) + tuple(arch_cfg.mlp))
        for chain in dims_chain:
            for a, b in zip(chain[:-1], chain[1:]):
                mlp += 2 * a * b
        inter = 2 * (F + 1) ** 2 * d if arch_cfg.interaction == "dot" else 2 * F * d
        if arch_cfg.kind == "din":
            be = 2 * d
            attn_chain = (4 * be,) + tuple(arch_cfg.attn_mlp) + (1,)
            attn = sum(2 * a * b for a, b in zip(attn_chain[:-1], attn_chain[1:]))
            inter += arch_cfg.seq_len * attn
        per_ex = mlp + inter
        mult = 3 if shape.mode == "train" else 1
        return mult * B * per_ex
    raise ValueError(fam)


def _sampled_nodes(shape):
    n = shape.batch_nodes
    total = n
    for f in shape.fanout:
        n = n * f
        total += n
    return total


def _sampled_edges(shape):
    n = shape.batch_nodes
    total = 0
    for f in shape.fanout:
        total += n * f
        n = n * f
    return total
