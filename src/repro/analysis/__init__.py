from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import roofline_terms, model_flops
