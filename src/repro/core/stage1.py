"""Stage I of Step 2: preliminary top-n cluster selection (paper §2.2).

SortByOverlap: multikey sort on the priority vector (P(C,B_1),...,P(C,B_v)),
ties broken by query-centroid similarity. Implemented as v+1 passes of
stable argsort (exact lexicographic order; no packed-key overflow).
SortByDist: the IVF-style baseline ordering (ablation Table 8).

expand_candidates: LADR-style proximity expansion (Kulkarni et al.,
2023) — deepen the sparse-seeded candidate list by walking the pre-built
cluster neighbor graph, so stage-1 recall rises without widening the
sparse seed set. Static-shape and jit-able: the output width is fixed by
the expansion budget, never by how many clusters the walk reaches.
"""

import jax
import jax.numpy as jnp


def _lexsort_desc(keys):
    """keys: list of (N,) arrays, primary first. Descending. Returns perm."""
    N = keys[0].shape[0]
    perm = jnp.arange(N)
    # least-significant pass first (stable sorts compose lexicographically)
    for key in reversed(keys):
        k = jnp.take(key, perm)
        order = jnp.argsort(-k, stable=True)
        perm = jnp.take(perm, order)
    return perm


def sort_by_overlap(P, qc_sim, n):
    """P: (B, N, v); qc_sim: (B, N) query-centroid similarity.

    Returns (B, n) candidate cluster ids, best first.
    """
    def one(Pq, simq):
        keys = [Pq[:, j] for j in range(Pq.shape[1])] + [simq]
        perm = _lexsort_desc(keys)
        return perm[:n].astype(jnp.int32)

    return jax.vmap(one)(P, qc_sim)


def sort_by_dist(qc_sim, n):
    """IVF ordering: top-n clusters by query-centroid similarity. (B, n)."""
    _, ids = jax.lax.top_k(qc_sim, n)
    return ids.astype(jnp.int32)


def expand_candidates(cand, neighbor_ids, neighbor_sims, qc_sim, depth,
                      n_out):
    """Proximity-expand stage-1 seed clusters through the neighbor graph.

    cand: (B, n) seed cluster ids in stage-1 priority order;
    neighbor_ids/neighbor_sims: (N, m) pre-built centroid kNN graph
    (self excluded); qc_sim: (B, N) query-centroid similarity;
    depth: neighbors considered per seed (clamped to m);
    n_out: static output width, n <= n_out <= N.

    Returns (B, n_out) int32, all-distinct per row: the seeds first
    (order untouched — depth 0 / n_out == n is exactly the current
    pipeline), then graph-reached clusters ordered by their best
    neighbor-similarity to any seed, then — when the walk reaches fewer
    distinct clusters than the remaining slots — the nearest untouched
    clusters by query-centroid similarity (IVF-style fill; keeps the
    shape static instead of mask-padding a ragged reach set, and every
    slot still holds a plausibly useful cluster).
    """
    B, n = cand.shape
    N = qc_sim.shape[1]
    ext = int(n_out) - n
    if ext <= 0 or depth <= 0:
        return cand
    if ext > N - n:
        raise ValueError(f"n_out={n_out} exceeds n_clusters={N}")
    depth = min(int(depth), neighbor_ids.shape[1])

    def one(cand_q, sim_q):
        nb_i = jnp.take(neighbor_ids, cand_q, axis=0)[:, :depth].reshape(-1)
        nb_s = jnp.take(neighbor_sims, cand_q, axis=0)[:, :depth].reshape(-1)
        # best seed->cluster edge per cluster; seeds themselves excluded
        reach = jnp.full((N,), -jnp.inf, nb_s.dtype).at[nb_i].max(nb_s)
        is_seed = jnp.zeros((N,), bool).at[cand_q].set(True)
        reach = jnp.where(is_seed, -jnp.inf, reach)
        reached = reach > -jnp.inf
        score = jnp.where(reached, reach,
                          jnp.where(is_seed, -jnp.inf, sim_q))
        # exact two-key order: graph-reached first (by edge sim), then
        # IVF fill (by query-centroid sim); seeds sort last and can never
        # re-enter because ext <= N - n
        perm = _lexsort_desc([reached.astype(jnp.float32), score])
        return jnp.concatenate([cand_q, perm[:ext].astype(jnp.int32)])

    return jax.vmap(one)(cand, qc_sim)
