"""Stage I of Step 2: preliminary top-n cluster selection (paper §2.2).

SortByOverlap: multikey sort on the priority vector (P(C,B_1),...,P(C,B_v)),
ties broken by query-centroid similarity. Implemented as v+1 passes of
stable argsort (exact lexicographic order; no packed-key overflow).
SortByDist: the IVF-style baseline ordering (ablation Table 8).
"""

import jax
import jax.numpy as jnp


def _lexsort_desc(keys):
    """keys: list of (N,) arrays, primary first. Descending. Returns perm."""
    N = keys[0].shape[0]
    perm = jnp.arange(N)
    # least-significant pass first (stable sorts compose lexicographically)
    for key in reversed(keys):
        k = jnp.take(key, perm)
        order = jnp.argsort(-k, stable=True)
        perm = jnp.take(perm, order)
    return perm


def sort_by_overlap(P, qc_sim, n):
    """P: (B, N, v); qc_sim: (B, N) query-centroid similarity.

    Returns (B, n) candidate cluster ids, best first.
    """
    def one(Pq, simq):
        keys = [Pq[:, j] for j in range(Pq.shape[1])] + [simq]
        perm = _lexsort_desc(keys)
        return perm[:n].astype(jnp.int32)

    return jax.vmap(one)(P, qc_sim)


def sort_by_dist(qc_sim, n):
    """IVF ordering: top-n clusters by query-centroid similarity. (B, n)."""
    _, ids = jax.lax.top_k(qc_sim, n)
    return ids.astype(jnp.int32)
