"""CluSD as a first-class feature for recsys candidate retrieval
(`retrieval_cand` shape: 1 query x 1M candidates).

Mapping of the paper onto the recsys setting (DESIGN.md §5):
  sparse lexical retrieval  -> cheap guide scores: the model's wide/linear
                               branch (wide-deep, deepfm) or a low-dim
                               prefix dot (dlrm, din)
  dense embedding clusters  -> k-means clusters of candidate item vectors,
                               cluster-blocked layout (n_clusters, cap, d)
  Stage I/II                 -> identical: bin-overlap multikey sort + LSTM
  partial dense retrieval    -> full-dim dot only on selected cluster blocks
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import bins as bins_lib
from repro.core import features as feat_lib
from repro.core import fusion as fusion_lib
from repro.core import stage1 as stage1_lib
from repro.core.lstm import lstm_apply
from repro.models import recsys as rs
from repro.models.sharding import logical


@dataclasses.dataclass(frozen=True)
class CandidateIndexSpec:
    """Static geometry of the candidate-side CluSD index."""
    n_candidates: int
    n_clusters: int = 4096
    cap: int = 512                 # cluster block size (padded)
    guide_dim: int = 16            # prefix-dot guide width (dlrm/din)
    k_guide: int = 1024            # guide retrieval depth (= paper's k)
    bins: tuple = (10, 25, 50, 100, 200, 500, 1024)
    n_candidates_stage1: int = 32  # n
    u_bins: int = 6
    max_selected: int = 32
    theta: float = 0.02
    alpha: float = 0.5
    k_final: int = 100
    local_topk: bool = False       # shard-local guide top-k merge (§Perf)

    @property
    def v_bins(self):
        return len(self.bins)


def guide_scores(cfg, params, u, item_vecs, cand_sparse):
    """Cheap guide over ALL candidates (the 'sparse retrieval' analogue)."""
    if cfg.kind in ("wide_deep", "deepfm"):
        n_item = cand_sparse.shape[1]
        g = sum(rs.embedding_lookup(params["wide"][f"t{i}"],
                                    cand_sparse[:, i])[:, 0]
                for i in range(n_item))
        return g
    # low-dim prefix dot (PQ-style coarse scorer)
    gd = min(16, item_vecs.shape[1])
    return item_vecs[:, :gd] @ u[0, :gd]


def _guide_topk(g, spec):
    """Guide-phase top-k. Optimized path (§Perf): per-shard local top-k +
    merge — wire bytes nm*k*8B instead of all-gathering the full score
    vector over the candidate shards."""
    from repro.models import sharding as sh
    from jax.sharding import PartitionSpec as P
    mesh = getattr(sh._state, "mesh", None)
    if not (spec.local_topk and mesh is not None and "model" in mesh.shape):
        return jax.lax.top_k(g, spec.k_guide)
    nm = mesh.shape["model"]
    shard = g.shape[0] // nm
    kk = min(spec.k_guide, shard)

    def local(g_l):
        v, i = jax.lax.top_k(g_l, kk)
        gid = i + jax.lax.axis_index("model") * shard
        v_all = jax.lax.all_gather(v, "model")            # (nm, kk)
        g_all = jax.lax.all_gather(gid, "model")
        mv, mi = jax.lax.top_k(v_all.reshape(-1), spec.k_guide)
        return mv, jnp.take(g_all.reshape(-1), mi)

    return jax.shard_map(local, mesh=mesh, in_specs=(P("model"),),
                         out_specs=(P(), P()), check_vma=False)(g)


def clusd_candidate_retrieval(model_cfg, spec: CandidateIndexSpec, params,
                              batch, cand_sparse, item_blocks, centroids,
                              lstm_params, neighbor_ids, neighbor_sims,
                              slot_valid=None):
    """One query against spec.n_candidates items, CluSD-accelerated.

    item_blocks: (N, cap, d) cluster-blocked candidate vectors — candidate id
    == c * cap + slot. slot_valid (N*cap,) masks pad slots out of the guide
    (pad slots otherwise alias item id 0 in the wide branch).
    """
    N, cap, d = item_blocks.shape
    u = rs.user_tower(model_cfg, params, batch)            # (1, d)

    flat_items = item_blocks.reshape(N * cap, d)
    g = guide_scores(model_cfg, params, u, flat_items, cand_sparse)
    if slot_valid is not None:
        g = jnp.where(slot_valid, g, -jnp.inf)
    g = logical(g, "candidates")
    g_scores, g_ids = _guide_topk(g, spec)                 # (k,)

    # Stage I: overlap of guide top-k with clusters (cluster = id // cap)
    bin_ids = bins_lib.rank_bin_ids(spec.bins, spec.k_guide)
    doc_cluster = g_ids // cap                             # (k,)
    slot = doc_cluster * spec.v_bins + bin_ids
    gn = fusion_lib.minmax_norm(g_scores[None])[0]
    cnt = jax.ops.segment_sum(jnp.ones_like(gn), slot,
                              num_segments=N * spec.v_bins)
    ssum = jax.ops.segment_sum(gn, slot, num_segments=N * spec.v_bins)
    P = cnt.reshape(N, spec.v_bins)[None]
    Q = (ssum / jnp.maximum(cnt, 1.0)).reshape(N, spec.v_bins)[None]
    qc_sim = (centroids @ u[0])[None]                      # (1, N)
    cand = stage1_lib.sort_by_overlap(P, qc_sim, spec.n_candidates_stage1)

    feats = feat_lib.candidate_features(
        cand, qc_sim, P, Q, neighbor_ids, neighbor_sims, spec.u_bins)
    probs = lstm_apply(lstm_params, feats)                 # (1, n)
    picked = probs >= spec.theta
    masked = jnp.where(picked, probs, -1.0)
    top_p, top_i = jax.lax.top_k(masked, spec.max_selected)
    sel_mask = top_p >= 0.0
    sel_ids = jnp.take_along_axis(cand, top_i, axis=1)[0]  # (S,)

    # Step 3: full-dim dot on selected blocks only
    blocks = jnp.take(item_blocks, sel_ids, axis=0)        # (S, cap, d)
    dscore = jnp.einsum("d,scd->sc", u[0], blocks)
    dscore = jnp.where(sel_mask[0][:, None], dscore, -jnp.inf)
    did = (sel_ids[:, None] * cap + jnp.arange(cap)[None, :]).reshape(-1)
    dmask = jnp.isfinite(dscore.reshape(-1))

    ids, scores = fusion_lib.fuse_topk(
        g_ids[None], g_scores[None], did[None].astype(jnp.int32),
        jnp.where(dmask, dscore.reshape(-1), 0.0)[None], dmask[None],
        N * cap, spec.alpha, spec.k_final)
    return ids[0], scores[0], {"n_selected": jnp.sum(sel_mask)}


def brute_force_retrieval(model_cfg, params, batch, item_blocks, k=100):
    """Baseline: full dot over all candidates."""
    N, cap, d = item_blocks.shape
    u = rs.user_tower(model_cfg, params, batch)
    flat = item_blocks.reshape(N * cap, d)
    scores = logical(flat @ u[0], "candidates")
    s, i = jax.lax.top_k(scores, k)
    return i.astype(jnp.int32), s
