"""K-means clustering of dense document embeddings (FAISS-IVF analogue) with
capacity-balanced padded member lists — TPU needs static cluster blocks, so
clusters are materialized as (N, cap) padded doc-id tables (DESIGN.md §2).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _assign(X, centroids, n_clusters):
    # (D, dim) x (N, dim) -> nearest centroid by L2
    x2 = jnp.sum(X * X, axis=1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=1)
    d2 = x2 + c2[None, :] - 2.0 * X @ centroids.T
    return jnp.argmin(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _update(X, assign, n_clusters):
    sums = jax.ops.segment_sum(X, assign, num_segments=n_clusters)
    counts = jax.ops.segment_sum(jnp.ones((X.shape[0],), X.dtype), assign,
                                 num_segments=n_clusters)
    return sums / jnp.maximum(counts, 1.0)[:, None], counts


def kmeans(rng, X, n_clusters, iters=15):
    """Lloyd's algorithm. X: (D, dim) array. Returns (centroids, assignments)."""
    D = X.shape[0]
    idx = jax.random.choice(rng, D, (n_clusters,), replace=False)
    centroids = X[idx]
    assign = None
    for _ in range(iters):
        assign = _assign(X, centroids, n_clusters)
        new_c, counts = _update(X, assign, n_clusters)
        # re-seed empty clusters from random points
        empty = counts < 0.5
        rng, sub = jax.random.split(rng)
        reseed = X[jax.random.choice(sub, D, (n_clusters,))]
        centroids = jnp.where(empty[:, None], reseed, new_c)
    assign = _assign(X, centroids, n_clusters)
    return centroids, assign


def _gather_rows(shards, offsets, idx):
    """Gather global row indices from a list of (D_i, dim) shards."""
    first = np.asarray(shards[0][:1])
    out = np.empty((len(idx), first.shape[1]), first.dtype)
    sid = np.searchsorted(offsets, idx, side="right") - 1
    for i, (s, g) in enumerate(zip(sid, idx)):
        out[i] = shards[s][g - offsets[s]]
    return out


def kmeans_shards(rng, shards, n_clusters, iters=15):
    """Streaming Lloyd's over embedding shards — the offline analogue of
    `kmeans` for corpora that never fit in device memory at once. `shards`
    is a sequence of (D_i, dim) host arrays (slices of an np.memmap are
    fine); one shard is device-resident at a time, and per-cluster sums /
    counts accumulate on the host. Empty clusters are reseeded from random
    corpus rows each iteration, like `kmeans`.

    Returns (centroids (N, dim) device array, assignments (D,) int32).
    """
    sizes = [int(s.shape[0]) for s in shards]
    D = sum(sizes)
    offsets = np.cumsum([0] + sizes)
    dim = int(shards[0].shape[1])
    init = np.sort(np.asarray(
        jax.random.choice(rng, D, (n_clusters,), replace=False)))
    centroids = jnp.asarray(_gather_rows(shards, offsets, init))
    for _ in range(iters):
        sums = np.zeros((n_clusters, dim), np.float32)
        counts = np.zeros((n_clusters,), np.float32)
        for s in shards:
            Xs = jnp.asarray(np.asarray(s))
            a = _assign(Xs, centroids, n_clusters)
            sums += np.asarray(jax.ops.segment_sum(
                Xs, a, num_segments=n_clusters))
            counts += np.asarray(jax.ops.segment_sum(
                jnp.ones((Xs.shape[0],), Xs.dtype), a,
                num_segments=n_clusters))
        new_c = sums / np.maximum(counts, 1.0)[:, None]
        rng, sub = jax.random.split(rng)      # unconditional: keep the
        empty = counts < 0.5                  # key stream deterministic
        if empty.any():
            reseed_idx = np.asarray(jax.random.choice(sub, D, (n_clusters,)))
            reseed = _gather_rows(shards, offsets, reseed_idx)
            new_c = np.where(empty[:, None], reseed, new_c)
        centroids = jnp.asarray(new_c.astype(np.float32))
    assign = np.concatenate([
        np.asarray(_assign(jnp.asarray(np.asarray(s)), centroids, n_clusters))
        for s in shards])
    return centroids, jnp.asarray(assign, dtype=jnp.int32)


def lloyd_refine(X, centroids, iters=4):
    """Deterministic local Lloyd's refinement from a centroid init — no
    random reseeding, pure host numpy. This is the re-clustering primitive
    of the incremental index updater (repro.index.update): when a shard's
    clusters overflow or go lopsided after upserts, its member vectors are
    re-refined *locally*, initialized from the shard's current centroids,
    so the result is reproducible and never depends on clusters outside
    the shard. Empty clusters keep their previous centroid (a reseed would
    need randomness and would break delta/compaction parity).

    X: (n, dim) member vectors; centroids: (k, dim) init.
    Returns (refined centroids (k, dim) f32, assignments (n,) int64).
    """
    X = np.asarray(X, np.float32)
    C = np.asarray(centroids, np.float32).copy()
    x2 = (X * X).sum(axis=1)[:, None]

    def assign_to(C):
        d2 = x2 + (C * C).sum(axis=1)[None, :] - 2.0 * X @ C.T
        return np.argmin(d2, axis=1)

    assign = assign_to(C)
    for _ in range(int(iters)):
        for c in range(C.shape[0]):
            sel = assign == c
            if sel.any():
                C[c] = X[sel].mean(axis=0)
        assign = assign_to(C)
    return C, assign


def gather_rows_chunked(X, idx, chunk_rows=8192):
    """Gather X[idx] in bounded fancy-index reads — X only needs row
    indexing (np.memmap or any capped/lazy source works; the full matrix is
    never materialized and no single read exceeds chunk_rows rows)."""
    idx = np.asarray(idx, np.int64)
    out = np.empty((len(idx), int(X.shape[1])), np.float32)
    for lo in range(0, len(idx), chunk_rows):
        sel = idx[lo:lo + chunk_rows]
        out[lo:lo + len(sel)] = np.asarray(X[sel], np.float32)
    return out


def build_cluster_table(assign, n_clusters, cap, X=None, centroids=None,
                        chunk_rows=8192):
    """Padded (N, cap) doc-id table; overflow docs are reassigned to their
    next-nearest cluster with free space (host-side greedy, like balanced IVF).

    `X` is only touched for overflow rows, gathered in `chunk_rows`-bounded
    reads, so a corpus-sized np.memmap never materializes.

    Returns (cluster_docs int32 (N, cap) padded with -1, doc_cluster (D,)).
    """
    assign = np.asarray(assign).copy()
    D = assign.shape[0]
    order = np.arange(D)
    members = [[] for _ in range(n_clusters)]
    overflow = []
    for d in order:
        c = assign[d]
        if len(members[c]) < cap:
            members[c].append(d)
        else:
            overflow.append(d)
    if overflow:
        if X is None or centroids is None:
            # round-robin into free slots
            free = [c for c in range(n_clusters) if len(members[c]) < cap]
            fi = 0
            for d in overflow:
                while len(members[free[fi]]) >= cap:
                    fi = (fi + 1) % len(free)
                members[free[fi]].append(d)
                assign[d] = free[fi]
        else:
            Xo = gather_rows_chunked(X, overflow, chunk_rows)
            C = np.asarray(centroids)
            d2 = (Xo * Xo).sum(1)[:, None] + (C * C).sum(1)[None] - 2 * Xo @ C.T
            pref = np.argsort(d2, axis=1)
            for i, d in enumerate(overflow):
                for c in pref[i]:
                    if len(members[c]) < cap:
                        members[c].append(d)
                        assign[d] = c
                        break
                else:
                    raise RuntimeError("total capacity exceeded")
    table = np.full((n_clusters, cap), -1, np.int32)
    for c in range(n_clusters):
        table[c, :len(members[c])] = members[c]
    return jnp.asarray(table), jnp.asarray(assign, dtype=jnp.int32)


def neighbor_graph(centroids, m):
    """Top-m inner-product neighbor lists among centroids: (N, m) ids+sims."""
    sims = centroids @ centroids.T
    sims = sims - 2e9 * jnp.eye(sims.shape[0], dtype=sims.dtype)  # no self
    vals, ids = jax.lax.top_k(sims, m)
    return ids.astype(jnp.int32), vals
