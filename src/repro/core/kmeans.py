"""K-means clustering of dense document embeddings (FAISS-IVF analogue) with
capacity-balanced padded member lists — TPU needs static cluster blocks, so
clusters are materialized as (N, cap) padded doc-id tables (DESIGN.md §2).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _assign(X, centroids, n_clusters):
    # (D, dim) x (N, dim) -> nearest centroid by L2
    x2 = jnp.sum(X * X, axis=1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=1)
    d2 = x2 + c2[None, :] - 2.0 * X @ centroids.T
    return jnp.argmin(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("n_clusters",))
def _update(X, assign, n_clusters):
    sums = jax.ops.segment_sum(X, assign, num_segments=n_clusters)
    counts = jax.ops.segment_sum(jnp.ones((X.shape[0],), X.dtype), assign,
                                 num_segments=n_clusters)
    return sums / jnp.maximum(counts, 1.0)[:, None], counts


def kmeans(rng, X, n_clusters, iters=15):
    """Lloyd's algorithm. X: (D, dim) array. Returns (centroids, assignments)."""
    D = X.shape[0]
    idx = jax.random.choice(rng, D, (n_clusters,), replace=False)
    centroids = X[idx]
    assign = None
    for _ in range(iters):
        assign = _assign(X, centroids, n_clusters)
        new_c, counts = _update(X, assign, n_clusters)
        # re-seed empty clusters from random points
        empty = counts < 0.5
        rng, sub = jax.random.split(rng)
        reseed = X[jax.random.choice(sub, D, (n_clusters,))]
        centroids = jnp.where(empty[:, None], reseed, new_c)
    assign = _assign(X, centroids, n_clusters)
    return centroids, assign


def build_cluster_table(assign, n_clusters, cap, X=None, centroids=None):
    """Padded (N, cap) doc-id table; overflow docs are reassigned to their
    next-nearest cluster with free space (host-side greedy, like balanced IVF).

    Returns (cluster_docs int32 (N, cap) padded with -1, doc_cluster (D,)).
    """
    assign = np.asarray(assign).copy()
    D = assign.shape[0]
    order = np.arange(D)
    members = [[] for _ in range(n_clusters)]
    overflow = []
    for d in order:
        c = assign[d]
        if len(members[c]) < cap:
            members[c].append(d)
        else:
            overflow.append(d)
    if overflow:
        if X is None or centroids is None:
            # round-robin into free slots
            free = [c for c in range(n_clusters) if len(members[c]) < cap]
            fi = 0
            for d in overflow:
                while len(members[free[fi]]) >= cap:
                    fi = (fi + 1) % len(free)
                members[free[fi]].append(d)
                assign[d] = free[fi]
        else:
            Xo = np.asarray(X)[overflow]
            C = np.asarray(centroids)
            d2 = (Xo * Xo).sum(1)[:, None] + (C * C).sum(1)[None] - 2 * Xo @ C.T
            pref = np.argsort(d2, axis=1)
            for i, d in enumerate(overflow):
                for c in pref[i]:
                    if len(members[c]) < cap:
                        members[c].append(d)
                        assign[d] = c
                        break
                else:
                    raise RuntimeError("total capacity exceeded")
    table = np.full((n_clusters, cap), -1, np.int32)
    for c in range(n_clusters):
        table[c, :len(members[c])] = members[c]
    return jnp.asarray(table), jnp.asarray(assign, dtype=jnp.int32)


def neighbor_graph(centroids, m):
    """Top-m inner-product neighbor lists among centroids: (N, m) ids+sims."""
    sims = centroids @ centroids.T
    sims = sims - 2e9 * jnp.eye(sims.shape[0], dtype=sims.dtype)  # no self
    vals, ids = jax.lax.top_k(sims, m)
    return ids.astype(jnp.int32), vals
