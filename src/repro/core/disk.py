"""On-disk embedding store with cluster-block I/O (paper §2.1 + Table 4).

Clusters are stored as contiguous fixed-size blocks in one binary file, so
selecting S clusters costs exactly S sequential block reads — vs per-doc
random reads for reranking / graph navigation. The latency model uses the
paper's measured constants (0.15 ms software+queueing overhead per I/O op on
their PCIe SSD) plus a bandwidth term; wall-clock I/O is also measured for
real (this container's disk), but the *model* is what reproduces Table 4
(DESIGN.md §2 assumption notes).
"""

import dataclasses
import os
import time

import numpy as np
import jax.numpy as jnp

PER_OP_MS = 0.15          # paper: per-I/O-op queueing/software overhead
SSD_BW_GBPS = 3.0         # PCIe SSD sequential bandwidth


@dataclasses.dataclass
class IOStats:
    n_ops: int = 0
    bytes: int = 0
    wall_ms: float = 0.0

    def model_ms(self):
        return self.n_ops * PER_OP_MS + self.bytes / (SSD_BW_GBPS * 1e6)

    def add(self, ops, nbytes, wall):
        self.n_ops += ops
        self.bytes += nbytes
        self.wall_ms += wall


def pack_blocks(embeddings, cluster_docs, dtype=np.float32, scale=None):
    """Materialize the (n, cap, dim) cluster-block tensor for a doc table.

    `embeddings` may be any row-indexable (D, dim) array (np.memmap is fine:
    only member rows are read); `cluster_docs` is a (n, cap) padded table —
    pass a slice of the full table to pack one shard at a time.

    `scale` quantizes: rows are divided by it, rounded, clipped to the
    target dtype's range (int8 shards; decode multiplies back).
    """
    cd = np.asarray(cluster_docs)
    dim = embeddings.shape[1]
    blocks = np.zeros(cd.shape + (dim,), dtype)
    mask = cd >= 0
    rows = np.asarray(embeddings[cd[mask]], np.float32)
    if scale is not None:
        info = np.iinfo(dtype)
        rows = np.clip(np.round(rows / np.float32(scale)),
                       info.min + 1, info.max)
    blocks[mask] = rows.astype(dtype)
    return blocks


def read_blocks_coalesced(mm, ids, out=None, out_offset=0):
    """Copy blocks `mm[ids]` into `out`, coalescing runs of adjacent ids
    into single contiguous memmap reads. Returns (out, n_runs) — one I/O op
    per run, not per block."""
    ids = np.asarray(ids, np.int64)
    n = len(ids)
    if out is None:
        out = np.empty((n,) + mm.shape[1:], mm.dtype)
    if n == 0:
        return out, 0
    brk = np.flatnonzero(np.diff(ids) != 1) + 1
    bounds = np.concatenate([[0], brk, [n]])
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        out[out_offset + lo:out_offset + hi] = mm[ids[lo]:ids[lo] + (hi - lo)]
    return out, len(bounds) - 1


class DiskClusterStore:
    """Embeddings laid out cluster-by-cluster (padded to cap) on disk.

    Pack-time and read-time are split: `pack()` (or constructing with an
    `embeddings` matrix) writes the block file once, offline; `open()`
    reopens an existing file strictly read-only — no embedding matrix in
    RAM, no rewrite. Serving paths should use `open()`.
    """

    def __init__(self, path, embeddings=None, cluster_docs=None,
                 dtype=np.float32, *, n_clusters=None, cap=None, dim=None):
        self.path = path
        self.dtype = np.dtype(dtype)
        if embeddings is not None:
            cd = np.asarray(cluster_docs)
            self.n_clusters, self.cap = cd.shape
            self.dim = embeddings.shape[1]
            pack_blocks(embeddings, cd, self.dtype).tofile(path)
        else:
            if n_clusters is None or cap is None or dim is None:
                raise ValueError(
                    "opening an existing store needs n_clusters/cap/dim")
            self.n_clusters, self.cap, self.dim = n_clusters, cap, dim
            expect = n_clusters * cap * dim * self.dtype.itemsize
            actual = os.path.getsize(path)
            if actual != expect:
                raise ValueError(f"{path}: expected {expect} bytes for "
                                 f"({n_clusters}, {cap}, {dim}) "
                                 f"{self.dtype}, found {actual}")
        self.block_bytes = self.cap * self.dim * self.dtype.itemsize
        self._mm = np.memmap(path, dtype=self.dtype, mode="r",
                             shape=(self.n_clusters, self.cap, self.dim))

    @classmethod
    def pack(cls, path, embeddings, cluster_docs, dtype=np.float32):
        """Write the block file from an embedding matrix (pack time)."""
        return cls(path, embeddings, cluster_docs, dtype)

    @classmethod
    def open(cls, path, n_clusters, cap, dim, dtype=np.float32):
        """Reopen an existing block file read-only (read time)."""
        return cls(path, dtype=dtype, n_clusters=n_clusters, cap=cap, dim=dim)

    def fetch_clusters(self, cluster_ids, stats: IOStats = None):
        """Read the given cluster blocks; runs of adjacent ids coalesce into
        one contiguous read (one I/O op per run). Returns (S, cap, dim)."""
        t0 = time.perf_counter()
        ids = np.asarray(cluster_ids, np.int64).reshape(-1)
        out, n_runs = read_blocks_coalesced(self._mm, ids)
        wall = (time.perf_counter() - t0) * 1e3
        if stats is not None:
            stats.add(n_runs, len(ids) * self.block_bytes, wall)
        return jnp.asarray(out)


class DiskDocStore:
    """Per-document random access (rerank / graph-nav I/O pattern)."""

    def __init__(self, path, embeddings, dtype=np.float32):
        emb = np.asarray(embeddings, dtype)
        emb.tofile(path)
        self.n_docs, self.dim = emb.shape
        self.dtype = dtype
        self.doc_bytes = self.dim * np.dtype(dtype).itemsize
        self._mm = np.memmap(path, dtype=dtype, mode="r",
                             shape=(self.n_docs, self.dim))

    def fetch_docs(self, doc_ids, stats: IOStats = None):
        t0 = time.perf_counter()
        out = np.stack([np.array(self._mm[d]) for d in doc_ids])
        wall = (time.perf_counter() - t0) * 1e3
        if stats is not None:
            stats.add(len(doc_ids), len(doc_ids) * self.doc_bytes, wall)
        return jnp.asarray(out)


def ondisk_clusd_retrieve(cfg, index, store: DiskClusterStore, q_dense,
                          q_terms, q_weights, *, k=None, cache=None):
    """CluSD with the embedding store on disk: stages 1-2 run on the
    (in-memory) centroids/postings; only *selected* cluster blocks are read.

    Thin wrapper over engine/pipeline.py with a DiskStore backend: selection
    is batched over the whole query set, and block I/O is one deduplicated
    fetch (optionally through an engine BlockCache) instead of the old
    per-query read loop. Returns (ids, scores, IOStats)."""
    from repro.engine import pipeline as pipe_lib
    from repro.engine import stores as stores_lib

    stats = IOStats()
    dstore = stores_lib.DiskStore(store, index.cluster_docs, stats=stats)
    ids, scores, _ = pipe_lib.retrieve(cfg, index, dstore, q_dense, q_terms,
                                       q_weights, k=k, cache=cache)
    return ids, scores, stats


def ondisk_rerank_retrieve(cfg, index, store: DiskDocStore, q_dense, q_terms,
                           q_weights, *, depth=1000, k=None):
    """S+Rerank with per-doc disk reads (Table 4 row 1)."""
    from repro.core import fusion as fusion_lib
    from repro.core import sparse as sparse_lib
    k = k or cfg.k_final
    stats = IOStats()
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, depth)
    B = q_dense.shape[0]
    all_ids, all_scores = [], []
    for b in range(B):
        vecs = store.fetch_docs(np.asarray(sparse_ids[b]), stats)
        dscore = (vecs @ q_dense[b]).reshape(1, -1)
        mask = jnp.ones_like(dscore, bool)
        ids_b, sc_b = fusion_lib.fuse_topk(
            sparse_ids[b:b + 1], sparse_scores[b:b + 1],
            sparse_ids[b:b + 1], dscore, mask, index.n_docs, cfg.alpha, k)
        all_ids.append(ids_b[0])
        all_scores.append(sc_b[0])
    return jnp.stack(all_ids), jnp.stack(all_scores), stats
