"""On-disk embedding store with cluster-block I/O (paper §2.1 + Table 4).

Clusters are stored as contiguous fixed-size blocks in one binary file, so
selecting S clusters costs exactly S sequential block reads — vs per-doc
random reads for reranking / graph navigation. The latency model uses the
paper's measured constants (0.15 ms software+queueing overhead per I/O op on
their PCIe SSD) plus a bandwidth term; wall-clock I/O is also measured for
real (this container's disk), but the *model* is what reproduces Table 4
(DESIGN.md §2 assumption notes).
"""

import dataclasses
import os
import time

import numpy as np
import jax.numpy as jnp

PER_OP_MS = 0.15          # paper: per-I/O-op queueing/software overhead
SSD_BW_GBPS = 3.0         # PCIe SSD sequential bandwidth


@dataclasses.dataclass
class IOStats:
    n_ops: int = 0
    bytes: int = 0
    wall_ms: float = 0.0

    def model_ms(self):
        return self.n_ops * PER_OP_MS + self.bytes / (SSD_BW_GBPS * 1e6)

    def add(self, ops, nbytes, wall):
        self.n_ops += ops
        self.bytes += nbytes
        self.wall_ms += wall


class DiskClusterStore:
    """Embeddings laid out cluster-by-cluster (padded to cap) on disk."""

    def __init__(self, path, embeddings, cluster_docs, dtype=np.float32):
        self.path = path
        emb = np.asarray(embeddings, dtype)
        cd = np.asarray(cluster_docs)
        self.n_clusters, self.cap = cd.shape
        self.dim = emb.shape[1]
        self.dtype = dtype
        blocks = np.zeros((self.n_clusters, self.cap, self.dim), dtype)
        mask = cd >= 0
        blocks[mask] = emb[cd[mask]]
        blocks.tofile(path)
        self.block_bytes = self.cap * self.dim * np.dtype(dtype).itemsize
        self._mm = np.memmap(path, dtype=dtype, mode="r",
                             shape=(self.n_clusters, self.cap, self.dim))

    def fetch_clusters(self, cluster_ids, stats: IOStats = None):
        """One block read per cluster. Returns (S, cap, dim)."""
        t0 = time.perf_counter()
        out = np.stack([np.array(self._mm[c]) for c in cluster_ids])
        wall = (time.perf_counter() - t0) * 1e3
        if stats is not None:
            stats.add(len(cluster_ids), len(cluster_ids) * self.block_bytes,
                      wall)
        return jnp.asarray(out)


class DiskDocStore:
    """Per-document random access (rerank / graph-nav I/O pattern)."""

    def __init__(self, path, embeddings, dtype=np.float32):
        emb = np.asarray(embeddings, dtype)
        emb.tofile(path)
        self.n_docs, self.dim = emb.shape
        self.dtype = dtype
        self.doc_bytes = self.dim * np.dtype(dtype).itemsize
        self._mm = np.memmap(path, dtype=dtype, mode="r",
                             shape=(self.n_docs, self.dim))

    def fetch_docs(self, doc_ids, stats: IOStats = None):
        t0 = time.perf_counter()
        out = np.stack([np.array(self._mm[d]) for d in doc_ids])
        wall = (time.perf_counter() - t0) * 1e3
        if stats is not None:
            stats.add(len(doc_ids), len(doc_ids) * self.doc_bytes, wall)
        return jnp.asarray(out)


def ondisk_clusd_retrieve(cfg, index, store: DiskClusterStore, q_dense,
                          q_terms, q_weights, *, k=None, cache=None):
    """CluSD with the embedding store on disk: stages 1-2 run on the
    (in-memory) centroids/postings; only *selected* cluster blocks are read.

    Thin wrapper over engine/pipeline.py with a DiskStore backend: selection
    is batched over the whole query set, and block I/O is one deduplicated
    fetch (optionally through an engine BlockCache) instead of the old
    per-query read loop. Returns (ids, scores, IOStats)."""
    from repro.engine import pipeline as pipe_lib
    from repro.engine import stores as stores_lib

    stats = IOStats()
    dstore = stores_lib.DiskStore(store, index.cluster_docs, stats=stats)
    ids, scores, _ = pipe_lib.retrieve(cfg, index, dstore, q_dense, q_terms,
                                       q_weights, k=k, cache=cache)
    return ids, scores, stats


def ondisk_rerank_retrieve(cfg, index, store: DiskDocStore, q_dense, q_terms,
                           q_weights, *, depth=1000, k=None):
    """S+Rerank with per-doc disk reads (Table 4 row 1)."""
    from repro.core import fusion as fusion_lib
    from repro.core import sparse as sparse_lib
    k = k or cfg.k_final
    stats = IOStats()
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, depth)
    B = q_dense.shape[0]
    all_ids, all_scores = [], []
    for b in range(B):
        vecs = store.fetch_docs(np.asarray(sparse_ids[b]), stats)
        dscore = (vecs @ q_dense[b]).reshape(1, -1)
        mask = jnp.ones_like(dscore, bool)
        ids_b, sc_b = fusion_lib.fuse_topk(
            sparse_ids[b:b + 1], sparse_scores[b:b + 1],
            sparse_ids[b:b + 1], dscore, mask, index.n_docs, cfg.alpha, k)
        all_ids.append(ids_b[0])
        all_scores.append(sc_b[0])
    return jnp.stack(all_ids), jnp.stack(all_scores), stats
