"""Stage II selector models (paper §2.3 + Table 8 ablations):

  - LSTM (default, CluSD): walks the n stage-1 candidates in order, emits
    f(C_i) in [0,1]; clusters with f >= theta are visited.
  - vanilla RNN (ablation)
  - pointwise MLP (stand-in for the XGBoost ablation: same features, no
    sequence state)

The fused Pallas LSTM kernel (repro/kernels/lstm) is used through
`use_kernel=True`; the jnp scan here doubles as its oracle.
"""

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def lstm_init(rng, feat_dim, hidden):
    r = jax.random.split(rng, 4)
    H = hidden
    return {
        "wx": dense_init(r[0], (feat_dim, 4 * H), jnp.float32),
        "wh": dense_init(r[1], (H, 4 * H), jnp.float32),
        "b": jnp.zeros((4 * H,), jnp.float32),
        "head_w": dense_init(r[2], (H, 1), jnp.float32),
        "head_b": jnp.zeros((1,), jnp.float32),
    }


def lstm_cell(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def lstm_apply(params, feats, use_kernel=False):
    """feats: (B, n, F) -> selection probabilities (B, n)."""
    if use_kernel:
        from repro.kernels.lstm import ops as lstm_ops
        h_seq = lstm_ops.lstm_sequence(
            feats, params["wx"], params["wh"], params["b"])
    else:
        B, n, F = feats.shape
        H = params["wh"].shape[0]

        def step(carry, x_t):
            h, c = carry
            h, c = lstm_cell(x_t, h, c, params["wx"], params["wh"], params["b"])
            return (h, c), h

        init = (jnp.zeros((B, H), feats.dtype), jnp.zeros((B, H), feats.dtype))
        _, h_seq = jax.lax.scan(step, init, jnp.moveaxis(feats, 1, 0))
        h_seq = jnp.moveaxis(h_seq, 0, 1)                   # (B, n, H)
    logits = (h_seq @ params["head_w"] + params["head_b"])[..., 0]
    return jax.nn.sigmoid(logits)


def rnn_init(rng, feat_dim, hidden):
    r = jax.random.split(rng, 3)
    return {
        "wx": dense_init(r[0], (feat_dim, hidden), jnp.float32),
        "wh": dense_init(r[1], (hidden, hidden), jnp.float32),
        "b": jnp.zeros((hidden,), jnp.float32),
        "head_w": dense_init(r[2], (hidden, 1), jnp.float32),
        "head_b": jnp.zeros((1,), jnp.float32),
    }


def rnn_apply(params, feats):
    B, n, F = feats.shape
    H = params["wh"].shape[0]

    def step(carry, x_t):
        h = jnp.tanh(x_t @ params["wx"] + carry @ params["wh"] + params["b"])
        return h, h

    _, h_seq = jax.lax.scan(step, jnp.zeros((B, H), feats.dtype),
                            jnp.moveaxis(feats, 1, 0))
    h_seq = jnp.moveaxis(h_seq, 0, 1)
    logits = (h_seq @ params["head_w"] + params["head_b"])[..., 0]
    return jax.nn.sigmoid(logits)


def mlp_init(rng, feat_dim, hidden):
    r = jax.random.split(rng, 3)
    return {
        "w1": dense_init(r[0], (feat_dim, hidden), jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": dense_init(r[1], (hidden, hidden), jnp.float32),
        "b2": jnp.zeros((hidden,), jnp.float32),
        "head_w": dense_init(r[2], (hidden, 1), jnp.float32),
        "head_b": jnp.zeros((1,), jnp.float32),
    }


def mlp_apply(params, feats):
    h = jax.nn.relu(feats @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = (h @ params["head_w"] + params["head_b"])[..., 0]
    return jax.nn.sigmoid(logits)


SELECTORS = {
    "lstm": (lstm_init, lstm_apply),
    "rnn": (rnn_init, rnn_apply),
    "mlp": (mlp_init, mlp_apply),
}
