"""LSTM selector training (paper §2.3) — thin compatibility wrapper.

The implementation moved to the `repro.train` subsystem, which adds what
this module never had: streaming index-backed label generation (exact
full-dense top-k off a built on-disk index, bounded reads), bucketed
training with checkpoints and mid-epoch resume, threshold/budget
calibration, and atomic publishing of a trained selector into an index
generation. See src/repro/train/README.md.

The seed API re-exported here is unchanged and in-RAM:

  make_labels(cfg, index, ...)   needs a materialized index.embeddings —
                                 fine offline/small-corpus; corpus-scale
                                 callers use
                                 repro.train.make_labels_streaming
  train_selector(cfg, rng, ...)  one-shot trainer; the BCE positive
                                 weight now comes from cfg.pos_weight
                                 (default 4.0 = the old hardcoded value;
                                 None derives it from the label set)
  selection_quality(...)         label-level precision/recall at theta
"""

from repro.train.calibrate import selection_quality  # noqa: F401
from repro.train.labels import make_labels  # noqa: F401
from repro.train.trainer import train_selector  # noqa: F401

__all__ = ["make_labels", "selection_quality", "train_selector"]
