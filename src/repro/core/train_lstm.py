"""LSTM selector training (paper §2.3 "Training of LSTM"):

For each training query, a candidate cluster is POSITIVE iff it contains at
least one of the query's top-10 *full dense retrieval* results. BCE over the
stage-1 candidate sequence, Adam, cfg.epochs epochs over cfg.train_queries
sampled queries.
"""

import jax
import jax.numpy as jnp

from repro.core import clusd as clusd_lib
from repro.core import fusion as fusion_lib
from repro.core import sparse as sparse_lib
from repro.core.lstm import SELECTORS
from repro.optim import adamw_init, adamw_update


def make_labels(cfg, index, q_dense, q_terms, q_weights, top_dense=10,
                stage1="overlap"):
    """Returns (cand (B, n), feats (B, n, F), labels (B, n))."""
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    sel = clusd_lib.select_clusters(
        cfg, index, q_dense, sparse_ids, sparse_scores,
        selector_params=None, stage1=stage1)
    cand, feats = sel["cand"], sel["feats"]
    dense_ids, _ = clusd_lib.full_dense_topk(index.embeddings, q_dense,
                                             top_dense)
    pos_clusters = jnp.take(index.doc_cluster, dense_ids, axis=0)  # (B, 10)
    labels = jnp.any(cand[:, :, None] == pos_clusters[:, None, :], axis=-1)
    return cand, feats, labels.astype(jnp.float32)


def train_selector(cfg, rng, feats, labels, selector="lstm", epochs=None,
                   lr=None, batch_size=256, log_every=0):
    """Train a stage-2 selector on precomputed (feats, labels)."""
    epochs = epochs or cfg.epochs
    lr = lr or cfg.lr
    init_fn, apply_fn = SELECTORS[selector]
    params = init_fn(rng, feats.shape[-1], cfg.lstm_hidden)
    opt = adamw_init(params)

    def loss_fn(p, f, y):
        probs = apply_fn(p, f)
        probs = jnp.clip(probs, 1e-6, 1 - 1e-6)
        # class-balance: positives are rare in the candidate sequence
        w_pos = 4.0
        bce = -(w_pos * y * jnp.log(probs) + (1 - y) * jnp.log(1 - probs))
        return jnp.mean(bce)

    @jax.jit
    def step(p, o, f, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, f, y)
        p, o, _ = adamw_update(grads, o, p, lr=lr, weight_decay=0.0)
        return p, o, loss

    nq = feats.shape[0]
    rngs = jax.random.split(jax.random.fold_in(rng, 1), epochs)
    history = []
    for e in range(epochs):
        perm = jax.random.permutation(rngs[e], nq)
        f_sh, y_sh = feats[perm], labels[perm]
        losses = []
        for i in range(0, nq - batch_size + 1, batch_size) or [0]:
            fb, yb = f_sh[i:i + batch_size], y_sh[i:i + batch_size]
            params, opt, loss = step(params, opt, fb, yb)
            losses.append(float(loss))
        if nq < batch_size:
            params, opt, loss = step(params, opt, f_sh, y_sh)
            losses.append(float(loss))
        history.append(sum(losses) / max(len(losses), 1))
        if log_every and (e + 1) % log_every == 0:
            print(f"epoch {e+1}/{epochs} loss={history[-1]:.4f}", flush=True)
    return params, history


def selection_quality(probs, labels, theta):
    """Precision / recall / avg #selected at threshold theta."""
    sel = probs >= theta
    tp = jnp.sum(sel * labels)
    prec = tp / jnp.maximum(jnp.sum(sel), 1)
    rec = tp / jnp.maximum(jnp.sum(labels), 1)
    return {"precision": prec, "recall": rec,
            "avg_selected": jnp.mean(jnp.sum(sel, axis=1))}
