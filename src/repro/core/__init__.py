from repro.core.clusd import CluSDIndex, build_index, retrieve, full_dense_topk
