"""Vector quantization for the embedding store (paper Tables 1, 6, 7):

  - PQ: product quantization, nsub subspaces x 256 codes, ADC scoring via
    per-query lookup tables (gather + sum — TPU-friendly).
  - OPQ-lite: PCA rotation before PQ (the eigen-allocation variant of OPQ;
    full OPQ alternates rotation/codebook — PCA-init is its standard seed).
  - DistillVQ/JPQ stand-ins (Table 7) are PQ retrained with different
    objectives; here they map to PQ with different nsub/rotation settings.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as km


@dataclasses.dataclass
class PQ:
    codebooks: jnp.ndarray   # (nsub, 256, dsub)
    codes: jnp.ndarray       # (D, nsub) uint8 — int32 on CPU backends
    rotation: jnp.ndarray    # (dim, dim) or None
    nsub: int

    def space_bytes(self):
        return int(self.codes.shape[0]) * self.nsub


def train_pq(rng, X, nsub, n_codes=256, iters=10, rotate=False):
    """X: (D, dim). dim % nsub == 0."""
    D, dim = X.shape
    assert dim % nsub == 0, (dim, nsub)
    R = None
    if rotate:
        Xc = X - jnp.mean(X, axis=0, keepdims=True)
        cov = Xc.T @ Xc / D
        _, vecs = jnp.linalg.eigh(cov)
        R = vecs[:, ::-1]                       # descending eigenvalue order
        X = X @ R
    dsub = dim // nsub
    Xs = X.reshape(D, nsub, dsub)
    books, codes = [], []
    for s in range(nsub):
        rng, sub = jax.random.split(rng)
        n_k = min(n_codes, D)
        c, a = km.kmeans(sub, Xs[:, s], n_k, iters=iters)
        if n_k < n_codes:
            c = jnp.pad(c, ((0, n_codes - n_k), (0, 0)))
        books.append(c)
        codes.append(a)
    return PQ(jnp.stack(books), jnp.stack(codes, axis=1).astype(jnp.int32),
              R, nsub)


def pq_encode(codebooks, X, rotation=None):
    """Assign each row of X (C, dim) to its nearest codebook entry per
    subspace: (C, nsub) int32 codes. Chunk-friendly: call per bounded row
    chunk — nothing here depends on seeing the whole corpus."""
    X = jnp.asarray(X, jnp.float32)
    if rotation is not None:
        X = X @ rotation
    nsub, n_codes, dsub = codebooks.shape
    Xs = X.reshape(X.shape[0], nsub, dsub)
    # argmin_k ||x_s - c_sk||^2 = argmin_k ||c_sk||^2 - 2 x_s . c_sk
    c2 = jnp.sum(codebooks * codebooks, axis=-1)             # (nsub, K)
    dots = jnp.einsum("csd,skd->csk", Xs, codebooks)         # (C, nsub, K)
    return jnp.argmin(c2[None] - 2.0 * dots, axis=-1).astype(jnp.int32)


def train_pq_stream(rng, embeddings, nsub, *, n_codes=256, iters=10,
                    rotate=False, sample_docs=1 << 16, chunk_docs=1 << 14):
    """PQ for corpora larger than RAM: codebooks are trained on a bounded
    random sample gathered in `chunk_docs`-row reads, then every document is
    encoded chunk-by-chunk. `embeddings` only needs row indexing (np.memmap
    is fine); no read ever touches more than max(chunk_docs, sample rows
    per chunk) rows, and the full float matrix is never materialized.

    Returns a PQ whose `codes` covers all D docs.
    """
    D = int(embeddings.shape[0])
    n_sample = min(D, sample_docs)
    rng, sub = jax.random.split(rng)
    idx = np.sort(np.asarray(
        jax.random.choice(sub, D, (n_sample,), replace=False)))
    sample = np.empty((n_sample, int(embeddings.shape[1])), np.float32)
    for lo in range(0, n_sample, chunk_docs):
        sel = idx[lo:lo + chunk_docs]
        sample[lo:lo + len(sel)] = np.asarray(embeddings[sel], np.float32)
    pq = train_pq(rng, jnp.asarray(sample), nsub, n_codes=n_codes,
                  iters=iters, rotate=rotate)
    codes = np.empty((D, nsub), np.int32)
    for lo in range(0, D, chunk_docs):
        chunk = np.asarray(embeddings[lo:lo + chunk_docs], np.float32)
        codes[lo:lo + len(chunk)] = np.asarray(
            pq_encode(pq.codebooks, chunk, pq.rotation))
    return PQ(pq.codebooks, jnp.asarray(codes), pq.rotation, nsub)


def decode_code_blocks(codebooks, codes, rotation=None):
    """Host-side ADC reconstruction of packed code blocks: codes
    (..., nsub) uint8/int -> float32 (..., dim). Used by the sharded PQ
    store; dot(q, decode(codes)) equals the ADC LUT score exactly (same
    per-subspace terms, summed in the same order)."""
    books = np.asarray(codebooks, np.float32)        # (nsub, K, dsub)
    nsub = books.shape[0]
    vecs = books[np.arange(nsub), np.asarray(codes, np.int64)]
    flat = vecs.reshape(codes.shape[:-1] + (-1,))
    if rotation is not None:
        flat = flat @ np.asarray(rotation, np.float32).T
    return flat


def adc_tables(pq: PQ, q):
    """q: (B, dim) -> LUT (B, nsub, 256)."""
    if pq.rotation is not None:
        q = q @ pq.rotation
    B = q.shape[0]
    dsub = pq.codebooks.shape[-1]
    qs = q.reshape(B, pq.nsub, dsub)
    return jnp.einsum("bsd,skd->bsk", qs, pq.codebooks)


def adc_score(pq: PQ, lut, doc_ids):
    """lut: (B, nsub, 256); doc_ids: (B, K) -> approx scores (B, K).

    score[b, k] = sum_s lut[b, s, codes[doc_ids[b, k], s]]
    """
    codes = jnp.take(pq.codes, jnp.maximum(doc_ids, 0), axis=0)  # (B, K, S)
    B, K, S = codes.shape
    s_idx = jnp.arange(S)[None, None, :]
    scores = lut[jnp.arange(B)[:, None, None], s_idx, codes]
    return jnp.sum(scores, axis=-1)


def reconstruct(pq: PQ, doc_ids):
    """Decode quantized embeddings for given ids: (K, dim)."""
    codes = jnp.take(pq.codes, doc_ids, axis=0)                  # (K, nsub)
    vecs = pq.codebooks[jnp.arange(pq.nsub)[None, :], codes]     # (K, nsub, dsub)
    flat = vecs.reshape(doc_ids.shape[0], -1)
    if pq.rotation is not None:
        flat = flat @ pq.rotation.T
    return flat


def score_selected_pq(index, q_dense, sel_ids, sel_mask):
    """Quantized Step-3 scoring — thin wrapper over the engine pipeline
    with a PQStore backend (ADC scoring via `score_docs`)."""
    from repro.engine import pipeline as pipe_lib
    from repro.engine import stores as stores_lib
    store = stores_lib.PQStore(index.quantizer, index.cluster_docs)
    return pipe_lib.score_selected(store, q_dense, sel_ids, sel_mask)


def identity_pq(embeddings, nsub=1):
    """Exact (lossless) PQ for corpora with <= 256 docs: doc d's code in
    every subspace is d, and codebook entries are the docs' own sub-vectors.
    ADC then reproduces the exact dot product — used by backend-parity
    tests and debugging, not by real indexes."""
    X = jnp.asarray(embeddings)
    D, dim = X.shape
    assert D <= 256, f"identity PQ needs <= 256 docs, got {D}"
    assert dim % nsub == 0, (dim, nsub)
    dsub = dim // nsub
    books = X.reshape(D, nsub, dsub).transpose(1, 0, 2)      # (nsub, D, dsub)
    books = jnp.pad(books, ((0, 0), (0, 256 - D), (0, 0)))
    codes = jnp.tile(jnp.arange(D, dtype=jnp.int32)[:, None], (1, nsub))
    return PQ(books, codes, None, nsub)
