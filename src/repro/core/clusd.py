"""CluSD end-to-end: index build + online inference (paper §2.1 steps 1-3).

Index artifacts (all static-shape, device-resident or disk-backed):
  centroids (N, dim) · cluster_docs (N, cap) · doc_cluster (D,)
  neighbor_ids/sims (N, m) · sparse inverted index · LSTM params

Online retrieve (batched over queries, jit-able end to end):
  1. sparse retrieval -> top-k ids/scores
  2. Stage I: P/Q overlap features -> multikey sort -> top-n candidates
     Stage II: LSTM over candidate sequence -> f(C_i) >= theta -> selected
     clusters (static budget max_selected, mask-padded)
  3. gather selected cluster blocks -> dense dot scores -> min-max fusion
"""

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import bins as bins_lib
from repro.core import features as feat_lib
from repro.core import fusion as fusion_lib
from repro.core import kmeans as km
from repro.core import sparse as sparse_lib
from repro.core import stage1 as stage1_lib
from repro.core.lstm import SELECTORS


@dataclasses.dataclass
class CluSDIndex:
    centroids: Any          # (N, dim)
    cluster_docs: Any       # (N, cap) int32, -1 pad
    doc_cluster: Any        # (D,) int32
    neighbor_ids: Any       # (N, m)
    neighbor_sims: Any      # (N, m)
    embeddings: Any         # (D, dim) float  (or None when on disk / quantized)
    sparse_index: Any       # SparseIndex
    lstm_params: Any = None
    quantizer: Any = None   # optional PQ/OPQ (core/quant.py)
    bin_ids: Any = None     # (k_sparse,) rank -> bin id

    @property
    def n_docs(self):
        return int(self.doc_cluster.shape[0])

    @property
    def n_clusters(self):
        return int(self.centroids.shape[0])


def build_index(cfg, rng, embeddings, doc_terms, doc_weights,
                kmeans_iters=15) -> CluSDIndex:
    centroids, assign = km.kmeans(rng, embeddings, cfg.n_clusters,
                                  iters=kmeans_iters)
    cluster_docs, doc_cluster = km.build_cluster_table(
        assign, cfg.n_clusters, cfg.cluster_cap, embeddings, centroids)
    m = min(cfg.n_neighbors, cfg.n_clusters - 1)
    nb_ids, nb_sims = km.neighbor_graph(centroids, m)
    sp = sparse_lib.SparseIndex.build(doc_terms, doc_weights, cfg.vocab,
                                      cfg.max_postings)
    return CluSDIndex(
        centroids=centroids, cluster_docs=cluster_docs,
        doc_cluster=doc_cluster, neighbor_ids=nb_ids, neighbor_sims=nb_sims,
        embeddings=embeddings, sparse_index=sp,
        bin_ids=bins_lib.rank_bin_ids(cfg.bins, cfg.k_sparse))


def full_dense_topk(embeddings, q_dense, k):
    scores = q_dense @ embeddings.T
    s, i = jax.lax.top_k(scores, k)
    return i.astype(jnp.int32), s


def stage1_candidates(cfg, index, q_dense, sparse_ids, sparse_scores, *,
                      stage1="overlap"):
    """Step 1: sparse-overlap features -> ordered candidate clusters.

    Split out from stage 2 so a serving layer can kick off block prefetch
    for the candidates while the LSTM selection runs (engine/server.py).
    """
    qc_sim = q_dense @ index.centroids.T                     # (B, N)
    P, Q = bins_lib.overlap_features(
        sparse_ids, fusion_lib.minmax_norm(sparse_scores), index.doc_cluster,
        index.n_clusters, index.bin_ids, cfg.v_bins)
    if stage1 == "overlap":
        cand = stage1_lib.sort_by_overlap(P, qc_sim, cfg.n_candidates)
    else:
        cand = stage1_lib.sort_by_dist(qc_sim, cfg.n_candidates)
    if cfg.expand_depth > 0 and cfg.n_candidates_total > cfg.n_candidates:
        # hybrid mode: deepen the seed list through the neighbor graph
        # (LADR-style); depth 0 is bitwise the unexpanded pipeline
        cand = stage1_lib.expand_candidates(
            cand, index.neighbor_ids, index.neighbor_sims, qc_sim,
            cfg.expand_depth, cfg.n_candidates_total)
    feats = feat_lib.candidate_features(
        cand, qc_sim, P, Q, index.neighbor_ids, index.neighbor_sims,
        cfg.u_bins)
    return {"cand": cand, "feats": feats, "qc_sim": qc_sim, "P": P, "Q": Q}


def stage2_select(cfg, index, cand, feats, *, selector="lstm", theta=None,
                  use_kernel=False, selector_params=None):
    """Step 2: selector probabilities -> thresholded, budgeted selection."""
    theta = cfg.theta if theta is None else theta
    params = selector_params if selector_params is not None else index.lstm_params
    if params is None:
        # untrained fallback: stage-1 order only — take first max_selected
        B, n = cand.shape
        probs = jnp.linspace(1.0, 0.5, n)[None, :].repeat(B, 0)
    else:
        _, apply = SELECTORS[selector]
        if selector == "lstm":
            probs = apply(params, feats, use_kernel=use_kernel)
        else:
            probs = apply(params, feats)

    picked = probs >= theta                                  # (B, n)
    # static budget: top max_selected by prob among picked. Unpicked entries
    # sort last via -inf; the mask is the picked bit carried through the
    # permutation (NOT a sentinel comparison, which broke for theta <= 0 /
    # selectors emitting scores outside [0, 1]).
    masked = jnp.where(picked, probs, -jnp.inf)
    _, top_i = jax.lax.top_k(masked, min(cfg.max_selected, cand.shape[1]))
    sel_mask = jnp.take_along_axis(picked, top_i, axis=1)
    sel_ids = jnp.take_along_axis(cand, top_i, axis=1)
    return {"probs": probs, "sel_ids": sel_ids, "sel_mask": sel_mask}


def select_clusters(cfg, index, q_dense, sparse_ids, sparse_scores, *,
                    selector="lstm", stage1="overlap", theta=None,
                    use_kernel=False, selector_params=None):
    """Steps 1-2. Returns dict with candidates, probs, selected ids + mask."""
    s1 = stage1_candidates(cfg, index, q_dense, sparse_ids, sparse_scores,
                           stage1=stage1)
    s2 = stage2_select(cfg, index, s1["cand"], s1["feats"], selector=selector,
                       theta=theta, use_kernel=use_kernel,
                       selector_params=selector_params)
    return {**s1, **s2}


def score_selected(index, q_dense, sel_ids, sel_mask, embeddings=None):
    """Step 3 dense scoring. Returns (doc_ids (B, S*cap), scores, mask).

    Thin wrapper over the engine pipeline with an in-memory backend (kept
    for baselines/benches that score explicit selections).
    """
    from repro.engine import pipeline as pipe_lib
    from repro.engine import stores as stores_lib
    emb = embeddings if embeddings is not None else index.embeddings
    store = stores_lib.InMemoryStore(emb, index.cluster_docs)
    return pipe_lib.score_selected(store, q_dense, sel_ids, sel_mask)


def retrieve(cfg, index, q_dense, q_terms, q_weights, *, selector="lstm",
             stage1="overlap", theta=None, use_kernel=False,
             selector_params=None, k=None):
    """Full CluSD pipeline (in-memory or PQ backend, chosen from the index).

    Thin wrapper over engine/pipeline.py — the select/score/fuse logic
    lives there, parameterized by a ClusterStore. Jit-able end to end.
    """
    from repro.engine import pipeline as pipe_lib
    from repro.engine import stores as stores_lib
    return pipe_lib.retrieve(
        cfg, index, stores_lib.store_for_index(index), q_dense, q_terms,
        q_weights, selector=selector, stage1=stage1, theta=theta,
        use_kernel=use_kernel, selector_params=selector_params, k=k)
