"""CluSD end-to-end: index build + online inference (paper §2.1 steps 1-3).

Index artifacts (all static-shape, device-resident or disk-backed):
  centroids (N, dim) · cluster_docs (N, cap) · doc_cluster (D,)
  neighbor_ids/sims (N, m) · sparse inverted index · LSTM params

Online retrieve (batched over queries, jit-able end to end):
  1. sparse retrieval -> top-k ids/scores
  2. Stage I: P/Q overlap features -> multikey sort -> top-n candidates
     Stage II: LSTM over candidate sequence -> f(C_i) >= theta -> selected
     clusters (static budget max_selected, mask-padded)
  3. gather selected cluster blocks -> dense dot scores -> min-max fusion
"""

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import bins as bins_lib
from repro.core import features as feat_lib
from repro.core import fusion as fusion_lib
from repro.core import kmeans as km
from repro.core import sparse as sparse_lib
from repro.core import stage1 as stage1_lib
from repro.core.lstm import SELECTORS


@dataclasses.dataclass
class CluSDIndex:
    centroids: Any          # (N, dim)
    cluster_docs: Any       # (N, cap) int32, -1 pad
    doc_cluster: Any        # (D,) int32
    neighbor_ids: Any       # (N, m)
    neighbor_sims: Any      # (N, m)
    embeddings: Any         # (D, dim) float  (or None when on disk / quantized)
    sparse_index: Any       # SparseIndex
    lstm_params: Any = None
    quantizer: Any = None   # optional PQ/OPQ (core/quant.py)
    bin_ids: Any = None     # (k_sparse,) rank -> bin id

    @property
    def n_docs(self):
        return int(self.doc_cluster.shape[0])

    @property
    def n_clusters(self):
        return int(self.centroids.shape[0])


def build_index(cfg, rng, embeddings, doc_terms, doc_weights,
                kmeans_iters=15) -> CluSDIndex:
    centroids, assign = km.kmeans(rng, embeddings, cfg.n_clusters,
                                  iters=kmeans_iters)
    cluster_docs, doc_cluster = km.build_cluster_table(
        assign, cfg.n_clusters, cfg.cluster_cap, embeddings, centroids)
    m = min(cfg.n_neighbors, cfg.n_clusters - 1)
    nb_ids, nb_sims = km.neighbor_graph(centroids, m)
    sp = sparse_lib.SparseIndex.build(doc_terms, doc_weights, cfg.vocab,
                                      cfg.max_postings)
    return CluSDIndex(
        centroids=centroids, cluster_docs=cluster_docs,
        doc_cluster=doc_cluster, neighbor_ids=nb_ids, neighbor_sims=nb_sims,
        embeddings=embeddings, sparse_index=sp,
        bin_ids=bins_lib.rank_bin_ids(cfg.bins, cfg.k_sparse))


def full_dense_topk(embeddings, q_dense, k):
    scores = q_dense @ embeddings.T
    s, i = jax.lax.top_k(scores, k)
    return i.astype(jnp.int32), s


def select_clusters(cfg, index, q_dense, sparse_ids, sparse_scores, *,
                    selector="lstm", stage1="overlap", theta=None,
                    use_kernel=False, selector_params=None):
    """Steps 1-2. Returns dict with candidates, probs, selected ids + mask."""
    theta = cfg.theta if theta is None else theta
    qc_sim = q_dense @ index.centroids.T                     # (B, N)
    P, Q = bins_lib.overlap_features(
        sparse_ids, fusion_lib.minmax_norm(sparse_scores), index.doc_cluster,
        index.n_clusters, index.bin_ids, cfg.v_bins)
    if stage1 == "overlap":
        cand = stage1_lib.sort_by_overlap(P, qc_sim, cfg.n_candidates)
    else:
        cand = stage1_lib.sort_by_dist(qc_sim, cfg.n_candidates)

    feats = feat_lib.candidate_features(
        cand, qc_sim, P, Q, index.neighbor_ids, index.neighbor_sims,
        cfg.u_bins)
    params = selector_params if selector_params is not None else index.lstm_params
    if params is None:
        # untrained fallback: stage-1 order only — take first max_selected
        B, n = cand.shape
        probs = jnp.linspace(1.0, 0.5, n)[None, :].repeat(B, 0)
    else:
        _, apply = SELECTORS[selector]
        if selector == "lstm":
            probs = apply(params, feats, use_kernel=use_kernel)
        else:
            probs = apply(params, feats)

    picked = probs >= theta                                  # (B, n)
    # static budget: top max_selected by prob among picked
    masked = jnp.where(picked, probs, -1.0)
    top_p, top_i = jax.lax.top_k(masked, min(cfg.max_selected, cand.shape[1]))
    sel_mask = top_p >= 0.0
    sel_ids = jnp.take_along_axis(cand, top_i, axis=1)
    return {"cand": cand, "feats": feats, "probs": probs,
            "sel_ids": sel_ids, "sel_mask": sel_mask, "qc_sim": qc_sim,
            "P": P, "Q": Q}


def score_selected(index, q_dense, sel_ids, sel_mask, embeddings=None):
    """Step 3 dense scoring. Returns (doc_ids (B, S*cap), scores, mask)."""
    emb = embeddings if embeddings is not None else index.embeddings
    docs = jnp.take(index.cluster_docs, sel_ids, axis=0)     # (B, S, cap)
    B, S, cap = docs.shape
    valid = (docs >= 0) & sel_mask[:, :, None]
    docs_flat = jnp.where(valid, docs, 0).reshape(B, S * cap)
    vecs = jnp.take(emb, docs_flat, axis=0)                  # (B, S*cap, dim)
    scores = jnp.einsum("bd,bkd->bk", q_dense, vecs)
    scores = jnp.where(valid.reshape(B, S * cap), scores, -jnp.inf)
    return docs_flat.astype(jnp.int32), scores, valid.reshape(B, S * cap)


def retrieve(cfg, index, q_dense, q_terms, q_weights, *, selector="lstm",
             stage1="overlap", theta=None, use_kernel=False,
             selector_params=None, k=None):
    """Full CluSD pipeline. Returns (ids, scores, diagnostics)."""
    k = k or cfg.k_final
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    sel = select_clusters(cfg, index, q_dense, sparse_ids, sparse_scores,
                          selector=selector, stage1=stage1, theta=theta,
                          use_kernel=use_kernel, selector_params=selector_params)
    if index.quantizer is not None:
        from repro.core import quant as quant_lib
        did, dscore, dmask = quant_lib.score_selected_pq(
            index, q_dense, sel["sel_ids"], sel["sel_mask"])
    else:
        did, dscore, dmask = score_selected(index, q_dense, sel["sel_ids"],
                                            sel["sel_mask"])
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, did, jnp.where(dmask, dscore, 0.0), dmask,
        index.n_docs, cfg.alpha, k)
    diag = {
        "n_selected": jnp.sum(sel["sel_mask"], axis=1),
        "frac_docs_scanned": jnp.mean(dmask.astype(jnp.float32), axis=1)
        * dmask.shape[1] / index.n_docs,
        "sparse_ids": sparse_ids, "sparse_scores": sparse_scores,
        **{k_: sel[k_] for k_ in ("cand", "probs", "sel_ids", "sel_mask")},
    }
    return ids, scores, diag
