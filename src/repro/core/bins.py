"""Position bins over sparse top-k results and the P/Q cluster-overlap
features (paper §2.2): P(C_i, B_j) = |C_i ∩ B_j| (count overlap) and
Q(C_i, B_j) = mean sparse score of docs in C_i ∩ B_j (score overlap)."""

import jax
import jax.numpy as jnp
import numpy as np


def rank_bin_ids(bins, k):
    """Map rank position 0..k-1 to bin id given cumulative edges, e.g.
    (10, 25, 50, 100, 200, 500, 1000) -> 7 bins."""
    ranks = np.arange(k)
    edges = np.asarray(bins)
    return jnp.asarray(np.searchsorted(edges, ranks, side="right"), jnp.int32)


def overlap_features(top_ids, top_scores, doc_cluster, n_clusters, bin_ids, v):
    """P and Q features for ALL clusters.

    top_ids: (B, k) sparse top-k doc ids; top_scores: (B, k) (min-max
    normalized upstream if desired); doc_cluster: (D,) cluster of each doc;
    bin_ids: (k,) bin of each rank. Returns P, Q: (B, N, v).
    """
    B, k = top_ids.shape
    c_of = jnp.take(doc_cluster, top_ids, axis=0)          # (B, k) — gather
    slot = c_of * v + bin_ids[None, :]                      # (B, k)

    def one(slots, scores):
        cnt = jax.ops.segment_sum(jnp.ones((k,), jnp.float32), slots,
                                  num_segments=n_clusters * v)
        ssum = jax.ops.segment_sum(scores, slots, num_segments=n_clusters * v)
        P = cnt.reshape(n_clusters, v)
        Q = (ssum / jnp.maximum(cnt, 1.0)).reshape(n_clusters, v)
        return P, Q

    P, Q = jax.vmap(one)(slot, top_scores)
    return P, Q
