"""Sparse lexical retrieval (SPLADE/BM25-style) as a TPU-native inverted
index: per-term posting lists are impact-ordered, truncated to a static
budget, and scoring is gather + scatter-add (`jnp.take` + `segment_sum`) —
the same primitive family as EmbeddingBag (DESIGN.md §2).

Documents/queries are bags of (term_id, weight); the exact rank score is
L(q) . L(d) = sum over shared terms of qw * dw.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SparseIndex:
    postings_docs: jnp.ndarray     # (V, P) int32, -1 padded, impact-ordered
    postings_weights: jnp.ndarray  # (V, P) f32
    n_docs: int

    @staticmethod
    def build(doc_terms, doc_weights, vocab, max_postings):
        """doc_terms: (D, T) int32 term ids (-1 pad); doc_weights: (D, T) f32."""
        doc_terms = np.asarray(doc_terms)
        doc_weights = np.asarray(doc_weights)
        D, T = doc_terms.shape
        lists = [[] for _ in range(vocab)]
        for d in range(D):
            for t, w in zip(doc_terms[d], doc_weights[d]):
                if t >= 0 and w > 0:
                    lists[int(t)].append((float(w), d))
        pd = np.full((vocab, max_postings), -1, np.int32)
        pw = np.zeros((vocab, max_postings), np.float32)
        truncated = 0
        for t in range(vocab):
            lst = sorted(lists[t], reverse=True)  # impact order
            if len(lst) > max_postings:
                truncated += len(lst) - max_postings
            lst = lst[:max_postings]
            for i, (w, d) in enumerate(lst):
                pd[t, i] = d
                pw[t, i] = w
        idx = SparseIndex(jnp.asarray(pd), jnp.asarray(pw), D)
        idx.truncated_postings = truncated
        return idx


def sparse_retrieve(index: SparseIndex, q_terms, q_weights, k):
    """q_terms: (B, Tq) int32 (-1 pad); q_weights: (B, Tq).

    Returns (top-k doc ids (B, k), top-k scores (B, k), full scores (B, D)).
    """
    B = q_terms.shape[0]
    D = index.n_docs
    qt = jnp.maximum(q_terms, 0)
    qmask = (q_terms >= 0) & (q_weights > 0)

    docs = jnp.take(index.postings_docs, qt, axis=0)       # (B, Tq, P)
    ws = jnp.take(index.postings_weights, qt, axis=0)      # (B, Tq, P)
    contrib = ws * q_weights[..., None]
    contrib = jnp.where(qmask[..., None], contrib, 0.0)
    dmask = docs >= 0
    flat_docs = jnp.where(dmask, docs, D).reshape(B, -1)   # overflow row D
    flat_contrib = jnp.where(dmask, contrib, 0.0).reshape(B, -1)

    def one(fd, fc):
        return jax.ops.segment_sum(fc, fd, num_segments=D + 1)[:D]

    scores = jax.vmap(one)(flat_docs, flat_contrib)        # (B, D)
    top_scores, top_ids = jax.lax.top_k(scores, k)
    return top_ids.astype(jnp.int32), top_scores, scores


def sparse_retrieve_topk(index: SparseIndex, q_terms, q_weights, k):
    ids, scores, _ = sparse_retrieve(index, q_terms, q_weights, k)
    return ids, scores
