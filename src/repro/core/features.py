"""Stage II LSTM input features (paper §2.3, Fig. 1):

  - query-cluster similarity sim(q, c_i)                      (1)
  - inter-cluster AvgDist(C_i, A_j), j=1..u over candidate bins (u)
    using only the top-m centroid neighbor graph (space O(N*m))
  - overlap features P(C_i, B_j), Q(C_i, B_j), j=1..v          (2v)

Feature vector dim = 1 + u + 2v.
"""

import jax
import jax.numpy as jnp


def feature_dim(cfg):
    return 1 + cfg.u_bins + 2 * cfg.v_bins


def candidate_features(cand, qc_sim, P, Q, neighbor_ids, neighbor_sims, u):
    """Build per-candidate LSTM features.

    cand: (B, n) candidate cluster ids (stage-1 order)
    qc_sim: (B, N); P, Q: (B, N, v); neighbor_ids/sims: (N, m)
    Returns (B, n, 1 + u + 2v) float32.
    """
    B, n = cand.shape

    def one(cand_q, sim_q, P_q, Q_q):
        f_sim = jnp.take(sim_q, cand_q)[:, None]            # (n, 1)
        f_P = jnp.take(P_q, cand_q, axis=0)                 # (n, v)
        f_Q = jnp.take(Q_q, cand_q, axis=0)                 # (n, v)

        # inter-cluster sims among candidates, masked by the m-NN graph:
        # sim[i, l] = neighbor_sims[cand_i, j] if cand_l == neighbor_ids[cand_i, j]
        nb_ids = jnp.take(neighbor_ids, cand_q, axis=0)     # (n, m)
        nb_sims = jnp.take(neighbor_sims, cand_q, axis=0)   # (n, m)
        match = nb_ids[:, :, None] == cand_q[None, None, :]  # (n, m, n)
        sim_mat = jnp.sum(jnp.where(match, nb_sims[:, :, None], 0.0), axis=1)

        # uniform partition of the n candidates into u bins (paper: A_1..A_u)
        u_size = n // u
        sim_bins = sim_mat[:, :u_size * u].reshape(n, u, u_size)
        f_avg = jnp.mean(sim_bins, axis=-1)                 # (n, u)
        return jnp.concatenate([f_sim, f_avg, f_P, f_Q], axis=-1)

    return jax.vmap(one)(cand, qc_sim, P, Q).astype(jnp.float32)
