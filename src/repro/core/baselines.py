"""Baselines the paper compares against (Tables 1, 2, 4, 8):

  - IVF top-p%: clusters ranked by query-centroid distance (FAISS IVF probe)
  - Rerank: dense-rescore only the sparse top-k ("S + Rerank")
  - CDFS-like: probabilistic cluster thresholding from order statistics of
    the sparse top-k overlap (the contemporary work CluSD is measured
    against; reimplemented from its published description — it assumes the
    rank-score distribution is iid, which is the weakness CluSD removes)
  - LADR-like graph navigation: sparse-seeded proximity-graph expansion
    (doc-level kNN graph, fixed depth/budget)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clusd as clusd_lib
from repro.core import fusion as fusion_lib
from repro.core import sparse as sparse_lib


# ---------------------------------------------------------------------------
# IVF p% probe
# ---------------------------------------------------------------------------

def ivf_retrieve(cfg, index, q_dense, q_terms, q_weights, n_probe, *,
                 fuse_sparse=True, k=None):
    """Select the top n_probe clusters by query-centroid similarity."""
    k = k or cfg.k_final
    qc_sim = q_dense @ index.centroids.T
    _, sel_ids = jax.lax.top_k(qc_sim, n_probe)
    sel_mask = jnp.ones_like(sel_ids, bool)
    did, dscore, dmask = clusd_lib.score_selected(
        index, q_dense, sel_ids.astype(jnp.int32), sel_mask)
    if not fuse_sparse:
        s, i = jax.lax.top_k(jnp.where(dmask, dscore, -jnp.inf), k)
        ids = jnp.take_along_axis(did, i, axis=1)
        return ids, s, {}
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, did, jnp.where(dmask, dscore, 0.0), dmask,
        index.n_docs, cfg.alpha, k)
    return ids, scores, {"n_selected": jnp.full((q_dense.shape[0],), n_probe)}


# ---------------------------------------------------------------------------
# Rerank (S + Rerank)
# ---------------------------------------------------------------------------

def rerank_retrieve(cfg, index, q_dense, q_terms, q_weights, *, k=None,
                    rerank_depth=None):
    k = k or cfg.k_final
    depth = rerank_depth or cfg.k_sparse
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, depth)
    vecs = jnp.take(index.embeddings, sparse_ids, axis=0)     # (B, k, dim)
    dscore = jnp.einsum("bd,bkd->bk", q_dense, vecs)
    mask = jnp.ones_like(dscore, bool)
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, sparse_ids, dscore, mask,
        index.n_docs, cfg.alpha, k)
    return ids, scores, {"n_docs_fetched": depth}


# ---------------------------------------------------------------------------
# CDFS-like probabilistic thresholding
# ---------------------------------------------------------------------------

def cdfs_select(cfg, index, q_dense, sparse_ids, sparse_scores, *,
                p_stop=0.95, max_selected=None):
    """Select clusters by the iid order-statistics model: treat each sparse
    top-k doc as an iid draw; a cluster's mass is the probability-weighted
    count of draws landing in it. Select (in mass order) until cumulative
    mass >= p_stop of the total, capped by the static budget."""
    S = max_selected or cfg.max_selected
    B, k = sparse_ids.shape
    # geometric rank weights (iid assumption: P(relevant | rank r) ~ rho^r);
    # rho calibrated on the synthetic corpus (0.95 — swept in EXPERIMENTS
    # §Validation; the CDFS authors tune their thresholds on MS MARCO)
    rho = 0.95
    w = rho ** jnp.arange(k, dtype=jnp.float32)
    c_of = jnp.take(index.doc_cluster, sparse_ids, axis=0)    # (B, k)

    def one(c_q):
        mass = jax.ops.segment_sum(w, c_q, num_segments=index.n_clusters)
        return mass

    mass = jax.vmap(one)(c_of)                                # (B, N)
    top_mass, sel_ids = jax.lax.top_k(mass, S)
    cum = jnp.cumsum(top_mass, axis=1)
    total = jnp.sum(mass, axis=1, keepdims=True)
    # keep cluster i if mass up to and excluding i hasn't reached p_stop
    prev = cum - top_mass
    sel_mask = (prev < p_stop * total) & (top_mass > 0)
    return sel_ids.astype(jnp.int32), sel_mask


def cdfs_retrieve(cfg, index, q_dense, q_terms, q_weights, *, p_stop=0.95,
                  k=None, max_selected=None):
    k = k or cfg.k_final
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    sel_ids, sel_mask = cdfs_select(cfg, index, q_dense, sparse_ids,
                                    sparse_scores, p_stop=p_stop,
                                    max_selected=max_selected)
    did, dscore, dmask = clusd_lib.score_selected(index, q_dense, sel_ids,
                                                  sel_mask)
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, did, jnp.where(dmask, dscore, 0.0), dmask,
        index.n_docs, cfg.alpha, k)
    return ids, scores, {"n_selected": jnp.sum(sel_mask, axis=1)}


# ---------------------------------------------------------------------------
# LADR-like graph navigation
# ---------------------------------------------------------------------------

def build_doc_knn(index, n_neighbors=16, probe_clusters=4):
    """Approximate doc-level kNN graph via cluster-restricted search
    (each doc is compared against docs of its `probe_clusters` nearest
    clusters). Returns (D, n_neighbors) int32 — the LADR proximity graph."""
    emb = np.asarray(index.embeddings)
    centroids = np.asarray(index.centroids)
    cluster_docs = np.asarray(index.cluster_docs)
    D = emb.shape[0]
    # nearest clusters per doc
    sims = emb @ centroids.T
    near_c = np.argsort(-sims, axis=1)[:, :probe_clusters]   # (D, pc)
    out = np.zeros((D, n_neighbors), np.int32)
    for d in range(D):
        cand = cluster_docs[near_c[d]].reshape(-1)
        cand = cand[cand >= 0]
        s = emb[cand] @ emb[d]
        order = np.argsort(-s)
        picked = [c for c in cand[order] if c != d][:n_neighbors]
        while len(picked) < n_neighbors:
            picked.append(picked[-1] if picked else d)
        out[d] = picked
    return jnp.asarray(out)


def ladr_retrieve(cfg, index, doc_knn, q_dense, q_terms, q_weights, *,
                  n_seeds=32, depth=2, budget=256, k=None):
    """Seed with sparse top-n_seeds docs; expand the kNN graph `depth` times,
    keeping a running candidate pool of `budget` best docs (LADR [20])."""
    k = k or cfg.k_final
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    seeds = sparse_ids[:, :n_seeds]                          # (B, s)
    B = seeds.shape[0]
    nn = doc_knn.shape[1]

    def expand(pool, pool_scores, q):
        nbrs = jnp.take(doc_knn, pool, axis=0).reshape(-1)   # (P*nn,)
        vecs = jnp.take(index.embeddings, nbrs, axis=0)
        s = vecs @ q
        all_ids = jnp.concatenate([pool, nbrs])
        all_s = jnp.concatenate([pool_scores, s])
        # dedup: keep the best-scoring copy by sorting ids then masking
        order = jnp.argsort(all_ids * 1_000_000 - all_s.astype(jnp.int32))
        sid = all_ids[order]
        ss = all_s[order]
        first = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
        ss = jnp.where(first, ss, -jnp.inf)
        top_s, top_i = jax.lax.top_k(ss, min(budget, ss.shape[0]))
        return sid[top_i], top_s

    def one(seed_q, q):
        vecs = jnp.take(index.embeddings, seed_q, axis=0)
        pool, pool_s = seed_q, vecs @ q
        for _ in range(depth):
            pool, pool_s = expand(pool, pool_s, q)
        return pool, pool_s

    pool, pool_s = jax.vmap(one)(seeds, q_dense)
    dmask = jnp.isfinite(pool_s)
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, pool, jnp.where(dmask, pool_s, 0.0), dmask,
        index.n_docs, cfg.alpha, k)
    n_fetched = n_seeds + depth * budget * nn  # unique-doc upper bound
    return ids, scores, {"n_docs_fetched": n_fetched}
