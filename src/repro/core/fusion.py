"""Score fusion (paper Step 3): combine the per-query top results of the
sparse and dense retrievers into one ranked list.

Two fusion methods share both formulations below:

  method="interp"  (paper / CC default): min-max normalize each side's
      VALID entries, then linear interpolation alpha*sparse +
      (1-alpha)*dense. Docs reached by only one retriever contribute 0 on
      the missing side after normalization.
  method="rrf"     weighted reciprocal-rank fusion (the hybrid-retrieval
      standard): fused(d) = alpha / (rrf_k + r_s(d)) +
      (1-alpha) / (rrf_k + r_d(d)), with 1-based ranks r among each
      side's VALID entries ordered (score desc, position asc — exactly
      lax.top_k's tie rule). Rank-based, so it needs no score
      normalization and is robust to incomparable score scales.

Both sides carry an explicit validity mask: `dense_mask` (required — the
dense candidate list is mask-padded by construction) and `sparse_mask`
(optional; None = every entry valid, the full-`k_sparse` serving case).
Masked entries contribute 0 AND are excluded from the min-max range /
rank assignment — a padded or ragged sparse list must not skew the
normalization of the real entries.

Two formulations, same semantics (property-tested against each other at
arbitrary id multiplicity in tests/test_clusd.py):

  fuse_topk        O(n_docs) scatter-add oracle (exact, jit-able).
  fuse_topk_merge  sort-merge without the O(n_docs) buffer — duplicates
      are folded by a segment-sum over the id-sorted entries, so a doc id
      may appear ANY number of times across (and within) the two lists;
      the distributed serving path and graph-expanded candidate lists
      both produce multiplicity > 2.
"""

import jax
import jax.numpy as jnp

FUSION_METHODS = ("interp", "rrf")


def minmax_norm(scores, mask=None):
    """Per-row min-max over valid entries. scores: (B, K)."""
    if mask is None:
        mask = jnp.ones_like(scores, bool)
    big = jnp.where(mask, scores, -jnp.inf)
    small = jnp.where(mask, scores, jnp.inf)
    mx = jnp.max(big, axis=-1, keepdims=True)
    mn = jnp.min(small, axis=-1, keepdims=True)
    rng = jnp.maximum(mx - mn, 1e-9)
    out = (scores - mn) / rng
    return jnp.where(mask, jnp.clip(out, 0.0, 1.0), 0.0)


def rank_desc(scores, mask):
    """1-based rank of every entry among its row's VALID entries, ordered
    (score desc, position asc) = lax.top_k's tie rule. Invalid entries
    rank after every valid one. scores/mask: (B, K) -> (B, K) int32."""
    keyed = jnp.where(mask, scores, -jnp.inf)
    order = jnp.argsort(-keyed, axis=-1, stable=True)
    inv = jnp.argsort(order, axis=-1, stable=True)     # inverse permutation
    return (inv + 1).astype(jnp.int32)


def side_contrib(scores, mask, weight, method, rrf_k):
    """Per-entry fused-score contribution of one retriever side.

    interp: weight * minmax_norm over valid entries; rrf: weight /
    (rrf_k + rank). Masked entries contribute exactly 0 either way."""
    if method == "interp":
        return weight * minmax_norm(scores, mask)
    if method == "rrf":
        r = rank_desc(scores, mask).astype(scores.dtype)
        return jnp.where(mask, weight / (rrf_k + r), 0.0)
    raise ValueError(f"unknown fusion method {method!r}; "
                     f"expected one of {FUSION_METHODS}")


def fuse_topk(sparse_ids, sparse_scores, dense_ids, dense_scores, dense_mask,
              n_docs, alpha, k, *, sparse_mask=None, method="interp",
              rrf_k=60.0):
    """Union-merge + fuse + global top-k (exact scatter formulation).

    sparse_ids/scores: (B, Ks) with optional sparse_mask for padding;
    dense_ids/scores: (B, Kd) with dense_mask for padding. Returns
    (ids (B, k), fused scores (B, k)).
    """
    if sparse_mask is None:
        sparse_mask = jnp.ones_like(sparse_ids, bool)
    s_c = side_contrib(sparse_scores, sparse_mask, alpha, method, rrf_k)
    d_c = side_contrib(dense_scores, dense_mask, 1.0 - alpha, method, rrf_k)

    def one(sid, sc, sm, did, dc, dm):
        fused = jnp.zeros((n_docs + 1,), jnp.float32)
        # masked entries carry contribution 0 and are routed to the dump
        # row n_docs, so a padded id can never touch a real doc's score
        fused = fused.at[jnp.where(dm, did, n_docs)].add(dc)
        fused = fused.at[jnp.where(sm, sid, n_docs)].add(sc)
        scores, ids = jax.lax.top_k(fused[:n_docs], k)
        return ids.astype(jnp.int32), scores

    return jax.vmap(one)(sparse_ids, s_c, sparse_mask,
                         dense_ids, d_c, dense_mask)


def fuse_topk_merge(sparse_ids, sparse_scores, dense_ids, dense_scores,
                    dense_mask, alpha, k, sentinel, *, sparse_mask=None,
                    method="interp", rrf_k=60.0):
    """Sort-merge fusion WITHOUT an O(n_docs) scatter buffer — the serving
    path for corpus-scale retrieval.

    Duplicate ids are folded by a segment-sum over the id-sorted entry
    list, so a doc may appear any number of times across the two sides
    (multi-shard gathers and graph-expanded candidate lists legitimately
    surface a doc 3+ times; the old pairwise `roll` merge silently
    dropped the third occurrence).

    sentinel: id strictly greater than any real doc id (pads sort last).
    """
    if sparse_mask is None:
        sparse_mask = jnp.ones_like(sparse_ids, bool)
    s_c = side_contrib(sparse_scores, sparse_mask, alpha, method, rrf_k)
    d_c = side_contrib(dense_scores, dense_mask, 1.0 - alpha, method, rrf_k)

    def one(sid, sc, sm, did, dc, dm):
        ids = jnp.concatenate([jnp.where(sm, sid, sentinel),
                               jnp.where(dm, did, sentinel)])
        contrib = jnp.concatenate([sc, dc])       # masked entries already 0
        order = jnp.argsort(ids)
        ids_s = jnp.take(ids, order)
        c_s = jnp.take(contrib, order)
        L = ids_s.shape[0]
        # contiguous segment index per distinct id run
        first = jnp.concatenate([jnp.ones((1,), bool),
                                 ids_s[1:] != ids_s[:-1]])
        seg = jnp.cumsum(first) - 1                              # (L,)
        totals = jax.ops.segment_sum(c_s, seg, num_segments=L)
        seg_ids = jax.ops.segment_max(ids_s, seg, num_segments=L)
        live = (jnp.arange(L) < seg[-1] + 1) & (seg_ids < sentinel)
        seg_ids = jnp.where(live, seg_ids, sentinel)
        final = jnp.where(live, totals, -jnp.inf)
        top_s, top_i = jax.lax.top_k(final, k)
        return jnp.take(seg_ids, top_i).astype(jnp.int32), top_s

    return jax.vmap(one)(sparse_ids, s_c, sparse_mask,
                         dense_ids, d_c, dense_mask)
