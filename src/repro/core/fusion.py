"""Score fusion (paper Step 3): min-max normalize the per-query top results
of each retriever, then linear interpolation alpha*sparse + (1-alpha)*dense.
Docs reached by only one retriever contribute 0 on the missing side after
normalization (standard CC fusion convention used by CluSD/CDFS)."""

import jax
import jax.numpy as jnp


def minmax_norm(scores, mask=None):
    """Per-row min-max over valid entries. scores: (B, K)."""
    if mask is None:
        mask = jnp.ones_like(scores, bool)
    big = jnp.where(mask, scores, -jnp.inf)
    small = jnp.where(mask, scores, jnp.inf)
    mx = jnp.max(big, axis=-1, keepdims=True)
    mn = jnp.min(small, axis=-1, keepdims=True)
    rng = jnp.maximum(mx - mn, 1e-9)
    out = (scores - mn) / rng
    return jnp.where(mask, jnp.clip(out, 0.0, 1.0), 0.0)


def fuse_topk(sparse_ids, sparse_scores, dense_ids, dense_scores, dense_mask,
              n_docs, alpha, k):
    """Union-merge + interpolate + global top-k (exact scatter formulation).

    sparse_ids/scores: (B, Ks); dense_ids/scores: (B, Kd) with dense_mask for
    padding. Returns (ids (B, k), fused scores (B, k)).
    """
    B = sparse_ids.shape[0]
    s_norm = minmax_norm(sparse_scores)
    d_norm = minmax_norm(dense_scores, dense_mask)

    def one(sid, ss, did, ds, dm):
        fused = jnp.zeros((n_docs + 1,), jnp.float32)
        # dense side: scatter (unique ids by construction; add is safe)
        did_safe = jnp.where(dm, did, n_docs)
        fused = fused.at[did_safe].add((1.0 - alpha) * ds * dm)
        # sparse side
        fused = fused.at[sid].add(alpha * ss)
        scores, ids = jax.lax.top_k(fused[:n_docs], k)
        return ids.astype(jnp.int32), scores

    return jax.vmap(one)(sparse_ids, s_norm, dense_ids, d_norm,
                         dense_mask.astype(jnp.float32))


def fuse_topk_merge(sparse_ids, sparse_scores, dense_ids, dense_scores,
                    dense_mask, alpha, k, sentinel):
    """Sort-merge fusion WITHOUT an O(n_docs) scatter buffer — the serving
    path for corpus-scale retrieval (each side's ids are unique; a doc can
    appear once per side, so duplicates come in pairs after the sort).

    sentinel: id strictly greater than any real doc id (pads sort last).
    """
    s_norm = minmax_norm(sparse_scores)
    d_norm = minmax_norm(dense_scores, dense_mask)

    def one(sid, ss, did, ds, dm):
        ids = jnp.concatenate([sid, jnp.where(dm, did, sentinel)])
        contrib = jnp.concatenate([alpha * ss,
                                   jnp.where(dm, (1 - alpha) * ds, 0.0)])
        order = jnp.argsort(ids)
        ids_s = ids[order]
        c_s = contrib[order]
        nxt_same = jnp.concatenate([ids_s[1:] == ids_s[:-1],
                                    jnp.zeros((1,), bool)])
        merged = c_s + jnp.where(nxt_same, jnp.roll(c_s, -1), 0.0)
        dup = jnp.concatenate([jnp.zeros((1,), bool),
                               ids_s[1:] == ids_s[:-1]])
        final = jnp.where(dup | (ids_s >= sentinel), -jnp.inf, merged)
        top_s, top_i = jax.lax.top_k(final, k)
        return ids_s[top_i].astype(jnp.int32), top_s

    return jax.vmap(one)(sparse_ids, s_norm, dense_ids, d_norm,
                         dense_mask)
