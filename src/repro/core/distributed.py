"""Distributed CluSD serving (the paper's system as a first-class sharded
feature — DESIGN.md §4).

Layout: docs are RENUMBERED into cluster-blocked order, doc id = c*cap + s,
so cluster membership is `id // cap` (no cluster_docs table) and the
embedding store is a (N, cap, dim) block array sharded over 'model' by
contiguous cluster ranges — the TPU analogue of the paper's on-disk cluster
blocks. Queries shard over 'data'.

Serve step (one shard_map over ('data','model')):
  1. sparse scoring against the locally-owned posting shard -> local dense
     score array (cap * N_local docs) -> local top-k -> all-gather over
     'model' -> merged global sparse top-k            [term-at-doc-owner]
  2. Stage I/II run replicated per query (tiny: O(N) + O(n) LSTM)
  3. each shard scores the selected clusters IT OWNS (local gather +
     (B_loc, S, cap, d) dot) -> local top-k -> all-gather merge
  4. sort-merge fusion (fuse_topk_merge; no O(D) buffer)
"""

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bins as bins_lib
from repro.core import features as feat_lib
from repro.core import fusion as fusion_lib
from repro.core import stage1 as stage1_lib
from repro.core.lstm import lstm_apply


@dataclasses.dataclass
class BlockedIndex:
    """Host-built, device-shardable CluSD index in blocked-doc layout."""
    blocks: np.ndarray          # (N, cap, dim)
    valid: np.ndarray           # (N, cap) bool
    centroids: np.ndarray       # (N, dim)
    neighbor_ids: np.ndarray    # (N, m)
    neighbor_sims: np.ndarray   # (N, m)
    postings_docs: np.ndarray   # (V, P) blocked doc ids, -1 pad
    postings_weights: np.ndarray  # (V, P)
    old_to_new: np.ndarray      # (D,) original doc id -> blocked id
    lstm_params: object = None

    @property
    def n_clusters(self):
        return self.blocks.shape[0]

    @property
    def cap(self):
        return self.blocks.shape[1]


def build_blocked_index(cfg, index, embeddings=None):
    """Convert a core.clusd.CluSDIndex into blocked layout (host-side)."""
    emb = np.asarray(embeddings if embeddings is not None else index.embeddings)
    cd = np.asarray(index.cluster_docs)
    N, cap = cd.shape
    dim = emb.shape[1]
    blocks = np.zeros((N, cap, dim), np.float32)
    valid = cd >= 0
    blocks[valid] = emb[cd[valid]]
    old_to_new = np.full(emb.shape[0], -1, np.int64)
    c_idx, s_idx = np.nonzero(valid)
    old_to_new[cd[valid]] = c_idx * cap + s_idx
    pd = np.asarray(index.sparse_index.postings_docs)
    pw = np.asarray(index.sparse_index.postings_weights)
    pd_new = np.where(pd >= 0, old_to_new[np.maximum(pd, 0)], -1).astype(np.int32)
    return BlockedIndex(
        blocks=blocks, valid=valid, centroids=np.asarray(index.centroids),
        neighbor_ids=np.asarray(index.neighbor_ids),
        neighbor_sims=np.asarray(index.neighbor_sims),
        postings_docs=pd_new, postings_weights=pw,
        old_to_new=old_to_new, lstm_params=index.lstm_params)


def shard_ranges(n_clusters, n_shards):
    """Balanced contiguous cluster partition: shard s owns
    [lo_s, hi_s) with sizes differing by at most 1 (the first
    `n_clusters % n_shards` shards get the extra cluster). For divisible
    n_clusters this is exactly the old equal split. Returns a list of
    (lo, hi) tuples covering [0, n_clusters) with no gaps."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_clusters < n_shards:
        raise ValueError(f"cannot split {n_clusters} clusters over "
                         f"{n_shards} shards (need >= 1 each)")
    bounds = [(s * n_clusters) // n_shards for s in range(n_shards + 1)]
    return list(zip(bounds[:-1], bounds[1:]))


def owner_of(cluster_ids, ranges):
    """Shard index owning each cluster id, per `ranges` (a list of
    (lo, hi) as from shard_ranges — contiguous ascending). Vectorized
    searchsorted over the range upper bounds; ids outside every range
    raise (ownership must be total)."""
    his = np.asarray([hi for _, hi in ranges], np.int64)
    los = np.asarray([lo for lo, _ in ranges], np.int64)
    ids = np.asarray(cluster_ids, np.int64)
    s = np.searchsorted(his, ids, side="right")
    if np.any((ids < 0) | (s >= len(his))) or np.any(ids < los[np.minimum(
            s, len(his) - 1)]):
        raise ValueError("cluster id outside every shard range")
    return s


def shard_postings_by_owner(bidx: BlockedIndex, n_shards):
    """Repartition each term's posting list by doc owner shard so sparse
    scoring is local: returns (V, n_shards, P_shard) ids + weights.

    Ownership is the balanced contiguous split from `shard_ranges` —
    identical to the old `cluster // (N // n_shards)` rule when N divides
    evenly, but total for any N (the old rule assigned tail clusters of a
    non-divisible N to a nonexistent shard and silently dropped their
    postings from every shard)."""
    V, P = bidx.postings_docs.shape
    N, cap = bidx.blocks.shape[:2]
    his = np.asarray([hi for _, hi in shard_ranges(N, n_shards)], np.int64)
    owner = np.where(bidx.postings_docs >= 0,
                     np.searchsorted(his, bidx.postings_docs // cap,
                                     side="right"), -1)
    p_shard = 0
    for s in range(n_shards):
        p_shard = max(p_shard, int((owner == s).sum(axis=1).max()))
    p_shard = max(8, -(-p_shard // 8) * 8)
    docs = np.full((V, n_shards, p_shard), -1, np.int32)
    ws = np.zeros((V, n_shards, p_shard), np.float32)
    for t in range(V):
        for s in range(n_shards):
            sel = owner[t] == s
            n = int(sel.sum())
            if n:
                docs[t, s, :n] = bidx.postings_docs[t, sel]
                ws[t, s, :n] = bidx.postings_weights[t, sel]
    return docs, ws


def make_serve_step(cfg, mesh, bidx_shapes, feat_dim):
    """Returns the jit-able sharded serve fn. bidx_shapes: (N, cap, dim,
    V, P_shard, m). All heavy arrays enter pre-sharded."""
    N, cap, dim, V, P_shard, m = bidx_shapes
    nd, nm = mesh.shape["data"], mesh.shape["model"]
    n_local = N // nm
    d_local = n_local * cap
    k = cfg.k_sparse
    sentinel = N * cap + 1

    def serve(blocks, postings_docs, postings_weights, centroids,
              nb_ids, nb_sims, lstm_params, q_dense, q_terms, q_weights):
        # ---- phase 1+3 under one shard_map ----
        def shard_fn(blocks_l, pd_l, pw_l, centroids, nb_ids, nb_sims,
                     lstm_params, q_d, q_t, q_w):
            mi = jax.lax.axis_index("model")
            B = q_d.shape[0]
            # sparse scoring over owned docs
            qt = jnp.maximum(q_t, 0)
            qmask = (q_t >= 0) & (q_w > 0)
            docs = pd_l[qt][:, :, 0, :]            # (B, Tq, P_shard)
            ws = pw_l[qt][:, :, 0, :]
            contrib = jnp.where(qmask[..., None] & (docs >= 0),
                                ws * q_w[..., None], 0.0)
            local_doc = jnp.where(docs >= 0, docs - mi * d_local, d_local)
            local_doc = jnp.clip(local_doc, 0, d_local)

            def seg(fd, fc):
                return jax.ops.segment_sum(fc.reshape(-1), fd.reshape(-1),
                                           num_segments=d_local + 1)[:d_local]

            s_scores = jax.vmap(seg)(local_doc, contrib)     # (B, d_local)
            kk = min(k, d_local)
            sv, si = jax.lax.top_k(s_scores, kk)             # local top-k
            gid = si + mi * d_local
            # merge over model axis
            sv_all = jax.lax.all_gather(sv, "model", axis=1)  # (B, nm, kk)
            gid_all = jax.lax.all_gather(gid, "model", axis=1)
            sv_f = sv_all.reshape(B, nm * kk)
            gid_f = gid_all.reshape(B, nm * kk)
            mv, mi_ = jax.lax.top_k(sv_f, k)
            sparse_ids = jnp.take_along_axis(gid_f, mi_, axis=1)
            sparse_scores = mv

            # ---- stage I/II (replicated across 'model'; per local query) ----
            qc_sim = q_d @ centroids.T                        # (B, N)
            doc_cluster = sparse_ids // cap
            bin_ids = bins_lib.rank_bin_ids(cfg.bins, k)
            v = cfg.v_bins
            slot = doc_cluster * v + bin_ids[None, :]
            sn = fusion_lib.minmax_norm(sparse_scores)

            def pq(sl, sc):
                cnt = jax.ops.segment_sum(jnp.ones((k,), jnp.float32), sl,
                                          num_segments=N * v)
                ssum = jax.ops.segment_sum(sc, sl, num_segments=N * v)
                return (cnt.reshape(N, v),
                        (ssum / jnp.maximum(cnt, 1.0)).reshape(N, v))

            P_, Q_ = jax.vmap(pq)(slot, sn)
            cand = stage1_lib.sort_by_overlap(P_, qc_sim, cfg.n_candidates)
            feats = feat_lib.candidate_features(
                cand, qc_sim, P_, Q_, nb_ids, nb_sims, cfg.u_bins)
            probs = lstm_apply(lstm_params, feats)
            picked = probs >= cfg.theta
            masked = jnp.where(picked, probs, -1.0)
            top_p, top_i = jax.lax.top_k(masked, cfg.max_selected)
            sel_mask = top_p >= 0.0
            sel_ids = jnp.take_along_axis(cand, top_i, axis=1)  # (B, S)

            # ---- phase 3: score owned selected clusters ----
            local_sel = sel_ids - mi * n_local
            owned = (local_sel >= 0) & (local_sel < n_local) & sel_mask
            blk = jnp.take(blocks_l, jnp.clip(local_sel, 0, n_local - 1),
                           axis=0)                            # (B, S, cap, dim)
            dsc = jnp.einsum("bd,bscd->bsc", q_d, blk)
            dsc = jnp.where(owned[:, :, None], dsc, -jnp.inf)
            d_ids = sel_ids[:, :, None] * cap + jnp.arange(cap)[None, None, :]
            kd = min(cfg.max_selected * cap, 4 * k)
            dv, di = jax.lax.top_k(dsc.reshape(B, -1), kd)
            dgid = jnp.take_along_axis(d_ids.reshape(B, -1), di, axis=1)
            dv_all = jax.lax.all_gather(dv, "model", axis=1).reshape(B, -1)
            dg_all = jax.lax.all_gather(dgid, "model", axis=1).reshape(B, -1)
            return sparse_ids, sparse_scores, dg_all, dv_all

        from jax.sharding import PartitionSpec as P
        fn = jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P("model", None, None), P(None, "model", None),
                      P(None, "model", None), P(None, None), P(None, None),
                      P(None, None), P(), P("data", None), P("data", None),
                      P("data", None)),
            out_specs=(P("data", None), P("data", None), P("data", None),
                       P("data", None)),
            check_vma=False)
        sparse_ids, sparse_scores, dgid, dval = fn(
            blocks, postings_docs, postings_weights, centroids, nb_ids,
            nb_sims, lstm_params, q_dense, q_terms, q_weights)
        dmask = jnp.isfinite(dval)
        ids, scores = fusion_lib.fuse_topk_merge(
            sparse_ids, sparse_scores, dgid,
            jnp.where(dmask, dval, 0.0), dmask, cfg.alpha,
            min(cfg.k_final, sparse_ids.shape[1]), sentinel,
            method=cfg.fusion, rrf_k=cfg.rrf_k)
        return ids, scores

    return serve
