"""Pure-jnp oracle for the cluster_score kernel."""

import jax.numpy as jnp


def cluster_score_ref(q, blocks, sel_ids):
    """q: (B, dim); blocks: (N, cap, dim); sel_ids: (B, S) -> (B, S, cap)."""
    gathered = jnp.take(blocks, sel_ids, axis=0)       # (B, S, cap, dim)
    return jnp.einsum("bd,bscd->bsc", q, gathered).astype(jnp.float32)
