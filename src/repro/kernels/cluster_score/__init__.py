from repro.kernels.cluster_score.ops import cluster_score
from repro.kernels.cluster_score.ref import cluster_score_ref
