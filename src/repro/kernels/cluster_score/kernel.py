"""Selected-cluster scoring kernel (paper Step 3, the partial-dense-retrieval
hot spot).

For each (query b, selection slot s): load the embedding block of cluster
sel_ids[b, s] from HBM into VMEM via a scalar-prefetch-driven BlockSpec
index_map (the gather happens in the DMA engine — no materialized
(B, S*cap, dim) gather in HBM, unlike the jnp reference), then one
(cap, dim) x (dim,) MXU matvec per slot.

This is the TPU-native form of the paper's "cluster-based block I/O": the
HBM->VMEM DMA of a contiguous cluster block plays the role of the paper's
SSD block read (DESIGN.md §2).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(sel_ref, q_ref, blocks_ref, out_ref):
    # q_ref: (1, dim); blocks_ref: (1, cap, dim); out_ref: (1, 1, cap)
    q = q_ref[0, :]                       # (dim,)
    blk = blocks_ref[0]                   # (cap, dim)
    out_ref[0, 0, :] = jnp.dot(blk, q, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cluster_score_pallas(q, blocks, sel_ids, *, interpret=True):
    """q: (B, dim); blocks: (N, cap, dim); sel_ids: (B, S) int32.

    Returns scores (B, S, cap) float32.
    """
    B, dim = q.shape
    N, cap, _ = blocks.shape
    S = sel_ids.shape[1]

    # scalar-prefetch grid spec: sel_ids drives the blocks index_map
    from jax.experimental.pallas import tpu as pltpu
    kernel = pl.pallas_call(
        _score_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, S),
            in_specs=[
                pl.BlockSpec((1, dim), lambda b, s, sel: (b, 0)),
                pl.BlockSpec((1, cap, dim), lambda b, s, sel: (sel[b, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, cap), lambda b, s, sel: (b, s, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, cap), jnp.float32),
        interpret=interpret,
    )
    return kernel(sel_ids, q, blocks)
