"""Public op: selected-cluster scoring. Pallas on TPU, interpret-mode
validation on CPU, with the jnp oracle available as an explicit fallback."""

import jax

from repro.kernels.cluster_score.kernel import cluster_score_pallas
from repro.kernels.cluster_score.ref import cluster_score_ref


def cluster_score(q, blocks, sel_ids, *, use_kernel=True):
    if not use_kernel:
        return cluster_score_ref(q, blocks, sel_ids)
    interpret = jax.default_backend() != "tpu"
    return cluster_score_pallas(q, blocks, sel_ids, interpret=interpret)
