"""Pure-jnp oracles for the ADC kernel family.

Accumulation-order contract (shared by ref, Pallas kernel, and the
quant-lib device path): a block score is

    score[b, s, c] = sum_{j=0}^{nsub-1} lut[b, j, codes[sel[b, s], c, j]]

accumulated in ascending subspace order j with a single float32
accumulator, where each LUT entry is itself the float32 dot product
lut[b, j, k] = q_rot[b, j*dsub:(j+1)*dsub] . codebooks[j, k]. This is
dot(q, decode(codes)) with the dim-length sum reassociated into nsub
partial dots — identical math, reordered — so ADC scoring is
rank-equivalent to decode-then-score and agrees to float rounding.
"""

import jax.numpy as jnp


def adc_tables_ref(q, codebooks, rotation=None):
    """Per-query ADC lookup tables.

    q: (B, dim); codebooks: (nsub, K, dsub); rotation: (dim, dim) or None
    (the OPQ rotation is folded into the LUT build: q is rotated once,
    then never touched again — code scoring is rotation-free).
    Returns (B, nsub, K) float32.
    """
    q = jnp.asarray(q, jnp.float32)
    if rotation is not None:
        q = q @ jnp.asarray(rotation, jnp.float32)
    nsub, K, dsub = codebooks.shape
    qs = q.reshape(q.shape[0], nsub, dsub)
    return jnp.einsum("bsd,skd->bsk", qs,
                      jnp.asarray(codebooks, jnp.float32))


def adc_score_blocks_ref(lut, code_blocks, sel_ids):
    """Score selected code blocks against per-query LUTs.

    lut: (B, nsub, K) float32; code_blocks: (N, cap, nsub) uint8/int;
    sel_ids: (B, S) int32. Returns (B, S, cap) float32 under the
    module-docstring accumulation order.
    """
    codes = jnp.take(code_blocks, sel_ids, axis=0).astype(jnp.int32)
    B = codes.shape[0]
    nsub = codes.shape[-1]
    b_idx = jnp.arange(B)[:, None, None, None]
    j_idx = jnp.arange(nsub)[None, None, None, :]
    vals = lut[b_idx, j_idx, codes]                  # (B, S, cap, nsub)
    return jnp.sum(vals, axis=-1).astype(jnp.float32)
