from repro.kernels.adc.ops import adc_score_blocks, adc_tables
from repro.kernels.adc.ref import adc_score_blocks_ref, adc_tables_ref

__all__ = ["adc_tables", "adc_score_blocks",
           "adc_tables_ref", "adc_score_blocks_ref"]
