"""ADC (asymmetric distance computation) Pallas kernels — the PQ serving
fast path: build per-query lookup tables once, then accumulate scores
directly over uint8 codes, never reconstructing float embeddings.

Two kernels:

  * adc_tables_pallas — LUT build. Grid (B, nsub); each cell is one
    (K, dsub) x (dsub,) MXU matvec: lut[b, j] = codebooks[j] @ q_sub.
    The OPQ rotation is folded in BEFORE the kernel (ops.py rotates q
    once), so the kernel sees only the rotated query.

  * adc_score_blocks_pallas — code scoring. Like cluster_score, sel_ids
    is scalar-prefetched and drives the code-block BlockSpec index_map:
    the (cap, nsub) uint8 block of cluster sel_ids[b, s] is DMA'd into
    VMEM (16x fewer bytes than the float block), then scores accumulate
    in-register in ascending subspace order (ref.py contract): per
    subspace a (cap, K) one-hot of the code column hits the (K,) LUT row
    on the MXU — a gather-free formulation that lowers on TPU.

Output is float32 and matches dot(q, decode(codes)) up to the documented
reassociation of the dim-length sum into nsub partial dots.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tables_kernel(q_ref, books_ref, out_ref):
    # q_ref: (1, dsub); books_ref: (1, K, dsub); out_ref: (1, 1, K)
    out_ref[0, 0, :] = jnp.dot(books_ref[0], q_ref[0, :],
                               preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def adc_tables_pallas(q, codebooks, *, interpret=True):
    """q: (B, dim) float32 (already rotated); codebooks: (nsub, K, dsub).

    Returns LUT (B, nsub, K) float32.
    """
    B, dim = q.shape
    nsub, K, dsub = codebooks.shape
    return pl.pallas_call(
        _tables_kernel,
        grid=(B, nsub),
        in_specs=[
            pl.BlockSpec((1, dsub), lambda b, j: (b, j)),
            pl.BlockSpec((1, K, dsub), lambda b, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, K), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nsub, K), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), jnp.asarray(codebooks, jnp.float32))


def _score_kernel(sel_ref, lut_ref, codes_ref, out_ref, *, nsub, K):
    # lut_ref: (1, nsub, K); codes_ref: (1, cap, nsub); out_ref: (1, 1, cap)
    codes = codes_ref[0].astype(jnp.int32)                 # (cap, nsub)
    lut = lut_ref[0]                                       # (nsub, K)
    cap = codes.shape[0]
    lanes = jax.lax.iota(jnp.int32, K)[None, :]            # (1, K)

    def body(j, acc):
        # one-hot(codes[:, j]) @ lut[j]: an MXU-friendly gather of K-wide
        # LUT rows; ascending j is the documented accumulation order
        col = jax.lax.dynamic_slice(codes, (0, j), (cap, 1))   # (cap, 1)
        onehot = (col == lanes).astype(jnp.float32)            # (cap, K)
        row = jax.lax.dynamic_slice(lut, (j, 0), (1, K))[0]    # (K,)
        return acc + jnp.dot(onehot, row,
                             preferred_element_type=jnp.float32)

    out_ref[0, 0, :] = jax.lax.fori_loop(
        0, nsub, body, jnp.zeros((cap,), jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def adc_score_blocks_pallas(lut, code_blocks, sel_ids, *, interpret=True):
    """lut: (B, nsub, K); code_blocks: (N, cap, nsub) uint8;
    sel_ids: (B, S) int32. Returns scores (B, S, cap) float32.
    """
    B, nsub, K = lut.shape
    N, cap, _ = code_blocks.shape
    S = sel_ids.shape[1]

    from jax.experimental.pallas import tpu as pltpu
    kernel = pl.pallas_call(
        functools.partial(_score_kernel, nsub=nsub, K=K),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, S),
            in_specs=[
                pl.BlockSpec((1, nsub, K), lambda b, s, sel: (b, 0, 0)),
                pl.BlockSpec((1, cap, nsub),
                             lambda b, s, sel: (sel[b, s], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, cap), lambda b, s, sel: (b, s, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, cap), jnp.float32),
        interpret=interpret,
    )
    return kernel(sel_ids, lut.astype(jnp.float32), code_blocks)
