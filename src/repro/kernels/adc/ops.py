"""Public ops: ADC LUT build + code-block scoring.

Dispatch policy differs from the other kernel packages on purpose: the
ADC ops sit on the SERVING hot path, where interpret-mode Pallas (a
Python-level emulator) would be orders of magnitude slower than XLA.
`use_kernel=None` (the default) therefore compiles the Pallas kernel on
TPU and falls back to the jnp oracle — same math, same accumulation
order (ref.py) — everywhere else. Tests pin `use_kernel=True` to
exercise the kernel bodies in interpret mode on CPU.
"""

import jax
import jax.numpy as jnp

from repro.kernels.adc.kernel import adc_score_blocks_pallas, adc_tables_pallas
from repro.kernels.adc.ref import adc_score_blocks_ref, adc_tables_ref


def _resolve(use_kernel):
    """-> (run_kernel, interpret)."""
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu
    return bool(use_kernel), not on_tpu


def adc_tables(q, codebooks, rotation=None, *, use_kernel=None):
    """q: (B, dim) -> LUT (B, nsub, K) float32. The OPQ rotation is folded
    in here (q is rotated once; codes are scored rotation-free)."""
    run_kernel, interpret = _resolve(use_kernel)
    if not run_kernel:
        return adc_tables_ref(q, codebooks, rotation)
    q = jnp.asarray(q, jnp.float32)
    if rotation is not None:
        q = q @ jnp.asarray(rotation, jnp.float32)
    return adc_tables_pallas(q, codebooks, interpret=interpret)


def adc_score_blocks(lut, code_blocks, sel_ids, *, use_kernel=None):
    """lut: (B, nsub, K); code_blocks: (N, cap, nsub) uint8;
    sel_ids: (B, S) -> (B, S, cap) float32 ADC scores."""
    run_kernel, interpret = _resolve(use_kernel)
    B, S = sel_ids.shape[0], sel_ids.shape[1]
    cap = code_blocks.shape[1]
    if S == 0 or cap == 0 or code_blocks.shape[0] == 0:
        # empty fetch/selection: nothing to score (a zero-size grid has no
        # kernel instances; keep the contract shape)
        return jnp.zeros((B, S, cap), jnp.float32)
    if not run_kernel:
        return adc_score_blocks_ref(lut, code_blocks, sel_ids)
    return adc_score_blocks_pallas(lut, jnp.asarray(code_blocks),
                                   jnp.asarray(sel_ids, jnp.int32),
                                   interpret=interpret)
