import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def embedding_bag(table, idx, *, use_kernel=True):
    if not use_kernel:
        return embedding_bag_ref(table, idx)
    interpret = jax.default_backend() != "tpu"
    return embedding_bag_pallas(table, idx, interpret=interpret)
