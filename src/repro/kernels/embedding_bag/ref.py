"""Oracle: EmbeddingBag = take + sum (equivalently segment_sum over bags)."""

import jax.numpy as jnp


def embedding_bag_ref(table, idx):
    return jnp.sum(jnp.take(table, idx, axis=0), axis=1)
