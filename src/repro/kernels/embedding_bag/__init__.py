from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
