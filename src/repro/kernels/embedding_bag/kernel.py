"""EmbeddingBag kernel: gather + pool over the hotness axis.

JAX has no native EmbeddingBag; this kernel performs the row gathers with a
scalar-prefetch index_map (rows are DMA'd HBM->VMEM directly, never
materializing the (B, hot, d) gather tensor) and accumulates in the output
VMEM block across the hot grid axis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, table_ref, out_ref):
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :] += table_ref[0, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(table, idx, *, interpret=True):
    """table: (V, d); idx: (B, hot) int32 -> sum-pooled (B, d)."""
    B, hot = idx.shape
    V, d = table.shape
    out = pl.pallas_call(
        _bag_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, hot),
            in_specs=[
                pl.BlockSpec((1, d), lambda b, h, idx: (idx[b, h], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda b, h, idx: (b, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, d), table.dtype),
        interpret=interpret,
    )(idx, table)
    return out
