from repro.kernels.topk.ops import topk
from repro.kernels.topk.ref import topk_ref
