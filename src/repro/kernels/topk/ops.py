import jax

from repro.kernels.topk.kernel import topk_pallas
from repro.kernels.topk.ref import topk_ref


def topk(x, k, *, use_kernel=True):
    if not use_kernel:
        return topk_ref(x, k)
    interpret = jax.default_backend() != "tpu"
    return topk_pallas(x, k, interpret=interpret)
