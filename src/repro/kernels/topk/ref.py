import jax
import jax.numpy as jnp


def topk_ref(x, k):
    return jax.lax.top_k(x, k)
