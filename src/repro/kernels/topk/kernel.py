"""Blocked top-k kernel: running top-k merge over score tiles.

Scores (B, D) are streamed tile-by-tile through VMEM; a (k,)-sized running
best (values + global indices) is carried in the output block across the
tile grid axis, merged per tile with lax.top_k over the concatenated
[running ; tile] pair. Avoids materializing a full (B, D) sort — D can be
the whole corpus shard while k ~ 1000.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k, block_d):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _():
        vals_ref[...] = jnp.full_like(vals_ref, -jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    tile = x_ref[0, :]                                     # (block_d,)
    base = t * block_d
    tile_idx = base + jax.lax.iota(jnp.int32, block_d)
    cat_v = jnp.concatenate([vals_ref[0, :], tile])
    cat_i = jnp.concatenate([idx_ref[0, :], tile_idx])
    best_v, pos = jax.lax.top_k(cat_v, k)
    vals_ref[0, :] = best_v
    idx_ref[0, :] = jnp.take(cat_i, pos)


@functools.partial(jax.jit, static_argnames=("k", "block_d", "interpret"))
def topk_pallas(x, k, *, block_d=2048, interpret=True):
    """x: (B, D) -> (values (B, k), indices (B, k))."""
    B, D = x.shape
    block_d = min(block_d, D)
    if D % block_d:
        pad = block_d - D % block_d
        x = jnp.pad(x, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    nt = x.shape[1] // block_d
    kern = functools.partial(_topk_kernel, k=k, block_d=block_d)
    vals, idx = pl.pallas_call(
        kern,
        grid=(B, nt),
        in_specs=[pl.BlockSpec((1, block_d), lambda b, t: (b, t))],
        out_specs=[
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), x.dtype),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return vals, idx
