"""Pure-jnp LSTM oracle (same math as core/lstm.py scan path)."""

import jax
import jax.numpy as jnp


def lstm_sequence_ref(x, wx, wh, b):
    B, n, F = x.shape
    H = wh.shape[0]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ wx + h @ wh + b
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, H), jnp.float32), jnp.zeros((B, H), jnp.float32))
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(x.astype(jnp.float32), 1, 0))
    return jnp.moveaxis(hs, 0, 1)
