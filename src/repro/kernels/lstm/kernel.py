"""Fused LSTM-selector kernel (paper Stage II).

The whole candidate sequence is processed in ONE kernel invocation per batch
block: weights (F x 4H, H x 4H) stay resident in VMEM across all n steps,
gates are computed fused (no per-step HLO op dispatch / HBM round-trips for
h and c). Grid = batch blocks only; the time loop is a fori_loop inside the
kernel over the (B_blk, n, F) VMEM-resident feature tile — n<=64 and F~21,
so the whole per-block working set is < 1 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, wx_ref, wh_ref, b_ref, out_ref):
    # x_ref: (Bb, n, F); wx: (F, 4H); wh: (H, 4H); b: (4H,); out: (Bb, n, H)
    Bb, n, F = x_ref.shape
    H = wh_ref.shape[0]
    wx = wx_ref[...]
    wh = wh_ref[...]
    b = b_ref[...]

    def step(t, carry):
        h, c = carry
        x_t = x_ref[:, t, :]                                  # (Bb, F)
        gates = (jnp.dot(x_t, wx, preferred_element_type=jnp.float32)
                 + jnp.dot(h, wh, preferred_element_type=jnp.float32) + b)
        i = jax.nn.sigmoid(gates[:, 0 * H:1 * H])
        f = jax.nn.sigmoid(gates[:, 1 * H:2 * H])
        g = jnp.tanh(gates[:, 2 * H:3 * H])
        o = jax.nn.sigmoid(gates[:, 3 * H:4 * H])
        c = f * c + i * g
        h = o * jnp.tanh(c)
        out_ref[:, t, :] = h
        return h, c

    h0 = jnp.zeros((Bb, H), jnp.float32)
    c0 = jnp.zeros((Bb, H), jnp.float32)
    jax.lax.fori_loop(0, n, step, (h0, c0))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lstm_sequence_pallas(x, wx, wh, b, *, block_b=8, interpret=True):
    """x: (B, n, F) -> hidden sequence (B, n, H) float32."""
    B, n, F = x.shape
    H = wh.shape[0]
    Bb = min(block_b, B)
    if B % Bb:
        pad = Bb - B % Bb
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    nb = x.shape[0] // Bb
    out = pl.pallas_call(
        _lstm_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((Bb, n, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda i: (0, 0)),
            pl.BlockSpec((4 * H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((Bb, n, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n, H), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), wx.astype(jnp.float32), wh.astype(jnp.float32),
      b.astype(jnp.float32))
    return out[:B]
