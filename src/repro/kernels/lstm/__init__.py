from repro.kernels.lstm.ops import lstm_sequence
from repro.kernels.lstm.ref import lstm_sequence_ref
