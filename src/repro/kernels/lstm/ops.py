import jax

from repro.kernels.lstm.kernel import lstm_sequence_pallas
from repro.kernels.lstm.ref import lstm_sequence_ref


def lstm_sequence(x, wx, wh, b, *, use_kernel=True):
    if not use_kernel:
        return lstm_sequence_ref(x, wx, wh, b)
    interpret = jax.default_backend() != "tpu"
    return lstm_sequence_pallas(x, wx, wh, b, interpret=interpret)
