"""Stage-I P/Q overlap-feature kernel.

Computes, per query, the count-overlap P(C, B_j) and score-overlap sum for
every (cluster, bin) pair from the sparse top-k result list. The (N, v)
accumulators live in VMEM (8192 x 8 x 4B = 256 KiB); the k result entries
are folded in with one-hot accumulation over bin columns — a dense
(k_blk, N) x scatter-free formulation that maps onto the VPU instead of
serial scalar stores.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _overlap_kernel(c_ref, b_ref, s_ref, p_ref, q_ref, *, n_clusters, v):
    # c_ref: (1, k) cluster ids; b_ref: (1, k) bin ids; s_ref: (1, k) scores
    k = c_ref.shape[1]
    c = c_ref[0, :]
    bi = b_ref[0, :]
    s = s_ref[0, :]
    # accumulate one bin at a time: mask entries of this bin, one-hot over
    # clusters via comparison against the cluster-id iota (vectorized).
    p_acc = jnp.zeros((n_clusters, v), jnp.float32)
    q_acc = jnp.zeros((n_clusters, v), jnp.float32)
    cl_iota = jax.lax.broadcasted_iota(jnp.int32, (n_clusters, k), 0)
    onehot = (cl_iota == c[None, :]).astype(jnp.float32)     # (N, k)
    for j in range(v):
        m = (bi == j).astype(jnp.float32)                    # (k,)
        p_acc = p_acc.at[:, j].set(onehot @ m)
        q_acc = q_acc.at[:, j].set(onehot @ (m * s))
    p_ref[0] = p_acc
    q_ref[0] = q_acc


@functools.partial(jax.jit, static_argnames=("n_clusters", "v", "interpret"))
def bin_overlap_pallas(cluster_of, bin_ids, scores, *, n_clusters, v,
                       interpret=True):
    """cluster_of: (B, k) int32; bin_ids: (B, k) int32; scores: (B, k).

    Returns (P, Qsum, count): P (B, N, v) counts and Q (B, N, v) mean scores.
    """
    B, k = cluster_of.shape
    kern = functools.partial(_overlap_kernel, n_clusters=n_clusters, v=v)
    P, Qs = pl.pallas_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, k), lambda b: (b, 0)),
            pl.BlockSpec((1, k), lambda b: (b, 0)),
            pl.BlockSpec((1, k), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_clusters, v), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, n_clusters, v), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_clusters, v), jnp.float32),
            jax.ShapeDtypeStruct((B, n_clusters, v), jnp.float32),
        ],
        interpret=interpret,
    )(cluster_of, bin_ids, scores.astype(jnp.float32))
    Q = Qs / jnp.maximum(P, 1.0)
    return P, Q
