"""Oracle for bin_overlap: segment_sum over combined (cluster, bin) slots."""

import jax
import jax.numpy as jnp


def bin_overlap_ref(cluster_of, bin_ids, scores, *, n_clusters, v):
    B, k = cluster_of.shape
    slot = cluster_of * v + bin_ids

    def one(sl, sc):
        cnt = jax.ops.segment_sum(jnp.ones((k,), jnp.float32), sl,
                                  num_segments=n_clusters * v)
        ssum = jax.ops.segment_sum(sc.astype(jnp.float32), sl,
                                   num_segments=n_clusters * v)
        P = cnt.reshape(n_clusters, v)
        Q = (ssum / jnp.maximum(cnt, 1.0)).reshape(n_clusters, v)
        return P, Q

    return jax.vmap(one)(slot, scores)
