from repro.kernels.bin_overlap.ops import bin_overlap
from repro.kernels.bin_overlap.ref import bin_overlap_ref
