import jax

from repro.kernels.bin_overlap.kernel import bin_overlap_pallas
from repro.kernels.bin_overlap.ref import bin_overlap_ref


def bin_overlap(cluster_of, bin_ids, scores, *, n_clusters, v,
                use_kernel=True):
    if not use_kernel:
        return bin_overlap_ref(cluster_of, bin_ids, scores,
                               n_clusters=n_clusters, v=v)
    interpret = jax.default_backend() != "tpu"
    return bin_overlap_pallas(cluster_of, bin_ids, scores,
                              n_clusters=n_clusters, v=v, interpret=interpret)
