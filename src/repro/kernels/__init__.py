"""Pallas TPU kernels for the compute hot-spots of CluSD + substrates.

Each kernel package has:
  kernel.py — pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True on CPU, compiled on TPU)
  ref.py    — pure-jnp oracle used by tests/benchmarks

Kernels:
  lstm          — fused LSTM-selector sequence (paper Stage II hot loop)
  cluster_score — selected-cluster block gather + dot + running top-k
                  (paper Step 3: partial dense retrieval)
  adc           — PQ asymmetric-distance scoring: per-query LUT build +
                  uint8 code-block gather/accumulate (v2 serving fast path)
  topk          — blocked top-k merge over score tiles
  embedding_bag — recsys gather+pool (JAX has no native EmbeddingBag)
  bin_overlap   — P/Q sparse-result x cluster overlap features (Stage I)

See README.md in this directory for the per-kernel contracts (ADC LUT
layout and the accumulation-order guarantee live there).
"""
