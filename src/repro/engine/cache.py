"""Bounded LRU cache of hot cluster blocks, keyed by cluster id.

Thread-safe: the serving thread and the background prefetcher share one
instance. Tracks hit/miss/eviction counts so benchmarks can report cache
effectiveness (BENCH_serve.json `cache_hit_rate`).

Two capacity modes:

  * entry count (`capacity`) — the original bound: at most N blocks,
    whatever their size.
  * byte budget (`capacity_bytes`) — bounds the ACTUAL bytes stored
    (`block.nbytes`), so what fits depends on what is cached: a PQ code
    block (cap x nsub uint8) is ~4*dim/nsub times smaller than its float
    block, and a byte-budgeted cache holds that many more clusters. The
    engine sizes the budget in float32-block equivalents, which keeps
    float-store behavior identical while code-backed stores gain the
    density win. `cached_bytes` in stats() reports the live total.

Exactly one bound must be set; with both modes' counters exposed the
benchmarks can compare hit rates at a fixed byte budget across formats.
"""

import collections
import threading


class BlockCache:
    def __init__(self, capacity=None, capacity_bytes=None):
        if capacity is None and capacity_bytes is None:
            raise ValueError("need capacity (entries) or capacity_bytes")
        if capacity is not None and capacity_bytes is not None:
            raise ValueError("pass capacity OR capacity_bytes, not both")
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError(
                f"capacity_bytes must be >= 1, got {capacity_bytes}")
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self._blocks = collections.OrderedDict()   # cid -> block array
        self._lock = threading.Lock()
        self._fetch_lock = threading.Lock()        # single-flight miss fills
        self.cached_bytes = 0    # actual stored bytes (sum of block.nbytes)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.clears = 0      # full invalidations (index generation swaps)

    def __len__(self):
        with self._lock:
            return len(self._blocks)

    def __contains__(self, cid):
        with self._lock:
            return cid in self._blocks

    def get(self, cid):
        """Block for `cid` (refreshing recency) or None on miss."""
        with self._lock:
            blk = self._blocks.get(cid)
            if blk is None:
                self.misses += 1
                return None
            self._blocks.move_to_end(cid)
            self.hits += 1
            return blk

    def _peek(self, cid):
        """Like get() but without hit/miss accounting (internal re-checks
        and prefetch probes must not skew serving-path stats)."""
        with self._lock:
            blk = self._blocks.get(cid)
            if blk is not None:
                self._blocks.move_to_end(cid)
            return blk

    def get_or_fetch_many(self, cids, fetch_fn, record=True):
        """{cid: block} for every cid; misses are filled via
        `fetch_fn(list_of_cids) -> (n, ...) array` under a
        single-flight lock, so a concurrent prefetcher and the serving
        thread never read the same cold block twice. `record=False`
        skips hit/miss accounting (prefetch path)."""
        out, misses, pending = {}, [], set()
        for c in cids:
            c = int(c)
            if c in out or c in pending:
                continue
            blk = self.get(c) if record else self._peek(c)
            if blk is None:
                misses.append(c)
                pending.add(c)
            else:
                out[c] = blk
        if misses:
            with self._fetch_lock:
                # another thread may have filled some while we waited
                need = []
                for c in misses:
                    blk = self._peek(c)
                    if blk is None:
                        need.append(c)
                    else:
                        out[c] = blk
                if need:
                    vecs = fetch_fn(need)
                    for i, c in enumerate(need):
                        # copy: caching a view of the batch-fetch array
                        # would pin the whole buffer past eviction
                        out[c] = vecs[i].copy()
                        self.put(c, out[c])
        return out

    @staticmethod
    def _nbytes(block):
        return int(getattr(block, "nbytes", 0))

    def _over_budget(self):
        if self.capacity is not None and len(self._blocks) > self.capacity:
            return True
        return self.capacity_bytes is not None \
            and self.cached_bytes > self.capacity_bytes

    def put(self, cid, block):
        with self._lock:
            old = self._blocks.pop(cid, None)    # re-insert at most-recent end
            if old is not None:
                self.cached_bytes -= self._nbytes(old)
            self._blocks[cid] = block
            self.cached_bytes += self._nbytes(block)
            while self._over_budget() and len(self._blocks) > 1:
                _, evicted = self._blocks.popitem(last=False)
                self.cached_bytes -= self._nbytes(evicted)
                self.evictions += 1

    def keys(self):
        """Cluster ids, least- to most-recently used."""
        with self._lock:
            return list(self._blocks.keys())

    def hit_rate(self):
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self):
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "clears": self.clears,
                "size": len(self), "cached_bytes": self.cached_bytes,
                "capacity": self.capacity,
                "capacity_bytes": self.capacity_bytes,
                "hit_rate": round(self.hit_rate(), 4)}

    def clear(self):
        """Drop every cached block (cluster ids name different blocks after
        an index generation swap — RetrievalEngine.reload_index calls this
        under its swap lock). Hit/miss counters are preserved; `clears`
        records the invalidation."""
        with self._lock:
            self._blocks.clear()
            self.cached_bytes = 0
            self.clears += 1
