"""Unified retrieval serving layer: one select/score/fuse pipeline behind
pluggable cluster-block storage backends, plus a serving front-end with
bucketed batching, an LRU block cache, and async prefetch. See README.md
in this directory for the backend protocol and knobs."""

from repro.engine.cache import BlockCache
from repro.engine.pipeline import (
    fetch_unique_blocks, retrieve, score_and_fuse, score_selected,
    score_selected_host)
from repro.engine.router import (
    MERGE_SENTINEL, EngineHost, HostDown, HostRequest, HostResponse,
    ShardPlacement, ShardRouter, merge_partial_topk)
from repro.engine.server import RetrievalEngine, ServeStats, bucket_size
from repro.engine.stores import (
    ClusterStore, DiskStore, InMemoryStore, PQStore, ShardedDiskStore,
    ShardedPQStore, store_for_index)

__all__ = [
    "BlockCache", "ClusterStore", "DiskStore", "EngineHost", "HostDown",
    "HostRequest", "HostResponse", "InMemoryStore", "MERGE_SENTINEL",
    "PQStore", "RetrievalEngine", "ServeStats", "ShardPlacement",
    "ShardRouter", "ShardedDiskStore", "ShardedPQStore", "bucket_size",
    "fetch_unique_blocks", "merge_partial_topk", "retrieve",
    "score_and_fuse", "score_selected", "score_selected_host",
    "store_for_index",
]
