"""Pluggable cluster-block storage backends behind one protocol.

Every backend answers the same question — "give me the embedding blocks for
these clusters" — so the select/score/fuse pipeline (engine/pipeline.py) is
written once and parameterized by the store:

  fetch_blocks(cluster_ids) -> (vecs, docs, valid)
    cluster_ids : int array; device stores accept any leading batch shape
                  (jit-traceable), host stores take a 1-D host sequence.
    vecs  : (..., cap, dim) float32 block embeddings
    docs  : (..., cap)      int32 doc ids, -1 pad
    valid : (..., cap)      bool  (docs >= 0)

Backends additionally expose:
  cluster_docs : (N, cap) doc-id table (device array)
  is_host      : True when fetch_blocks does host I/O (not jit-traceable);
                 the pipeline then batches selection on device and fetches
                 deduplicated blocks on the host.
  is_coded     : True when the backend's native records are PQ codes; it
                 then also exposes `fetch_code_blocks(cluster_ids) ->
                 (codes, docs, valid)` returning RAW (..., cap, nsub)
                 uint8 code blocks plus `codebooks`/`rotation`/`nsub`, so
                 the pipeline can score codes directly via ADC lookup
                 tables (repro.kernels.adc) without ever decoding floats.
  score_docs(q_dense, doc_ids) [optional] : backend-native scoring kernel
                 (dense gather+dot, PQ ADC); the pipeline prefers it on the
                 device path so numerics match the pre-engine code exactly.

Five backends speak the protocol: InMemoryStore and PQStore (device),
DiskStore, and — re-exported from repro.index.sharded — ShardedDiskStore
(format-v1 float block shards) and ShardedPQStore (format-v2 PQ code
shards, decode-on-fetch ADC). The sharded stores additionally accept an
incrementally-updated index's tombstone bitmap and mask deleted slots at
fetch time (docs=-1/valid=False; the shard bytes are never rewritten for
a delete). The full contract — fetch semantics, IOStats run-counting,
thread safety — is documented in engine/README.md.
"""

from typing import Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import quant as quant_lib
from repro.core.disk import DiskClusterStore, IOStats
from repro.index.sharded import ShardedDiskStore, ShardedPQStore  # noqa: F401


@runtime_checkable
class ClusterStore(Protocol):
    is_host: bool

    def fetch_blocks(self, cluster_ids):
        """-> (vecs, docs, valid); see module docstring."""
        ...


class InMemoryStore:
    """Device-resident embeddings; fetch is a jit-friendly gather."""

    is_host = False
    is_coded = False

    def __init__(self, embeddings, cluster_docs):
        self.embeddings = embeddings          # (D, dim)
        self.cluster_docs = cluster_docs      # (N, cap)

    def fetch_blocks(self, cluster_ids):
        docs = jnp.take(self.cluster_docs, cluster_ids, axis=0)
        valid = docs >= 0
        vecs = jnp.take(self.embeddings, jnp.where(valid, docs, 0), axis=0)
        vecs = jnp.where(valid[..., None], vecs, 0.0)
        return vecs, docs, valid

    def score_docs(self, q_dense, doc_ids):
        """(B, dim) x (B, K) -> (B, K) exact dot scores."""
        vecs = jnp.take(self.embeddings, doc_ids, axis=0)
        return jnp.einsum("bd,bkd->bk", q_dense, vecs)


class PQStore:
    """Product-quantized embeddings; scoring via ADC lookup tables,
    block fetch via codebook reconstruction (identical scores up to fp).

    Code-backed (`is_coded`): `fetch_code_blocks` gathers raw per-cluster
    code blocks so the jit'd pipeline can score codes in-kernel, never
    reconstructing float embeddings on the scoring path."""

    is_host = False
    is_coded = True

    def __init__(self, pq, cluster_docs):
        self.pq = pq
        self.cluster_docs = cluster_docs

    @property
    def codebooks(self):
        return self.pq.codebooks

    @property
    def rotation(self):
        return self.pq.rotation

    @property
    def nsub(self):
        return self.pq.nsub

    def fetch_code_blocks(self, cluster_ids):
        """-> (codes, docs, valid): (..., cap, nsub) code blocks, padded
        slots coded as doc 0 but masked by valid. Jit-traceable."""
        docs = jnp.take(self.cluster_docs, cluster_ids, axis=0)
        valid = docs >= 0
        codes = jnp.take(self.pq.codes, jnp.where(valid, docs, 0), axis=0)
        return codes, docs, valid

    def fetch_blocks(self, cluster_ids):
        docs = jnp.take(self.cluster_docs, cluster_ids, axis=0)
        valid = docs >= 0
        flat = jnp.where(valid, docs, 0).reshape(-1)
        vecs = quant_lib.reconstruct(self.pq, flat)
        vecs = vecs.reshape(docs.shape + (vecs.shape[-1],))
        vecs = jnp.where(valid[..., None], vecs, 0.0)
        return vecs, docs, valid

    def score_docs(self, q_dense, doc_ids):
        lut = quant_lib.adc_tables(self.pq, q_dense)
        return quant_lib.adc_score(self.pq, lut, doc_ids)


class DiskStore:
    """On-disk cluster blocks (wraps core.disk.DiskClusterStore).

    fetch_blocks takes a 1-D host sequence of cluster ids and reads one
    block per id, counting I/O ops/bytes into `stats` (thread-safe, so a
    background prefetcher can share the store with the serving thread).
    """

    is_host = True
    is_coded = False

    def __init__(self, block_store: DiskClusterStore, cluster_docs,
                 stats: IOStats = None):
        import threading
        self.blocks = block_store
        self.cluster_docs = cluster_docs
        self.cluster_docs_np = np.asarray(cluster_docs)
        self.stats = stats if stats is not None else IOStats()
        self._lock = threading.Lock()

    @classmethod
    def create(cls, path, embeddings, cluster_docs, **kw):
        return cls(DiskClusterStore(path, embeddings, cluster_docs),
                   cluster_docs, **kw)

    @property
    def block_bytes(self):
        return self.blocks.block_bytes

    @property
    def cap(self):
        return self.blocks.cap

    @property
    def dim(self):
        return self.blocks.dim

    def fetch_blocks(self, cluster_ids):
        cluster_ids = np.asarray(cluster_ids, np.int64).reshape(-1)
        docs = self.cluster_docs_np[cluster_ids]
        if len(cluster_ids) == 0:
            return (np.zeros((0, self.blocks.cap, self.blocks.dim), np.float32),
                    docs, docs >= 0)
        local = IOStats()
        vecs = np.asarray(self.blocks.fetch_clusters(cluster_ids, local))
        with self._lock:
            self.stats.add(local.n_ops, local.bytes, local.wall_ms)
        return vecs, docs, docs >= 0


def store_for_index(index):
    """Default device store for a CluSDIndex: PQ if quantized, else dense."""
    if getattr(index, "quantizer", None) is not None:
        return PQStore(index.quantizer, index.cluster_docs)
    return InMemoryStore(index.embeddings, index.cluster_docs)
