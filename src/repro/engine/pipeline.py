"""The single CluSD select/score/fuse pipeline, parameterized by a
ClusterStore backend (engine/stores.py).

Pre-engine, the repo had three copies of this logic — in-memory
(core/clusd.py), on-disk with a per-query Python loop (core/disk.py), and
PQ (core/quant.py). They now all route here:

  retrieve(cfg, index, store, ...) =
      sparse retrieval
      -> Stage I/II cluster selection (core/clusd.py, batched over queries)
      -> dense scoring of the selected cluster blocks via `store`
      -> min-max fusion + global top-k

Scoring has two shapes:
  * device stores (InMemoryStore, PQStore): a jit-traceable gather/ADC over
    (B, S) selected clusters — identical numerics to the pre-engine code.
  * host stores (DiskStore): selection still runs batched on device; block
    I/O is ONE deduplicated fetch for the whole query batch (optionally
    through a BlockCache), replacing the old per-query read loop.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import clusd as clusd_lib
from repro.core import fusion as fusion_lib
from repro.core import sparse as sparse_lib


# ---------------------------------------------------------------------------
# dense scoring of selected clusters
# ---------------------------------------------------------------------------

def score_selected(store, q_dense, sel_ids, sel_mask):
    """Device-store scoring (jit-traceable).

    q_dense (B, dim); sel_ids/sel_mask (B, S).
    Returns (doc_ids (B, S*cap) int32, scores with -inf at invalid, valid).
    """
    docs = jnp.take(store.cluster_docs, sel_ids, axis=0)     # (B, S, cap)
    B, S, cap = docs.shape
    valid = (docs >= 0) & sel_mask[:, :, None]
    docs_flat = jnp.where(valid, docs, 0).reshape(B, S * cap)
    scorer = getattr(store, "score_docs", None)
    if scorer is not None:
        scores = scorer(q_dense, docs_flat)
    else:
        vecs, _, _ = store.fetch_blocks(sel_ids)             # (B, S, cap, dim)
        scores = jnp.einsum("bd,bscd->bsc", q_dense, vecs).reshape(B, S * cap)
    scores = jnp.where(valid.reshape(B, S * cap), scores, -jnp.inf)
    return docs_flat.astype(jnp.int32), scores, valid.reshape(B, S * cap)


def fetch_unique_blocks(store, uniq, cache=None):
    """Fetch blocks for sorted unique cluster ids, through the LRU cache
    when given. Only cache misses hit the store (and count as I/O ops).
    Returns (U, cap, dim) float32."""
    if cache is None:
        vecs, _, _ = store.fetch_blocks(uniq)
        return np.asarray(vecs)
    got = cache.get_or_fetch_many(
        uniq, lambda cids: np.asarray(store.fetch_blocks(np.asarray(cids))[0]))
    return np.stack([got[int(c)] for c in uniq])


def score_selected_host(store, q_dense, sel_ids, sel_mask, cache=None,
                        use_kernel=False):
    """Host-store scoring: dedup selected cluster ids across the whole query
    batch, fetch each block at most once, then score on device. Mirrors
    `score_selected`'s contract exactly.

    use_kernel routes the block dot products through the cluster_score
    Pallas kernel over the (U, cap, dim) unique-block tensor — the per-slot
    block gather happens in the kernel's DMA index_map instead of a
    materialized (B, S, cap, dim) jnp.take."""
    sel = np.asarray(sel_ids)
    mask = np.asarray(sel_mask)
    B, S = sel.shape
    docs = store.cluster_docs_np[sel]                        # (B, S, cap)
    cap = docs.shape[-1]
    valid = (docs >= 0) & mask[:, :, None]
    if mask.any():
        uniq = np.unique(sel[mask])
        blocks = fetch_unique_blocks(store, uniq, cache)     # (U, cap, dim)
        pos = np.searchsorted(uniq, np.where(mask, sel, uniq[0]))
        if use_kernel:
            from repro.kernels.cluster_score import cluster_score
            scores = cluster_score(
                jnp.asarray(q_dense), jnp.asarray(blocks),
                jnp.asarray(pos, jnp.int32)).reshape(B, S * cap)
        else:
            # ship only the U unique blocks to device; expand by gather there
            vecs = jnp.take(jnp.asarray(blocks), jnp.asarray(pos), axis=0)
            scores = jnp.einsum("bd,bscd->bsc", q_dense,
                                vecs).reshape(B, S * cap)
    else:
        scores = jnp.zeros((B, S * cap), jnp.float32)
    valid_flat = jnp.asarray(valid.reshape(B, S * cap))
    scores = jnp.where(valid_flat, scores, -jnp.inf)
    docs_flat = jnp.asarray(np.where(valid, docs, 0).reshape(B, S * cap))
    return docs_flat.astype(jnp.int32), scores, valid_flat


# ---------------------------------------------------------------------------
# fusion + full pipeline
# ---------------------------------------------------------------------------

def score_and_fuse(cfg, index, store, q_dense, sparse_ids, sparse_scores,
                   sel_ids, sel_mask, *, k=None, cache=None,
                   use_kernel=False):
    """Step 3: dense-score the selected clusters via `store`, fuse with the
    sparse results. Returns (ids, scores, dmask)."""
    k = k or cfg.k_final
    if getattr(store, "is_host", False):
        did, dscore, dmask = score_selected_host(store, q_dense, sel_ids,
                                                 sel_mask, cache=cache,
                                                 use_kernel=use_kernel)
    else:
        did, dscore, dmask = score_selected(store, q_dense, sel_ids, sel_mask)
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, did, jnp.where(dmask, dscore, 0.0), dmask,
        index.n_docs, cfg.alpha, k)
    return ids, scores, dmask


def retrieve(cfg, index, store, q_dense, q_terms, q_weights, *,
             selector="lstm", stage1="overlap", theta=None, use_kernel=False,
             selector_params=None, k=None, cache=None):
    """Full CluSD pipeline against any backend. Returns (ids, scores, diag).

    Jit-able end to end for device stores; for host stores selection runs
    on device and block fetch/score runs eagerly (call outside jit).
    """
    k = k or cfg.k_final
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    sel = clusd_lib.select_clusters(cfg, index, q_dense, sparse_ids,
                                    sparse_scores, selector=selector,
                                    stage1=stage1, theta=theta,
                                    use_kernel=use_kernel,
                                    selector_params=selector_params)
    ids, scores, dmask = score_and_fuse(
        cfg, index, store, q_dense, sparse_ids, sparse_scores,
        sel["sel_ids"], sel["sel_mask"], k=k, cache=cache,
        use_kernel=use_kernel)
    diag = {
        "n_selected": jnp.sum(sel["sel_mask"], axis=1),
        "frac_docs_scanned": jnp.mean(dmask.astype(jnp.float32), axis=1)
        * dmask.shape[1] / index.n_docs,
        "sparse_ids": sparse_ids, "sparse_scores": sparse_scores,
        **{k_: sel[k_] for k_ in ("cand", "probs", "sel_ids", "sel_mask")},
    }
    return ids, scores, diag
