"""The single CluSD select/score/fuse pipeline, parameterized by a
ClusterStore backend (engine/stores.py).

Pre-engine, the repo had three copies of this logic — in-memory
(core/clusd.py), on-disk with a per-query Python loop (core/disk.py), and
PQ (core/quant.py). They now all route here:

  retrieve(cfg, index, store, ...) =
      sparse retrieval
      -> Stage I/II cluster selection (core/clusd.py, batched over queries)
      -> dense scoring of the selected cluster blocks via `store`
      -> min-max fusion + global top-k

Scoring has two shapes:
  * device stores (InMemoryStore, PQStore): a jit-traceable gather/ADC over
    (B, S) selected clusters — identical numerics to the pre-engine code.
  * host stores (DiskStore): selection still runs batched on device; block
    I/O is ONE deduplicated fetch for the whole query batch (optionally
    through a BlockCache), replacing the old per-query read loop.

The serving engine (engine/server.py) drives host stores through the
FUSED path instead of eager `score_and_fuse`: `dedup_selected` +
`fetch_unique_blocks`/`fetch_unique_code_blocks` stay on the host, and
`build_fused_scorer` compiles score -> mask -> fuse -> top-k into ONE
jitted pass per request bucket (unique-block count padded to power-of-two
so compilations stay bounded). For code-backed stores (`is_coded`) the
fused pass scores raw PQ codes via ADC lookup tables
(repro.kernels.adc) — floats are never decoded on the host.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clusd as clusd_lib
from repro.core import fusion as fusion_lib
from repro.core import sparse as sparse_lib
from repro.kernels import adc as adc_ops
from repro.obs import NOOP_TRACE


# ---------------------------------------------------------------------------
# compiled stage builders (shared by RetrievalEngine and ShardRouter)
# ---------------------------------------------------------------------------
# Each returns a fresh jitted fn closing over (cfg, index/codebooks) AS
# PASSED — callers key them per request bucket and drop them when the
# closed-over state moves (index reloads; selector publishes for stage2).

def build_stage1_fn(cfg, index):
    """Sparse retrieval + Stage-I candidate generation.
    fn(qd, qt, qw) -> (sparse_ids, sparse_scores, cand, feats)."""
    def run(qd, qt, qw):
        sid, ss = sparse_lib.sparse_retrieve_topk(
            index.sparse_index, qt, qw, cfg.k_sparse)
        s1 = clusd_lib.stage1_candidates(cfg, index, qd, sid, ss)
        return sid, ss, s1["cand"], s1["feats"]
    return jax.jit(run)


def build_stage2_fn(cfg, index):
    """Stage-II LSTM cluster selection.
    fn(cand, feats) -> (sel_ids, sel_mask, probs) — probs are the raw
    per-candidate selector probabilities (explain telemetry compares them
    against theta/budget; they are computed anyway, so returning them is
    free)."""
    def run(cand, feats):
        s2 = clusd_lib.stage2_select(cfg, index, cand, feats)
        return s2["sel_ids"], s2["sel_mask"], s2["probs"]
    return jax.jit(run)


def build_lut_fn(codebooks, rotation):
    """Per-query ADC LUT build (OPQ rotation folded in).
    fn(qd) -> (B, nsub, 256) float32."""
    cb = jnp.asarray(codebooks)
    rot = None if rotation is None else jnp.asarray(rotation)
    return jax.jit(lambda qd: adc_ops.adc_tables(qd, cb, rot))


# ---------------------------------------------------------------------------
# dense scoring of selected clusters
# ---------------------------------------------------------------------------

def score_selected(store, q_dense, sel_ids, sel_mask):
    """Device-store scoring (jit-traceable).

    q_dense (B, dim); sel_ids/sel_mask (B, S).
    Returns (doc_ids (B, S*cap) int32, scores with -inf at invalid, valid).
    """
    docs = jnp.take(store.cluster_docs, sel_ids, axis=0)     # (B, S, cap)
    B, S, cap = docs.shape
    valid = (docs >= 0) & sel_mask[:, :, None]
    docs_flat = jnp.where(valid, docs, 0).reshape(B, S * cap)
    scorer = getattr(store, "score_docs", None)
    if scorer is not None:
        scores = scorer(q_dense, docs_flat)
    else:
        vecs, _, _ = store.fetch_blocks(sel_ids)             # (B, S, cap, dim)
        scores = jnp.einsum("bd,bscd->bsc", q_dense, vecs).reshape(B, S * cap)
    scores = jnp.where(valid.reshape(B, S * cap), scores, -jnp.inf)
    return docs_flat.astype(jnp.int32), scores, valid.reshape(B, S * cap)


def fetch_unique_blocks(store, uniq, cache=None, trace=None):
    """Fetch blocks for sorted unique cluster ids, through the LRU cache
    when given. Only cache misses hit the store (and count as I/O ops).
    Returns (U, cap, dim) float32. `trace` (a repro.obs Trace) wraps the
    store reads in nested `disk_fetch` spans — cache hits emit none."""
    tr = trace if trace is not None else NOOP_TRACE

    def fill(cids):
        with tr.span("disk_fetch", n_blocks=len(cids)):
            return np.asarray(store.fetch_blocks(np.asarray(cids))[0])

    if cache is None:
        return fill(uniq)
    got = cache.get_or_fetch_many(uniq, fill)
    return np.stack([got[int(c)] for c in uniq])


def fetch_unique_code_blocks(store, uniq, cache=None, trace=None):
    """Raw-code sibling of `fetch_unique_blocks` for code-backed stores:
    returns (U, cap, nsub) uint8 — no decode happens anywhere on this
    path, and the cache holds CODE blocks (4*dim/nsub more clusters per
    cache byte than float blocks under a byte budget)."""
    tr = trace if trace is not None else NOOP_TRACE

    def fill(cids):
        with tr.span("disk_fetch", n_blocks=len(cids)):
            return np.asarray(store.fetch_code_blocks(np.asarray(cids))[0])

    if cache is None:
        return fill(uniq)
    got = cache.get_or_fetch_many(uniq, fill)
    return np.stack([got[int(c)] for c in uniq])


def dedup_selected(sel_ids, sel_mask):
    """Host-side dedup of the batch's selected clusters.

    -> (uniq (U,) int64 sorted unique cluster ids — never empty: an
    all-masked selection yields a single placeholder id 0 so downstream
    shapes stay static — and pos (B, S) positions into uniq; masked slots
    point at uniq[0] and are dropped by the validity mask later)."""
    sel = np.asarray(sel_ids)
    mask = np.asarray(sel_mask)
    if mask.any():
        uniq = np.unique(sel[mask])
    else:
        uniq = np.zeros((1,), np.int64)
    pos = np.searchsorted(uniq, np.where(mask, sel, uniq[0]))
    return uniq, pos.astype(np.int32)


def build_fused_scorer(cfg, index, store, *, k, mode):
    """Compile score -> mask -> fuse -> top-k into one jitted pass.

    mode "adc":  blocks are (U, cap, nsub) uint8 PQ codes and q_or_lut is
                 the (B, nsub, 256) ADC lookup table (adc_tables, built
                 once per batch — the OPQ rotation is already folded in).
    mode "dot":  blocks are (U, cap, dim) float and q_or_lut is (B, dim).

    The returned fn(q_or_lut, sid, ss, sel_ids, sel_mask, blocks, pos)
    -> (ids, scores) closes over cfg/cluster_docs (including the fusion
    method/rrf_k), so the engine must drop it on index reloads (and on
    selector reloads: cfg is re-read)."""
    n_docs, alpha = index.n_docs, cfg.alpha
    method, rrf_k = cfg.fusion, cfg.rrf_k
    cluster_docs = index.cluster_docs

    def run(q_or_lut, sid, ss, sel_ids, sel_mask, blocks, pos):
        docs = jnp.take(cluster_docs, sel_ids, axis=0)         # (B, S, cap)
        B, S, cap = docs.shape
        valid = (docs >= 0) & sel_mask[:, :, None]
        if mode == "adc":
            scores3 = adc_ops.adc_score_blocks(q_or_lut, blocks, pos)
        else:
            vecs = jnp.take(blocks, pos, axis=0)               # (B,S,cap,dim)
            scores3 = jnp.einsum("bd,bscd->bsc", q_or_lut, vecs)
        vf = valid.reshape(B, S * cap)
        dscore = jnp.where(vf, scores3.reshape(B, S * cap), 0.0)
        did = jnp.where(valid, docs, 0).reshape(B, S * cap).astype(jnp.int32)
        return fusion_lib.fuse_topk(sid, ss, did, dscore, vf,
                                    n_docs, alpha, k,
                                    method=method, rrf_k=rrf_k)

    return jax.jit(run)


def score_selected_host(store, q_dense, sel_ids, sel_mask, cache=None,
                        use_kernel=False):
    """Host-store scoring: dedup selected cluster ids across the whole query
    batch, fetch each block at most once, then score on device. Mirrors
    `score_selected`'s contract exactly.

    use_kernel routes the block dot products through the cluster_score
    Pallas kernel over the (U, cap, dim) unique-block tensor — the per-slot
    block gather happens in the kernel's DMA index_map instead of a
    materialized (B, S, cap, dim) jnp.take."""
    sel = np.asarray(sel_ids)
    mask = np.asarray(sel_mask)
    B, S = sel.shape
    docs = store.cluster_docs_np[sel]                        # (B, S, cap)
    cap = docs.shape[-1]
    valid = (docs >= 0) & mask[:, :, None]
    if mask.any():
        uniq = np.unique(sel[mask])
        blocks = fetch_unique_blocks(store, uniq, cache)     # (U, cap, dim)
        pos = np.searchsorted(uniq, np.where(mask, sel, uniq[0]))
        if use_kernel:
            from repro.kernels.cluster_score import cluster_score
            scores = cluster_score(
                jnp.asarray(q_dense), jnp.asarray(blocks),
                jnp.asarray(pos, jnp.int32)).reshape(B, S * cap)
        else:
            # ship only the U unique blocks to device; expand by gather there
            vecs = jnp.take(jnp.asarray(blocks), jnp.asarray(pos), axis=0)
            scores = jnp.einsum("bd,bscd->bsc", q_dense,
                                vecs).reshape(B, S * cap)
    else:
        scores = jnp.zeros((B, S * cap), jnp.float32)
    valid_flat = jnp.asarray(valid.reshape(B, S * cap))
    scores = jnp.where(valid_flat, scores, -jnp.inf)
    docs_flat = jnp.asarray(np.where(valid, docs, 0).reshape(B, S * cap))
    return docs_flat.astype(jnp.int32), scores, valid_flat


# ---------------------------------------------------------------------------
# fusion + full pipeline
# ---------------------------------------------------------------------------

def score_and_fuse(cfg, index, store, q_dense, sparse_ids, sparse_scores,
                   sel_ids, sel_mask, *, k=None, cache=None,
                   use_kernel=False):
    """Step 3: dense-score the selected clusters via `store`, fuse with the
    sparse results. Returns (ids, scores, dmask)."""
    k = k or cfg.k_final
    if getattr(store, "is_host", False):
        did, dscore, dmask = score_selected_host(store, q_dense, sel_ids,
                                                 sel_mask, cache=cache,
                                                 use_kernel=use_kernel)
    else:
        did, dscore, dmask = score_selected(store, q_dense, sel_ids, sel_mask)
    ids, scores = fusion_lib.fuse_topk(
        sparse_ids, sparse_scores, did, jnp.where(dmask, dscore, 0.0), dmask,
        index.n_docs, cfg.alpha, k, method=cfg.fusion, rrf_k=cfg.rrf_k)
    return ids, scores, dmask


def retrieve(cfg, index, store, q_dense, q_terms, q_weights, *,
             selector="lstm", stage1="overlap", theta=None, use_kernel=False,
             selector_params=None, k=None, cache=None):
    """Full CluSD pipeline against any backend. Returns (ids, scores, diag).

    Jit-able end to end for device stores; for host stores selection runs
    on device and block fetch/score runs eagerly (call outside jit).
    """
    k = k or cfg.k_final
    sparse_ids, sparse_scores = sparse_lib.sparse_retrieve_topk(
        index.sparse_index, q_terms, q_weights, cfg.k_sparse)
    sel = clusd_lib.select_clusters(cfg, index, q_dense, sparse_ids,
                                    sparse_scores, selector=selector,
                                    stage1=stage1, theta=theta,
                                    use_kernel=use_kernel,
                                    selector_params=selector_params)
    ids, scores, dmask = score_and_fuse(
        cfg, index, store, q_dense, sparse_ids, sparse_scores,
        sel["sel_ids"], sel["sel_mask"], k=k, cache=cache,
        use_kernel=use_kernel)
    diag = {
        "n_selected": jnp.sum(sel["sel_mask"], axis=1),
        "frac_docs_scanned": jnp.mean(dmask.astype(jnp.float32), axis=1)
        * dmask.shape[1] / index.n_docs,
        "sparse_ids": sparse_ids, "sparse_scores": sparse_scores,
        **{k_: sel[k_] for k_ in ("cand", "probs", "sel_ids", "sel_mask")},
    }
    return ids, scores, diag
