"""RetrievalEngine: the serving front-end over the unified pipeline.

Serving optimizations on top of engine/pipeline.py:

  * bucketed batching — incoming query batches are padded to power-of-two
    sizes (capped at `max_batch`), so `jax.jit` compiles once per bucket
    instead of once per ragged tail size. Oversize batches are chunked.
  * LRU block cache — for host (disk) stores, fetched cluster blocks land
    in a byte-budgeted BlockCache keyed by cluster id; hot clusters are
    served from memory. The budget is sized in float32-block equivalents
    (`cache_capacity * cap * dim * 4` bytes), so a float store caches
    exactly `cache_capacity` blocks while a code-backed store fits
    ~4*dim/nsub times more clusters in the same budget.
  * async prefetch — a background thread pulls Stage-I candidate cluster
    blocks from disk into the cache while the Stage-II LSTM selection is
    still running, so by the time the selection lands, most selected
    blocks are already cache hits.
  * fused tail — for host stores the whole score -> fuse -> top-k tail
    runs as ONE jitted pass per (batch bucket, unique-block bucket)
    (pipeline.build_fused_scorer) instead of eager per-stage dispatch.
  * ADC serving (`use_adc`, auto-on for code-backed stores): raw PQ codes
    flow disk -> cache -> device and are scored against per-query ADC
    lookup tables (repro.kernels.adc) inside the fused pass — the host
    never decodes a float block; the LUT is built right after Stage I so
    it overlaps the Stage-II selection. Timings surface in stats() as
    `lut_build_ms` / `adc_ms` (and `decode_ms` stays 0 on this path).

Plus zero-downtime index swaps: `reload_index()` hops a serving engine to
a newer committed index generation (repro.index.update) between batches —
the store/arrays are rebuilt from the reader, compiled buckets and the
block cache are invalidated (geometry may have changed), and the prefetch
worker is quiesced across the swap so no stale block can repopulate the
fresh cache. In-flight batches finish on the old generation; no request
ever fails. When only the Stage-II selector moved (repro.train publishes
weights + calibrated thresholds as a generation that rewrites zero corpus
bytes), `reload_selector()` swaps just the LSTM params and theta/budget —
Stage-I compilations, the block cache, and the prefetch worker survive.

Usage:
    engine = RetrievalEngine(cfg, index)                  # in-memory / PQ
    engine = RetrievalEngine(cfg, index, store=DiskStore(...))
    ids, scores = engine.retrieve(q_dense, q_terms, q_weights)
    engine.stats()   # latency percentiles, cache hit rate, I/O counters
    engine.reload_index()   # adopt a newer generation (reader-backed)
    engine.close()
"""

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import pipeline as pipe_lib
from repro.engine import stores as stores_lib
from repro.engine.cache import BlockCache
from repro.obs import NOOP_TRACE, MetricsRegistry, Tracer


def bucket_size(n, max_batch):
    """Smallest power of two >= n, capped at max_batch."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


def _pad_rows(x, n_pad):
    """Pad axis 0 by repeating the last row (keeps ids/terms in range)."""
    if n_pad == 0:
        return x
    return np.concatenate([x, np.repeat(np.asarray(x)[-1:], n_pad, axis=0)])


def build_explain_records(cfg, *, qid_base, generation, n, cand, probs,
                          sel_ids, sel_mask, final_ids, sparse_ids,
                          doc_cluster):
    """Explain records for one served batch (schema in
    docs/OBSERVABILITY.md). Shared by RetrievalEngine and ShardRouter —
    the router appends its own `host_contrib` field afterwards.

    All array args are batch-major; only the first `n` rows (real
    queries, not bucket padding) produce records. `doc_cluster` maps doc
    id -> cluster id and decides dense-side membership for the fusion
    contribution split."""
    cand = np.asarray(cand)[:n]
    probs = np.asarray(probs)[:n]
    sel_np = np.asarray(sel_ids)[:n]
    mask_np = np.asarray(sel_mask)[:n].astype(bool)
    final = np.asarray(final_ids)[:n]
    sid = np.asarray(sparse_ids)[:n]
    dc = np.asarray(doc_cluster)
    n_seed = int(cfg.n_candidates)
    theta = float(cfg.theta)
    records = []
    for i in range(n):
        p = probs[i]
        selected = [int(x) for x in sel_np[i][mask_np[i]]]
        sel_set = set(selected)
        over = int((p >= theta).sum())
        sparse_set = {int(d) for d in sid[i] if int(d) >= 0}
        contrib = {"sparse_only": 0, "dense_only": 0, "both": 0}
        for d in (int(x) for x in final[i] if int(x) >= 0):
            in_sparse = d in sparse_set
            in_dense = d < len(dc) and int(dc[d]) in sel_set
            if in_sparse and in_dense:
                contrib["both"] += 1
            elif in_sparse:
                contrib["sparse_only"] += 1
            elif in_dense:
                contrib["dense_only"] += 1
        records.append({
            "qid": int(qid_base + i),
            "generation": None if generation is None else int(generation),
            "theta": round(theta, 6),
            "budget": int(cfg.max_selected),
            "fusion": cfg.fusion,
            "expand_depth": int(cfg.expand_depth),
            "n_seed": n_seed,
            "cand": [int(x) for x in cand[i]],
            "provenance": ["seed" if j < n_seed else "expand"
                           for j in range(cand.shape[1])],
            "probs": [round(float(x), 4) for x in p],
            "selected": selected,
            "n_over_theta": over,
            "skipped_over_theta": max(0, over - len(selected)),
            "fusion_contrib": contrib,
        })
    return records


@dataclasses.dataclass
class BatchRecord:
    size: int          # real queries in the batch (before padding)
    bucket: int        # padded bucket it ran in
    compiled: bool     # this batch triggered a jit compile for its bucket
    ms: float


class ServeStats:
    """Serving counters, registry-backed and bounded.

    Cumulative counts (queries, batches, compile batches, prefetch,
    reloads, steady time) live as counters in a MetricsRegistry — exact
    over the engine's whole lifetime. Per-batch records land in a ring
    (`deque(maxlen=window)`, default 8192) plus the registry's
    `serve.batch_ms` histogram, so a long soak holds memory constant:
    `latency_percentiles()` / `per_query_ms()` cover the most recent
    `window` steady batches (identical to the old unbounded list until
    the window overflows), while `steady_qps()` stays lifetime-exact
    from the cumulative counters."""

    WINDOW = 8192

    def __init__(self, registry=None, window=WINDOW):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.window = int(window)
        reg = self.registry
        self._queries = reg.counter("serve.queries")
        self._batches = reg.counter("serve.batches")
        self._compile_batches = reg.counter("serve.compile_batches")
        self._steady_queries = reg.counter("serve.steady_queries")
        self._steady_ms = reg.counter("serve.steady_ms")
        self._batch_ms_hist = reg.histogram("serve.batch_ms",
                                            ring=self.window)
        self._prefetch_enqueued = reg.counter("serve.prefetch_enqueued")
        self._prefetch_errors = reg.counter("serve.prefetch_errors")
        self._reloads = reg.counter("serve.reloads")
        self._selector_reloads = reg.counter("serve.selector_reloads")
        self.batches = collections.deque(maxlen=self.window)
        self._compiled_bucket_set = set()

    # cumulative counts read back from the registry
    @property
    def n_queries(self):
        return int(self._queries.value)

    @property
    def n_batches(self):
        return int(self._batches.value)

    @property
    def n_compile_batches(self):
        return int(self._compile_batches.value)

    @property
    def prefetch_enqueued(self):
        return int(self._prefetch_enqueued.value)

    @property
    def prefetch_errors(self):
        return int(self._prefetch_errors.value)

    @property
    def reloads(self):
        return int(self._reloads.value)

    @property
    def selector_reloads(self):
        return int(self._selector_reloads.value)

    def record(self, size, bucket, compiled, ms):
        self._queries.inc(size)
        self._batches.inc()
        if compiled:
            self._compile_batches.inc()
            self._compiled_bucket_set.add(bucket)
        else:
            self._steady_queries.inc(size)
            self._steady_ms.inc(ms)
            self._batch_ms_hist.observe(ms)
        self.batches.append(BatchRecord(size, bucket, compiled, ms))

    def record_prefetch(self, n):
        self._prefetch_enqueued.inc(n)

    def record_prefetch_error(self):
        self._prefetch_errors.inc()

    def record_reload(self):
        self._reloads.inc()

    def record_selector_reload(self):
        self._selector_reloads.inc()

    @property
    def batch_ms(self):
        return [b.ms for b in self.batches]

    @property
    def compiled_buckets(self):
        return sorted(self._compiled_bucket_set)

    def _steady(self):
        return [b for b in self.batches if not b.compiled]

    def per_query_ms(self):
        """Per-query latencies, excluding jit-compile batches (recent
        `window` batches)."""
        return [b.ms / b.size for b in self._steady()]

    def steady_qps(self):
        t = float(self._steady_ms.value)
        return float(self._steady_queries.value) / (t / 1e3) if t else 0.0

    def latency_percentiles(self):
        """Steady-state (compile batches excluded) batch-latency summary."""
        steady = [b.ms for b in self._steady()]
        if not steady:
            return {}
        lat = np.asarray(steady)
        return {"p50_ms": round(float(np.percentile(lat, 50)), 3),
                "p99_ms": round(float(np.percentile(lat, 99)), 3),
                "mean_ms": round(float(lat.mean()), 3)}

    def reset(self):
        """Zero every counter and drop the batch window (the registry
        metrics this instance registered are reset in place)."""
        for c in (self._queries, self._batches, self._compile_batches,
                  self._steady_queries, self._steady_ms,
                  self._prefetch_enqueued, self._prefetch_errors,
                  self._reloads, self._selector_reloads):
            c.reset()
        self._batch_ms_hist.reset()
        self.batches.clear()
        self._compiled_bucket_set.clear()


class RetrievalEngine:
    """Unified serving layer over a ClusterStore backend."""

    _PF_CHUNK = 8            # blocks per prefetch fetch (lock granularity)

    def __init__(self, cfg, index, store=None, *, max_batch=256,
                 cache_capacity=512, prefetch=True, prefetch_depth=None,
                 k=None, reader=None, use_adc=None, metrics=None,
                 tracer=None, trace_sample_rate=None, fusion=None,
                 explain=None):
        # per-engine fusion override ("interp" | "rrf"): wins over the
        # manifest config and is re-applied across index/selector reloads
        from repro.core.fusion import FUSION_METHODS
        if fusion is not None and fusion not in FUSION_METHODS:
            raise ValueError(f"fusion must be one of {FUSION_METHODS}, "
                             f"got {fusion!r}")
        self._fusion_override = fusion
        cfg = self._apply_cfg_overrides(cfg)
        self.cfg = cfg
        self.index = index
        self.store = store if store is not None \
            else stores_lib.store_for_index(index)
        self.is_host = bool(getattr(self.store, "is_host", False))
        self.max_batch = max(1, max_batch)
        self.k = k or cfg.k_final
        self.reader = reader            # IndexReader backing reload_index()
        # ADC serving: score raw PQ codes against per-query LUTs on the
        # host path. None = auto (on exactly when the store is code-backed);
        # True demands a code-backed store; False forces decode-then-score.
        self._explicit_use_adc = use_adc
        self.use_adc = self._resolve_use_adc(self.store)
        # observability (repro.obs): the registry backs stats()/ServeStats;
        # the tracer emits per-batch stage spans when trace_sample_rate > 0
        # (0 by default: the disabled path hands out a shared no-op trace).
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if tracer is None:
            tracer = Tracer(sample_rate=trace_sample_rate or 0.0)
        elif trace_sample_rate is not None:
            tracer.sample_rate = float(trace_sample_rate)
        self.tracer = tracer
        # sampled per-query explain telemetry (repro.obs.ExplainLogger);
        # None (the default) costs a single attribute check per batch.
        # Covers the host serving path — the fully-fused device path has
        # no per-stage host visibility to explain.
        self.explain = explain
        self._adc_ms = self.metrics.counter("serve.adc_ms")
        self._lut_build_ms = self.metrics.counter("serve.lut_build_ms")
        self._prefetch_enabled = bool(prefetch)
        self._swap_lock = threading.RLock()   # serving vs reload_index
        self._pf_drop = False           # quiesce flag across index swaps
        self.serve_stats = ServeStats(self.metrics)
        self._cache_capacity = cache_capacity
        self.cache = self._make_cache(self.store) \
            if (self.is_host and cache_capacity) else None
        # prefetch candidates a bit past the selection budget: Stage-II
        # mostly keeps high-ranked Stage-I candidates, so this covers the
        # selection without reading the whole candidate list. An explicit
        # depth is pinned; the default tracks cfg.max_selected across
        # reloads (a calibrated publish may raise the budget).
        self._explicit_prefetch_depth = prefetch_depth
        self.prefetch_depth = prefetch_depth if prefetch_depth is not None \
            else self._default_prefetch_depth(cfg)
        self._fns: Dict[Any, Any] = {}          # (kind, bucket) -> jitted fn
        self._pf_q = None
        self._pf_thread = None
        self._start_prefetch()

    # cumulative fused-ADC / LUT-build device time (steady-state only);
    # registry-backed so stats(), metrics exports, and reset_stats() agree
    @property
    def adc_ms(self):
        return float(self._adc_ms.value)

    @property
    def lut_build_ms(self):
        return float(self._lut_build_ms.value)

    # -- lifecycle ----------------------------------------------------------

    def _resolve_use_adc(self, store):
        coded = bool(getattr(store, "is_coded", False))
        if self._explicit_use_adc is None:
            return self.is_host and coded
        if self._explicit_use_adc and not coded:
            raise ValueError("use_adc=True needs a code-backed store "
                             "(is_coded); this store serves float blocks")
        return bool(self._explicit_use_adc) and self.is_host

    def _make_cache(self, store):
        """Byte-budgeted cache sized in float32-block equivalents when the
        store's geometry is known (identical behavior to the old
        entry-count bound for float stores; ~4*dim/nsub more clusters for
        code-backed stores), else the legacy entry-count bound."""
        cap = getattr(store, "cap", None)
        dim = getattr(store, "dim", None)
        if cap and dim:
            return BlockCache(
                capacity_bytes=int(self._cache_capacity) * int(cap)
                * int(dim) * 4)
        return BlockCache(self._cache_capacity)

    def _apply_cfg_overrides(self, cfg):
        if self._fusion_override is not None \
                and cfg.fusion != self._fusion_override:
            cfg = dataclasses.replace(cfg, fusion=self._fusion_override)
        return cfg

    @staticmethod
    def _default_prefetch_depth(cfg):
        # expansion widens the candidate list; the prefetch window still
        # tracks the selection budget, capped at the EXPANDED width
        return min(cfg.n_candidates_total,
                   cfg.max_selected + cfg.max_selected // 2)

    def _refresh_prefetch_depth(self, cfg):
        if self._explicit_prefetch_depth is None:
            self.prefetch_depth = self._default_prefetch_depth(cfg)

    def _start_prefetch(self):
        if self._prefetch_enabled and self.is_host and self.cache is not None:
            self._pf_q = queue.Queue(maxsize=64)
            self._pf_thread = threading.Thread(target=self._prefetch_worker,
                                               daemon=True)
            self._pf_thread.start()

    def _stop_prefetch(self):
        if self._pf_q is not None:
            self._pf_q.put(None)
            # unbounded join: the queue is bounded and fetches are chunked,
            # so drain is finite — and stats() after close() must be final
            self._pf_thread.join()
            self._pf_q = None
            self._pf_thread = None

    def close(self):
        self._stop_prefetch()

    def reload_index(self, reader=None, *, verify="none"):
        """Hot-swap to the index's current committed generation with no
        downtime: re-reads the manifest (`IndexReader.refresh`), rebuilds
        the arrays/store, and atomically replaces them between batches —
        compiled buckets and the block cache are invalidated (geometry and
        doc membership may have changed), and the prefetch worker is
        stopped across the swap so an in-flight prefetch of the OLD
        generation can never repopulate the fresh cache.

        `reader` defaults to the one the engine was constructed with
        (`IndexReader.engine()` wires it). Returns the generation now
        being served. Safe to call from a control thread while another
        thread serves: in-flight batches finish on the old generation.

        Stats semantics: every cumulative counter in stats() — I/O
        ops/bytes, decode_ms, cache hit/miss/eviction/clear, adc/LUT
        times — is ENGINE-lifetime. The swap carries the old store's
        counters onto the new store, so a reload never zeroes history;
        `reset_stats()` is the only reset."""
        reader = reader if reader is not None else self.reader
        if reader is None:
            raise ValueError("reload_index needs an IndexReader (construct "
                             "the engine via IndexReader.engine, or pass "
                             "reader=)")
        tr = self.tracer.trace("reload_index")
        with tr.span("reload"):
            reader.refresh(verify=verify)
            cfg, index = reader.load_index()
            cfg = self._apply_cfg_overrides(cfg)
            store = reader.open_store(cluster_docs=index.cluster_docs)
            # quiesce prefetch: drop queued candidate ids and wait out any
            # fetch against the old store before the cache is cleared
            restart = self._pf_thread is not None
            self._pf_drop = True
            if restart:
                self._stop_prefetch()
            with self._swap_lock:
                old_store = self.store
                self.cfg, self.index, self.store = cfg, index, store
                self.reader = reader
                self.use_adc = self._resolve_use_adc(store)
                self._refresh_prefetch_depth(cfg)
                self._fns.clear()           # bucket shapes/geometry changed
                self._carry_store_counters(old_store, store)
                if self.cache is not None:
                    # block ids now name new-gen blocks, and the new
                    # geometry may change the byte budget (cap/dim moved):
                    # replace the cache but carry the lifetime counters —
                    # a swap IS a clear, stats() must not lose history
                    # across generations
                    old = self.cache
                    new = self._make_cache(store)
                    new.hits, new.misses = old.hits, old.misses
                    new.evictions, new.clears = old.evictions, old.clears + 1
                    self.cache = new
                self.serve_stats.record_reload()
            self._pf_drop = False
            if restart:
                self._start_prefetch()
        tr.finish(generation=reader.generation)
        return reader.generation

    @staticmethod
    def _stage1_cfg(cfg):
        """The config slice compiled into Stage-I buckets (candidate
        generation + sparse depth). A selector publish that changes any of
        these must invalidate stage1 fns too."""
        return (cfg.k_sparse, cfg.bins, cfg.n_candidates, cfg.expand_depth,
                cfg.n_candidates_total, cfg.u_bins)

    @staticmethod
    def _carry_store_counters(old_store, new_store):
        """Copy cumulative I/O + host-decode counters from the outgoing
        store onto its replacement, keeping stats() engine-lifetime (the
        cache carries its counters the same way). Before this,
        `decode_ms` and IOStats silently reset on reload_index but
        survived reload_selector — now both paths behave identically."""
        if new_store is old_store:
            return
        old_io = getattr(old_store, "stats", None)
        new_io = getattr(new_store, "stats", None)
        if old_io is not None and new_io is not None \
                and hasattr(old_io, "n_ops") and hasattr(new_io, "add"):
            new_io.add(old_io.n_ops, old_io.bytes, old_io.wall_ms)
        if hasattr(old_store, "decode_ms") and hasattr(new_store,
                                                       "decode_ms"):
            new_store.decode_ms += old_store.decode_ms

    def reload_selector(self, reader=None, *, verify="none"):
        """Hot-swap ONLY the Stage-II selector: adopt a newer committed
        generation's LSTM weights + calibrated theta/budget (published by
        repro.train.publish_selector) without touching the store, the
        block cache, the prefetch worker, or the compiled Stage-I
        buckets. Far cheaper than `reload_index()` — selector publishes
        rewrite zero corpus bytes, so corpus-derived state stays valid.

        If the refreshed manifest shows the corpus itself moved too
        (arrays/block shards differ — e.g. a delta landed between
        publishes), this falls back to a full `reload_index()`. Returns
        the generation now being served."""
        reader = reader if reader is not None else self.reader
        if reader is None:
            raise ValueError("reload_selector needs an IndexReader "
                             "(construct the engine via IndexReader.engine, "
                             "or pass reader=)")
        before = (reader.manifest.get("arrays"),
                  reader.manifest.get("block_shards"))
        reader.refresh(verify=verify)
        after = (reader.manifest.get("arrays"),
                 reader.manifest.get("block_shards"))
        if before != after:
            return self.reload_index(reader, verify="none")
        tr = self.tracer.trace("reload_selector")
        with tr.span("reload"):
            cfg = self._apply_cfg_overrides(reader.config())
            params = reader.lstm_params()
            with self._swap_lock:
                old_cfg = self.cfg
                self.cfg = cfg
                self.index.lstm_params = params
                self.reader = reader
                # the calibrated budget may exceed the old one: keep the
                # prefetch window covering the selection
                self._refresh_prefetch_depth(cfg)
                # only selector-dependent compilations are stale: stage2
                # closes over (params, theta, max_selected); the fused
                # device path and the fused host tails close over the
                # whole (re-read) config. Stage-I buckets, the LUT builder
                # (codebooks only), and the block cache survive — the
                # corpus didn't move.
                stale = {"stage2", "device", "adc", "dot"}
                if self._stage1_cfg(old_cfg) != self._stage1_cfg(cfg):
                    # a publish may also retune candidate generation
                    # (expansion depth / width): those values are BAKED
                    # into the compiled Stage-I buckets, so keeping them
                    # would serve the old candidate shape forever
                    stale.add("stage1")
                for key in [k for k in self._fns if k[0] in stale]:
                    del self._fns[key]
                self.serve_stats.record_selector_reload()
        tr.finish(generation=reader.generation)
        return reader.generation

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- prefetch -----------------------------------------------------------

    def _cache_fill_fn(self):
        """What a cache miss fetches: raw CODE blocks under ADC serving
        (the cache must hold one consistent record type per generation —
        the fused scorer consumes whatever the prefetcher cached), float
        blocks otherwise."""
        store = self.store
        if self.use_adc:
            return lambda c: np.asarray(
                store.fetch_code_blocks(np.asarray(c))[0])
        return lambda c: np.asarray(store.fetch_blocks(np.asarray(c))[0])

    def _prefetch_worker(self):
        while True:
            cids = self._pf_q.get()
            if cids is None:
                return
            if self._pf_drop:
                continue        # reload in progress: stale candidate ids
            try:
                # record=False: prefetch probes must not skew the serving
                # hit-rate; single-flight inside keeps the serving thread
                # from re-reading blocks this fetch is already pulling.
                # Fetch in small chunks so the serving thread never waits
                # behind the whole candidate set for its selected blocks.
                fill = self._cache_fill_fn()
                for i in range(0, len(cids), self._PF_CHUNK):
                    self.cache.get_or_fetch_many(
                        cids[i:i + self._PF_CHUNK], fill, record=False)
            except Exception:       # prefetch is best-effort; never kill serving
                self.serve_stats.record_prefetch_error()

    def _enqueue_prefetch(self, cand):
        """cand: (B, n_candidates) host array, stage-1 ordered."""
        q = self._pf_q     # snapshot: reload_index() may null the attribute
        if q is None:      # between this check and the put (TOCTOU)
            return
        cids = np.unique(np.asarray(cand)[:, :self.prefetch_depth])
        cids = [int(c) for c in cids if int(c) not in self.cache]
        if not cids:
            return
        try:
            q.put_nowait(cids)
            self.serve_stats.record_prefetch(len(cids))
        except queue.Full:
            pass

    # -- compiled stages ----------------------------------------------------

    def _fn(self, kind, bucket, builder):
        key = (kind, bucket)
        fn = self._fns.get(key)
        if fn is None:
            fn = builder()
            self._fns[key] = fn
            self._built_fn = True     # this batch pays a compile somewhere
        return fn

    def _device_fn(self, bucket):
        def build():
            def run(qd, qt, qw):
                ids, scores, diag = pipe_lib.retrieve(
                    self.cfg, self.index, self.store, qd, qt, qw, k=self.k)
                return ids, scores, diag["n_selected"]
            return jax.jit(run)
        return self._fn("device", bucket, build)

    def _stage1_fn(self, bucket):
        return self._fn("stage1", bucket,
                        lambda: pipe_lib.build_stage1_fn(self.cfg, self.index))

    def _stage2_fn(self, bucket):
        return self._fn("stage2", bucket,
                        lambda: pipe_lib.build_stage2_fn(self.cfg, self.index))

    def _lut_fn(self, bucket):
        """Per-query ADC LUT build (rotation folded in). Keyed per bucket
        only — survives selector reloads (closes over codebooks alone)."""
        return self._fn("lut", bucket,
                        lambda: pipe_lib.build_lut_fn(self.store.codebooks,
                                                      self.store.rotation))

    def _fused_fn(self, kind, bucket, ubucket):
        """One compiled score->fuse->top-k tail per (mode, batch bucket,
        unique-block bucket)."""
        def build():
            return pipe_lib.build_fused_scorer(self.cfg, self.index,
                                               self.store, k=self.k,
                                               mode=kind)
        return self._fn(kind, (bucket, ubucket), build)

    # -- serving ------------------------------------------------------------

    def retrieve(self, q_dense, q_terms, q_weights, *, k=None):
        """Serve a query batch of any size. Returns (ids, scores) with the
        caller's batch dimension preserved."""
        if k is not None and k != self.k:
            raise ValueError("per-call k would defeat bucketed compilation; "
                             "construct the engine with the serving k")
        n = int(np.asarray(q_dense).shape[0])
        if n < 1:
            raise ValueError("empty query batch")
        out_ids, out_scores = [], []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            ids, scores = self._retrieve_chunk(
                q_dense[lo:hi], q_terms[lo:hi], q_weights[lo:hi])
            out_ids.append(ids)
            out_scores.append(scores)
        if len(out_ids) == 1:
            return out_ids[0], out_scores[0]
        return (jnp.concatenate(out_ids, axis=0),
                jnp.concatenate(out_scores, axis=0))

    def _retrieve_chunk(self, q_dense, q_terms, q_weights):
        # one chunk serves entirely on one index generation: reload_index
        # takes the same lock, so swaps land between chunks, never inside
        with self._swap_lock:
            n = int(np.asarray(q_dense).shape[0])
            bucket = bucket_size(n, self.max_batch)
            self._built_fn = False
            tr = self.tracer.trace("batch", size=n, bucket=bucket)
            with tr.span("pad"):
                pad = bucket - n
                qd = jnp.asarray(_pad_rows(q_dense, pad))
                qt = jnp.asarray(_pad_rows(q_terms, pad))
                qw = jnp.asarray(_pad_rows(q_weights, pad))
            # batch_ms starts AFTER input pad/transfer, matching the
            # pre-obs measurement exactly (the `pad` span still shows it)
            t0 = time.perf_counter()
            if self.is_host:
                ids, scores = self._serve_host(bucket, qd, qt, qw, tr, n=n)
                ids.block_until_ready()
            else:
                with tr.span("device_pipeline"):
                    ids, scores, _ = self._device_fn(bucket)(qd, qt, qw)
                    ids.block_until_ready()
            ms = (time.perf_counter() - t0) * 1e3
            # a batch "compiled" if ANY stage built a new jitted fn for it
            # (stage buckets, but also a first-seen unique-block bucket of
            # the fused tail) — steady-state latency stats exclude those,
            # but traces flag them (`compiled`) instead of dropping them
            tr.finish(compiled=self._built_fn, batch_ms=round(ms, 3))
            self.serve_stats.record(n, bucket, self._built_fn, ms)
            return ids[:n], scores[:n]

    @staticmethod
    def _pow2(n):
        b = 1
        while b < n:
            b *= 2
        return b

    def _serve_host(self, bucket, qd, qt, qw, tr=NOOP_TRACE, n=None):
        n = bucket if n is None else n
        with tr.span("stage1"):
            sid, ss, cand, feats = self._stage1_fn(bucket)(qd, qt, qw)
            cand_np = np.asarray(cand)      # device sync for Stage I
            # overlap: start pulling candidate blocks while Stage II runs
            # (the enqueue itself is host work, charged to this span)
            self._enqueue_prefetch(cand_np)
        lut = None
        if self.use_adc:
            # the LUT depends only on the queries — build it while the
            # prefetcher is pulling candidate code blocks
            with tr.span("lut_build"):
                t0 = time.perf_counter()
                lut = self._lut_fn(bucket)(qd)
                lut.block_until_ready()
                if not self._built_fn:   # steady-state only (no compile skew)
                    self._lut_build_ms.inc((time.perf_counter() - t0) * 1e3)
        with tr.span("stage2_select"):
            sel_ids, sel_mask, probs = self._stage2_fn(bucket)(cand, feats)
            sel_np = np.asarray(sel_ids)    # device sync for Stage II
            mask_np = np.asarray(sel_mask)
        with tr.span("fuse"):               # host glue: dedup + positions
            uniq, pos = pipe_lib.dedup_selected(sel_np, mask_np)
        if bool(mask_np.any()):
            with tr.span("cache_fetch", n_blocks=len(uniq)) as sp:
                fetch = pipe_lib.fetch_unique_code_blocks if self.use_adc \
                    else pipe_lib.fetch_unique_blocks
                blocks = fetch(self.store, uniq, self.cache, trace=tr)
                sp.annotate(bytes=int(blocks.nbytes))
        else:       # nothing selected: zero placeholder, no I/O
            blocks = np.zeros(
                (1, self.store.cap,
                 self.store.nsub if self.use_adc else self.store.dim),
                np.uint8 if self.use_adc else np.float32)
        with tr.span("fused_score_topk"):
            # pad the unique-block axis to a power of two so fused-tail
            # compilations stay bounded (pos only ever indexes real rows)
            ub = self._pow2(blocks.shape[0])
            if ub > blocks.shape[0]:
                blocks = np.concatenate(
                    [blocks,
                     np.zeros((ub - blocks.shape[0],) + blocks.shape[1:],
                              blocks.dtype)])
            kind = "adc" if self.use_adc else "dot"
            fn = self._fused_fn(kind, bucket, ub)
            t0 = time.perf_counter()
            ids, scores = fn(lut if self.use_adc else qd, sid, ss,
                             sel_ids, sel_mask, jnp.asarray(blocks),
                             jnp.asarray(pos))
            ids.block_until_ready()
            if self.use_adc and not self._built_fn:
                # steady-state only (no compile skew)
                self._adc_ms.inc((time.perf_counter() - t0) * 1e3)
        if self.explain is not None and self.explain.sample():
            for rec in build_explain_records(
                    self.cfg,
                    qid_base=self.serve_stats.n_queries,
                    generation=None if self.reader is None
                    else self.reader.generation,
                    n=n, cand=cand_np, probs=probs, sel_ids=sel_np,
                    sel_mask=mask_np, final_ids=ids, sparse_ids=sid,
                    doc_cluster=self.index.doc_cluster):
                self.explain.emit(rec)
        return ids, scores

    # -- introspection ------------------------------------------------------

    def _sync_gauges(self):
        """Mirror cache/IOStats/store counters into registry gauges so a
        metrics export (`--metrics-out`, Prometheus scrape) carries them
        without callers having to join stats() themselves."""
        reg = self.metrics
        if self.cache is not None:
            for k, v in self.cache.stats().items():
                if isinstance(v, (int, float)) and v is not None:
                    reg.gauge(f"cache.{k}").set(v)
        io = getattr(self.store, "stats", None)
        if io is not None and hasattr(io, "n_ops"):
            reg.gauge("io.n_ops").set(io.n_ops)
            reg.gauge("io.bytes").set(io.bytes)
            reg.gauge("io.wall_ms").set(round(io.wall_ms, 2))
            reg.gauge("io.model_ms").set(round(io.model_ms(), 2))
        decode_ms = getattr(self.store, "decode_ms", None)
        if decode_ms is not None:
            reg.gauge("serve.decode_ms").set(round(decode_ms, 2))
        if self.reader is not None:
            reg.gauge("serve.generation").set(self.reader.generation)

    def stats(self):
        self._sync_gauges()
        ss = self.serve_stats
        out = {"n_queries": ss.n_queries,
               "n_batches": ss.n_batches,
               "n_compile_batches": ss.n_compile_batches,
               "compiled_buckets": ss.compiled_buckets,
               "qps_steady": round(ss.steady_qps(), 1),
               "prefetch_enqueued": ss.prefetch_enqueued,
               "prefetch_errors": ss.prefetch_errors,
               "reloads": ss.reloads,
               "selector_reloads": ss.selector_reloads,
               "fusion": self.cfg.fusion,
               "expand_depth": self.cfg.expand_depth,
               **ss.latency_percentiles()}
        if self.reader is not None:
            out["generation"] = self.reader.generation
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        io = getattr(self.store, "stats", None)
        if io is not None and hasattr(io, "n_ops"):
            out["io"] = {"n_ops": io.n_ops, "bytes": io.bytes,
                         "wall_ms": round(io.wall_ms, 2),
                         "model_ms": round(io.model_ms(), 2)}
        if self.is_host:
            out["use_adc"] = self.use_adc
            decode_ms = getattr(self.store, "decode_ms", None)
            if decode_ms is not None:
                out["decode_ms"] = round(decode_ms, 2)
            if self.use_adc:
                out["adc_ms"] = round(self.adc_ms, 2)
                out["lut_build_ms"] = round(self.lut_build_ms, 2)
        return out

    def reset_stats(self):
        """Zero every serving statistic, in place, without touching
        compiled functions, the cached blocks themselves, or the tracer's
        retained traces.

        Semantics: stats() counters are ENGINE-lifetime — they survive
        both `reload_index()` (I/O, decode, and cache counters are carried
        onto the new store/cache) and `reload_selector()`, and reset ONLY
        here. After reset: batch/latency windows, compile-batch history,
        prefetch/reload counts, adc/LUT/decode times, cache
        hit/miss/eviction/clear counts, and store IOStats all read zero;
        the next stats() reflects serving from this instant."""
        with self._swap_lock:
            self.metrics.reset()
            self.serve_stats.reset()
            if self.cache is not None:
                with self.cache._lock:
                    self.cache.hits = self.cache.misses = 0
                    self.cache.evictions = self.cache.clears = 0
            io = getattr(self.store, "stats", None)
            if io is not None and hasattr(io, "n_ops"):
                io.n_ops, io.bytes, io.wall_ms = 0, 0, 0.0
            if getattr(self.store, "decode_ms", None) is not None:
                self.store.decode_ms = 0.0
