"""RetrievalEngine: the serving front-end over the unified pipeline.

Serving optimizations on top of engine/pipeline.py:

  * bucketed batching — incoming query batches are padded to power-of-two
    sizes (capped at `max_batch`), so `jax.jit` compiles once per bucket
    instead of once per ragged tail size. Oversize batches are chunked.
  * LRU block cache — for host (disk) stores, fetched cluster blocks land
    in a byte-budgeted BlockCache keyed by cluster id; hot clusters are
    served from memory. The budget is sized in float32-block equivalents
    (`cache_capacity * cap * dim * 4` bytes), so a float store caches
    exactly `cache_capacity` blocks while a code-backed store fits
    ~4*dim/nsub times more clusters in the same budget.
  * async prefetch — a background thread pulls Stage-I candidate cluster
    blocks from disk into the cache while the Stage-II LSTM selection is
    still running, so by the time the selection lands, most selected
    blocks are already cache hits.
  * fused tail — for host stores the whole score -> fuse -> top-k tail
    runs as ONE jitted pass per (batch bucket, unique-block bucket)
    (pipeline.build_fused_scorer) instead of eager per-stage dispatch.
  * ADC serving (`use_adc`, auto-on for code-backed stores): raw PQ codes
    flow disk -> cache -> device and are scored against per-query ADC
    lookup tables (repro.kernels.adc) inside the fused pass — the host
    never decodes a float block; the LUT is built right after Stage I so
    it overlaps the Stage-II selection. Timings surface in stats() as
    `lut_build_ms` / `adc_ms` (and `decode_ms` stays 0 on this path).

Plus zero-downtime index swaps: `reload_index()` hops a serving engine to
a newer committed index generation (repro.index.update) between batches —
the store/arrays are rebuilt from the reader, compiled buckets and the
block cache are invalidated (geometry may have changed), and the prefetch
worker is quiesced across the swap so no stale block can repopulate the
fresh cache. In-flight batches finish on the old generation; no request
ever fails. When only the Stage-II selector moved (repro.train publishes
weights + calibrated thresholds as a generation that rewrites zero corpus
bytes), `reload_selector()` swaps just the LSTM params and theta/budget —
Stage-I compilations, the block cache, and the prefetch worker survive.

Usage:
    engine = RetrievalEngine(cfg, index)                  # in-memory / PQ
    engine = RetrievalEngine(cfg, index, store=DiskStore(...))
    ids, scores = engine.retrieve(q_dense, q_terms, q_weights)
    engine.stats()   # latency percentiles, cache hit rate, I/O counters
    engine.reload_index()   # adopt a newer generation (reader-backed)
    engine.close()
"""

import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clusd as clusd_lib
from repro.core import sparse as sparse_lib
from repro.engine import pipeline as pipe_lib
from repro.engine import stores as stores_lib
from repro.engine.cache import BlockCache
from repro.kernels import adc as adc_ops


def bucket_size(n, max_batch):
    """Smallest power of two >= n, capped at max_batch."""
    if n < 1:
        raise ValueError(f"batch size must be >= 1, got {n}")
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch)


def _pad_rows(x, n_pad):
    """Pad axis 0 by repeating the last row (keeps ids/terms in range)."""
    if n_pad == 0:
        return x
    return np.concatenate([x, np.repeat(np.asarray(x)[-1:], n_pad, axis=0)])


@dataclasses.dataclass
class BatchRecord:
    size: int          # real queries in the batch (before padding)
    bucket: int        # padded bucket it ran in
    compiled: bool     # this batch triggered a jit compile for its bucket
    ms: float


@dataclasses.dataclass
class ServeStats:
    n_queries: int = 0
    n_batches: int = 0
    batches: List[BatchRecord] = dataclasses.field(default_factory=list)
    prefetch_enqueued: int = 0
    prefetch_errors: int = 0
    reloads: int = 0
    selector_reloads: int = 0

    def record(self, size, bucket, compiled, ms):
        self.n_queries += size
        self.n_batches += 1
        self.batches.append(BatchRecord(size, bucket, compiled, ms))

    @property
    def batch_ms(self):
        return [b.ms for b in self.batches]

    @property
    def compiled_buckets(self):
        return sorted({b.bucket for b in self.batches if b.compiled})

    def _steady(self):
        return [b for b in self.batches if not b.compiled]

    def per_query_ms(self):
        """Per-query latencies, excluding jit-compile batches."""
        return [b.ms / b.size for b in self._steady()]

    def steady_qps(self):
        s = self._steady()
        t = sum(b.ms for b in s)
        return sum(b.size for b in s) / (t / 1e3) if t else 0.0

    def latency_percentiles(self):
        """Steady-state (compile batches excluded) batch-latency summary."""
        steady = [b.ms for b in self._steady()]
        if not steady:
            return {}
        lat = np.asarray(steady)
        return {"p50_ms": round(float(np.percentile(lat, 50)), 3),
                "p99_ms": round(float(np.percentile(lat, 99)), 3),
                "mean_ms": round(float(lat.mean()), 3)}


class RetrievalEngine:
    """Unified serving layer over a ClusterStore backend."""

    _PF_CHUNK = 8            # blocks per prefetch fetch (lock granularity)

    def __init__(self, cfg, index, store=None, *, max_batch=256,
                 cache_capacity=512, prefetch=True, prefetch_depth=None,
                 k=None, reader=None, use_adc=None):
        self.cfg = cfg
        self.index = index
        self.store = store if store is not None \
            else stores_lib.store_for_index(index)
        self.is_host = bool(getattr(self.store, "is_host", False))
        self.max_batch = max(1, max_batch)
        self.k = k or cfg.k_final
        self.reader = reader            # IndexReader backing reload_index()
        # ADC serving: score raw PQ codes against per-query LUTs on the
        # host path. None = auto (on exactly when the store is code-backed);
        # True demands a code-backed store; False forces decode-then-score.
        self._explicit_use_adc = use_adc
        self.use_adc = self._resolve_use_adc(self.store)
        self.adc_ms = 0.0           # fused ADC score+fuse+topk device time
        self.lut_build_ms = 0.0     # per-batch ADC LUT builds
        self._prefetch_enabled = bool(prefetch)
        self._swap_lock = threading.RLock()   # serving vs reload_index
        self._pf_drop = False           # quiesce flag across index swaps
        self.serve_stats = ServeStats()
        self._cache_capacity = cache_capacity
        self.cache = self._make_cache(self.store) \
            if (self.is_host and cache_capacity) else None
        # prefetch candidates a bit past the selection budget: Stage-II
        # mostly keeps high-ranked Stage-I candidates, so this covers the
        # selection without reading the whole candidate list. An explicit
        # depth is pinned; the default tracks cfg.max_selected across
        # reloads (a calibrated publish may raise the budget).
        self._explicit_prefetch_depth = prefetch_depth
        self.prefetch_depth = prefetch_depth if prefetch_depth is not None \
            else self._default_prefetch_depth(cfg)
        self._fns: Dict[Any, Any] = {}          # (kind, bucket) -> jitted fn
        self._pf_q = None
        self._pf_thread = None
        self._start_prefetch()

    # -- lifecycle ----------------------------------------------------------

    def _resolve_use_adc(self, store):
        coded = bool(getattr(store, "is_coded", False))
        if self._explicit_use_adc is None:
            return self.is_host and coded
        if self._explicit_use_adc and not coded:
            raise ValueError("use_adc=True needs a code-backed store "
                             "(is_coded); this store serves float blocks")
        return bool(self._explicit_use_adc) and self.is_host

    def _make_cache(self, store):
        """Byte-budgeted cache sized in float32-block equivalents when the
        store's geometry is known (identical behavior to the old
        entry-count bound for float stores; ~4*dim/nsub more clusters for
        code-backed stores), else the legacy entry-count bound."""
        cap = getattr(store, "cap", None)
        dim = getattr(store, "dim", None)
        if cap and dim:
            return BlockCache(
                capacity_bytes=int(self._cache_capacity) * int(cap)
                * int(dim) * 4)
        return BlockCache(self._cache_capacity)

    @staticmethod
    def _default_prefetch_depth(cfg):
        return min(cfg.n_candidates,
                   cfg.max_selected + cfg.max_selected // 2)

    def _refresh_prefetch_depth(self, cfg):
        if self._explicit_prefetch_depth is None:
            self.prefetch_depth = self._default_prefetch_depth(cfg)

    def _start_prefetch(self):
        if self._prefetch_enabled and self.is_host and self.cache is not None:
            self._pf_q = queue.Queue(maxsize=64)
            self._pf_thread = threading.Thread(target=self._prefetch_worker,
                                               daemon=True)
            self._pf_thread.start()

    def _stop_prefetch(self):
        if self._pf_q is not None:
            self._pf_q.put(None)
            # unbounded join: the queue is bounded and fetches are chunked,
            # so drain is finite — and stats() after close() must be final
            self._pf_thread.join()
            self._pf_q = None
            self._pf_thread = None

    def close(self):
        self._stop_prefetch()

    def reload_index(self, reader=None, *, verify="none"):
        """Hot-swap to the index's current committed generation with no
        downtime: re-reads the manifest (`IndexReader.refresh`), rebuilds
        the arrays/store, and atomically replaces them between batches —
        compiled buckets and the block cache are invalidated (geometry and
        doc membership may have changed), and the prefetch worker is
        stopped across the swap so an in-flight prefetch of the OLD
        generation can never repopulate the fresh cache.

        `reader` defaults to the one the engine was constructed with
        (`IndexReader.engine()` wires it). Returns the generation now
        being served. Safe to call from a control thread while another
        thread serves: in-flight batches finish on the old generation."""
        reader = reader if reader is not None else self.reader
        if reader is None:
            raise ValueError("reload_index needs an IndexReader (construct "
                             "the engine via IndexReader.engine, or pass "
                             "reader=)")
        reader.refresh(verify=verify)
        cfg, index = reader.load_index()
        store = reader.open_store(cluster_docs=index.cluster_docs)
        # quiesce prefetch: drop queued candidate ids and wait out any
        # fetch against the old store before the cache is cleared
        restart = self._pf_thread is not None
        self._pf_drop = True
        if restart:
            self._stop_prefetch()
        with self._swap_lock:
            self.cfg, self.index, self.store = cfg, index, store
            self.reader = reader
            self.use_adc = self._resolve_use_adc(store)
            self._refresh_prefetch_depth(cfg)
            self._fns.clear()           # bucket shapes/geometry changed
            if self.cache is not None:
                # block ids now name new-gen blocks, and the new geometry
                # may change the byte budget (cap/dim moved): replace the
                # cache but carry the lifetime counters — a swap IS a
                # clear, stats() must not lose history across generations
                old = self.cache
                new = self._make_cache(store)
                new.hits, new.misses = old.hits, old.misses
                new.evictions, new.clears = old.evictions, old.clears + 1
                self.cache = new
            self.serve_stats.reloads += 1
        self._pf_drop = False
        if restart:
            self._start_prefetch()
        return reader.generation

    def reload_selector(self, reader=None, *, verify="none"):
        """Hot-swap ONLY the Stage-II selector: adopt a newer committed
        generation's LSTM weights + calibrated theta/budget (published by
        repro.train.publish_selector) without touching the store, the
        block cache, the prefetch worker, or the compiled Stage-I
        buckets. Far cheaper than `reload_index()` — selector publishes
        rewrite zero corpus bytes, so corpus-derived state stays valid.

        If the refreshed manifest shows the corpus itself moved too
        (arrays/block shards differ — e.g. a delta landed between
        publishes), this falls back to a full `reload_index()`. Returns
        the generation now being served."""
        reader = reader if reader is not None else self.reader
        if reader is None:
            raise ValueError("reload_selector needs an IndexReader "
                             "(construct the engine via IndexReader.engine, "
                             "or pass reader=)")
        before = (reader.manifest.get("arrays"),
                  reader.manifest.get("block_shards"))
        reader.refresh(verify=verify)
        after = (reader.manifest.get("arrays"),
                 reader.manifest.get("block_shards"))
        if before != after:
            return self.reload_index(reader, verify="none")
        cfg = reader.config()
        params = reader.lstm_params()
        with self._swap_lock:
            self.cfg = cfg
            self.index.lstm_params = params
            self.reader = reader
            # the calibrated budget may exceed the old one: keep the
            # prefetch window covering the selection
            self._refresh_prefetch_depth(cfg)
            # only selector-dependent compilations are stale: stage2
            # closes over (params, theta, max_selected); the fused device
            # path and the fused host tails close over the whole (re-read)
            # config. Stage-I buckets, the LUT builder (codebooks only),
            # and the block cache survive — the corpus didn't move.
            for key in [k for k in self._fns
                        if k[0] in ("stage2", "device", "adc", "dot")]:
                del self._fns[key]
            self.serve_stats.selector_reloads += 1
        return reader.generation

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- prefetch -----------------------------------------------------------

    def _cache_fill_fn(self):
        """What a cache miss fetches: raw CODE blocks under ADC serving
        (the cache must hold one consistent record type per generation —
        the fused scorer consumes whatever the prefetcher cached), float
        blocks otherwise."""
        store = self.store
        if self.use_adc:
            return lambda c: np.asarray(
                store.fetch_code_blocks(np.asarray(c))[0])
        return lambda c: np.asarray(store.fetch_blocks(np.asarray(c))[0])

    def _prefetch_worker(self):
        while True:
            cids = self._pf_q.get()
            if cids is None:
                return
            if self._pf_drop:
                continue        # reload in progress: stale candidate ids
            try:
                # record=False: prefetch probes must not skew the serving
                # hit-rate; single-flight inside keeps the serving thread
                # from re-reading blocks this fetch is already pulling.
                # Fetch in small chunks so the serving thread never waits
                # behind the whole candidate set for its selected blocks.
                fill = self._cache_fill_fn()
                for i in range(0, len(cids), self._PF_CHUNK):
                    self.cache.get_or_fetch_many(
                        cids[i:i + self._PF_CHUNK], fill, record=False)
            except Exception:       # prefetch is best-effort; never kill serving
                self.serve_stats.prefetch_errors += 1

    def _enqueue_prefetch(self, cand):
        """cand: (B, n_candidates) host array, stage-1 ordered."""
        q = self._pf_q     # snapshot: reload_index() may null the attribute
        if q is None:      # between this check and the put (TOCTOU)
            return
        cids = np.unique(np.asarray(cand)[:, :self.prefetch_depth])
        cids = [int(c) for c in cids if int(c) not in self.cache]
        if not cids:
            return
        try:
            q.put_nowait(cids)
            self.serve_stats.prefetch_enqueued += len(cids)
        except queue.Full:
            pass

    # -- compiled stages ----------------------------------------------------

    def _fn(self, kind, bucket, builder):
        key = (kind, bucket)
        fn = self._fns.get(key)
        if fn is None:
            fn = builder()
            self._fns[key] = fn
            self._built_fn = True     # this batch pays a compile somewhere
        return fn

    def _device_fn(self, bucket):
        def build():
            def run(qd, qt, qw):
                ids, scores, diag = pipe_lib.retrieve(
                    self.cfg, self.index, self.store, qd, qt, qw, k=self.k)
                return ids, scores, diag["n_selected"]
            return jax.jit(run)
        return self._fn("device", bucket, build)

    def _stage1_fn(self, bucket):
        def build():
            def run(qd, qt, qw):
                sid, ss = sparse_lib.sparse_retrieve_topk(
                    self.index.sparse_index, qt, qw, self.cfg.k_sparse)
                s1 = clusd_lib.stage1_candidates(self.cfg, self.index, qd,
                                                 sid, ss)
                return sid, ss, s1["cand"], s1["feats"]
            return jax.jit(run)
        return self._fn("stage1", bucket, build)

    def _stage2_fn(self, bucket):
        def build():
            def run(cand, feats):
                s2 = clusd_lib.stage2_select(self.cfg, self.index, cand, feats)
                return s2["sel_ids"], s2["sel_mask"]
            return jax.jit(run)
        return self._fn("stage2", bucket, build)

    def _lut_fn(self, bucket):
        """Per-query ADC LUT build (rotation folded in). Keyed per bucket
        only — survives selector reloads (closes over codebooks alone)."""
        def build():
            codebooks = jnp.asarray(self.store.codebooks)
            rotation = None if self.store.rotation is None \
                else jnp.asarray(self.store.rotation)
            return jax.jit(lambda qd: adc_ops.adc_tables(
                qd, codebooks, rotation))
        return self._fn("lut", bucket, build)

    def _fused_fn(self, kind, bucket, ubucket):
        """One compiled score->fuse->top-k tail per (mode, batch bucket,
        unique-block bucket)."""
        def build():
            return pipe_lib.build_fused_scorer(self.cfg, self.index,
                                               self.store, k=self.k,
                                               mode=kind)
        return self._fn(kind, (bucket, ubucket), build)

    # -- serving ------------------------------------------------------------

    def retrieve(self, q_dense, q_terms, q_weights, *, k=None):
        """Serve a query batch of any size. Returns (ids, scores) with the
        caller's batch dimension preserved."""
        if k is not None and k != self.k:
            raise ValueError("per-call k would defeat bucketed compilation; "
                             "construct the engine with the serving k")
        n = int(np.asarray(q_dense).shape[0])
        if n < 1:
            raise ValueError("empty query batch")
        out_ids, out_scores = [], []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            ids, scores = self._retrieve_chunk(
                q_dense[lo:hi], q_terms[lo:hi], q_weights[lo:hi])
            out_ids.append(ids)
            out_scores.append(scores)
        if len(out_ids) == 1:
            return out_ids[0], out_scores[0]
        return (jnp.concatenate(out_ids, axis=0),
                jnp.concatenate(out_scores, axis=0))

    def _retrieve_chunk(self, q_dense, q_terms, q_weights):
        # one chunk serves entirely on one index generation: reload_index
        # takes the same lock, so swaps land between chunks, never inside
        with self._swap_lock:
            n = int(np.asarray(q_dense).shape[0])
            bucket = bucket_size(n, self.max_batch)
            self._built_fn = False
            pad = bucket - n
            qd = jnp.asarray(_pad_rows(q_dense, pad))
            qt = jnp.asarray(_pad_rows(q_terms, pad))
            qw = jnp.asarray(_pad_rows(q_weights, pad))
            t0 = time.perf_counter()
            if self.is_host:
                ids, scores = self._serve_host(bucket, qd, qt, qw)
            else:
                ids, scores, _ = self._device_fn(bucket)(qd, qt, qw)
            ids.block_until_ready()
            # a batch "compiled" if ANY stage built a new jitted fn for it
            # (stage buckets, but also a first-seen unique-block bucket of
            # the fused tail) — steady-state latency stats exclude those
            self.serve_stats.record(n, bucket, self._built_fn,
                                    (time.perf_counter() - t0) * 1e3)
            return ids[:n], scores[:n]

    @staticmethod
    def _pow2(n):
        b = 1
        while b < n:
            b *= 2
        return b

    def _serve_host(self, bucket, qd, qt, qw):
        sid, ss, cand, feats = self._stage1_fn(bucket)(qd, qt, qw)
        # overlap: start pulling candidate blocks while Stage II runs
        self._enqueue_prefetch(np.asarray(cand))
        lut = None
        if self.use_adc:
            # the LUT depends only on the queries — build it while the
            # prefetcher is pulling candidate code blocks
            t0 = time.perf_counter()
            lut = self._lut_fn(bucket)(qd)
            lut.block_until_ready()
            if not self._built_fn:     # steady-state only (no compile skew)
                self.lut_build_ms += (time.perf_counter() - t0) * 1e3
        sel_ids, sel_mask = self._stage2_fn(bucket)(cand, feats)
        uniq, pos = pipe_lib.dedup_selected(sel_ids, sel_mask)
        if bool(np.asarray(sel_mask).any()):
            fetch = pipe_lib.fetch_unique_code_blocks if self.use_adc \
                else pipe_lib.fetch_unique_blocks
            blocks = fetch(self.store, uniq, self.cache)
        else:       # nothing selected: zero placeholder, no I/O
            blocks = np.zeros(
                (1, self.store.cap,
                 self.store.nsub if self.use_adc else self.store.dim),
                np.uint8 if self.use_adc else np.float32)
        # pad the unique-block axis to a power of two so fused-tail
        # compilations stay bounded (pos only ever indexes real rows)
        ub = self._pow2(blocks.shape[0])
        if ub > blocks.shape[0]:
            blocks = np.concatenate(
                [blocks, np.zeros((ub - blocks.shape[0],) + blocks.shape[1:],
                                  blocks.dtype)])
        kind = "adc" if self.use_adc else "dot"
        fn = self._fused_fn(kind, bucket, ub)
        t0 = time.perf_counter()
        ids, scores = fn(lut if self.use_adc else qd, sid, ss,
                         sel_ids, sel_mask, jnp.asarray(blocks),
                         jnp.asarray(pos))
        if self.use_adc:
            ids.block_until_ready()
            if not self._built_fn:     # steady-state only (no compile skew)
                self.adc_ms += (time.perf_counter() - t0) * 1e3
        return ids, scores

    # -- introspection ------------------------------------------------------

    def stats(self):
        out = {"n_queries": self.serve_stats.n_queries,
               "n_batches": self.serve_stats.n_batches,
               "compiled_buckets": self.serve_stats.compiled_buckets,
               "qps_steady": round(self.serve_stats.steady_qps(), 1),
               "prefetch_enqueued": self.serve_stats.prefetch_enqueued,
               "prefetch_errors": self.serve_stats.prefetch_errors,
               "reloads": self.serve_stats.reloads,
               "selector_reloads": self.serve_stats.selector_reloads,
               **self.serve_stats.latency_percentiles()}
        if self.reader is not None:
            out["generation"] = self.reader.generation
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        io = getattr(self.store, "stats", None)
        if io is not None and hasattr(io, "n_ops"):
            out["io"] = {"n_ops": io.n_ops, "bytes": io.bytes,
                         "wall_ms": round(io.wall_ms, 2),
                         "model_ms": round(io.model_ms(), 2)}
        if self.is_host:
            out["use_adc"] = self.use_adc
            decode_ms = getattr(self.store, "decode_ms", None)
            if decode_ms is not None:
                out["decode_ms"] = round(decode_ms, 2)
            if self.use_adc:
                out["adc_ms"] = round(self.adc_ms, 2)
                out["lut_build_ms"] = round(self.lut_build_ms, 2)
        return out
