"""Multi-host scatter-gather serving tier: ShardRouter + simulated hosts.

The CluSD pipeline selects a small set of clusters per query, which makes
the dense side naturally partitionable: each host only needs the shard
blocks it owns. This module promotes the repo's dormant distributed
design (core/distributed.py's replicated-selection / sharded-scoring
split) into the engine as a real serving tier that runs on one machine
with N simulated hosts (each a thread-backed `EngineHost` with its own
shard-subset ShardedDiskStore/ShardedPQStore + BlockCache):

  router (ShardRouter)                 host (EngineHost)
  --------------------                 -----------------
  sparse retrieval + Stage I           fetch owned blocks (cache -> disk)
  ADC LUT build (v2)                   score owned selected slots
  Stage-II LSTM selection              partial top-k (score desc, id asc)
  scatter selections to owners   --->
                                 <---  per-host partial lists
  merge partial top-k (exact tie rule)
  fuse with sparse side + final top-k

Shard placement: block shard s (a contiguous cluster range from the index
manifest) is served by replica hosts [(s + r) % n_hosts for r in
range(replication)]. A slot's owner is looked up by searchsorted over the
manifest's shard upper bounds — the same balanced contiguous ownership
rule as core.distributed.shard_ranges.

Merge tie rule: per-host partial results merge under (score desc, doc id
asc) — exactly `train/labels.py`'s streaming `_merge_topk` lexsort rule,
which is also `lax.top_k`'s tie rule over an id-indexed array. Entry
MULTIPLICITY is preserved (no id-dedup): the single-host fused tail
scatter-adds duplicate selected slots, so the router must too; double
counting across hosts cannot happen because shard slot-sets partition the
selection and each shard group is accepted from exactly one replica.

Exactness: hosts run the same elementwise score ops as the single-host
fused tail (ADC LUT scoring / block dot), the merged dense candidate
list is the same multiset as the single-host (B, S*cap) slot list, and
fusion runs the same `fuse_topk` scatter — so `method="interp"` (the
paper default) is BITWISE identical to the single-host engine. RRF
breaks exact-score ties by list position, so rrf parity is exact except
on exact dense-score ties across distinct docs.

Failover: per-host timeout (futures), retry with exponential backoff
(injectable `sleep` for tests), per-host cooldown health tracking, and
replica failover — a killed host's shard groups are reassigned to the
next live replica; `failed_requests` stays 0 as long as one replica per
shard survives. When EVERY replica of a shard is down the request still
completes in degraded mode: the missing shard's slots are simply absent
from the merged list (exactly equal to serving without that shard), the
batch is counted in `degraded_requests`, and `stats()` raises the
`degraded` flag with the `missing_shards` list while the outage lasts.

Generation hops roll host-by-host: `reload_index()` prepares the new
generation on every host (new shard-subset store + cache alongside the
old), flips the router's own arrays/compiled buckets atomically, then
retires the old generation through each host's serve queue — in-flight
batches finish on the generation they started on, every response is
served from exactly one generation, and zero requests fail.
`reload_selector()` is router-local (selection runs at the router).
"""

import collections
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import pipeline as pipe_lib
from repro.engine.cache import BlockCache
from repro.engine.server import (ServeStats, _pad_rows, bucket_size,
                                 build_explain_records)
from repro.core import fusion as fusion_lib
from repro.kernels import adc as adc_ops
from repro.obs import NOOP_TRACE, MetricsRegistry, Tracer

# pads/invalid entries in merged partial top-k lists; sorts after every
# real doc id on score ties (same value as train/labels._PAD_ID)
MERGE_SENTINEL = np.int64(1) << 62


# ---------------------------------------------------------------------------
# partial top-k merge
# ---------------------------------------------------------------------------

def merge_partial_topk(parts, k):
    """Merge per-host partial top-k lists into one (B, k) list under the
    (score desc, doc id asc) tie rule — the exact rule of
    train/labels.py's streaming `_merge_topk` (np.lexsort((i, -s))) and of
    `lax.top_k` over an id-indexed score array.

    parts: list of (ids (B, Ki) int, scores (B, Ki) float) — Ki may vary
    per part. Entries with non-finite scores or sentinel ids are treated
    as padding. Duplicate ids are KEPT at their multiplicity (the fused
    tail scatter-adds duplicate slots; at-most-once delivery per shard
    group is the router's job, not the merge's).

    Returns (ids (B, k) int64, scores (B, k) float32); when fewer than k
    real entries exist, the tail is (MERGE_SENTINEL, -inf).
    """
    if not parts:
        raise ValueError("merge_partial_topk needs at least one part")
    ids = np.concatenate([np.asarray(p[0], np.int64) for p in parts], axis=1)
    ss = np.concatenate(
        [np.asarray(p[1], np.float32) for p in parts], axis=1)
    if ids.shape != ss.shape:
        raise ValueError(f"ids/scores shapes differ: {ids.shape} vs {ss.shape}")
    B, L = ids.shape
    if L < k:
        ids = np.concatenate(
            [ids, np.full((B, k - L), MERGE_SENTINEL, np.int64)], axis=1)
        ss = np.concatenate(
            [ss, np.full((B, k - L), -np.inf, np.float32)], axis=1)
    invalid = ~np.isfinite(ss) | (ids >= MERGE_SENTINEL) | (ids < 0)
    ids = np.where(invalid, MERGE_SENTINEL, ids)
    ss = np.where(invalid, np.float32(-np.inf), ss).astype(np.float32)
    # primary key: score desc; secondary: id asc (sentinels sort last).
    # np.lexsort sorts by the LAST key first.
    order = np.lexsort((ids, -ss), axis=-1)[:, :k]
    return (np.take_along_axis(ids, order, axis=-1),
            np.take_along_axis(ss, order, axis=-1))


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

class ShardPlacement:
    """Maps index block shards to replica hosts.

    Default rule: replicas of shard s are [(s + r) % n_hosts for r in
    range(replication)] — every host owns a balanced subset, consecutive
    shards land on different primaries, and replication R survives any
    R-1 host failures. An explicit `replicas` dict {shard: [hosts]}
    overrides the rule (a shard mapped to [] is served by nobody —
    permanently degraded, used as the "serving without that shard"
    reference in tests)."""

    def __init__(self, n_shards, n_hosts, replication=1, replicas=None):
        if n_hosts < 1 or n_shards < 1:
            raise ValueError(f"need >=1 hosts and shards, got "
                             f"{n_hosts}/{n_shards}")
        if not (1 <= replication <= n_hosts):
            raise ValueError(f"replication {replication} must be in "
                             f"[1, n_hosts={n_hosts}]")
        self.n_shards, self.n_hosts = int(n_shards), int(n_hosts)
        self.replication = int(replication)
        if replicas is None:
            replicas = {s: [(s + r) % n_hosts for r in range(replication)]
                        for s in range(n_shards)}
        else:
            replicas = {int(s): list(hs) for s, hs in replicas.items()}
            for s in range(n_shards):
                replicas.setdefault(s, [])
        self.replicas = replicas

    def hosts_for(self, shard):
        return list(self.replicas[int(shard)])

    def shards_of(self, host):
        return sorted(s for s, hs in self.replicas.items() if host in hs)


# ---------------------------------------------------------------------------
# host tier
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HostRequest:
    generation: int
    mode: str                    # "adc" | "dot"
    q_or_lut: np.ndarray         # (B, nsub, 256) LUT or (B, dim) queries
    sel_ids: np.ndarray          # (B, S) selected cluster ids
    mine: np.ndarray             # (B, S) bool: selected AND owned here
    uniq: np.ndarray             # sorted unique owned cluster ids to fetch
    trace: bool = False          # record host-side span timings


@dataclasses.dataclass
class HostResponse:
    host_id: int
    generation: int
    ids: np.ndarray              # (B, Kp) int64, (score desc, id asc)
    scores: np.ndarray           # (B, Kp) float32, -inf padding
    # host-side span records when req.trace (else None): list of
    # {"name", "t0" (absolute perf_counter at span start), "dur_ms",
    #  "parent" (local index, -1 = root), "annot"} — record 0 is the
    # "host_serve" root. Absolute perf_counter timestamps are valid
    # across the HostRequest boundary because hosts are threads in THIS
    # process (one clock); a real RPC transport would need clock-offset
    # translation here.
    spans: Any = None


class HostDown(RuntimeError):
    pass


@dataclasses.dataclass
class _HostGen:
    store: Any
    cache: Optional[BlockCache]


class EngineHost:
    """One simulated serving host: a shard-subset store + BlockCache behind
    the engine's fetch->score ops, driven through a single-worker executor
    (its "process"). Thread-backed stand-in for a real remote host; the
    HostRequest/HostResponse boundary is the wire format.

    Fault injection (tests/bench):
      kill()/revive()            — hard down: every serve raises HostDown
      inject_delay(ms, times=N)  — next N serves sleep first (timeouts)
      sim_latency=(base_ms, per_block_ms) — EVERY serve sleeps
          base + per_block * len(uniq), modeling a remote block store's
          RTT + payload time (the QPS-scaling bench measures how the
          scatter splits this bill across hosts)."""

    def __init__(self, host_id, reader, shard_ids, *, cache_capacity=512,
                 use_adc=None, sim_latency=None, sleep=time.sleep):
        if not shard_ids:
            raise ValueError(f"host {host_id} owns no shards; use fewer "
                             f"hosts or more index shards")
        self.host_id = int(host_id)
        self.shard_ids = sorted(int(s) for s in shard_ids)
        self._cache_capacity = int(cache_capacity)
        self._use_adc = bool(reader.is_pq) if use_adc is None else bool(use_adc)
        self.sim_latency = sim_latency
        self._sleep = sleep
        self._lock = threading.Lock()
        self._gens: Dict[int, _HostGen] = {}
        self._fns: Dict[Any, Any] = {}
        self._alive = True
        self._delay_ms = 0.0
        self._delay_times = 0
        self.served = 0
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"host{host_id}")
        self.prepare_generation(reader, reader.generation).result()

    # -- lifecycle ----------------------------------------------------------

    @property
    def alive(self):
        return self._alive

    def kill(self):
        self._alive = False

    def revive(self):
        self._alive = True

    def inject_delay(self, ms, times=1):
        with self._lock:
            self._delay_ms = float(ms)
            self._delay_times = int(times)

    def close(self):
        self._exec.shutdown(wait=True)

    def prepare_generation(self, reader, generation):
        """Open the reader's CURRENT manifest state as `generation` on this
        host, alongside any generations already serving (blue/green).
        Runs through the serve queue, so it serializes with in-flight
        requests on this host. Returns the future."""
        return self._exec.submit(self._prepare, reader, int(generation))

    def _prepare(self, reader, generation):
        store = reader.open_store(shards=self.shard_ids)
        cache = None
        if self._cache_capacity:
            cap = getattr(store, "cap", None)
            dim = getattr(store, "dim", None)
            if cap and dim:
                cache = BlockCache(capacity_bytes=self._cache_capacity
                                   * int(cap) * int(dim) * 4)
            else:
                cache = BlockCache(self._cache_capacity)
        with self._lock:
            self._gens[generation] = _HostGen(store, cache)
        return generation

    def retire_generation(self, generation):
        """Drop a generation's store/cache/compiled fns through the serve
        queue — every request enqueued before the retire (which can only
        be for an older generation) is served first."""
        def _retire():
            with self._lock:
                self._gens.pop(int(generation), None)
                for key in [k for k in self._fns if k[0] == int(generation)]:
                    del self._fns[key]
        return self._exec.submit(_retire)

    def generations(self):
        with self._lock:
            return sorted(self._gens)

    # -- serving ------------------------------------------------------------

    def submit(self, req: HostRequest):
        """Enqueue a request on this host's serve queue; returns a Future
        resolving to a HostResponse (or raising HostDown)."""
        return self._exec.submit(self._serve, req)

    @staticmethod
    def _pow2(n):
        b = 1
        while b < n:
            b *= 2
        return b

    def _score_fn(self, generation, mode, B, U, S):
        key = (generation, mode, B, U, S)
        fn = self._fns.get(key)
        if fn is None:
            if mode == "adc":
                def run(lut, blocks, pos):
                    return adc_ops.adc_score_blocks(lut, blocks, pos)
            else:
                def run(q, blocks, pos):
                    vecs = jnp.take(blocks, pos, axis=0)   # (B, S, cap, dim)
                    return jnp.einsum("bd,bscd->bsc", q, vecs)
            fn = jax.jit(run)
            self._fns[key] = fn
        return fn

    def _serve(self, req: HostRequest):
        if not self._alive:
            raise HostDown(f"host {self.host_id} is down")
        with self._lock:
            gen = self._gens.get(req.generation)
            delay = 0.0
            if self._delay_times > 0:
                delay = self._delay_ms
                self._delay_times -= 1
        if gen is None:
            raise HostDown(f"host {self.host_id} lacks generation "
                           f"{req.generation} (has {self.generations()})")
        # host-side span records (router grafts them under its scatter
        # span): opened before the fault-injection sleeps so host_serve
        # covers the host's whole wall time for this request
        spans = None
        if req.trace:
            spans = [{"name": "host_serve", "t0": time.perf_counter(),
                      "dur_ms": 0.0, "parent": -1,
                      "annot": {"generation": req.generation}}]

        def _rec(name, t0, **annot):
            if spans is not None:
                spans.append({"name": name, "t0": t0,
                              "dur_ms": (time.perf_counter() - t0) * 1e3,
                              "parent": 0, "annot": annot})
        if delay:
            self._sleep(delay / 1e3)
        if self.sim_latency:
            base_ms, per_block_ms = self.sim_latency
            self._sleep((base_ms + per_block_ms * len(req.uniq)) / 1e3)
        store, cache = gen.store, gen.cache
        uniq = np.asarray(req.uniq, np.int64)
        if uniq.size:
            fetch = pipe_lib.fetch_unique_code_blocks if req.mode == "adc" \
                else pipe_lib.fetch_unique_blocks
            t0 = time.perf_counter()
            blocks = fetch(store, uniq, cache)
            _rec("block_fetch", t0, n_blocks=int(uniq.size),
                 bytes=int(blocks.nbytes))
        else:
            blocks = np.zeros(
                (1, store.cap,
                 store.nsub if req.mode == "adc" else store.dim),
                np.uint8 if req.mode == "adc" else np.float32)
            uniq = np.zeros((1,), np.int64)
        ub = self._pow2(blocks.shape[0])
        if ub > blocks.shape[0]:
            blocks = np.concatenate(
                [blocks, np.zeros((ub - blocks.shape[0],) + blocks.shape[1:],
                                  blocks.dtype)])
        sel = np.asarray(req.sel_ids)
        mine = np.asarray(req.mine, bool)
        B, S = sel.shape
        # compact each request's columns down to this host's own slots:
        # scoring is elementwise per slot, so dropping the ~(H-1)/H columns
        # owned by other hosts changes no kept score bit while cutting this
        # host's compute to its share of the selection. The stable argsort
        # preserves slot order (ties in the merge are identical (id, score)
        # pairs, so relative order never affects the fused result).
        t0 = time.perf_counter()
        sc = self._pow2(max(int(mine.sum(axis=1).max()), 1))
        if sc < S:
            keep = np.argsort(~mine, axis=1, kind="stable")[:, :sc]
            sel = np.take_along_axis(sel, keep, axis=1)
            mine = np.take_along_axis(mine, keep, axis=1)
            S = sc
        pos = np.searchsorted(uniq, np.where(mine, sel, uniq[0]))
        _rec("compact", t0, n_slots=int(S))
        t0 = time.perf_counter()
        fn = self._score_fn(req.generation, req.mode, B, ub, S)
        scores3 = np.asarray(fn(jnp.asarray(req.q_or_lut),
                                jnp.asarray(blocks),
                                jnp.asarray(pos.astype(np.int32))))
        _rec("score", t0, mode=req.mode)
        t0 = time.perf_counter()
        docs = store.cluster_docs_np[sel]                  # (B, S, cap)
        cap = docs.shape[-1]
        valid = (docs >= 0) & mine[:, :, None]
        flat_ids = np.where(valid, docs, MERGE_SENTINEL) \
            .reshape(B, S * cap).astype(np.int64)
        flat_ss = np.where(valid.reshape(B, S * cap),
                           scores3.reshape(B, S * cap),
                           -np.inf).astype(np.float32)
        # partial top-k: (score desc, id asc); truncate the all-pad tail
        order = np.lexsort((flat_ids, -flat_ss), axis=-1)
        kp = max(1, int(valid.reshape(B, -1).sum(axis=1).max()))
        order = order[:, :kp]
        _rec("partial_topk", t0, kp=int(kp))
        self.served += 1
        if spans is not None:
            spans[0]["dur_ms"] = \
                (time.perf_counter() - spans[0]["t0"]) * 1e3
        return HostResponse(
            host_id=self.host_id, generation=req.generation,
            ids=np.take_along_axis(flat_ids, order, axis=-1),
            scores=np.take_along_axis(flat_ss, order, axis=-1),
            spans=spans)

    # -- introspection ------------------------------------------------------

    def stats(self):
        with self._lock:
            gens = sorted(self._gens)
            out = {"host": self.host_id, "alive": self._alive,
                   "shards": self.shard_ids, "served": self.served,
                   "generations": gens}
            newest = self._gens.get(gens[-1]) if gens else None
        if newest is not None:
            io = getattr(newest.store, "stats", None)
            if io is not None and hasattr(io, "n_ops"):
                out["io"] = {"n_ops": io.n_ops, "bytes": io.bytes}
            if newest.cache is not None:
                out["cache"] = newest.cache.stats()
        return out


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

class ShardRouter:
    """Scatter-gather front-end over a fleet of EngineHost-compatible
    handles. Runs sparse retrieval + Stage I/II + (v2) the ADC LUT build
    replicated at the router — mirroring core/distributed.py's
    replicated-selection design — then scatters each batch's selected
    slots to the hosts owning their shards, gathers per-host partial
    top-k lists, merges them under the (score desc, id asc) rule, and
    fuses with the sparse side. See the module docstring for exactness,
    failover, and generation-hop semantics."""

    def __init__(self, cfg, index, reader, hosts, placement, *,
                 max_batch=256, k=None, metrics=None, tracer=None,
                 trace_sample_rate=None, fusion=None, explain=None,
                 host_timeout=10.0, max_retries=3, backoff_ms=20.0,
                 host_cooldown=2.0, sleep=time.sleep):
        from repro.core.fusion import FUSION_METHODS
        if fusion is not None and fusion not in FUSION_METHODS:
            raise ValueError(f"fusion must be one of {FUSION_METHODS}, "
                             f"got {fusion!r}")
        self._fusion_override = fusion
        self.cfg = self._apply_cfg_overrides(cfg)
        self.index = index
        self.reader = reader
        self.hosts: List[Any] = list(hosts)
        self.placement = placement
        if placement.n_hosts != len(self.hosts):
            raise ValueError(f"placement maps {placement.n_hosts} hosts, "
                             f"got {len(self.hosts)}")
        self.max_batch = max(1, max_batch)
        self.k = k or cfg.k_final
        self.use_adc = bool(reader.is_pq)
        self.host_timeout = float(host_timeout)
        self.max_retries = int(max_retries)
        self.backoff_ms = float(backoff_ms)
        self.host_cooldown = float(host_cooldown)
        self._sleep = sleep
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if tracer is None:
            tracer = Tracer(sample_rate=trace_sample_rate or 0.0)
        elif trace_sample_rate is not None:
            tracer.sample_rate = float(trace_sample_rate)
        self.tracer = tracer
        # sampled per-query explain telemetry (repro.obs.ExplainLogger);
        # router records add per-host score attribution (host_contrib)
        self.explain = explain
        self.serve_stats = ServeStats(self.metrics)
        self._failed = self.metrics.counter("router.failed_requests")
        self._degraded = self.metrics.counter("router.degraded_requests")
        self._retries = self.metrics.counter("router.retries")
        self._failovers = self.metrics.counter("router.failovers")
        self._swap_lock = threading.RLock()
        self._fns: Dict[Any, Any] = {}
        self._generation = reader.generation
        self._shard_his = self._read_shard_his(reader)
        # per-host health: monotonic time before which the host is skipped
        self._down_until = collections.defaultdict(float)
        # per-batch metadata ring for tests/debugging: generation served,
        # degraded flag, shards that had no live replica, hosts used
        self.last_batches = collections.deque(maxlen=256)

    @staticmethod
    def _read_shard_his(reader):
        return np.asarray([s["cluster_hi"]
                           for s in reader.manifest["block_shards"]],
                          np.int64)

    def _apply_cfg_overrides(self, cfg):
        if self._fusion_override is not None \
                and cfg.fusion != self._fusion_override:
            cfg = dataclasses.replace(cfg, fusion=self._fusion_override)
        return cfg

    @classmethod
    def local(cls, reader, n_hosts, replication=1, *, cfg=None, index=None,
              cache_capacity=512, sim_latency=None, placement=None,
              **router_kw):
        """Build a router over `n_hosts` thread-backed EngineHosts serving
        the reader's index with the default placement rule."""
        if index is None:
            loaded_cfg, index = reader.load_index()
            cfg = cfg if cfg is not None else loaded_cfg
        cfg = cfg if cfg is not None else reader.config()
        n_shards = reader.n_block_shards()
        if placement is None:
            placement = ShardPlacement(n_shards, n_hosts, replication)
        hosts = []
        for h in range(n_hosts):
            owned = placement.shards_of(h)
            hosts.append(EngineHost(h, reader, owned,
                                    cache_capacity=cache_capacity,
                                    sim_latency=sim_latency))
        return cls(cfg, index, reader, hosts, placement, **router_kw)

    def close(self):
        for h in self.hosts:
            close = getattr(h, "close", None)
            if close:
                close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- compiled router stages --------------------------------------------

    def _fn(self, kind, bucket, builder):
        key = (kind, bucket)
        fn = self._fns.get(key)
        if fn is None:
            fn = builder()
            self._fns[key] = fn
            self._built_fn = True
        return fn

    def _stage1_fn(self, bucket):
        return self._fn("stage1", bucket,
                        lambda: pipe_lib.build_stage1_fn(self.cfg, self.index))

    def _stage2_fn(self, bucket):
        return self._fn("stage2", bucket,
                        lambda: pipe_lib.build_stage2_fn(self.cfg, self.index))

    def _lut_fn(self, bucket):
        def build():
            return pipe_lib.build_lut_fn(self.reader._pq_array("codebooks"),
                                         self.reader._pq_array("rotation"))
        return self._fn("lut", bucket, build)

    def _fuse_fn(self, bucket, kd):
        """Fuse the merged dense candidate list with the sparse side — the
        same fuse_topk scatter the single-host fused tail ends in."""
        def build():
            cfg, n_docs, k = self.cfg, self.index.n_docs, self.k

            def run(sid, ss, did, dscore, dmask):
                return fusion_lib.fuse_topk(
                    sid, ss, did, jnp.where(dmask, dscore, 0.0), dmask,
                    n_docs, cfg.alpha, k, method=cfg.fusion, rrf_k=cfg.rrf_k)
            return jax.jit(run)
        return self._fn("fuse", (bucket, kd), build)

    # -- failover helpers ---------------------------------------------------

    def _host_live(self, h, now):
        return self.hosts[h].alive and self._down_until[h] <= now

    def _pick_host(self, shard, tried):
        """Choose a replica for `shard`: prefer live hosts not yet tried
        this request; else re-try a live host (timeouts may be transient);
        else, if every replica is hard-down, nobody (None)."""
        now = time.monotonic()
        replicas = self.placement.hosts_for(shard)
        for h in replicas:
            if h not in tried and self._host_live(h, now):
                return h
        for h in replicas:
            if self._host_live(h, now):
                return h
        # everything in cooldown or dead: probe a not-killed host anyway
        # (cooldown must not turn a transient timeout into an outage)
        for h in replicas:
            if self.hosts[h].alive:
                return h
        return None

    def _mark_failed(self, h):
        self._down_until[h] = time.monotonic() + self.host_cooldown

    def missing_shards(self):
        """Shards with NO live replica right now (degraded mode while
        non-empty: their slots are skipped, requests still complete)."""
        now = time.monotonic()
        return sorted(
            s for s in range(self.placement.n_shards)
            if not any(self.hosts[h].alive
                       for h in self.placement.hosts_for(s)))

    # -- serving ------------------------------------------------------------

    def retrieve(self, q_dense, q_terms, q_weights, *, k=None):
        """Serve a query batch of any size. Returns (ids, scores) with the
        caller's batch dimension preserved."""
        if k is not None and k != self.k:
            raise ValueError("per-call k would defeat bucketed compilation; "
                             "construct the router with the serving k")
        n = int(np.asarray(q_dense).shape[0])
        if n < 1:
            raise ValueError("empty query batch")
        out_ids, out_scores = [], []
        for lo in range(0, n, self.max_batch):
            hi = min(lo + self.max_batch, n)
            ids, scores = self._retrieve_chunk(
                q_dense[lo:hi], q_terms[lo:hi], q_weights[lo:hi])
            out_ids.append(ids)
            out_scores.append(scores)
        if len(out_ids) == 1:
            return out_ids[0], out_scores[0]
        return (jnp.concatenate(out_ids, axis=0),
                jnp.concatenate(out_scores, axis=0))

    def _retrieve_chunk(self, q_dense, q_terms, q_weights):
        with self._swap_lock:
            try:
                return self._retrieve_locked(q_dense, q_terms, q_weights)
            except Exception:
                self._failed.inc()
                raise

    def _retrieve_locked(self, q_dense, q_terms, q_weights):
        n = int(np.asarray(q_dense).shape[0])
        bucket = bucket_size(n, self.max_batch)
        self._built_fn = False
        generation = self._generation
        tr = self.tracer.trace("batch", size=n, bucket=bucket,
                               generation=generation)
        with tr.span("pad"):
            pad = bucket - n
            qd = jnp.asarray(_pad_rows(q_dense, pad))
            qt = jnp.asarray(_pad_rows(q_terms, pad))
            qw = jnp.asarray(_pad_rows(q_weights, pad))
        t0 = time.perf_counter()
        with tr.span("stage1"):
            sid, ss, cand, feats = self._stage1_fn(bucket)(qd, qt, qw)
        q_or_lut = qd
        if self.use_adc:
            with tr.span("lut_build"):
                q_or_lut = self._lut_fn(bucket)(qd)
                q_or_lut.block_until_ready()
        with tr.span("stage2_select"):
            sel_ids, sel_mask, probs = self._stage2_fn(bucket)(cand, feats)
            sel_np = np.asarray(sel_ids)
            mask_np = np.asarray(sel_mask)
        mode = "adc" if self.use_adc else "dot"
        q_host = np.asarray(q_or_lut)
        # slot ownership: shard = searchsorted over manifest cluster_hi
        shard_of = np.searchsorted(self._shard_his,
                                   np.where(mask_np, sel_np, 0),
                                   side="right")
        responses, meta = self._scatter_gather(
            generation, mode, q_host, sel_np, mask_np, shard_of, tr)
        B, S = sel_np.shape
        cap = int(self.index.cluster_docs.shape[1])
        kd = S * cap
        with tr.span("merge", n_parts=len(responses)):
            if responses:
                mids, mscores = merge_partial_topk(
                    [(r.ids, r.scores) for r in responses], kd)
            else:
                mids = np.full((B, kd), MERGE_SENTINEL, np.int64)
                mscores = np.full((B, kd), -np.inf, np.float32)
            dmask = np.isfinite(mscores)
            did = np.where(dmask, mids, 0).astype(np.int32)
            dscore = np.where(dmask, mscores, 0.0).astype(np.float32)
        with tr.span("fuse"):
            ids, scores = self._fuse_fn(bucket, kd)(
                sid, ss, jnp.asarray(did), jnp.asarray(dscore),
                jnp.asarray(dmask))
            ids.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        gens = {r.generation for r in responses} or {generation}
        assert gens == {generation}, \
            f"mixed-generation responses: {gens} (router at {generation})"
        meta.update(generation=generation, size=n, bucket=bucket)
        self.last_batches.append(meta)
        if meta["degraded"]:
            self._degraded.inc()
        if self.explain is not None and self.explain.sample():
            recs = build_explain_records(
                self.cfg, qid_base=self.serve_stats.n_queries,
                generation=generation, n=n, cand=cand, probs=probs,
                sel_ids=sel_np, sel_mask=mask_np, final_ids=ids,
                sparse_ids=sid, doc_cluster=self.index.doc_cluster)
            final_np = np.asarray(ids)[:n]
            for i, rec in enumerate(recs):
                fset = {int(x) for x in final_np[i] if int(x) >= 0}
                contrib = {}
                for r in responses:
                    hit = len(fset & {int(x) for x in r.ids[i]
                                      if 0 <= int(x) < MERGE_SENTINEL})
                    if hit:
                        key = str(r.host_id)
                        contrib[key] = contrib.get(key, 0) + hit
                rec["host_contrib"] = contrib
                rec["degraded"] = meta["degraded"]
                self.explain.emit(rec)
        tr.finish(compiled=self._built_fn, batch_ms=round(ms, 3),
                  degraded=meta["degraded"])
        self.serve_stats.record(n, bucket, self._built_fn, ms)
        return ids[:n], scores[:n]

    def _scatter_gather(self, generation, mode, q_host, sel_np, mask_np,
                        shard_of, tr):
        """Scatter per-shard slot groups to live replicas, gather partial
        top-k responses with timeout/retry/backoff + replica failover.
        Returns (responses, meta)."""
        # pending: shard -> (B, S) bool slot mask still unserved
        pending = {}
        for s in np.unique(shard_of[mask_np]):
            pending[int(s)] = mask_np & (shard_of == int(s))
        meta = {"degraded": False, "missing_shards": [], "hosts": [],
                "retries": 0}
        responses = []
        if not pending:
            with tr.span("scatter", n_hosts=0):
                with tr.span("gather", n_hosts=0):
                    pass
            return responses, meta
        # hosts record spans only when this batch itself is traced
        trace_hosts = tr is not NOOP_TRACE
        tried = {s: set() for s in pending}
        attempt = 0
        while pending:
            # the scatter span COVERS the gather (its child), so host-side
            # spans grafted under scatter always fall inside the parent
            # window — the containment rule check_trace enforces
            with tr.span("scatter", attempt=attempt,
                         n_shards=len(pending)) as sp:
                groups = {}
                for s in sorted(pending):
                    h = self._pick_host(s, tried[s])
                    if h is None:
                        continue
                    if h != self.placement.hosts_for(s)[0]:
                        # served by a non-primary replica (primary dead,
                        # cooling down, or already tried this request)
                        self._failovers.inc()
                    groups.setdefault(h, []).append(s)
                futures = {}
                for h, shards in groups.items():
                    mine = np.zeros_like(mask_np)
                    for s in shards:
                        mine |= pending[s]
                    uniq = np.unique(sel_np[mine]) if mine.any() \
                        else np.zeros((0,), np.int64)
                    req = HostRequest(generation=generation, mode=mode,
                                      q_or_lut=q_host, sel_ids=sel_np,
                                      mine=mine, uniq=uniq,
                                      trace=trace_hosts)
                    futures[h] = (shards, self.hosts[h].submit(req))
                sp.annotate(n_hosts=len(futures))
                if not futures:    # every pending shard has no live replica
                    break
                with tr.span("gather", attempt=attempt,
                             n_hosts=len(futures)):
                    deadline = time.monotonic() + self.host_timeout
                    for h, (shards, fut) in futures.items():
                        try:
                            resp = fut.result(
                                timeout=max(0.0,
                                            deadline - time.monotonic()))
                            assert resp.generation == generation
                            responses.append(resp)
                            meta["hosts"].append(h)
                            for s in shards:
                                pending.pop(s, None)
                            if resp.spans:
                                self._graft_host_spans(tr, sp, h,
                                                       resp.spans)
                        except Exception:
                            # timeout, HostDown, or host-side error:
                            # discard (a late response is never merged),
                            # mark the host, fail shards over to a replica
                            fut.cancel()
                            self._mark_failed(h)
                            for s in shards:
                                tried[s].add(h)
            if pending:
                if attempt >= self.max_retries:
                    break
                self._retries.inc()
                meta["retries"] += 1
                self._sleep(self.backoff_ms * (2 ** attempt) / 1e3)
                attempt += 1
        if pending:
            # no live replica for these shards: complete without them —
            # results are exactly "serving without that shard"
            meta["degraded"] = True
            meta["missing_shards"] = sorted(pending)
        return responses, meta

    @staticmethod
    def _graft_host_spans(tr, scatter_sp, host_id, records):
        """Attach one host's completed span records under the router's
        open scatter span, preserving the host-local parent structure.
        Every grafted span is annotated host=<id> — the Chrome exporter
        routes those to per-host lanes, and check_trace requires the
        annotation on scatter children. Valid because hosts share this
        process's perf_counter clock (see HostResponse.spans)."""
        grafted = {}
        for j, rec in enumerate(records):
            parent = scatter_sp if rec["parent"] < 0 \
                else grafted[rec["parent"]]
            grafted[j] = tr.add_completed(
                rec["name"], t0_abs=rec["t0"], dur_ms=rec["dur_ms"],
                parent=parent, host=int(host_id), **rec["annot"])

    # -- generation hops ----------------------------------------------------

    def reload_index(self, *, verify="none"):
        """Roll the fleet to the index's current committed generation,
        host by host, with zero failed requests: prepare the new
        generation on every host (blue/green: old keeps serving), flip
        the router's arrays + compiled buckets atomically, then retire
        the old generation through each host's serve queue. Returns the
        generation now served."""
        tr = self.tracer.trace("reload_index")
        with tr.span("reload"):
            old_gen = self._generation
            self.reader.refresh(verify=verify)
            new_gen = self.reader.generation
            if new_gen == old_gen:
                tr.finish(generation=old_gen)
                return old_gen
            cfg, index = self.reader.load_index()
            cfg = self._apply_cfg_overrides(cfg)
            for host in self.hosts:        # roll host-by-host
                with tr.span("prepare_host", host=host.host_id):
                    host.prepare_generation(self.reader, new_gen).result()
            with self._swap_lock:
                self.cfg, self.index = cfg, index
                self.use_adc = bool(self.reader.is_pq)
                self._shard_his = self._read_shard_his(self.reader)
                self._fns.clear()
                self._generation = new_gen
                self.serve_stats.record_reload()
            for host in self.hosts:
                host.retire_generation(old_gen)
        tr.finish(generation=new_gen)
        return new_gen

    def reload_selector(self, *, verify="none"):
        """Hot-swap ONLY the Stage-II selector (selection runs at the
        router, so no host participates): adopt a newer generation's LSTM
        weights + calibrated theta/budget. Falls back to a full
        `reload_index()` when the corpus moved too."""
        from repro.engine.server import RetrievalEngine
        before = (self.reader.manifest.get("arrays"),
                  self.reader.manifest.get("block_shards"))
        self.reader.refresh(verify=verify)
        after = (self.reader.manifest.get("arrays"),
                 self.reader.manifest.get("block_shards"))
        if before != after:
            return self.reload_index(verify="none")
        if self.reader.generation == self._generation:
            return self._generation
        cfg = self._apply_cfg_overrides(self.reader.config())
        params = self.reader.lstm_params()
        # a selector publish is still a generation hop: hosts key their
        # stores by generation, so they adopt it too (content-identical —
        # the prepare is mmap-open only)
        old_gen = self._generation
        for host in self.hosts:
            host.prepare_generation(self.reader, self.reader.generation) \
                .result()
        with self._swap_lock:
            old_cfg = self.cfg
            self.cfg = cfg
            self.index.lstm_params = params
            stale = {"stage2", "fuse"}
            if RetrievalEngine._stage1_cfg(old_cfg) != \
                    RetrievalEngine._stage1_cfg(cfg):
                stale.add("stage1")
            for key in [k for k in self._fns if k[0] in stale]:
                del self._fns[key]
            self._generation = self.reader.generation
            self.serve_stats.record_selector_reload()
        for host in self.hosts:
            host.retire_generation(old_gen)
        return self.reader.generation

    # -- introspection ------------------------------------------------------

    def _sync_gauges(self):
        """Mirror router + per-host state into registry gauges so one
        metrics export (`--metrics-out`, a /metrics scrape) captures the
        whole fleet. Before this, per-host cache/IO counters lived ONLY
        in stats()["per_host"] and were silently dropped from exports;
        now each host's numbers appear as `host<i>.cache.*` / `host<i>.
        io.*` / `host<i>.alive` / `host<i>.served` metrics."""
        reg = self.metrics
        missing = self.missing_shards()
        reg.gauge("router.generation").set(self._generation)
        reg.gauge("router.missing_shards").set(len(missing))
        reg.gauge("router.hosts_alive").set(
            sum(1 for h in self.hosts if h.alive))
        for h in self.hosts:
            st = h.stats()
            i = st["host"]
            reg.gauge(f"host{i}.alive").set(int(st["alive"]))
            reg.gauge(f"host{i}.served").set(int(st["served"]))
            for k, v in (st.get("cache") or {}).items():
                if isinstance(v, (int, float)):
                    reg.gauge(f"host{i}.cache.{k}").set(v)
            for k, v in (st.get("io") or {}).items():
                if isinstance(v, (int, float)):
                    reg.gauge(f"host{i}.io.{k}").set(v)
        return missing

    def stats(self):
        ss = self.serve_stats
        missing = self._sync_gauges()
        out = {"n_queries": ss.n_queries,
               "n_batches": ss.n_batches,
               "n_compile_batches": ss.n_compile_batches,
               "qps_steady": round(ss.steady_qps(), 1),
               "generation": self._generation,
               "hosts": len(self.hosts),
               "replication": self.placement.replication,
               "n_shards": self.placement.n_shards,
               "failed_requests": int(self._failed.value),
               "degraded_requests": int(self._degraded.value),
               "retries": int(self._retries.value),
               "failovers": int(self._failovers.value),
               "missing_shards": missing,
               "degraded": bool(missing),
               "reloads": ss.reloads,
               "selector_reloads": ss.selector_reloads,
               "fusion": self.cfg.fusion,
               "use_adc": self.use_adc,
               **ss.latency_percentiles()}
        out["per_host"] = [h.stats() for h in self.hosts]
        return out

    def reset_stats(self):
        with self._swap_lock:
            self.metrics.reset()
            self.serve_stats.reset()
            self.last_batches.clear()
