from repro.common.hw import TPU_V5E
from repro.common import tree
