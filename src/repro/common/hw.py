"""Hardware constants for the roofline model (target: TPU v5e)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float     # per chip, FLOP/s
    hbm_bandwidth: float       # per chip, B/s
    hbm_bytes: float           # per chip capacity
    ici_link_bandwidth: float  # per link per direction, B/s
    ici_links: int             # torus links per chip (2D torus on v5e: 4)
    vmem_bytes: float          # VMEM per core
    mxu_dim: int               # systolic array tile (128x128)


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_link_bandwidth=50e9,
    ici_links=4,
    vmem_bytes=128 * 1024**2,
    mxu_dim=128,
)
