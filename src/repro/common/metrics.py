"""JSONL metric logging for train/serve/benchmark drivers."""

import json
import os
import time


class MetricLogger:
    def __init__(self, path=None, stdout=True):
        self.path = path
        self.stdout = stdout
        self._fh = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a", buffering=1)
        self._t0 = time.time()

    def log(self, step=None, **kv):
        rec = {"t": round(time.time() - self._t0, 4)}
        if step is not None:
            rec["step"] = int(step)
        for k, v in kv.items():
            rec[k] = float(v) if hasattr(v, "item") else v
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
        if self.stdout:
            parts = " ".join(f"{k}={v:.5g}" if isinstance(v, float) else f"{k}={v}"
                             for k, v in rec.items())
            print(parts, flush=True)
        return rec

    def close(self):
        if self._fh:
            self._fh.close()
