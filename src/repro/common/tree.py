"""Small pytree utilities (the framework does not depend on flax/optax)."""

import jax
import jax.numpy as jnp


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return tree_scale(tree, scale), norm


def param_count(tree):
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree):
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
