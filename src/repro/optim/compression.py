"""Int8 error-feedback gradient compression for cross-pod data parallelism.

The pod axis crosses DCN/optical links (an order of magnitude slower than
ICI), so gradient all-reduce over 'pod' is the term worth compressing.
Scheme: EF21-style — quantize (g + error_carry) to int8 with a per-tensor
scale, all-reduce the int8 payload (pre-scaled by 1/n so the sum cannot
overflow), dequantize, and carry the quantization residual to the next
step. Convergence-safe: the residual is re-injected, so the compressor is
contractive.
"""

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x, n_shards):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / (scale * n_shards)), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, error, axis_name, n_shards):
    """Inside shard_map: all-reduce int8-quantized (grad + error) over
    `axis_name`; returns (mean grads fp32, new error carry)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        # COMMON scale across shards (pmax): per-shard scales cannot be
        # combined in integer space
        scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        # int8 payload on the wire; the reduction happens locally in int32
        qs = jax.lax.all_gather(q, axis_name)            # (n_shards, ...)
        deq = jnp.sum(qs.astype(jnp.int32), axis=0).astype(jnp.float32) * scale
        new_e = g32 - q.astype(jnp.float32) * scale      # error feedback
        return deq, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = tree.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tree.unflatten([o[0] for o in out]), tree.unflatten(
        [o[1] for o in out])


def compress_roundtrip(g, e):
    """Single-process building block (tested without a mesh): returns
    (dequantized, new_error) for one tensor."""
    g32 = g.astype(jnp.float32) + e
    q, scale = _quantize(g32, 1)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq
