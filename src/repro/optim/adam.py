"""AdamW / SGD in pure JAX (pytree-structured state, dtype-policy aware).

State layout mirrors the params pytree so optimizer state inherits the same
sharding rules as the parameters (critical for the 480B-param configs).
"""

import jax
import jax.numpy as jnp

from repro.common import tree as tu


def adamw_init(params, dtype=None):
    def z(x):
        dt = dtype or x.dtype
        return jnp.zeros(x.shape, dt)
    return {
        "mu": jax.tree.map(z, params),
        "nu": jax.tree.map(z, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0, grad_clip=0.0):
    """Returns (new_params, new_state, stats). lr may be a scalar array."""
    gnorm = tu.global_norm(grads)
    if grad_clip:
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
        grads = tu.tree_scale(grads, scale)
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        step = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(g, mu, nu, p)
           for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, {"grad_norm": gnorm}


def sgd_init(params, **_):
    return {"count": jnp.zeros((), jnp.int32)}


def sgd_update(grads, state, params, *, lr, grad_clip=0.0, **_):
    gnorm = tu.global_norm(grads)
    if grad_clip:
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
        grads = tu.tree_scale(grads, scale)
    new_p = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                       - lr * g.astype(jnp.float32)).astype(p.dtype),
                         params, grads)
    return new_p, {"count": state["count"] + 1}, {"grad_norm": gnorm}
