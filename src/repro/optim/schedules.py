"""Learning-rate schedules as jit-friendly step -> lr functions."""

import jax.numpy as jnp


def make_schedule(kind, base_lr, warmup_steps=0, total_steps=1000, min_ratio=0.1):
    warmup_steps = max(warmup_steps, 1)

    def cosine(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup_steps
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    def linear(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup_steps
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        lin = base_lr * (1 - (1 - min_ratio) * t)
        return jnp.where(step < warmup_steps, warm, lin)

    def constant(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / warmup_steps
        return jnp.where(step < warmup_steps, warm, base_lr)

    return {"cosine": cosine, "linear": linear, "constant": constant}[kind]
