from repro.optim.adam import adamw_init, adamw_update, sgd_init, sgd_update
from repro.optim.schedules import make_schedule
