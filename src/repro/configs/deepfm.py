"""deepfm [recsys] — FM branch + deep MLP. [arXiv:1703.04247; paper]

n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm.
Criteo display-ads style cardinalities (39 fields: 13 bucketized-dense +
26 categorical, all embedded per the DeepFM paper's formulation).
"""

from repro.configs.base import RecsysConfig

# 13 bucketized numeric fields (small vocabs) + 26 categorical fields.
DEEPFM_TABLE_SIZES = tuple([64] * 13) + (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
    5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
    7046547, 18, 15, 286181, 105, 142572,
)


def full() -> RecsysConfig:
    return RecsysConfig(
        name="deepfm", kind="deepfm",
        n_dense=0, n_sparse=39, embed_dim=10,
        table_sizes=DEEPFM_TABLE_SIZES,
        mlp=(400, 400, 400),
        interaction="fm",
    )


def smoke() -> RecsysConfig:
    return RecsysConfig(
        name="deepfm-smoke", kind="deepfm",
        n_dense=0, n_sparse=8, embed_dim=8,
        table_sizes=(64,) * 4 + (500, 100, 1000, 13),
        mlp=(32, 32),
        interaction="fm",
    )
