"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2.
"""

from repro.configs.base import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        moe=True, n_experts=128, moe_top_k=2, moe_d_ff=4864,
        dense_residual=True,
        rope_theta=1e6,
        # 480B params: bf16 optimizer state is required to fit 16GiB/chip HBM
        # on a 256-chip pod (see DESIGN.md §4).
        param_dtype="bfloat16", opt_state_dtype="bfloat16",
        logits_chunk=2048, microbatch=8,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab_size=256,
        moe=True, n_experts=8, moe_top_k=2, moe_d_ff=96,
        dense_residual=True, param_dtype="float32", dtype="float32",
    )
