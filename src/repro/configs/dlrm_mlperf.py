"""dlrm-mlperf [recsys] — MLPerf DLRM benchmark config (Criteo 1TB).

[arXiv:1906.00091; paper] n_dense=13 n_sparse=26 embed_dim=128
bot_mlp=13-512-256-128 top_mlp=1024-1024-512-256-1 interaction=dot.
Table sizes are the standard Criteo-1TB cardinalities used by MLPerf.
"""

from repro.configs.base import RecsysConfig

# MLPerf / Criteo Terabyte categorical cardinalities (26 tables, ~188M rows).
CRITEO_1TB_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


def full() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-mlperf", kind="dlrm",
        n_dense=13, n_sparse=26, embed_dim=128,
        table_sizes=CRITEO_1TB_TABLE_SIZES,
        bot_mlp=(512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1),
        interaction="dot",
    )


def smoke() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-mlperf-smoke", kind="dlrm",
        n_dense=13, n_sparse=6, embed_dim=16,
        table_sizes=(1000, 200, 50, 1000, 31, 7),
        bot_mlp=(32, 16),
        top_mlp=(64, 32, 1),
        interaction="dot",
    )
