"""Assigned input-shape sets, one per architecture family.

Every (arch x shape) pair is a dry-run cell. `mode` selects which step gets
lowered: train_step / prefill_step / decode_step / serve_step.
"""

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    mode: str                      # train | prefill | decode | serve | retrieval
    # lm
    seq_len: int = 0
    global_batch: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    n_graphs: int = 0
    # recsys
    batch: int = 0
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k":    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k":   ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg":  ShapeSpec("minibatch_lg", "train",
                               n_nodes=232965, n_edges=114615892,
                               batch_nodes=1024, fanout=(15, 10), d_feat=602),
    "ogb_products":  ShapeSpec("ogb_products", "train",
                               n_nodes=2449029, n_edges=61859140, d_feat=100),
    "molecule":      ShapeSpec("molecule", "train",
                               n_nodes=30, n_edges=64, n_graphs=128),
}

RECSYS_SHAPES = {
    "train_batch":    ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99":      ShapeSpec("serve_p99", "serve", batch=512),
    "serve_bulk":     ShapeSpec("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                batch=1, n_candidates=1_000_000),
}

# the paper's own retrieval system (extra, beyond the 40 assigned cells)
RETRIEVAL_SHAPES = {
    "serve_256": ShapeSpec("serve_256", "retrieval", batch=256),
}

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
    "retrieval": RETRIEVAL_SHAPES,
}


def shapes_for(family: str):
    return FAMILY_SHAPES[family]


def cell_is_skipped(arch_cfg, shape: ShapeSpec) -> Optional[str]:
    """Return a skip-reason string if this (arch, shape) cell must be skipped.

    Policy (assignment): long_500k needs sub-quadratic attention; run it only
    for archs with bounded-window / sub-quadratic attention (mixtral SWA).
    """
    if shape.name == "long_500k" and getattr(arch_cfg, "family", "") == "lm":
        if getattr(arch_cfg, "sliding_window", None) is None:
            return ("SKIP(full-attention): 524288-token KV with pure full "
                    "attention is excluded per assignment; see DESIGN.md §6")
    return None
