"""The paper's own system config: CluSD on MS MARCO passages.

N=8192 clusters, n=32 LSTM candidates, v=6 sparse-result bins, u=6
inter-cluster bins, m=128 neighbor graph, hidden=32, theta=0.02,
5000 training queries, 150 epochs (paper §2-3). `ondisk()` mirrors the
Table-4 setting (N=65000, smaller clusters for block-I/O control).
"""

from repro.configs.base import CluSDConfig


def full() -> CluSDConfig:
    return CluSDConfig(name="clusd-msmarco")


def ondisk() -> CluSDConfig:
    return CluSDConfig(name="clusd-msmarco-ondisk", n_clusters=65000,
                       max_selected=64)


def repllama() -> CluSDConfig:
    # Table 5: RepLLaMA 4096-dim embeddings, N=60000.
    return CluSDConfig(name="clusd-repllama", dim=4096, n_clusters=60000,
                       max_selected=64)


def smoke() -> CluSDConfig:
    return CluSDConfig(
        name="clusd-smoke",
        n_docs=4096, dim=32, n_clusters=64, vocab=512,
        max_postings=256, doc_terms=16,
        k_sparse=128, bins=(10, 25, 50, 128), n_candidates=16,
        lstm_hidden=16, n_neighbors=16, u_bins=4,
        max_selected=8, k_final=64,
        train_queries=64, epochs=10,
    )
