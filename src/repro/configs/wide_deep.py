"""wide-deep [recsys] — wide linear branch + deep MLP. [arXiv:1606.07792]

n_sparse=40 embed_dim=32 mlp=1024-512-256 interaction=concat.
Google-Play-scale cardinalities: a few huge id vocabs (user/app ids),
mid-size categorical, and small demographic fields.
"""

from repro.configs.base import RecsysConfig

WIDE_DEEP_TABLE_SIZES = (
    # huge id spaces
    10_000_000, 10_000_000, 1_000_000, 1_000_000,
    # mid categorical
    100_000, 100_000, 50_000, 50_000, 10_000, 10_000, 10_000, 10_000,
    5_000, 5_000, 2_000, 2_000, 1_000, 1_000, 1_000, 1_000,
    # small demographic / device fields
    500, 500, 200, 200, 100, 100, 100, 100, 50, 50,
    40, 40, 30, 30, 20, 20, 10, 10, 5, 5,
)


def full() -> RecsysConfig:
    return RecsysConfig(
        name="wide-deep", kind="wide_deep",
        n_dense=0, n_sparse=40, embed_dim=32,
        table_sizes=WIDE_DEEP_TABLE_SIZES,
        mlp=(1024, 512, 256),
        interaction="concat",
    )


def smoke() -> RecsysConfig:
    return RecsysConfig(
        name="wide-deep-smoke", kind="wide_deep",
        n_dense=0, n_sparse=6, embed_dim=8,
        table_sizes=(2000, 500, 100, 50, 10, 5),
        mlp=(32, 16),
        interaction="concat",
    )
