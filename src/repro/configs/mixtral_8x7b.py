"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA window 4096.
"""

from repro.configs.base import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x7b",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        moe=True, n_experts=8, moe_top_k=2, moe_d_ff=14336,
        sliding_window=4096,
        rope_theta=1e6,
        logits_chunk=2048, microbatch=8,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        moe=True, n_experts=4, moe_top_k=2, moe_d_ff=128,
        sliding_window=16, param_dtype="float32", dtype="float32",
    )
