"""qwen2-1.5b [dense] — GQA kv=2, QKV bias. [arXiv:2407.10671; hf]"""

from repro.configs.base import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936,
        qkv_bias=True, rope_theta=1e6,
        logits_chunk=2048, microbatch=4,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-1.5b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256,
        qkv_bias=True, param_dtype="float32", dtype="float32",
    )
