"""nequip [gnn] — O(3)-equivariant interatomic potential. [arXiv:2101.03164]

n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3) tensor products.
Non-molecular graph shapes (citation / product graphs) carry no physical
coordinates; `input_specs` supplies synthetic 3-D positions so the
equivariant machinery is exercised unchanged (DESIGN.md §6).
"""

from repro.configs.base import NequIPConfig


def full() -> NequIPConfig:
    return NequIPConfig(
        name="nequip",
        n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0,
        n_species=32,
    )


def smoke() -> NequIPConfig:
    return NequIPConfig(
        name="nequip-smoke",
        n_layers=2, d_hidden=8, l_max=2, n_rbf=4, cutoff=5.0,
        n_species=8,
    )
