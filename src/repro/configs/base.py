"""Config dataclasses for every architecture family + the CluSD retrieval system.

Configs are plain frozen dataclasses (no framework deps) so they can be
constructed from CLI flags, serialized into checkpoints, and hashed for
dry-run artifact caching.
"""

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    family: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qkv_bias: bool = False
    # MoE
    moe: bool = False
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False            # arctic: dense FFN parallel to MoE
    # attention
    sliding_window: Optional[int] = None    # SWA window (mixtral)
    rope_theta: float = 1e6
    # numerics / memory policy
    dtype: str = "bfloat16"                 # activations / compute
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"        # arctic uses bf16 to fit HBM
    remat: bool = True
    logits_chunk: int = 0                   # chunked xent (0 = off)
    microbatch: int = 0                     # grad-accumulation splits (0 = off)
    moe_impl: str = "sort"                  # sort | ep_shard_map (§Perf)
    grad_accum_dtype: str = "float32"       # bf16 halves grad-RS wire (§Perf)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + L layers + final norm)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        dense_ffn = 3 * d * self.d_ff
        per_layer = attn + 2 * d  # norms
        if self.moe:
            per_layer += self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
            if self.dense_residual:
                per_layer += dense_ffn
        else:
            per_layer += dense_ffn
        return self.vocab_size * d * 2 + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.moe_top_k) * 3 * d * self.moe_d_ff
        return total - inactive


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    family: str = "gnn"
    n_layers: int = 5
    d_hidden: int = 32          # channels per irrep order
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 0             # raw input node feature dim (0 = species one-hot)
    n_species: int = 32
    readout_dim: int = 1        # per-node energy head
    dtype: str = "float32"
    param_dtype: str = "float32"
    msg_impl: str = "pjit"      # pjit | owner_shard_map (§Perf)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    family: str = "recsys"
    kind: str = "dlrm"                      # dlrm | deepfm | wide_deep | din
    n_dense: int = 0
    n_sparse: int = 26
    embed_dim: int = 128
    table_sizes: Tuple[int, ...] = ()       # rows per sparse table
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()               # deepfm / wide_deep / din deep branch
    attn_mlp: Tuple[int, ...] = ()          # din local activation unit
    seq_len: int = 0                        # din behavior sequence
    interaction: str = "dot"                # dot | fm | concat | target-attn
    multi_hot: int = 1                      # lookups per sparse feature
    dtype: str = "float32"
    param_dtype: str = "float32"
    retrieval_local_topk: bool = False      # shard-local guide top-k (§Perf)

    def total_rows(self) -> int:
        return sum(self.table_sizes)


@dataclasses.dataclass(frozen=True)
class CluSDConfig:
    """The paper's system. Defaults = paper's MS MARCO settings (§2, §3)."""
    name: str = "clusd"
    family: str = "retrieval"
    # corpus
    n_docs: int = 8_800_000
    dim: int = 768                   # RetroMAE/SimLM dim; RepLLaMA = 4096
    n_clusters: int = 8192           # N
    # sparse index
    vocab: int = 30522
    max_postings: int = 4096         # per-term posting budget (padded)
    doc_terms: int = 128             # avg nnz per doc (synthetic)
    # stage 1
    k_sparse: int = 1000             # sparse retrieval depth k
    bins: Tuple[int, ...] = (10, 25, 50, 100, 200, 500, 1000)  # bin edges (v=6+tail)
    n_candidates: int = 32           # n = LSTM input sequence length
    # stage 2
    lstm_hidden: int = 32
    n_neighbors: int = 128           # m: top-m centroid neighbor graph
    u_bins: int = 6                  # inter-cluster distance bins
    theta: float = 0.02              # selection threshold
    max_selected: int = 32           # static selection budget (TPU adaptation)
    # fusion
    alpha: float = 0.5               # sparse weight (both fusion methods)
    k_final: int = 1000
    fusion: str = "interp"           # "interp" | "rrf" (core/fusion.py)
    rrf_k: float = 60.0              # RRF rank constant (fusion="rrf")
    # hybrid candidate generation: LADR-style neighbor-graph expansion of
    # the stage-1 seeds (core/stage1.expand_candidates); 0 = off
    expand_depth: int = 0
    # training
    train_queries: int = 5000
    epochs: int = 150
    lr: float = 1e-3
    # BCE positive-class weight for selector training; None = derive from
    # the observed positive rate of the label set (repro.train.trainer)
    pos_weight: Optional[float] = 4.0
    dtype: str = "float32"
    impl: str = "shard_map"          # shard_map (optimized) | pjit (naive)
    serve_batch: int = 256

    @property
    def v_bins(self) -> int:
        return len(self.bins)

    @property
    def n_candidates_total(self) -> int:
        """Stage-1 candidate width after graph expansion: each expansion
        step budgets one extra n_candidates block, capped at N."""
        return min(self.n_candidates * (1 + max(self.expand_depth, 0)),
                   self.n_clusters)

    @property
    def cluster_cap(self) -> int:
        """Padded (balanced) cluster block size."""
        import math
        return max(8, 2 ** math.ceil(math.log2(1.5 * self.n_docs / self.n_clusters)))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    schedule: str = "cosine"
    ckpt_every: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    grad_compression: bool = False   # int8 error-feedback all-reduce
    microbatch: int = 0              # grad accumulation (0 = off)
