"""din [recsys] — Deep Interest Network, target attention over behavior
sequence. [arXiv:1706.06978; paper]

embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80 interaction=target-attn.
Amazon-Books-style cardinalities: item id, category id (behavior and target
share tables), plus user-profile fields.
"""

from repro.configs.base import RecsysConfig

# tables: [item_id, cate_id, user_id, age_bucket, gender]
DIN_TABLE_SIZES = (371530, 1601, 543060, 8, 3)


def full() -> RecsysConfig:
    return RecsysConfig(
        name="din", kind="din",
        n_dense=0, n_sparse=5, embed_dim=18,
        table_sizes=DIN_TABLE_SIZES,
        mlp=(200, 80),
        attn_mlp=(80, 40),
        seq_len=100,
        interaction="target-attn",
    )


def smoke() -> RecsysConfig:
    return RecsysConfig(
        name="din-smoke", kind="din",
        n_dense=0, n_sparse=5, embed_dim=8,
        table_sizes=(1000, 50, 500, 8, 3),
        mlp=(32, 16),
        attn_mlp=(16, 8),
        seq_len=12,
        interaction="target-attn",
    )
