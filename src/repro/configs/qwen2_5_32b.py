"""qwen2.5-32b [dense] — GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.configs.base import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-32b",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=27648, vocab_size=152064,
        qkv_bias=True, rope_theta=1e6,
        logits_chunk=2048, microbatch=8,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2.5-32b-smoke",
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=1,
        d_ff=192, vocab_size=256,
        qkv_bias=True, head_dim=16, param_dtype="float32", dtype="float32",
    )
