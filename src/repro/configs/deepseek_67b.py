"""deepseek-67b [dense] — llama-arch GQA kv=8. [arXiv:2401.02954; hf]

Also used as the RepLLaMA-style LLM dense-retrieval encoder in the CluSD
Table-5 benchmark (high-dimension corpus embeddings).
"""

from repro.configs.base import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-67b",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=102400,
        rope_theta=1e4,
        logits_chunk=2048, microbatch=16,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-67b-smoke",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=160, vocab_size=256, param_dtype="float32", dtype="float32",
    )
