"""Architecture registry: `get_config("<arch-id>")` / `--arch <id>` CLI.

Each module exposes `full()` (the exact assigned config) and `smoke()`
(a reduced same-family config used by CPU tests).
"""

import importlib

from repro.configs.base import (
    TransformerConfig, NequIPConfig, RecsysConfig, CluSDConfig, TrainConfig)
from repro.configs.shapes import (
    ShapeSpec, shapes_for, cell_is_skipped, FAMILY_SHAPES)

# arch-id -> module name
ARCH_REGISTRY = {
    "arctic-480b": "arctic_480b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-67b": "deepseek_67b",
    "qwen2.5-32b": "qwen2_5_32b",
    "nequip": "nequip",
    "wide-deep": "wide_deep",
    "din": "din",
    "deepfm": "deepfm",
    "dlrm-mlperf": "dlrm_mlperf",
    # the paper's own retrieval system
    "clusd-msmarco": "clusd_msmarco",
}

ASSIGNED_ARCHS = [a for a in ARCH_REGISTRY if a != "clusd-msmarco"]


def _module(arch: str):
    if arch not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCH_REGISTRY)}")
    return importlib.import_module(f"repro.configs.{ARCH_REGISTRY[arch]}")


def get_config(arch: str, variant: str = "full"):
    mod = _module(arch)
    if not hasattr(mod, variant):
        raise KeyError(f"arch {arch!r} has no variant {variant!r}")
    return getattr(mod, variant)()


def list_archs():
    return list(ARCH_REGISTRY)


def cells(include_skipped=True):
    """All (arch, shape_spec, skip_reason) dry-run cells."""
    out = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg.family).values():
            reason = cell_is_skipped(cfg, shape)
            if reason and not include_skipped:
                continue
            out.append((arch, shape, reason))
    return out
