"""Live metrics endpoint: a stdlib `ThreadingHTTPServer` that exposes a
serving target's MetricsRegistry (and optional SLOMonitor) over HTTP.

The target is duck-typed so the same exporter attaches to a
`RetrievalEngine`, a `ShardRouter`, or any test double:

  * `target.metrics`           — a MetricsRegistry (required)
  * `target.stats()`           — called before each export to let the
                                 target sync derived gauges (optional;
                                 exceptions are swallowed so a scrape
                                 can never take down serving)
  * `target.missing_shards()`  — shards with zero live replicas
                                 (optional; router only) — feeds /healthz

Routes:

  GET /metrics       Prometheus text exposition (registry.to_prometheus())
  GET /metrics.json  registry.snapshot() as JSON
  GET /slo           SLOMonitor.evaluate() + status (or {"state":
                     "disabled"} when no monitor is attached)
  GET /healthz       200 {"ok": true} — or 503 with a "reasons" list when
                     the SLO state is PAGE or any shard has lost every
                     replica

The server runs daemon-threaded on `host:port` (port 0 binds an
ephemeral port, exposed as `exporter.port`), one thread per request, and
never writes access logs. Scrapes are read-only against the registry's
own locks, so concurrent scrapes during live serving are safe.
"""

import http.server
import json
import socketserver
import threading


class _Handler(http.server.BaseHTTPRequestHandler):
    # BaseHTTPRequestHandler logs every request to stderr by default;
    # a scraper polling /metrics at 1 Hz would drown serving output.
    def log_message(self, fmt, *args):
        pass

    def _send(self, code, body, content_type="application/json"):
        data = body.encode() if isinstance(body, str) else body
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):
        exp = self.server.exporter
        path = self.path.split("?", 1)[0]
        try:
            if path == "/metrics":
                self._send(200, exp.render_prometheus(),
                           content_type="text/plain; version=0.0.4")
            elif path == "/metrics.json":
                self._send(200, json.dumps(exp.render_snapshot()))
            elif path == "/slo":
                self._send(200, json.dumps(exp.render_slo()))
            elif path == "/healthz":
                ok, reasons = exp.health()
                self._send(200 if ok else 503,
                           json.dumps({"ok": ok, "reasons": reasons}))
            else:
                self._send(404, json.dumps({"error": f"no route {path}"}))
        except Exception as e:  # a scrape must never crash the server
            try:
                self._send(500, json.dumps({"error": repr(e)}))
            except Exception:
                pass


class _Server(socketserver.ThreadingMixIn, http.server.HTTPServer):
    daemon_threads = True
    allow_reuse_address = True


class MetricsExporter:
    """Attach an HTTP metrics/health surface to a serving target.

    Usage:
        with MetricsExporter(engine, port=0, slo=monitor) as exp:
            url = f"http://127.0.0.1:{exp.port}/metrics"
    """

    def __init__(self, target, *, port=0, host="127.0.0.1", slo=None):
        self.target = target
        self.slo = slo
        self._server = _Server((host, port), _Handler)
        self._server.exporter = self
        self.host = host
        self.port = self._server.server_address[1]
        self._thread = None

    # -- lifecycle ---------------------------------------------------------

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._server.serve_forever, name="metrics-exporter",
                daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._server.shutdown()
        self._server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # -- rendering ---------------------------------------------------------

    def _sync(self):
        """Let the target fold derived/per-host gauges into its registry
        before export. Best-effort: serving state may be mid-transition
        (e.g. a reload), and a scrape must never raise into serving."""
        stats = getattr(self.target, "stats", None)
        if callable(stats):
            try:
                stats()
            except Exception:
                pass

    def render_prometheus(self):
        self._sync()
        return self.target.metrics.to_prometheus()

    def render_snapshot(self):
        self._sync()
        return self.target.metrics.snapshot()

    def render_slo(self):
        if self.slo is None:
            return {"state": "disabled"}
        self.slo.evaluate()
        return self.slo.status()

    def health(self):
        """(ok, reasons). Unhealthy when the SLO pages or a shard has no
        live replica left; otherwise healthy."""
        reasons = []
        if self.slo is not None:
            self.slo.evaluate()
            if self.slo.state == "PAGE":
                reasons.append("slo_page")
        missing = getattr(self.target, "missing_shards", None)
        if callable(missing):
            try:
                lost = list(missing())
            except Exception as e:
                lost = []
                reasons.append(f"missing_shards_error:{e!r}")
            if lost:
                reasons.append(f"shards_without_replicas:{sorted(lost)}")
        return (not reasons), reasons
