"""repro.obs: the repo's single observability surface.

Two small, dependency-free primitives that every hot path (serve, index
build/update, selector training) reports through:

  * MetricsRegistry (obs/registry.py) — named counters, gauges, and
    fixed-bucket latency histograms. Thread-safe, bounded memory,
    snapshot-able to a plain dict and to Prometheus text exposition.
  * Tracer (obs/trace.py) — per-request/per-batch stage-span traces
    (nested spans with wall-clock + byte/op annotations), a
    `sample_rate` knob, and JSONL / Chrome-trace exporters.

The catalog of every metric and span the repo emits lives in
docs/OBSERVABILITY.md. Neither primitive imports jax or anything under
repro.engine/index/train, so any layer can depend on obs without cycles.
"""

from repro.obs.registry import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, write_metrics,
)
from repro.obs.trace import (  # noqa: F401
    NOOP_SPAN, NOOP_TRACE, Span, Trace, Tracer, write_trace,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "write_metrics",
    "NOOP_SPAN", "NOOP_TRACE", "Span", "Trace", "Tracer", "write_trace",
]
