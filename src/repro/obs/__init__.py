"""repro.obs: the repo's single observability surface.

Dependency-free primitives that every hot path (serve, index
build/update, selector training) reports through:

  * MetricsRegistry (obs/registry.py) — named counters, gauges, and
    fixed-bucket latency histograms. Thread-safe, bounded memory,
    snapshot-able to a plain dict and to Prometheus text exposition.
  * Tracer (obs/trace.py) — per-request/per-batch stage-span traces
    (nested spans with wall-clock + byte/op annotations), a
    `sample_rate` knob, and JSONL / Chrome-trace exporters.
  * SLOMonitor (obs/slo.py) — declarative objectives (latency p99,
    error rate, gauge drift) evaluated as multi-window burn rates
    against any registry snapshot, with an OK/WARN/PAGE state machine.
  * MetricsExporter (obs/exporter.py) — live HTTP surface (/metrics,
    /metrics.json, /slo, /healthz) over a serving target's registry.
  * ExplainLogger (obs/explain.py) — sampled per-query explain
    telemetry transport (JSONL + bounded in-memory ring).

The catalog of every metric and span the repo emits lives in
docs/OBSERVABILITY.md. Nothing here imports jax or anything under
repro.engine/index/train, so any layer can depend on obs without cycles.
"""

from repro.obs.explain import ExplainLogger  # noqa: F401
from repro.obs.exporter import MetricsExporter  # noqa: F401
from repro.obs.registry import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, write_metrics,
)
from repro.obs.slo import (  # noqa: F401
    SLOMonitor, SLOObjective, default_objectives,
)
from repro.obs.trace import (  # noqa: F401
    NOOP_SPAN, NOOP_TRACE, Span, Trace, Tracer, write_trace,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "write_metrics",
    "NOOP_SPAN", "NOOP_TRACE", "Span", "Trace", "Tracer", "write_trace",
    "SLOMonitor", "SLOObjective", "default_objectives",
    "MetricsExporter", "ExplainLogger",
]
