"""Declarative SLO evaluation over a MetricsRegistry: burn rates,
multi-window alerting, and an OK/WARN/PAGE state machine.

An `SLOObjective` names a metric in the registry and a threshold; an
`SLOMonitor` samples `registry.snapshot()` on every `evaluate()` call,
keeps a bounded time series per objective, and reduces each objective to
a *burn rate* — how fast the error budget is being consumed, where 1.0
means "exactly at the objective" — over TWO rolling windows (SRE-style
multi-window alerting):

  * the FAST window (default 60 s) reacts quickly but is noisy;
  * the SLOW window (default 300 s) confirms the burn is sustained.

An objective pages only when BOTH windows burn past `page_burn` (and
warns when both pass `warn_burn`), so a single slow batch cannot page
and a sustained regression cannot hide behind an old quiet period.

Objective kinds (all read the plain `snapshot()` dict, so any registry-
shaped object works and nothing here imports the engine):

  * "latency"    — histogram `metric`; the sampled value is the recent-
                   window p99 (the histogram ring); burn = p99/threshold.
  * "error_rate" — counter `metric` (errors) over counter `total`
                   (requests); the windowed value is delta(errors)/
                   delta(total); burn = rate/threshold. A threshold of 0
                   means zero tolerance: any windowed error pages.
  * "gauge"      — gauge `metric`; burn = abs(value)/threshold (used for
                   recall-proxy drift, where the gauge carries the drift).

State transitions append to a bounded event log (`deque(maxlen=...)`):
{"t", "objective", "from", "to", "value", "burn_fast", "burn_slow"}.

The clock is injectable (`clock=` a monotonic-seconds callable), so every
transition above is unit-testable deterministically; serving code uses
the default `time.monotonic`.

Dependency-free (stdlib only) like the rest of repro.obs.
"""

import collections
import dataclasses
import json
import math
import time

OK, WARN, PAGE = "OK", "WARN", "PAGE"
_SEVERITY = {OK: 0, WARN: 1, PAGE: 2}


@dataclasses.dataclass(frozen=True)
class SLOObjective:
    """One declarative objective. `threshold` is the objective itself
    (ms for "latency", error fraction for "error_rate", absolute value
    for "gauge"); burn = measured/threshold, 1.0 = exactly on budget."""

    name: str
    kind: str                    # "latency" | "error_rate" | "gauge"
    metric: str                  # histogram / counter / gauge name
    threshold: float
    total: str = ""              # denominator counter ("error_rate" only)
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    warn_burn: float = 1.0
    page_burn: float = 2.0

    def __post_init__(self):
        if self.kind not in ("latency", "error_rate", "gauge"):
            raise ValueError(f"unknown objective kind {self.kind!r}")
        if self.kind == "error_rate" and not self.total:
            raise ValueError(f"error_rate objective {self.name!r} needs a "
                             f"`total` counter")
        if self.threshold < 0:
            raise ValueError(f"negative threshold on {self.name!r}")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError(f"fast window > slow window on {self.name!r}")

    @classmethod
    def from_dict(cls, d):
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown SLO objective keys {sorted(extra)}")
        return cls(**d)


def default_objectives(p99_gate_ms=500.0, failure_budget=0.0,
                       drift_gate=0.05, fast_window_s=15.0,
                       slow_window_s=60.0):
    """The standard serving triple: p99 latency on `serve.batch_ms`,
    failed-request rate on `soak.failed_requests`/`soak.requests`, and
    recall-proxy drift on the `soak.recall_drift` gauge. The soak harness
    (benchmarks/soak.py) maintains the soak.* metrics; a plain serve run
    that never registers them evaluates those objectives as burn 0."""
    return [
        SLOObjective(name="p99_latency", kind="latency",
                     metric="serve.batch_ms", threshold=float(p99_gate_ms),
                     fast_window_s=fast_window_s,
                     slow_window_s=slow_window_s,
                     warn_burn=0.75, page_burn=1.0),
        SLOObjective(name="failed_requests", kind="error_rate",
                     metric="soak.failed_requests", total="soak.requests",
                     threshold=float(failure_budget),
                     fast_window_s=fast_window_s,
                     slow_window_s=slow_window_s,
                     warn_burn=1.0, page_burn=1.0),
        SLOObjective(name="recall_drift", kind="gauge",
                     metric="soak.recall_drift", threshold=float(drift_gate),
                     fast_window_s=fast_window_s,
                     slow_window_s=slow_window_s,
                     warn_burn=0.75, page_burn=1.0),
    ]


class SLOMonitor:
    """Evaluates objectives against a registry's snapshot() time series.

    Usage:
        mon = SLOMonitor(engine.metrics, default_objectives())
        ...
        mon.evaluate()          # call periodically (a control loop / the
        mon.state               # /slo endpoint); OK | WARN | PAGE
        mon.verdict()           # summary dict for BENCH_soak.json
    """

    def __init__(self, registry, objectives, *, clock=time.monotonic,
                 event_capacity=256, max_samples=4096):
        if not objectives:
            raise ValueError("SLOMonitor needs at least one objective")
        self.registry = registry
        self.objectives = list(objectives)
        names = [o.name for o in self.objectives]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objective names: {names}")
        self._clock = clock
        self._max_samples = int(max_samples)
        # per objective: deque of (t, value, total_value) samples
        self._samples = {o.name: collections.deque(maxlen=self._max_samples)
                         for o in self.objectives}
        self._states = {o.name: OK for o in self.objectives}
        self._last = {o.name: {"state": OK, "value": None,
                               "burn_fast": 0.0, "burn_slow": 0.0}
                      for o in self.objectives}
        self.events = collections.deque(maxlen=int(event_capacity))
        self.n_evaluations = 0
        self._worst_state = OK
        self._page_count = 0
        self._warn_count = 0

    # -- config ------------------------------------------------------------

    @classmethod
    def from_config(cls, registry, config, **kw):
        """`config` is a dict {"objectives": [...]} or a path to a JSON
        file with that shape (the --slo-config format)."""
        if isinstance(config, str):
            with open(config) as f:
                config = json.load(f)
        objs = [SLOObjective.from_dict(d) for d in config["objectives"]]
        return cls(registry, objs, **kw)

    # -- sampling ----------------------------------------------------------

    @staticmethod
    def _read(snap, obj):
        """(value, total) sample for one objective from a snapshot dict.
        Unregistered metrics read as 0 — an objective over a metric the
        process never touched burns nothing."""
        if obj.kind == "latency":
            h = snap.get("histograms", {}).get(obj.metric) or {}
            return float(h.get("p99", 0.0) or 0.0), 0.0
        if obj.kind == "error_rate":
            c = snap.get("counters", {})
            return (float(c.get(obj.metric, 0) or 0),
                    float(c.get(obj.total, 0) or 0))
        g = snap.get("gauges", {})
        return float(g.get(obj.metric, 0.0) or 0.0), 0.0

    @staticmethod
    def _window(samples, now, window_s):
        """Samples inside [now - window_s, now], plus the newest older
        sample as the delta baseline (cumulative counters need a start
        point; without one the window starts at the oldest sample)."""
        cutoff = now - window_s
        inside, baseline = [], None
        for s in samples:
            if s[0] >= cutoff:
                inside.append(s)
            else:
                baseline = s
        return inside, baseline

    def _burn(self, obj, now):
        """(value, burn) over one window width for `obj`."""
        out = {}
        samples = self._samples[obj.name]
        for label, width in (("fast", obj.fast_window_s),
                             ("slow", obj.slow_window_s)):
            inside, baseline = self._window(samples, now, width)
            if not inside:
                out[label] = (0.0, 0.0)
                continue
            if obj.kind == "error_rate":
                first = baseline if baseline is not None else inside[0]
                last = inside[-1]
                d_err = last[1] - first[1]
                d_tot = last[2] - first[2]
                if d_err <= 0:
                    rate = 0.0
                elif d_tot <= 0:
                    rate = math.inf
                else:
                    rate = d_err / d_tot
                if obj.threshold > 0:
                    burn = rate / obj.threshold
                else:
                    burn = math.inf if rate > 0 else 0.0
                out[label] = (rate, burn)
            else:
                # latency/gauge: the windowed value is the worst sample
                value = max(abs(s[1]) for s in inside)
                burn = value / obj.threshold if obj.threshold > 0 \
                    else (math.inf if value > 0 else 0.0)
                out[label] = (value, burn)
        return out

    # -- evaluation --------------------------------------------------------

    def evaluate(self):
        """Sample the registry, update every objective's multi-window burn
        and state, log transitions. Returns {"t", "state", "objectives"}."""
        now = float(self._clock())
        snap = self.registry.snapshot()
        self.n_evaluations += 1
        results = {}
        for obj in self.objectives:
            value, total = self._read(snap, obj)
            self._samples[obj.name].append((now, value, total))
            burns = self._burn(obj, now)
            (vf, bf), (vs, bs) = burns["fast"], burns["slow"]
            if bf >= obj.page_burn and bs >= obj.page_burn:
                state = PAGE
            elif bf >= obj.warn_burn and bs >= obj.warn_burn:
                state = WARN
            else:
                state = OK
            prev = self._states[obj.name]
            if state != prev:
                self.events.append({
                    "t": round(now, 3), "objective": obj.name,
                    "from": prev, "to": state,
                    "value": round(vf, 6) if math.isfinite(vf) else vf,
                    "burn_fast": round(bf, 4) if math.isfinite(bf) else "inf",
                    "burn_slow": round(bs, 4) if math.isfinite(bs) else "inf",
                })
                self._states[obj.name] = state
                if state == PAGE:
                    self._page_count += 1
                elif state == WARN:
                    self._warn_count += 1
            if _SEVERITY[state] > _SEVERITY[self._worst_state]:
                self._worst_state = state
            self._last[obj.name] = {
                "state": state,
                "value": round(vf, 6) if math.isfinite(vf) else "inf",
                "burn_fast": round(bf, 4) if math.isfinite(bf) else "inf",
                "burn_slow": round(bs, 4) if math.isfinite(bs) else "inf",
            }
            results[obj.name] = self._last[obj.name]
        return {"t": round(now, 3), "state": self.state,
                "objectives": results}

    @property
    def state(self):
        """Current overall state: the worst of the per-objective states."""
        worst = OK
        for s in self._states.values():
            if _SEVERITY[s] > _SEVERITY[worst]:
                worst = s
        return worst

    def status(self):
        """Snapshot for the /slo endpoint: current state, last evaluation
        per objective, recent transition events."""
        return {"state": self.state,
                "n_evaluations": self.n_evaluations,
                "objectives": {o.name: dict(self._last[o.name],
                                            kind=o.kind, metric=o.metric,
                                            threshold=o.threshold)
                               for o in self.objectives},
                "events": list(self.events)}

    def verdict(self):
        """End-of-run judgement for BENCH_soak.json: final + worst state,
        page/warn transition counts, per-objective last burns."""
        return {"final_state": self.state,
                "worst_state": self._worst_state,
                "pages": self._page_count,
                "warns": self._warn_count,
                "n_evaluations": self.n_evaluations,
                "objectives": {o.name: dict(self._last[o.name],
                                            threshold=o.threshold)
                               for o in self.objectives},
                "ok": self._worst_state != PAGE}
