"""Per-query explain telemetry: sampled JSONL records of *why* each
query retrieved what it did.

`ExplainLogger` is pure transport — sampling, serialization, bounded
in-memory retention — and stays stdlib-only like the rest of repro.obs.
Record *construction* (which needs numpy and pipeline internals) lives in
`engine/server.py::build_explain_records`, shared by the single-host
engine and the shard router.

Sampling mirrors `Tracer`: a deterministic accumulator, so rate 0.25
means exactly every 4th batch is explained — reproducible runs, no RNG.

Each emitted record is one JSON object per line (JSONL). The schema is
documented in docs/OBSERVABILITY.md; the load-bearing fields:

  qid          global query index (engine serve-stats order)
  generation   index generation that served the query
  cand         stage-1 candidate cluster ids (seed + graph expansion)
  provenance   per-candidate "seed" | "expand" (seed = rank < n_candidates)
  probs        selector probability per candidate (rounded)
  selected     cluster ids the selector kept (theta + budget)
  n_over_theta / skipped_over_theta   budget-cutoff visibility
  fusion_contrib  final-top-k membership split: sparse_only/dense_only/both
  host_contrib    (router only) final ids contributed per host

Disabled path: `engine.explain is None` — a single attribute check per
batch, so the PR-7 trace-overhead gate is unaffected.
"""

import json
import threading


class ExplainLogger:
    """Sampled JSONL sink for explain records.

    Args:
        path: output JSONL file (opened lazily on first emit); None keeps
            records only in the in-memory ring (tests).
        sample_rate: fraction of batches to explain, in [0, 1].
            Deterministic accumulator — rate r explains every ~1/r-th
            batch exactly, starting with the first.
        capacity: in-memory ring size (most recent records kept).
    """

    def __init__(self, path=None, *, sample_rate=1.0, capacity=512):
        if not (0.0 <= sample_rate <= 1.0):
            raise ValueError(f"sample_rate {sample_rate} not in [0, 1]")
        self.path = path
        self.sample_rate = float(sample_rate)
        self.capacity = int(capacity)
        self._acc = 1.0          # first batch sampled when rate > 0
        self._lock = threading.Lock()
        self._fh = None
        self._ring = []
        self.n_sampled = 0
        self.n_skipped = 0
        self.n_records = 0

    def sample(self):
        """Decide whether to explain the next batch. Deterministic: an
        accumulator gains `sample_rate` per call and a batch is sampled
        each time it crosses 1."""
        with self._lock:
            if self.sample_rate <= 0.0:
                self.n_skipped += 1
                return False
            self._acc += self.sample_rate
            if self._acc >= 1.0:
                self._acc -= 1.0
                self.n_sampled += 1
                return True
            self.n_skipped += 1
            return False

    def emit(self, record):
        """Write one explain record (a JSON-serializable dict)."""
        line = json.dumps(record, separators=(",", ":"))
        with self._lock:
            self._ring.append(record)
            if len(self._ring) > self.capacity:
                del self._ring[:len(self._ring) - self.capacity]
            self.n_records += 1
            if self.path is not None:
                if self._fh is None:
                    self._fh = open(self.path, "w")
                self._fh.write(line + "\n")

    def recent(self):
        """Most recent records (bounded by `capacity`), oldest first."""
        with self._lock:
            return list(self._ring)

    def flush(self):
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self):
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def stats(self):
        with self._lock:
            return {"n_sampled": self.n_sampled,
                    "n_skipped": self.n_skipped,
                    "n_records": self.n_records,
                    "sample_rate": self.sample_rate,
                    "path": self.path}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
