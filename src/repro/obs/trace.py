"""Stage-span tracing: nested wall-clock spans per request/batch.

A `Trace` is one request's (or one build/reload's) tree of named spans:

    tr = tracer.trace("batch", size=32)       # opens the root span
    with tr.span("stage1"):
        ...
    with tr.span("cache_fetch", n_blocks=17) as sp:
        with tr.span("disk_fetch"):           # nests under cache_fetch
            ...
        sp.annotate(bytes=blocks.nbytes)
    tr.finish(compiled=False)                 # closes the root span

Spans record start offset + duration (time.perf_counter), nesting depth,
parent index, and free-form annotations (byte/op counts). The span
catalog the engine/index/train paths emit is in docs/OBSERVABILITY.md.

`Tracer` owns sampling and retention: `sample_rate` in [0, 1] decides
(deterministically, via an accumulator — no RNG) which traces are
recorded; unsampled requests get the shared NOOP_TRACE whose span() is a
reusable no-op context manager, so the disabled path costs one float add
and no allocation. Finished traces land in a bounded deque (`capacity`,
oldest dropped and counted) and export as:

  * JSONL — one span per line:
      {"trace": 3, "trace_name": "batch", "span": "stage1", "index": 1,
       "parent": 0, "depth": 1, "t0_ms": 0.01, "dur_ms": 1.2, ...annot}
  * Chrome trace JSON ({"traceEvents": [...]}, "X" complete events,
    microsecond timestamps) — open in chrome://tracing or Perfetto.

Validated by benchmarks/check_trace.py (CI runs it on a serve trace).
"""

import json
import threading
import time


class Span:
    """One timed region. Context manager; closes itself on __exit__."""

    __slots__ = ("name", "index", "parent", "depth", "t0_ms", "dur_ms",
                 "annot", "_trace")

    def __init__(self, trace, name, index, parent, depth, t0_ms, annot):
        self._trace = trace
        self.name = name
        self.index = index
        self.parent = parent
        self.depth = depth
        self.t0_ms = t0_ms
        self.dur_ms = None          # open until __exit__/end()
        self.annot = annot

    def annotate(self, **kw):
        self.annot.update(kw)
        return self

    def end(self):
        """Close without a `with` block (phases that straddle scopes)."""
        self._trace._close(self)
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._trace._close(self)
        return False

    def to_dict(self, trace_id, trace_name):
        d = {"trace": trace_id, "trace_name": trace_name,
             "span": self.name, "index": self.index, "parent": self.parent,
             "depth": self.depth, "t0_ms": round(self.t0_ms, 3),
             "dur_ms": round(self.dur_ms or 0.0, 3)}
        d.update(self.annot)
        return d


class _NoopSpan:
    """Shared do-nothing span: the tracing-disabled hot path."""

    __slots__ = ()

    def annotate(self, **kw):
        return self

    def end(self):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class _NoopTrace:
    """Shared do-nothing trace returned for unsampled requests."""

    __slots__ = ()
    spans = ()

    def span(self, name, **annot):
        return NOOP_SPAN

    def add_completed(self, name, *, t0_abs, dur_ms, parent=None, **annot):
        return NOOP_SPAN

    def annotate(self, **kw):
        return self

    def finish(self, **annot):
        return self


NOOP_SPAN = _NoopSpan()
NOOP_TRACE = _NoopTrace()


class Trace:
    """A tree of spans for one request/batch. Single-threaded by design:
    spans nest via a stack owned by the thread driving the request."""

    def __init__(self, tracer, trace_id, name, t0_rel_ms, annot):
        self._tracer = tracer
        self.trace_id = trace_id
        self.name = name
        self.t0_rel_ms = t0_rel_ms      # offset from tracer epoch
        self._t0 = time.perf_counter()
        self.spans = []
        self._stack = []
        # span 0 is the implicit root covering the whole trace
        root = Span(self, name, 0, -1, 0, 0.0, dict(annot))
        self.spans.append(root)
        self._stack.append(root)

    def _now_ms(self):
        return (time.perf_counter() - self._t0) * 1e3

    def span(self, name, **annot):
        """Open a child span of the innermost open span."""
        parent = self._stack[-1] if self._stack else self.spans[0]
        sp = Span(self, name, len(self.spans), parent.index,
                  parent.depth + 1, self._now_ms(), annot)
        self.spans.append(sp)
        self._stack.append(sp)
        return sp

    def add_completed(self, name, *, t0_abs, dur_ms, parent=None, **annot):
        """Graft an already-measured span under an explicit parent.

        For work timed on another thread with the same perf_counter
        clock (e.g. EngineHost workers in-process): `t0_abs` is the raw
        `time.perf_counter()` at span start, `dur_ms` its duration, and
        `parent` a Span of this trace (default: innermost open span).
        The span is appended CLOSED and never touches the nesting stack,
        so the calling thread's own span structure is unaffected."""
        if parent is None:
            parent = self._stack[-1] if self._stack else self.spans[0]
        sp = Span(self, name, len(self.spans), parent.index,
                  parent.depth + 1, (t0_abs - self._t0) * 1e3, annot)
        sp.dur_ms = float(dur_ms)
        self.spans.append(sp)
        return sp

    def _close(self, sp):
        if sp.dur_ms is None:
            sp.dur_ms = self._now_ms() - sp.t0_ms
        if self._stack and self._stack[-1] is sp:
            self._stack.pop()

    def annotate(self, **kw):
        """Annotate the innermost open span (the root before any child)."""
        (self._stack[-1] if self._stack else self.spans[0]).annot.update(kw)
        return self

    def finish(self, **annot):
        """Close any open spans (root last) and hand the trace to the
        tracer's bounded retention."""
        while self._stack:
            self._close(self._stack[-1])
        self.spans[0].annot.update(annot)
        self._tracer._retain(self)
        return self

    @property
    def dur_ms(self):
        return self.spans[0].dur_ms

    def to_dicts(self):
        return [sp.to_dict(self.trace_id, self.name) for sp in self.spans]


class Tracer:
    """Sampling + bounded retention + exporters. Thread-safe at the
    trace granularity (each Trace itself is single-threaded)."""

    def __init__(self, sample_rate=0.0, capacity=1024):
        self.sample_rate = float(sample_rate)
        self.capacity = int(capacity)
        self._epoch = time.perf_counter()
        self._lock = threading.Lock()
        self._traces = []           # finished, bounded by capacity
        self._acc = 0.0             # deterministic sampling accumulator
        self._next_id = 0
        self.started = 0            # sampled traces opened
        self.skipped = 0            # unsampled requests (NOOP handed out)
        self.dropped = 0            # finished traces evicted by capacity

    @property
    def enabled(self):
        return self.sample_rate > 0.0

    def trace(self, name, **annot):
        """A sampled Trace, or the shared NOOP_TRACE. Deterministic: a
        rate of 0.25 records exactly every 4th request."""
        with self._lock:
            self._acc += self.sample_rate
            if self._acc < 1.0:
                self.skipped += 1
                return NOOP_TRACE
            self._acc -= 1.0
            tid = self._next_id
            self._next_id += 1
            self.started += 1
        return Trace(self, tid, name,
                     (time.perf_counter() - self._epoch) * 1e3, annot)

    def _retain(self, trace):
        with self._lock:
            self._traces.append(trace)
            while len(self._traces) > self.capacity:
                self._traces.pop(0)
                self.dropped += 1

    @property
    def traces(self):
        with self._lock:
            return list(self._traces)

    def clear(self):
        with self._lock:
            self._traces.clear()

    def span_totals(self, trace_name=None, skip_root=True):
        """{span name: {"ms": total, "count": n}} across retained traces
        (optionally only traces named `trace_name`; the root span — which
        spans the whole trace — is excluded unless skip_root=False)."""
        out = {}
        for tr in self.traces:
            if trace_name is not None and tr.name != trace_name:
                continue
            for sp in tr.spans:
                if skip_root and sp.index == 0:
                    continue
                agg = out.setdefault(sp.name, {"ms": 0.0, "count": 0})
                agg["ms"] += sp.dur_ms or 0.0
                agg["count"] += 1
        for agg in out.values():
            agg["ms"] = round(agg["ms"], 3)
        return out

    def export_jsonl(self, path):
        """One span per line (schema in the module docstring)."""
        with open(path, "w") as f:
            for tr in self.traces:
                for d in tr.to_dicts():
                    f.write(json.dumps(d) + "\n")
        return path

    def export_chrome(self, path):
        """Chrome trace JSON: open in chrome://tracing or Perfetto."""
        events = []
        for tr in self.traces:
            for sp in tr.spans:
                # host-attributed spans (cross-host graft) get their own
                # per-host lane so scatter fan-out reads as parallel work
                tid = tr.trace_id
                if "host" in sp.annot:
                    tid = f"{tr.trace_id}.host{sp.annot['host']}"
                events.append({
                    "name": sp.name, "cat": tr.name, "ph": "X",
                    "ts": round((tr.t0_rel_ms + sp.t0_ms) * 1e3, 1),
                    "dur": round((sp.dur_ms or 0.0) * 1e3, 1),
                    "pid": 0, "tid": tid,
                    "args": {k: v for k, v in sp.annot.items()},
                })
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return path


def write_trace(tracer, path):
    """Export retained traces, format by suffix: .jsonl -> JSONL span
    lines, anything else -> Chrome trace JSON."""
    p = str(path)
    if p.endswith(".jsonl"):
        return tracer.export_jsonl(p)
    return tracer.export_chrome(p)
