"""Low-overhead metrics registry: counters, gauges, bounded histograms.

Design constraints (this sits on the serving hot path):

  * every mutation is one lock acquire + one or two float adds — no
    allocation, no string formatting;
  * memory is bounded: a Histogram keeps fixed bucket counts plus a ring
    of the most recent `ring` raw observations (for exact percentiles
    over the recent window); counters and gauges are single cells;
  * thread-safe: the serving thread, the prefetch worker, and a control
    thread calling snapshot()/reset() may all touch one registry.

Snapshots come in two shapes: `snapshot()` returns a plain nested dict
(JSON-ready), `to_prometheus()` returns text exposition (counter/gauge/
histogram with cumulative `_bucket{le=...}` lines) so a scrape endpoint
or a file drop can feed standard dashboards. Metric names use dotted
paths ("serve.batch_ms"); the Prometheus view rewrites them to
underscores. The full catalog is in docs/OBSERVABILITY.md.
"""

import collections
import json
import math
import threading

# Upper bounds (ms) for latency histograms: sub-ms resolution where the
# fused serving tail lives, decade coverage up to multi-second builds.
DEFAULT_MS_BUCKETS = (0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
                      250.0, 500.0, 1000.0, 2500.0, 5000.0, math.inf)
DEFAULT_RING = 8192


class Counter:
    """Monotonic accumulator (int or float increments)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n=1):
        with self._lock:
            self.value += n

    def reset(self):
        with self._lock:
            self.value = 0


class Gauge:
    """Last-write-wins sampled value."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v):
        with self._lock:
            self.value = v

    def reset(self):
        with self._lock:
            self.value = 0.0


class Histogram:
    """Fixed-bucket histogram + bounded ring of recent raw observations.

    The bucket counts and count/sum are exact over the histogram's whole
    lifetime; `values()`/`percentile()` are exact over the most recent
    `ring` observations (a deque(maxlen=ring), so memory never grows past
    the window — the fix for the unbounded ServeStats batch list)."""

    __slots__ = ("name", "buckets", "bucket_counts", "count", "sum",
                 "_ring", "_lock")

    def __init__(self, name, buckets=DEFAULT_MS_BUCKETS, ring=DEFAULT_RING):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds or bounds[-1] != math.inf:
            bounds = bounds + (math.inf,)
        self.name = name
        self.buckets = bounds
        self.bucket_counts = [0] * len(bounds)
        self.count = 0
        self.sum = 0.0
        self._ring = collections.deque(maxlen=int(ring))
        self._lock = threading.Lock()

    def observe(self, v):
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            for i, ub in enumerate(self.buckets):
                if v <= ub:
                    self.bucket_counts[i] += 1
                    break
            self._ring.append(v)

    def values(self):
        """Most recent observations, oldest first (bounded by `ring`)."""
        with self._lock:
            return list(self._ring)

    def percentile(self, q):
        """Exact percentile over the recent window; None when empty."""
        vals = sorted(self.values())
        if not vals:
            return None
        if len(vals) == 1:
            return vals[0]
        # linear interpolation, matching np.percentile's default
        rank = (q / 100.0) * (len(vals) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(vals) - 1)
        return vals[lo] + (vals[hi] - vals[lo]) * (rank - lo)

    def mean(self):
        vals = self.values()
        return sum(vals) / len(vals) if vals else None

    def reset(self):
        with self._lock:
            self.bucket_counts = [0] * len(self.buckets)
            self.count = 0
            self.sum = 0.0
            self._ring.clear()

    def snapshot(self):
        with self._lock:
            counts = list(self.bucket_counts)
            count, total = self.count, self.sum
            vals = sorted(self._ring)
        out = {"count": count, "sum": round(total, 3),
               # string keys (JSON-safe, sortable): {"0.5": n, ..., "+Inf": n}
               "buckets": {("+Inf" if ub == math.inf else repr(ub)): c
                           for ub, c in zip(self.buckets, counts)}}
        if vals:
            def pct(q):
                rank = (q / 100.0) * (len(vals) - 1)
                lo = int(math.floor(rank))
                hi = min(lo + 1, len(vals) - 1)
                return round(vals[lo] + (vals[hi] - vals[lo]) * (rank - lo), 3)
            out.update(p50=pct(50), p99=pct(99),
                       mean=round(sum(vals) / len(vals), 3))
        return out


class MetricsRegistry:
    """Get-or-create registry of named metrics; one per process/engine.

    `counter`/`gauge`/`histogram` return the existing metric when the
    name is already registered (and raise if it is registered as a
    different kind — one name, one meaning)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics = {}          # name -> metric (insertion-ordered)

    def _get(self, name, kind, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name):
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name):
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name, buckets=DEFAULT_MS_BUCKETS, ring=DEFAULT_RING):
        return self._get(name, Histogram,
                         lambda: Histogram(name, buckets, ring))

    def _items(self):
        with self._lock:
            return list(self._metrics.items())

    def reset(self):
        """Zero every registered metric (keeps registrations)."""
        for _, m in self._items():
            m.reset()

    def snapshot(self):
        """Plain nested dict: {counters:{}, gauges:{}, histograms:{}}."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in self._items():
            if isinstance(m, Counter):
                v = m.value
                out["counters"][name] = round(v, 3) \
                    if isinstance(v, float) else v
            elif isinstance(m, Gauge):
                v = m.value
                out["gauges"][name] = round(v, 4) \
                    if isinstance(v, float) else v
            else:
                out["histograms"][name] = m.snapshot()
        return out

    @staticmethod
    def _prom_name(name):
        return "".join(c if (c.isalnum() or c == "_") else "_"
                       for c in name)

    def to_prometheus(self):
        """Prometheus text exposition (counters, gauges, cumulative
        histogram buckets + _count/_sum)."""
        lines = []
        for name, m in self._items():
            pn = self._prom_name(name)
            if isinstance(m, Counter):
                lines += [f"# TYPE {pn} counter", f"{pn} {m.value}"]
            elif isinstance(m, Gauge):
                lines += [f"# TYPE {pn} gauge", f"{pn} {m.value}"]
            else:
                lines.append(f"# TYPE {pn} histogram")
                cum = 0
                with m._lock:
                    counts = list(m.bucket_counts)
                    count, total = m.count, m.sum
                for ub, c in zip(m.buckets, counts):
                    cum += c
                    le = "+Inf" if ub == math.inf else repr(ub)
                    lines.append(f'{pn}_bucket{{le="{le}"}} {cum}')
                lines += [f"{pn}_sum {total}", f"{pn}_count {count}"]
        return "\n".join(lines) + "\n"

    def write_json(self, path):
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")

    def write_prometheus(self, path):
        with open(path, "w") as f:
            f.write(self.to_prometheus())


def write_metrics(registry, path):
    """Write a snapshot, format by suffix: .prom/.txt -> Prometheus text
    exposition, anything else -> JSON."""
    p = str(path)
    if p.endswith((".prom", ".txt")):
        registry.write_prometheus(p)
    else:
        registry.write_json(p)
    return p
