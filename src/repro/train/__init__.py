"""Streaming selector-training subsystem: index-backed label generation,
bucketed LSTM training with checkpoint/resume, threshold/budget
calibration, and atomic selector publishing into a built index. See
README.md in this directory for the label pipeline, bucketing, and
publish flow; `python -m repro.launch.train_selector` drives the whole
loop against an index built by `repro.launch.build_index`."""

from repro.train.calibrate import (
    calibration_table, choose_operating_point, expansion_sweep,
    recall_at_budget, select_at, selection_quality, selector_probs)
from repro.train.data import (
    Batch, bucket_lengths, bucketed_batches, effective_lengths,
    n_batches_per_epoch)
from repro.train.labels import (
    LabelCache, LabelConfig, LabelGenStats, LabelSet, label_cache_key,
    make_labels, make_labels_streaming, query_fingerprint,
    relabel_for_config, stage1_for_queries, streaming_full_dense_topk)
from repro.train.publish import publish_selector
from repro.train.trainer import (
    SelectorTrainConfig, SelectorTrainer, derive_pos_weight,
    resolve_pos_weight, selector_apply, train_selector)

__all__ = [
    "Batch", "LabelCache", "LabelConfig", "LabelGenStats", "LabelSet",
    "SelectorTrainConfig", "SelectorTrainer", "bucket_lengths",
    "bucketed_batches", "calibration_table", "choose_operating_point",
    "derive_pos_weight", "effective_lengths", "expansion_sweep",
    "label_cache_key", "make_labels", "make_labels_streaming",
    "n_batches_per_epoch", "publish_selector", "query_fingerprint",
    "recall_at_budget", "relabel_for_config", "resolve_pos_weight",
    "select_at", "selection_quality", "selector_apply", "selector_probs",
    "stage1_for_queries", "streaming_full_dense_topk", "train_selector",
]
