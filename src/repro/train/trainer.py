"""Selector training: the legacy one-shot `train_selector` (seed API,
now config-driven) and the production `SelectorTrainer` — bucketed
batches, jit-compiled steps on the fused Pallas LSTM cell, periodic
`repro.checkpoint` checkpoints, and deterministic mid-epoch resume.

Loss: class-balanced BCE over the candidate sequence. The positive weight
comes from `cfg.pos_weight` (default 4.0, the historical constant); when
the config sets it to None the trainer derives it from the observed
positive rate of the label set (w = (1-p)/p, clipped), so rebalancing
tracks the corpus instead of a hardcoded guess.

Kernel path: with `use_kernel` (default "auto" = on TPU), the forward
hidden sequence runs through the fused `repro.kernels.lstm` Pallas cell;
the backward pass is a `jax.custom_vjp` that differentiates the jnp
reference scan (same math, so gradients are exact for the kernel's
function). On CPU the interpret-mode kernel is slower than the scan, so
"auto" keeps the reference path there.

Checkpoint layout (`repro.checkpoint`): tree {params, opt}, extra
{epoch, batch, pos_weight, selector}. The batch stream is a pure function
of (seed, epoch) — see train/data.py — so restoring a mid-epoch
checkpoint and skipping the consumed batches replays the exact schedule:
train N steps == train k steps, resume, train N-k (property-tested).
"""

import dataclasses
import time
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.lstm import SELECTORS
from repro.kernels.lstm import ops as lstm_ops
from repro.kernels.lstm.ref import lstm_sequence_ref
from repro.obs import MetricsRegistry
from repro.optim import adamw_init, adamw_update
from repro.train import data as data_lib

_DERIVED_POS_WEIGHT_MAX = 100.0


def derive_pos_weight(labels, lo=1.0, hi=_DERIVED_POS_WEIGHT_MAX):
    """Class-balance weight from the observed positive rate: w = (1-p)/p
    (each positive weighted like the negatives it is outnumbered by),
    clipped to [lo, hi] so a near-empty label set cannot explode the
    loss."""
    p = float(np.asarray(labels).mean())
    if p <= 0.0:
        return float(hi)
    return float(np.clip((1.0 - p) / p, lo, hi))


def resolve_pos_weight(cfg, labels, override=None):
    """Effective positive weight: explicit override > cfg.pos_weight >
    derived-from-labels (when the config value is None)."""
    w = override if override is not None else getattr(cfg, "pos_weight", 4.0)
    if w is None:
        return derive_pos_weight(labels)
    return float(w)


# ---------------------------------------------------------------------------
# kernel-forward LSTM with exact custom backward
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _lstm_hseq_kernel(feats, wx, wh, b):
    return lstm_ops.lstm_sequence(feats, wx, wh, b)


def _lstm_hseq_fwd(feats, wx, wh, b):
    return _lstm_hseq_kernel(feats, wx, wh, b), (feats, wx, wh, b)


def _lstm_hseq_bwd(residuals, g):
    # recompute-through-reference: the jnp scan computes the same function
    # as the fused kernel, so its VJP is the kernel's exact gradient
    _, vjp = jax.vjp(lstm_sequence_ref, *residuals)
    return vjp(g)


_lstm_hseq_kernel.defvjp(_lstm_hseq_fwd, _lstm_hseq_bwd)


def selector_apply(params, feats, *, selector="lstm", use_kernel=False):
    """Selection probabilities (B, n); `use_kernel` routes the LSTM
    forward through the fused Pallas cell (differentiable via the
    custom VJP above)."""
    if selector == "lstm" and use_kernel:
        h_seq = _lstm_hseq_kernel(feats, params["wx"], params["wh"],
                                  params["b"])
        logits = (h_seq @ params["head_w"] + params["head_b"])[..., 0]
        return jax.nn.sigmoid(logits)
    _, apply = SELECTORS[selector]
    return apply(params, feats)


def _resolve_use_kernel(use_kernel):
    if use_kernel == "auto":
        return jax.default_backend() == "tpu"
    return bool(use_kernel)


# ---------------------------------------------------------------------------
# legacy one-shot API (seed behavior; core.train_lstm wraps this)
# ---------------------------------------------------------------------------

def train_selector(cfg, rng, feats, labels, selector="lstm", epochs=None,
                   lr=None, batch_size=256, log_every=0, pos_weight=None):
    """Train a stage-2 selector on precomputed (feats, labels).

    The seed trainer, kept as the simple path (whole label set in memory,
    no bucketing/checkpoints). pos_weight: None defers to cfg.pos_weight
    (and derives from the label positive rate when that is None too)."""
    epochs = epochs or cfg.epochs
    lr = lr or cfg.lr
    init_fn, apply_fn = SELECTORS[selector]
    params = init_fn(rng, feats.shape[-1], cfg.lstm_hidden)
    opt = adamw_init(params)
    w_pos = resolve_pos_weight(cfg, labels, pos_weight)

    def loss_fn(p, f, y):
        probs = apply_fn(p, f)
        probs = jnp.clip(probs, 1e-6, 1 - 1e-6)
        # class-balance: positives are rare in the candidate sequence
        bce = -(w_pos * y * jnp.log(probs) + (1 - y) * jnp.log(1 - probs))
        return jnp.mean(bce)

    @jax.jit
    def step(p, o, f, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, f, y)
        p, o, _ = adamw_update(grads, o, p, lr=lr, weight_decay=0.0)
        return p, o, loss

    nq = feats.shape[0]
    rngs = jax.random.split(jax.random.fold_in(rng, 1), epochs)
    history = []
    for e in range(epochs):
        perm = jax.random.permutation(rngs[e], nq)
        f_sh, y_sh = feats[perm], labels[perm]
        losses = []
        for i in range(0, nq - batch_size + 1, batch_size) or [0]:
            fb, yb = f_sh[i:i + batch_size], y_sh[i:i + batch_size]
            params, opt, loss = step(params, opt, fb, yb)
            losses.append(float(loss))
        if nq < batch_size:
            params, opt, loss = step(params, opt, f_sh, y_sh)
            losses.append(float(loss))
        history.append(sum(losses) / max(len(losses), 1))
        if log_every and (e + 1) % log_every == 0:
            print(f"epoch {e+1}/{epochs} loss={history[-1]:.4f}", flush=True)
    return params, history


# ---------------------------------------------------------------------------
# production trainer: buckets + checkpoints + resume
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SelectorTrainConfig:
    """Knobs of the streaming trainer (None defers to the CluSDConfig)."""

    selector: str = "lstm"
    epochs: Optional[int] = None        # None -> cfg.epochs
    lr: Optional[float] = None          # None -> cfg.lr
    batch_size: int = 256
    pos_weight: Optional[float] = None  # None -> cfg.pos_weight / derived
    bucket: bool = True                 # power-of-two sequence buckets
    min_len: int = 4
    use_kernel: Union[bool, str] = "auto"   # Pallas LSTM cell forward
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every_steps: int = 0           # 0 = checkpoint only at the end
    keep_ckpts: int = 3
    max_steps: int = 0                  # stop (and checkpoint) after N
                                        # optimizer steps; 0 = unlimited


class SelectorTrainer:
    """Bucketed, checkpointed selector training over a LabelSet."""

    def __init__(self, cfg, tcfg: SelectorTrainConfig = SelectorTrainConfig()):
        self.cfg = cfg
        self.tcfg = tcfg
        self.use_kernel = _resolve_use_kernel(tcfg.use_kernel)
        self._steps = {}                    # bucket length L -> jitted step
        self.pos_weight = None              # resolved by fit()

    # -- compiled step per bucket length -----------------------------------

    def _step_fn(self, L):
        fn = self._steps.get(L)
        if fn is not None:
            return fn
        selector = self.tcfg.selector
        use_kernel = self.use_kernel
        lr = self.tcfg.lr or self.cfg.lr

        def loss_fn(p, f, y, w, pos_w):
            probs = selector_apply(p, f, selector=selector,
                                   use_kernel=use_kernel)
            probs = jnp.clip(probs, 1e-6, 1 - 1e-6)
            bce = -(pos_w * y * jnp.log(probs)
                    + (1 - y) * jnp.log(1 - probs))         # (B, L)
            per_row = jnp.mean(bce, axis=1)
            return jnp.sum(per_row * w) / jnp.maximum(jnp.sum(w), 1.0)

        def step(p, o, f, y, w, pos_w):
            loss, grads = jax.value_and_grad(loss_fn)(p, f, y, w, pos_w)
            p, o, _ = adamw_update(grads, o, p, lr=lr, weight_decay=0.0)
            return p, o, loss

        fn = jax.jit(step)
        self._steps[L] = fn
        return fn

    # -- training ----------------------------------------------------------

    def init_params(self, rng, feat_dim):
        init_fn, _ = SELECTORS[self.tcfg.selector]
        return init_fn(rng, feat_dim, self.cfg.lstm_hidden)

    def fit(self, rng, feats, labels, *, resume=False, log_every=0,
            metrics=None):
        """Train; returns (params, history). With tcfg.ckpt_dir set,
        checkpoints land every ckpt_every_steps steps (and at the end);
        resume=True restores the latest checkpoint and replays the
        deterministic batch schedule from right after it.

        `metrics` (repro.obs.MetricsRegistry) receives `train.steps` /
        `train.epochs` counters, a `train.step_ms` histogram, and
        `train.steps_per_s` / `train.last_loss` gauges."""
        feats = np.asarray(feats, np.float32)
        labels = np.asarray(labels, np.float32)
        reg = metrics if metrics is not None else MetricsRegistry()
        c_steps = reg.counter("train.steps")
        c_epochs = reg.counter("train.epochs")
        h_step = reg.histogram("train.step_ms")
        t_fit = time.perf_counter()
        tc = self.tcfg
        epochs = tc.epochs or self.cfg.epochs
        self.pos_weight = resolve_pos_weight(self.cfg, labels, tc.pos_weight)
        pos_w = jnp.float32(self.pos_weight)
        if tc.bucket:
            buckets = data_lib.bucket_lengths(self.cfg, feats, labels,
                                              min_len=tc.min_len)
        else:
            buckets = np.full(feats.shape[0], feats.shape[1], np.int64)
        per_epoch = data_lib.n_batches_per_epoch(buckets, tc.batch_size)

        params = self.init_params(rng, feats.shape[-1])
        opt = adamw_init(params)
        start_epoch = start_batch = global_step = 0
        mgr = None
        if tc.ckpt_dir:
            mgr = CheckpointManager(tc.ckpt_dir, keep=tc.keep_ckpts)
            if resume:
                step0, tree, extra = mgr.restore_latest(
                    {"params": params, "opt": opt})
                if step0 is not None:
                    params, opt = tree["params"], tree["opt"]
                    global_step = int(step0)
                    start_epoch = int(extra.get("epoch", 0))
                    start_batch = int(extra.get("batch", 0))
                    if start_batch >= per_epoch:    # epoch boundary ckpt
                        start_epoch, start_batch = start_epoch + 1, 0

        def save(epoch, batch):
            if mgr is not None:
                mgr.save(global_step,
                         {"params": params, "opt": opt},
                         extra={"epoch": epoch, "batch": batch,
                                "selector": tc.selector,
                                "pos_weight": self.pos_weight})

        def finalize():
            wall = time.perf_counter() - t_fit
            done = global_step - start_step
            reg.gauge("train.steps_per_s").set(
                round(done / wall, 2) if wall > 0 else 0.0)
            if history:
                reg.gauge("train.last_loss").set(round(history[-1], 6))

        history = []
        start_step = global_step
        for e in range(start_epoch, epochs):
            losses = []
            for batch in data_lib.bucketed_batches(
                    feats, labels, buckets, batch_size=tc.batch_size,
                    seed=tc.seed, epoch=e):
                if e == start_epoch and batch.index < start_batch:
                    continue
                step = self._step_fn(batch.length)
                t_step = time.perf_counter()
                params, opt, loss = step(
                    params, opt, jnp.asarray(batch.feats),
                    jnp.asarray(batch.labels), jnp.asarray(batch.weights),
                    pos_w)
                global_step += 1
                losses.append(float(loss))     # device sync for this step
                c_steps.inc()
                h_step.observe((time.perf_counter() - t_step) * 1e3)
                if tc.ckpt_every_steps and \
                        global_step % tc.ckpt_every_steps == 0:
                    save(e, batch.index + 1)
                if tc.max_steps and global_step >= tc.max_steps:
                    save(e, batch.index + 1)    # resumable stop point
                    if losses:
                        history.append(sum(losses) / len(losses))
                    if mgr is not None:
                        mgr.wait()
                    finalize()
                    return params, history
            c_epochs.inc()
            if losses:
                history.append(sum(losses) / len(losses))
            if log_every and (e + 1) % log_every == 0:
                print(f"epoch {e+1}/{epochs} loss={history[-1]:.4f} "
                      f"(pos_weight={self.pos_weight:.2f})", flush=True)
        save(epochs, 0)
        if mgr is not None:
            mgr.wait()
        finalize()
        return params, history
