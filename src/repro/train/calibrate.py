"""Threshold/budget calibration on held-out queries.

Serving exposes two knobs: the Stage-II probability threshold theta
(cfg.theta) and the cluster budget (cfg.max_selected — the static number
of blocks the engine will read per query). The paper tunes theta to hit a
target average #selected (Table 8); the hybrid-index line of work
calibrates its routing threshold against a target recall. This module
does both: sweep (theta, budget) on a held-out LabelSet, measure

  recall@k      fraction of the query's full-dense top-k documents whose
                cluster is selected (k = the label config's top_dense) —
                the quantity selective dense retrieval trades I/O for,
  avg_selected  mean clusters actually selected (= cluster-block reads),
  est_read_bytes  avg_selected x the store's per-block byte cost,

and pick an operating point for a target recall (cheapest selection that
reaches it) or a target I/O budget (best recall within it). Selection
semantics mirror `core.clusd.stage2_select` exactly (threshold then
top-budget by probability, lax.top_k tie order), so calibrated numbers
are what the serving engine will do.

The chosen (theta, budget) and the full table are published into the
index manifest (train/publish.py) — the engine picks them up on
`reload_selector()` / `reload_index()` with no restart.
"""

import numpy as np

from repro.train.trainer import selector_apply


def selector_probs(params, feats, *, selector="lstm", use_kernel=False,
                   batch=1024):
    """(B, n) selection probabilities, computed in bounded batches.

    Keep use_kernel=False when the probabilities feed calibration: the
    serving engine's stage2_select runs the reference path, and a kernel
    forward may differ in low-order bits right at a swept threshold."""
    import jax.numpy as jnp
    feats = np.asarray(feats, np.float32)
    out = []
    for lo in range(0, feats.shape[0], batch):
        out.append(np.asarray(selector_apply(
            params, jnp.asarray(feats[lo:lo + batch]), selector=selector,
            use_kernel=use_kernel)))
    return np.concatenate(out, axis=0)


def select_at(cand, probs, theta, budget):
    """stage2_select semantics on the host: picked = probs >= theta, then
    top-`budget` picked candidates by probability (ties -> lower stage-1
    rank, matching lax.top_k). Returns (sel_ids, sel_mask) (B, budget)."""
    cand = np.asarray(cand)
    probs = np.asarray(probs)
    budget = min(int(budget), cand.shape[1])
    picked = probs >= theta
    masked = np.where(picked, probs, -np.inf)
    top_i = np.argsort(-masked, axis=1, kind="stable")[:, :budget]
    sel_mask = np.take_along_axis(picked, top_i, axis=1)
    sel_ids = np.take_along_axis(cand, top_i, axis=1)
    return sel_ids, sel_mask


def recall_at_budget(cand, probs, pos_clusters, theta, budget):
    """(recall@k, avg_selected): recall counts the full-dense top-k docs
    whose cluster made the selection, averaged per query then over
    queries."""
    sel_ids, sel_mask = select_at(cand, probs, theta, budget)
    sel = np.where(sel_mask, sel_ids, -1)
    covered = (np.asarray(pos_clusters)[:, :, None]
               == sel[:, None, :]).any(axis=-1)            # (B, k)
    return float(covered.mean()), float(sel_mask.sum(axis=1).mean())


def calibration_table(label_set, probs, doc_cluster, *, thetas, budgets,
                      block_bytes=0):
    """Sweep rows sorted by (budget, theta). Every row: theta, budget,
    recall, avg_selected, est_read_bytes."""
    pos_clusters = np.asarray(doc_cluster)[np.asarray(label_set.dense_ids)]
    rows = []
    for budget in sorted(int(b) for b in budgets):
        for theta in sorted(float(t) for t in thetas):
            rec, avg_sel = recall_at_budget(label_set.cand, probs,
                                            pos_clusters, theta, budget)
            rows.append({
                "theta": round(theta, 6),
                "budget": budget,
                "recall": round(rec, 4),
                "avg_selected": round(avg_sel, 2),
                "est_read_bytes": int(round(avg_sel * block_bytes)),
            })
    return rows


def expansion_sweep(cfg, index, params, q_dense, q_terms, q_weights,
                    dense_ids, *, depths, thetas, budgets, block_bytes=0,
                    stage1="overlap", selector="lstm", use_kernel=False):
    """Extend the theta x budget sweep with a stage-1 expansion-depth axis.

    For each depth the stage-1 candidate list is regenerated through the
    neighbor graph (core/stage1.expand_candidates) and swept exactly like
    `calibration_table` — same select_at semantics, same row schema plus a
    "depth" / "n_candidates" field per row. `dense_ids` is the full-dense
    top-k from an existing LabelSet: it does not depend on stage 1, so the
    sweep reuses it instead of re-streaming the corpus per depth.

    Returns a list of per-depth dicts:
      {"depth", "n_candidates", "stage1_ceiling", "rows": [...]}
    where stage1_ceiling is recall with EVERY candidate selected — the
    best any selector could do at that depth. Budgets stay as given (the
    point of expansion is more recall at the SAME read budget), so
    est_read_bytes is comparable across depths.
    """
    import dataclasses
    from repro.train import labels as labels_lib

    dense_ids = np.asarray(dense_ids)
    pos_clusters = np.asarray(index.doc_cluster)[dense_ids]
    out = []
    for depth in sorted({int(d) for d in depths}):
        dcfg = dataclasses.replace(cfg, expand_depth=depth)
        cand, feats = labels_lib.stage1_for_queries(
            dcfg, index, q_dense, q_terms, q_weights, stage1=stage1)
        probs = selector_probs(params, feats, selector=selector,
                               use_kernel=use_kernel)
        ceiling, _ = recall_at_budget(cand, probs, pos_clusters, -np.inf,
                                      cand.shape[1])
        rows = []
        for budget in sorted(int(b) for b in budgets):
            for theta in sorted(float(t) for t in thetas):
                rec, avg_sel = recall_at_budget(cand, probs, pos_clusters,
                                                theta, budget)
                rows.append({
                    "depth": depth,
                    "n_candidates": int(cand.shape[1]),
                    "theta": round(theta, 6),
                    "budget": budget,
                    "recall": round(rec, 4),
                    "avg_selected": round(avg_sel, 2),
                    "est_read_bytes": int(round(avg_sel * block_bytes)),
                })
        out.append({"depth": depth, "n_candidates": int(cand.shape[1]),
                    "stage1_ceiling": round(ceiling, 4), "rows": rows})
    return out


def choose_operating_point(table, *, target_recall=None, target_budget=None):
    """Pick a row from a calibration table.

    target_recall: cheapest selection (min avg_selected, then min budget,
      then max theta) whose recall meets the target; falls back to the
      best-recall row (flagged "target_met": False) when nothing does.
    target_budget: best recall among rows with budget <= target (ties ->
      fewer clusters actually selected).
    Exactly one target must be given."""
    if (target_recall is None) == (target_budget is None):
        raise ValueError("pass exactly one of target_recall/target_budget")
    table = list(table)
    if not table:
        raise ValueError("empty calibration table")
    if target_recall is not None:
        ok = [r for r in table if r["recall"] >= target_recall]
        if ok:
            pick = min(ok, key=lambda r: (r["avg_selected"], r["budget"],
                                          -r["theta"]))
            return dict(pick, target_met=True)
        pick = max(table, key=lambda r: (r["recall"], -r["avg_selected"]))
        return dict(pick, target_met=False)
    ok = [r for r in table if r["budget"] <= target_budget]
    met = bool(ok)
    if not ok:                 # nothing fits: flag it, pick the cheapest
        ok = [min(table, key=lambda r: r["budget"])]
    pick = max(ok, key=lambda r: (r["recall"], -r["avg_selected"],
                                  -r["theta"]))
    return dict(pick, target_met=met)


def selection_quality(probs, labels, theta):
    """Precision / recall / avg #selected at threshold theta (label-level,
    the seed metric — recall here is over positive *candidates*, not the
    dense top-k; see recall_at_budget for the serving-facing quantity)."""
    import jax.numpy as jnp
    sel = probs >= theta
    tp = jnp.sum(sel * labels)
    prec = tp / jnp.maximum(jnp.sum(sel), 1)
    rec = tp / jnp.maximum(jnp.sum(labels), 1)
    return {"precision": prec, "recall": rec,
            "avg_selected": jnp.mean(jnp.sum(sel, axis=1))}
